"""Layer-2 jax graphs for the SLOFetch online ML controller (paper §IV).

Three jitted functions make up the AOT surface the Rust coordinator loads:

  score(w, b, x)                -> (p,)                 issue probabilities
  train_step(w, b, x, y, lr)    -> (w', b', loss)       one BCE-SGD step
  bandit_update(v, onehot, r, lr) -> (v',)              bandit value update

All heavy math happens inside the Layer-1 Pallas kernels
(``kernels/logistic.py``); this module only wires parameters and applies
the SGD update, so XLA fuses each module into a single small computation.

The controller state (w, b, bandit values) lives in Rust and is threaded
through every call — the modules are pure functions, which keeps the
artifact stateless and trivially shardable across simulated cores.
"""

import jax.numpy as jnp

from compile.kernels import logistic


def score(w, b, x):
    """Issue-probability forward pass. Returns a 1-tuple (AOT lowers with
    return_tuple=True; the Rust side unwraps with ``to_tuple1``)."""
    return (logistic.score(w, b, x),)


def train_step(w, b, x, y, lr):
    """One SGD step on mean BCE with analytic logistic gradients.

    Matches ``ref.train_step_ref`` exactly; the forward + gradient GEMVs run
    in the fused Pallas kernel. lr arrives as a traced scalar so the Rust
    side can anneal it without recompiling.
    """
    dw, db, loss = logistic.grads(w, b, x, y)
    return w - lr * dw, b - lr * db, loss


def bandit_update(values, arm_onehot, reward, lr):
    """Incremental (context x arm) value update, v' = v + lr*onehot*(r-v)."""
    return (logistic.bandit_update(values, arm_onehot, reward, lr),)
