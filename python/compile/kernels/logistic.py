"""Layer-1 Pallas kernels for the SLOFetch online controller (paper §IV).

The compute hot-spot of the controller is batched logistic scoring
(a GEMV + sigmoid over a [B, F] feature block) and the fused BCE-SGD
training step built on top of it. Both are written as Pallas kernels and
called from the Layer-2 jax graphs in ``model.py`` so they lower into the
same HLO module that the Rust runtime loads.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the whole (B=256,
F=16) block fits in a single VMEM tile (256*16*4 B = 16 KiB), so the
BlockSpec keeps one HBM->VMEM transfer per step and the reduction is
shaped as a (BxF)·(Fx1) GEMV the MXU can consume. On this CPU image we
must run ``interpret=True`` (real TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute); numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# AOT contract dimensions (rust/src/runtime/engine.rs pads to these).
BATCH = 256
FEATURES = 16

# interpret=True is mandatory on CPU; see module docstring.
INTERPRET = True


def _score_kernel(w_ref, b_ref, x_ref, o_ref):
    """o = sigmoid(x @ w + b). Single-tile kernel: everything in VMEM."""
    x = x_ref[...]                      # [B, F]
    w = w_ref[...]                      # [F]
    z = x @ w + b_ref[0]                # GEMV -> [B]
    o_ref[...] = jax.nn.sigmoid(z)


def score(w, b, x):
    """Batched issue-probability scoring. w:[F] b:[] x:[B,F] -> p:[B]."""
    batch, feats = x.shape
    return pl.pallas_call(
        _score_kernel,
        out_shape=jax.ShapeDtypeStruct((batch,), x.dtype),
        interpret=INTERPRET,
    )(w, jnp.reshape(b, (1,)), x)


def _grad_kernel(w_ref, b_ref, x_ref, y_ref, o_dw_ref, o_db_ref, o_loss_ref):
    """Fused forward + analytic BCE gradient.

    g = sigmoid(x@w+b) - y ; dw = x^T g / B ; db = mean(g);
    loss = mean BCE before the step. One VMEM tile, two GEMVs (forward and
    the x^T g reduction) — the transpose contraction is also MXU-shaped.
    """
    x = x_ref[...]                      # [B, F]
    w = w_ref[...]                      # [F]
    y = y_ref[...]                      # [B]
    z = x @ w + b_ref[0]
    p = jax.nn.sigmoid(z)
    g = p - y                           # [B]
    inv_b = 1.0 / x.shape[0]
    o_dw_ref[...] = (g @ x) * inv_b     # [F]
    o_db_ref[0] = jnp.sum(g) * inv_b
    eps = 1e-7
    pc = jnp.clip(p, eps, 1.0 - eps)
    o_loss_ref[0] = -jnp.sum(y * jnp.log(pc) + (1.0 - y) * jnp.log(1.0 - pc)) * inv_b


def grads(w, b, x, y):
    """Returns (dw:[F], db:[], loss:[]) for one BCE-SGD step."""
    batch, feats = x.shape
    dw, db, loss = pl.pallas_call(
        _grad_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((feats,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ),
        interpret=INTERPRET,
    )(w, jnp.reshape(b, (1,)), x, y)
    return dw, db[0], loss[0]


def _bandit_kernel(v_ref, onehot_ref, r_ref, lr_ref, o_ref):
    """v' = v + lr * onehot * (r - v) — elementwise, one VPU pass."""
    v = v_ref[...]
    o_ref[...] = v + lr_ref[0] * onehot_ref[...] * (r_ref[0] - v)


def bandit_update(values, arm_onehot, reward, lr):
    """Contextual-bandit value update (paper §IV-B). values:[N] -> [N]."""
    (n,) = values.shape
    return pl.pallas_call(
        _bandit_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), values.dtype),
        interpret=INTERPRET,
    )(values, arm_onehot, jnp.reshape(reward, (1,)), jnp.reshape(lr, (1,)))
