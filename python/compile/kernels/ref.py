"""Pure-jnp oracles for the SLOFetch controller kernels.

These are the correctness references the Pallas kernels (and the Rust mirror
in ``rust/src/ml/logistic.rs``) are validated against. Keep them boring: no
pallas, no custom control flow — just the math from the paper §IV.

Shapes (AOT contract, see ``aot.py``):
  w : [F]      logistic weights
  b : []       bias (scalar)
  x : [B, F]   feature batch
  y : [B]      labels (1.0 = prefetch was profitable)
"""

import jax
import jax.numpy as jnp


def score_ref(w, b, x):
    """Calibrated issue probability: sigmoid(x @ w + b)  ->  [B]."""
    return jax.nn.sigmoid(x @ w + b)


def bce_loss_ref(w, b, x, y):
    """Mean binary cross-entropy of the scorer on (x, y)."""
    p = score_ref(w, b, x)
    eps = 1e-7
    p = jnp.clip(p, eps, 1.0 - eps)
    return -jnp.mean(y * jnp.log(p) + (1.0 - y) * jnp.log(1.0 - p))


def bce_loss_stable_ref(w, b, x, y):
    """Numerically stable BCE: mean(softplus(z) - y*z). Identical to
    ``bce_loss_ref`` away from saturation, but its autodiff gradient is the
    exact analytic (p - y) form even for |z| large — used to validate the
    Pallas gradient kernel against jax.grad."""
    z = x @ w + b
    return jnp.mean(jnp.logaddexp(0.0, z) - y * z)


def train_step_ref(w, b, x, y, lr):
    """One SGD step on BCE. Analytic gradient (g = p - y):

        dL/dw = x^T (p - y) / B      dL/db = mean(p - y)

    Returns (w', b', loss-before-step). Matches the paper's "small learning
    rate, periodic millisecond-granularity updates" controller.
    """
    p = score_ref(w, b, x)
    g = p - y
    batch = x.shape[0]
    dw = x.T @ g / batch
    db = jnp.mean(g)
    loss = bce_loss_ref(w, b, x, y)
    return w - lr * dw, b - lr * db, loss


def bandit_update_ref(values, arm_onehot, reward, lr):
    """Incremental value update for the contextual bandit (§IV-B).

    values     : [N]  flattened (context x arm) action-value table
    arm_onehot : [N]  1.0 at the (context, arm) that was played
    reward     : []   shaped reward (hits - penalties) over the horizon
    """
    return values + lr * arm_onehot * (reward - values)
