"""AOT compile path: lower the Layer-2 controller graphs to HLO text.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

A manifest.json records the AOT contract (module shapes + a content hash
of the python sources) so ``make artifacts`` is a no-op when nothing
changed and the Rust runtime can sanity-check shape agreement at startup.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.logistic import BATCH, FEATURES

# Flattened (context x arm) bandit value-table size. 8 context buckets x
# (4 threshold arms + 3 window arms mapped into one table of 8 slots each).
BANDIT_SLOTS = 64

F32 = jnp.float32


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, F32)


# name -> (callable, example-arg specs, human description)
MODULES = {
    "score": (
        model.score,
        (_spec((FEATURES,)), _spec(()), _spec((BATCH, FEATURES))),
        "sigmoid(x@w+b) issue-probability batch",
    ),
    "train": (
        model.train_step,
        (
            _spec((FEATURES,)),
            _spec(()),
            _spec((BATCH, FEATURES)),
            _spec((BATCH,)),
            _spec(()),
        ),
        "one BCE-SGD step -> (w', b', loss)",
    ),
    "bandit": (
        model.bandit_update,
        (_spec((BANDIT_SLOTS,)), _spec((BANDIT_SLOTS,)), _spec(()), _spec(())),
        "bandit value-table update",
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def source_hash() -> str:
    """Hash of every python source feeding the artifacts."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        for name in sorted(files):
            if name.endswith(".py"):
                with open(os.path.join(root, name), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    src_hash = source_hash()

    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("source_hash") == src_hash and all(
                os.path.exists(os.path.join(args.out_dir, f"{m}.hlo.txt"))
                for m in MODULES
            ):
                print("artifacts unchanged (source hash match); skipping")
                return 0
        except (json.JSONDecodeError, OSError):
            pass  # fall through and rebuild

    manifest = {
        "source_hash": src_hash,
        "batch": BATCH,
        "features": FEATURES,
        "bandit_slots": BANDIT_SLOTS,
        "dtype": "f32",
        "modules": {},
    }
    for name, (fn, specs, desc) in MODULES.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        out_path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(out_path, "w") as f:
            f.write(text)
        manifest["modules"][name] = {
            "file": f"{name}.hlo.txt",
            "description": desc,
            "arg_shapes": [list(s.shape) for s in specs],
            "hlo_bytes": len(text),
        }
        print(f"wrote {out_path} ({len(text)} chars)")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
