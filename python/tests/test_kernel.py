"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (and regimes) — the CORE numeric signal that the
HLO the Rust runtime executes computes the paper's controller math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logistic, ref

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


@st.composite
def batch_feat(draw):
    b = draw(st.integers(min_value=1, max_value=512))
    f = draw(st.integers(min_value=1, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return b, f, seed


@given(batch_feat())
def test_score_matches_ref(bf):
    b, f, seed = bf
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    w, x = _rand(k1, f), _rand(k2, b, f)
    bias = jax.random.normal(k3, (), dtype=jnp.float32)
    got = logistic.score(w, bias, x)
    want = ref.score_ref(w, bias, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@given(batch_feat())
def test_grads_match_ref_train_step(bf):
    b, f, seed = bf
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    w, x = _rand(k1, f), _rand(k2, b, f)
    bias = jax.random.normal(k3, (), dtype=jnp.float32)
    y = (jax.random.uniform(k4, (b,)) > 0.5).astype(jnp.float32)
    lr = jnp.float32(0.05)
    dw, db, loss = logistic.grads(w, bias, x, y)
    w2, b2 = w - lr * dw, bias - lr * db
    rw, rb, rloss = ref.train_step_ref(w, bias, x, y, lr)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(rw), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(b2), np.asarray(rb), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(rloss), rtol=1e-4, atol=1e-5)


@given(batch_feat())
def test_grads_match_jax_autodiff(bf):
    """Analytic gradient must equal jax.grad of the BCE oracle."""
    b, f, seed = bf
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    w, x = _rand(k1, f), _rand(k2, b, f)
    bias = jax.random.normal(k3, (), dtype=jnp.float32)
    y = (jax.random.uniform(k4, (b,)) > 0.5).astype(jnp.float32)
    dw, db, _ = logistic.grads(w, bias, x, y)
    # Differentiate the *stable* BCE: the clipped-log form zeroes gradients
    # where sigmoid saturates in f32, which the analytic form correctly
    # does not (see ref.bce_loss_stable_ref docstring).
    gw, gb = jax.grad(ref.bce_loss_stable_ref, argnums=(0, 1))(w, bias, x, y)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(gw), rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(db), np.asarray(gb), rtol=1e-3, atol=1e-5)


@given(
    st.integers(min_value=1, max_value=256),
    st.integers(min_value=0, max_value=2**31 - 1),
    st.floats(min_value=0.0, max_value=1.0),
)
def test_bandit_update_matches_ref(n, seed, lr):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    v = _rand(k1, n)
    arm = jax.nn.one_hot(jax.random.randint(k2, (), 0, n), n, dtype=jnp.float32)
    r = jnp.float32(2.5)
    got = logistic.bandit_update(v, arm, r, jnp.float32(lr))
    want = ref.bandit_update_ref(v, arm, r, jnp.float32(lr))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_score_extremes_saturate_not_nan():
    """Large |logits| must clamp to {0,1} without NaN (controller safety)."""
    w = jnp.full((16,), 100.0, dtype=jnp.float32)
    x = jnp.ones((8, 16), dtype=jnp.float32)
    p_hi = logistic.score(w, jnp.float32(0.0), x)
    p_lo = logistic.score(-w, jnp.float32(0.0), x)
    assert np.all(np.isfinite(np.asarray(p_hi)))
    np.testing.assert_allclose(np.asarray(p_hi), 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(p_lo), 0.0, atol=1e-6)


def test_training_reduces_loss_on_separable_data():
    """End-to-end L2 sanity: SGD on linearly separable features converges."""
    from compile import model

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (256, 16), dtype=jnp.float32)
    true_w = jax.random.normal(k2, (16,), dtype=jnp.float32)
    y = (x @ true_w > 0).astype(jnp.float32)
    w, b = jnp.zeros((16,), jnp.float32), jnp.float32(0.0)
    losses = []
    for _ in range(60):
        w, b, loss = model.train_step(w, b, x, y, jnp.float32(0.5))
        losses.append(float(loss))
    assert losses[-1] < 0.35 * losses[0], losses[::10]
