"""AOT path tests: lowering produces loadable HLO text + a sane manifest,
and the no-op fast path works when sources are unchanged."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.logistic import BATCH, FEATURES


def test_aot_writes_all_modules(tmp_path):
    out = str(tmp_path / "artifacts")
    assert aot.main(["--out-dir", out]) == 0
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["batch"] == BATCH and manifest["features"] == FEATURES
    for name in ("score", "train", "bandit"):
        path = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(path)
        text = open(path).read()
        # HLO text modules start with an `HloModule` header.
        assert text.lstrip().startswith("HloModule"), text[:80]
        assert manifest["modules"][name]["hlo_bytes"] == len(text)


def test_aot_noop_when_unchanged(tmp_path, capsys):
    out = str(tmp_path / "artifacts")
    assert aot.main(["--out-dir", out]) == 0
    capsys.readouterr()
    assert aot.main(["--out-dir", out]) == 0
    assert "skipping" in capsys.readouterr().out


def test_lowered_score_matches_eager():
    """The exact jitted function that gets lowered must agree with eager."""
    k = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(k)
    w = jax.random.normal(k1, (FEATURES,), dtype=jnp.float32)
    x = jax.random.normal(k2, (BATCH, FEATURES), dtype=jnp.float32)
    b = jnp.float32(0.1)
    (jitted,) = jax.jit(model.score)(w, b, x)
    (eager,) = model.score(w, b, x)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager), rtol=1e-6)


def test_hlo_text_is_id_safe(tmp_path):
    """Guard the 64-bit-id gotcha: text modules must parse as ASCII and not
    embed serialized protos (the failure mode of .serialize())."""
    out = str(tmp_path / "a")
    aot.main(["--out-dir", out])
    for name in ("score", "train", "bandit"):
        raw = open(os.path.join(out, f"{name}.hlo.txt"), "rb").read()
        raw.decode("ascii")  # raises if binary proto snuck in
