#!/usr/bin/env bash
# Determinism gate (DESIGN.md §6/§8/§13), runnable locally and in CI:
#
#   cargo build --release --manifest-path rust/Cargo.toml
#   bash ci/determinism.sh
#
# Contracts checked, in order:
#   - cluster stdout is byte-identical across --threads 1 / --threads 8
#     for every shipped example spec (analytic, empirical, slft-replay,
#     tenants, faults, obs, sketch telemetry), and --faults off lands
#     on the plain example's exact bytes (DESIGN.md §14);
#   - cluster stdout is byte-identical across --scheduler heap /
#     --scheduler calendar (the §13 scheduler-equivalence oracle);
#   - campaign stores are byte-identical across thread counts and a
#     rerun against an existing store recomputes zero cells — checked
#     for BOTH store formats (DESIGN.md §6): the legacy single-file
#     JSONL log (cmp) and the tiered segment directory (diff -r);
#   - jsonl-format and tiered-format campaigns render byte-identical
#     reports, a legacy JSONL store imports into the tiered layout with
#     0 recomputed cells, and explicit compaction changes no report byte;
#   - observability artifacts (Perfetto trace, metrics JSONL) are
#     thread-count invariant and parse as JSON.
#
# Outputs land under /tmp with fixed names; CI uploads
# /tmp/obs-metrics-t1.jsonl, /tmp/fleet-metrics-t1.jsonl, and
# /tmp/campaign-sketch.jsonl as the cluster_metrics artifact.
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
BIN="$ROOT/rust/target/release/slofetch"
EX="$ROOT/examples"

if [[ ! -x "$BIN" ]]; then
    echo "error: $BIN not found — build first:" >&2
    echo "  cargo build --release --manifest-path $ROOT/rust/Cargo.toml" >&2
    exit 1
fi

step() { echo "== $* =="; }

step "cluster stdout is thread-count invariant"
"$BIN" cluster --spec "$EX/cluster.json" --threads 1 > /tmp/cluster-t1.out
"$BIN" cluster --spec "$EX/cluster.json" --threads 8 > /tmp/cluster-t8.out
diff -u /tmp/cluster-t1.out /tmp/cluster-t8.out

step "heap and calendar schedulers produce byte-identical stdout (DESIGN.md §13)"
"$BIN" cluster --spec "$EX/cluster.json" --scheduler heap --threads 8 > /tmp/cluster-heap.out
"$BIN" cluster --spec "$EX/cluster.json" --scheduler calendar --threads 8 > /tmp/cluster-cal.out
diff -u /tmp/cluster-heap.out /tmp/cluster-cal.out
# The calendar queue is the default: an explicit --scheduler calendar
# must also be a no-op against the plain run.
diff -u /tmp/cluster-t8.out /tmp/cluster-cal.out

step "trace-replayed (empirical) cluster stdout is thread-count invariant"
"$BIN" cluster --spec "$EX/cluster_empirical.json" --threads 1 > /tmp/cluster-emp-t1.out
"$BIN" cluster --spec "$EX/cluster_empirical.json" --threads 8 > /tmp/cluster-emp-t8.out
diff -u /tmp/cluster-emp-t1.out /tmp/cluster-emp-t8.out
grep -q "cluster_models" /tmp/cluster-emp-t1.out
grep -q -- "~emp" /tmp/cluster-emp-t1.out

step "multi-tenant cluster stdout is thread-count invariant"
"$BIN" cluster --spec "$EX/cluster_tenants.json" --threads 1 > /tmp/cluster-ten-t1.out
"$BIN" cluster --spec "$EX/cluster_tenants.json" --threads 8 > /tmp/cluster-ten-t8.out
diff -u /tmp/cluster-ten-t1.out /tmp/cluster-ten-t8.out
grep -q "cluster_tenants" /tmp/cluster-ten-t1.out
grep -q "tenant-ctrl" /tmp/cluster-ten-t1.out

step "multi-tenant stdout is scheduler invariant"
"$BIN" cluster --spec "$EX/cluster_tenants.json" --scheduler heap --threads 8 > /tmp/cluster-ten-heap.out
diff -u /tmp/cluster-ten-t8.out /tmp/cluster-ten-heap.out

step "tenants off reproduces the single-tenant baseline shape"
"$BIN" cluster --spec "$EX/cluster_tenants.json" --tenants off --threads 8 > /tmp/cluster-ten-off.out
! grep -q "cluster_tenants" /tmp/cluster-ten-off.out

step "faulted cluster stdout is thread-count invariant (DESIGN.md §14)"
"$BIN" cluster --spec "$EX/cluster_faults.json" --threads 1 > /tmp/cluster-fault-t1.out
"$BIN" cluster --spec "$EX/cluster_faults.json" --threads 8 > /tmp/cluster-fault-t8.out
diff -u /tmp/cluster-fault-t1.out /tmp/cluster-fault-t8.out
grep -q "cluster_faults" /tmp/cluster-fault-t1.out

step "faulted stdout is scheduler invariant"
"$BIN" cluster --spec "$EX/cluster_faults.json" --scheduler heap --threads 8 > /tmp/cluster-fault-heap.out
diff -u /tmp/cluster-fault-t8.out /tmp/cluster-fault-heap.out

step "faults off reproduces the plain example byte-for-byte"
# cluster_faults.json is cluster.json + a faults section, so --faults
# off must land on the exact bytes of the plain cluster.json run.
"$BIN" cluster --spec "$EX/cluster_faults.json" --faults off --threads 8 > /tmp/cluster-fault-off.out
diff -u /tmp/cluster-t8.out /tmp/cluster-fault-off.out
! grep -q "cluster_faults" /tmp/cluster-fault-off.out

step "slft file replay is rerun invariant"
"$BIN" gen-trace --app websearch --records 40000 --out /tmp/ws.slft
"$BIN" cluster --spec "$EX/cluster_empirical.json" --trace /tmp/ws.slft --threads 8 > /tmp/cluster-slft-a.out
"$BIN" cluster --spec "$EX/cluster_empirical.json" --trace /tmp/ws.slft --threads 1 > /tmp/cluster-slft-b.out
diff -u /tmp/cluster-slft-a.out /tmp/cluster-slft-b.out

step "campaign store (jsonl format) is thread-count invariant"
rm -f /tmp/campaign-t1.jsonl /tmp/campaign-t8.jsonl
"$BIN" campaign --spec "$EX/campaign_cluster.json" --store-format jsonl --threads 1 --out /tmp/campaign-t1.jsonl > /dev/null
"$BIN" campaign --spec "$EX/campaign_cluster.json" --store-format jsonl --threads 8 --out /tmp/campaign-t8.jsonl > /dev/null
cmp /tmp/campaign-t1.jsonl /tmp/campaign-t8.jsonl

step "campaign rerun (jsonl format) recomputes zero cells"
"$BIN" campaign --spec "$EX/campaign_cluster.json" --store-format jsonl --threads 8 --out /tmp/campaign-t1.jsonl | tee /tmp/rerun.log
grep -q "(0 computed," /tmp/rerun.log
cmp /tmp/campaign-t1.jsonl /tmp/campaign-t8.jsonl

step "tenant campaign renders the pairing report and resumes"
rm -f /tmp/campaign-ten.jsonl
"$BIN" campaign --spec "$EX/campaign_tenants.json" --store-format jsonl --threads 8 --out /tmp/campaign-ten.jsonl | tee /tmp/campaign-ten.log
grep -q "campaign_tenants" /tmp/campaign-ten.log
"$BIN" campaign --spec "$EX/campaign_tenants.json" --store-format jsonl --threads 2 --out /tmp/campaign-ten.jsonl | tee /tmp/campaign-ten-rerun.log
grep -q "(0 computed," /tmp/campaign-ten-rerun.log
grep -q "campaign_tenants" /tmp/campaign-ten-rerun.log

step "fault campaign renders the regime ranking and resumes"
rm -f /tmp/campaign-faults.jsonl
"$BIN" campaign --spec "$EX/campaign_faults.json" --store-format jsonl --threads 8 --out /tmp/campaign-faults.jsonl | tee /tmp/campaign-faults.log
grep -q "campaign_faults" /tmp/campaign-faults.log
grep -q "campaign_cluster_rank" /tmp/campaign-faults.log
"$BIN" campaign --spec "$EX/campaign_faults.json" --store-format jsonl --threads 2 --out /tmp/campaign-faults.jsonl | tee /tmp/campaign-faults-rerun.log
grep -q "(0 computed," /tmp/campaign-faults-rerun.log
grep -q "campaign_faults" /tmp/campaign-faults-rerun.log

step "observability artifacts are thread-count invariant (DESIGN.md §11)"
"$BIN" cluster --spec "$EX/cluster_obs.json" --threads 1 \
    --trace-out /tmp/obs-trace-t1.json --metrics-out /tmp/obs-metrics-t1.jsonl > /tmp/cluster-obs-t1.out
"$BIN" cluster --spec "$EX/cluster_obs.json" --threads 8 \
    --trace-out /tmp/obs-trace-t8.json --metrics-out /tmp/obs-metrics-t8.jsonl > /tmp/cluster-obs-t8.out
diff -u /tmp/cluster-obs-t1.out /tmp/cluster-obs-t8.out
cmp /tmp/obs-trace-t1.json /tmp/obs-trace-t8.json
cmp /tmp/obs-metrics-t1.jsonl /tmp/obs-metrics-t8.jsonl
grep -q "cluster_critical_path" /tmp/cluster-obs-t1.out
python3 -c "import json,sys; d=json.load(open('/tmp/obs-trace-t1.json')); sys.exit(0 if d['traceEvents'] else 1)"
python3 -c "import json; [json.loads(l) for l in open('/tmp/obs-metrics-t1.jsonl')]"

step "observability artifacts are scheduler invariant"
"$BIN" cluster --spec "$EX/cluster_obs.json" --scheduler heap --threads 8 \
    --trace-out /tmp/obs-trace-heap.json --metrics-out /tmp/obs-metrics-heap.jsonl > /tmp/cluster-obs-heap.out
diff -u /tmp/cluster-obs-t8.out /tmp/cluster-obs-heap.out
cmp /tmp/obs-trace-t8.json /tmp/obs-trace-heap.json
cmp /tmp/obs-metrics-t8.jsonl /tmp/obs-metrics-heap.jsonl

step "obs-off stdout carries no observability output"
"$BIN" cluster --spec "$EX/cluster_obs.json" --threads 8 > /tmp/cluster-obs-off.out
! grep -q "cluster_critical_path" /tmp/cluster-obs-off.out

step "sketch fleet telemetry is thread-count invariant (DESIGN.md §12)"
"$BIN" cluster --spec "$EX/cluster_obs.json" --telemetry sketch --threads 1 \
    --metrics-out /tmp/fleet-metrics-t1.jsonl > /tmp/cluster-sketch-t1.out
"$BIN" cluster --spec "$EX/cluster_obs.json" --telemetry sketch --threads 8 \
    --metrics-out /tmp/fleet-metrics-t8.jsonl > /tmp/cluster-sketch-t8.out
diff -u /tmp/cluster-sketch-t1.out /tmp/cluster-sketch-t8.out
cmp /tmp/fleet-metrics-t1.jsonl /tmp/fleet-metrics-t8.jsonl
grep -q "cluster_fleet" /tmp/cluster-sketch-t1.out
grep -q '"scenario":"fleet"' /tmp/fleet-metrics-t1.jsonl
python3 -c "import json; [json.loads(l) for l in open('/tmp/fleet-metrics-t1.jsonl')]"

step "exact telemetry (the default) leaves cluster stdout unchanged"
"$BIN" cluster --spec "$EX/cluster_obs.json" --telemetry exact --threads 8 > /tmp/cluster-exact.out
diff -u /tmp/cluster-obs-off.out /tmp/cluster-exact.out
! grep -q "cluster_fleet" /tmp/cluster-exact.out

step "sketch campaign renders the accuracy report and resumes"
rm -f /tmp/campaign-sketch.jsonl
"$BIN" campaign --spec "$EX/campaign_sketch.json" --store-format jsonl --threads 8 --out /tmp/campaign-sketch.jsonl | tee /tmp/campaign-sketch.log
grep -q "campaign_sketch" /tmp/campaign-sketch.log
"$BIN" campaign --spec "$EX/campaign_sketch.json" --store-format jsonl --threads 2 --out /tmp/campaign-sketch.jsonl | tee /tmp/campaign-sketch-rerun.log
grep -q "(0 computed," /tmp/campaign-sketch-rerun.log
grep -q "campaign_sketch" /tmp/campaign-sketch-rerun.log

# ---- tiered store (DESIGN.md §6) -------------------------------------
# The summary line carries wall-clock timing, so report comparisons
# filter it out; everything else on stdout is the byte-compared surface.

step "tiered campaign store is thread-count invariant"
rm -rf /tmp/campaign-t1.store /tmp/campaign-t8.store
"$BIN" campaign --spec "$EX/campaign_cluster.json" --threads 1 --out /tmp/campaign-t1.store > /tmp/campaign-tier-t1.log
"$BIN" campaign --spec "$EX/campaign_cluster.json" --threads 8 --out /tmp/campaign-t8.store > /tmp/campaign-tier-t8.log
diff -r /tmp/campaign-t1.store /tmp/campaign-t8.store
grep -v "^campaign '" /tmp/campaign-tier-t1.log > /tmp/campaign-tier-t1.rpt
grep -v "^campaign '" /tmp/campaign-tier-t8.log > /tmp/campaign-tier-t8.rpt
diff -u /tmp/campaign-tier-t1.rpt /tmp/campaign-tier-t8.rpt

step "tiered campaign rerun recomputes zero cells and leaves the store untouched"
"$BIN" campaign --spec "$EX/campaign_cluster.json" --threads 8 --out /tmp/campaign-t1.store | tee /tmp/campaign-tier-rerun.log
grep -q "(0 computed," /tmp/campaign-tier-rerun.log
diff -r /tmp/campaign-t1.store /tmp/campaign-t8.store

step "jsonl-format and tiered-format campaigns render identical report bytes"
grep -v "^campaign '" /tmp/rerun.log > /tmp/campaign-jsonl.rpt
grep -v "^campaign '" /tmp/campaign-tier-rerun.log > /tmp/campaign-tier.rpt
cmp /tmp/campaign-jsonl.rpt /tmp/campaign-tier.rpt

step "a legacy jsonl store imports into the tiered layout (0 computed)"
rm -rf /tmp/campaign-legacy.jsonl /tmp/campaign-legacy.jsonl.migrate-tmp
cp /tmp/campaign-sketch.jsonl /tmp/campaign-legacy.jsonl
"$BIN" campaign --spec "$EX/campaign_sketch.json" --store-format tiered --threads 8 --out /tmp/campaign-legacy.jsonl | tee /tmp/campaign-import.log
grep -q "(0 computed," /tmp/campaign-import.log
test -d /tmp/campaign-legacy.jsonl
grep -v "^campaign '" /tmp/campaign-import.log > /tmp/campaign-import.rpt
grep -v "^campaign '" /tmp/campaign-sketch-rerun.log > /tmp/campaign-sketch.rpt
cmp /tmp/campaign-sketch.rpt /tmp/campaign-import.rpt

step "campaign compact merges segments and changes no report byte"
"$BIN" campaign compact --out /tmp/campaign-t1.store | tee /tmp/compact.log
grep -q "compacted" /tmp/compact.log
"$BIN" campaign --spec "$EX/campaign_cluster.json" --threads 8 --out /tmp/campaign-t1.store | tee /tmp/campaign-postcompact.log
grep -q "(0 computed," /tmp/campaign-postcompact.log
grep -v "^campaign '" /tmp/campaign-postcompact.log > /tmp/campaign-postcompact.rpt
cmp /tmp/campaign-tier.rpt /tmp/campaign-postcompact.rpt

echo "determinism gate: all checks passed"
