#!/usr/bin/env python3
"""Bench-trajectory gate: compare fresh bench JSON reports against the
committed ci/BENCH_baseline.json.

Usage:
    python3 ci/check_bench.py CURRENT.json [CURRENT2.json ...] BASELINE.json [tolerance]

The last .json argument is the baseline; every earlier one is a current
bench report (e.g. BENCH_cluster.json and BENCH_store.json from one CI
run). Current reports are merged: their `events_per_sec` maps must not
collide, and every `speedup_vs_<suffix>` map is collected per suffix.

For every scenario in the baseline's `events_per_sec` map, the merged
current events/sec must be >= tolerance * baseline (default 0.85, i.e.
fail on a >15% regression). Scenarios present only in the current
reports are printed but not gated, so adding a bench scenario never
requires a baseline update in the same commit.

For every baseline key `min_speedup_vs_<suffix>` (e.g.
`min_speedup_vs_heap` for the calendar-queue claim,
`min_speedup_vs_jsonl` for the tiered-store cold-open claim), every
entry of the merged `speedup_vs_<suffix>` map must clear that floor —
the tentpole perf claims stay enforced, not aspirational.

Exit status: 0 when every gated ratio clears its floor, 1 otherwise.
"""

import json
import sys


def main(argv):
    args = argv[1:]
    tolerance = 0.85
    if args and not args[-1].endswith(".json"):
        tolerance = float(args.pop())
    if len(args) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    base_path = args.pop()
    cur_paths = args

    with open(base_path) as f:
        base = json.load(f)

    cur_eps = {}
    speedups = {}  # suffix -> {scenario: ratio}
    for path in cur_paths:
        with open(path) as f:
            cur = json.load(f)
        for name, val in cur.get("events_per_sec", {}).items():
            if name in cur_eps:
                print(f"bench gate: duplicate scenario '{name}' in {path}", file=sys.stderr)
                return 2
            cur_eps[name] = val
        for key, val in cur.items():
            if key.startswith("speedup_vs_") and isinstance(val, dict):
                speedups.setdefault(key[len("speedup_vs_"):], {}).update(val)

    base_eps = base.get("events_per_sec", {})
    flat_speedups = {n: r for per in speedups.values() for n, r in per.items()}

    failures = []
    print(f"bench gate: tolerance {tolerance:.2f}x of baseline ({base_path})")
    for name in sorted(base_eps):
        floor = base_eps[name]
        got = cur_eps.get(name)
        if got is None:
            failures.append(f"{name}: missing from {', '.join(cur_paths)}")
            continue
        ratio = got / floor if floor > 0 else float("inf")
        verdict = "ok" if ratio >= tolerance else "FAIL"
        line = (
            f"  {name:<28} {got / 1e6:8.2f}M ev/s  baseline {floor / 1e6:8.2f}M"
            f"  ratio {ratio:5.2f}x  {verdict}"
        )
        if name in flat_speedups:
            line += f"  (speedup {flat_speedups[name]:.2f}x)"
        print(line)
        if ratio < tolerance:
            failures.append(f"{name}: {ratio:.2f}x < {tolerance:.2f}x floor")
    for name in sorted(set(cur_eps) - set(base_eps)):
        print(f"  {name:<28} {cur_eps[name] / 1e6:8.2f}M ev/s  (no baseline, not gated)")

    for suffix, per in sorted(speedups.items()):
        floor = base.get(f"min_speedup_vs_{suffix}")
        if floor is None:
            continue
        for name in sorted(per):
            verdict = "ok" if per[name] >= floor else "FAIL"
            print(
                f"  speedup_vs_{suffix}[{name}] {per[name]:6.2f}x"
                f"  floor {floor:.2f}x  {verdict}"
            )
            if per[name] < floor:
                failures.append(
                    f"{name}: speedup vs {suffix} {per[name]:.2f}x"
                    f" < required {floor:.2f}x"
                )

    if failures:
        print("bench gate: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench gate: passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
