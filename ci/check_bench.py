#!/usr/bin/env python3
"""Bench-trajectory gate: compare a fresh BENCH_cluster.json against the
committed ci/BENCH_baseline.json.

Usage:
    python3 ci/check_bench.py CURRENT.json BASELINE.json [tolerance]

For every scenario in the baseline's `events_per_sec` map, the current
events/sec must be >= tolerance * baseline (default 0.85, i.e. fail on a
>15% regression). Scenarios present only in the current file are
reported but not gated, so adding a bench scenario never requires a
baseline update in the same commit. The calendar-vs-heap speedup is
printed (and gated >= `min_speedup_vs_heap` when the baseline sets it)
so the tentpole perf claim stays enforced, not aspirational.

Exit status: 0 when every gated ratio clears the floor, 1 otherwise.
"""

import json
import sys


def main(argv):
    if len(argv) < 3 or len(argv) > 4:
        print(__doc__, file=sys.stderr)
        return 2
    cur_path, base_path = argv[1], argv[2]
    tolerance = float(argv[3]) if len(argv) == 4 else 0.85

    with open(cur_path) as f:
        cur = json.load(f)
    with open(base_path) as f:
        base = json.load(f)

    cur_eps = cur.get("events_per_sec", {})
    base_eps = base.get("events_per_sec", {})
    speedups = cur.get("speedup_vs_heap", {})
    min_speedup = base.get("min_speedup_vs_heap")

    failures = []
    print(f"bench gate: tolerance {tolerance:.2f}x of baseline ({base_path})")
    for name in sorted(base_eps):
        floor = base_eps[name]
        got = cur_eps.get(name)
        if got is None:
            failures.append(f"{name}: missing from {cur_path}")
            continue
        ratio = got / floor if floor > 0 else float("inf")
        verdict = "ok" if ratio >= tolerance else "FAIL"
        line = (
            f"  {name:<22} {got / 1e6:8.2f}M ev/s  baseline {floor / 1e6:8.2f}M"
            f"  ratio {ratio:5.2f}x  {verdict}"
        )
        if name in speedups:
            line += f"  (calendar/heap {speedups[name]:.2f}x)"
        print(line)
        if ratio < tolerance:
            failures.append(f"{name}: {ratio:.2f}x < {tolerance:.2f}x floor")
        if min_speedup is not None and name in speedups:
            if speedups[name] < min_speedup:
                failures.append(
                    f"{name}: calendar/heap speedup {speedups[name]:.2f}x"
                    f" < required {min_speedup:.2f}x"
                )
    for name in sorted(set(cur_eps) - set(base_eps)):
        print(f"  {name:<22} {cur_eps[name] / 1e6:8.2f}M ev/s  (no baseline, not gated)")

    if failures:
        print("bench gate: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench gate: passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
