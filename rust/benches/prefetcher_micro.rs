//! Prefetcher micro-benchmarks: per-operation cost of every prefetcher's
//! hot-path entry points (§Perf L3 targets in EXPERIMENTS.md).

use slofetch::prefetch::{
    ceip::Ceip, cheip::Cheip, eip::Eip, next_line::NextLine, Candidate, Prefetcher,
};
use slofetch::util::rng::Rng;
use slofetch::util::timer::bench;

const OPS: u64 = 1_000_000;

fn addr_mix(n: usize) -> Vec<u64> {
    // Clustered fetch stream like the generator's output.
    let mut r = Rng::new(1);
    let mut out = Vec::with_capacity(n);
    let mut line = 0x40_0000u64;
    for _ in 0..n {
        if r.chance(0.1) {
            line = 0x40_0000 + r.below(1 << 16);
        } else {
            line += 1;
        }
        out.push(line);
    }
    out
}

fn bench_prefetcher(name: &str, pf: &mut dyn Prefetcher, addrs: &[u64]) {
    // Train with a representative miss stream first.
    for (i, &a) in addrs.iter().take(100_000).enumerate() {
        pf.on_demand_miss(a, i as u64 * 4);
        pf.on_miss_resolved(a, i as u64 * 4, 35);
    }
    let mut out: Vec<Candidate> = Vec::with_capacity(16);
    let r = bench(&format!("{name}::on_fetch"), 1, 7, OPS, || {
        let mut cycle = 0u64;
        for &a in addrs.iter().take(OPS as usize) {
            out.clear();
            pf.on_fetch(a, cycle, &mut out);
            cycle += 4;
        }
    });
    println!("{}", r.report());

    let r = bench(&format!("{name}::train(miss+resolve)"), 1, 5, OPS / 4, || {
        let mut cycle = 0u64;
        for &a in addrs.iter().take((OPS / 4) as usize) {
            pf.on_demand_miss(a, cycle);
            pf.on_miss_resolved(a ^ 0x3, cycle, 35);
            cycle += 40;
        }
    });
    println!("{}", r.report());
}

fn main() {
    println!("== prefetcher_micro ({OPS} ops/run, median of runs) ==");
    let addrs = addr_mix(OPS as usize);
    bench_prefetcher("nl", &mut NextLine::new(1), &addrs);
    bench_prefetcher("eip4096", &mut Eip::new(4096, 1), &addrs);
    bench_prefetcher("ceip4096w8", &mut Ceip::new(4096, 8, true, 1), &addrs);
    bench_prefetcher(
        "cheip2k",
        &mut Cheip::new(2048, 8, true, 1, 512, 15),
        &addrs,
    );
}
