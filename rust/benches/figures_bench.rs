//! End-to-end figure benches: regenerates EVERY table/figure of the paper
//! at bench scale and times each driver (custom harness — criterion is
//! unavailable offline). `cargo bench --bench figures_bench` prints the
//! same rows the paper reports plus the wall-clock cost of regeneration.
//!
//! Scale with SLOFETCH_BENCH_RECORDS (default 300k records/app).

use slofetch::figures::{self, FigureCtx, Matrix};
use slofetch::util::timer::time_it;

fn main() {
    let records = std::env::var("SLOFETCH_BENCH_RECORDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300_000u64);
    let ctx = FigureCtx {
        records_per_app: records,
        out_dir: Some("results".into()),
        ..Default::default()
    };
    println!("== figures_bench: matrix at {records} records/app ==");
    let (m, secs) = time_it(|| Matrix::compute(ctx.clone()));
    let cells = m.apps.len() * figures::standard_configs().len();
    println!(
        "matrix: {cells} cells in {secs:.1}s ({:.1} Mrec/s aggregate)\n",
        cells as f64 * records as f64 / secs / 1e6
    );

    let mut timings = Vec::new();
    macro_rules! fig {
        ($name:expr, $f:expr) => {{
            let (t, s) = time_it(|| $f);
            println!("{}", t.markdown());
            t.save(std::path::Path::new("results")).ok();
            timings.push(($name, s));
        }};
    }
    fig!("table1", figures::table1());
    fig!("fig1", figures::fig1(&m));
    fig!("fig2", figures::fig2(&m));
    fig!("fig3", figures::schematics::fig3());
    fig!("fig4", figures::schematics::fig4());
    fig!("fig5", figures::schematics::fig5());
    fig!("fig6", figures::fig6(&m));
    fig!("fig7", figures::fig7(&m));
    fig!("fig8", figures::fig8(&m));
    fig!("fig9", figures::fig9(&m));
    fig!("fig10", figures::fig10(&m));
    fig!("fig11", figures::fig11(&m));
    fig!("fig12", figures::fig12(&m));
    fig!("fig13", figures::fig13(&m));
    fig!("summary", figures::summary(&m));
    fig!("rpc", figures::rpc_tails(&m));
    fig!("ablation", figures::ablation(&ctx));

    println!("== regeneration timings ==");
    for (name, s) in timings {
        println!("{name:<10} {s:>8.3}s");
    }
    println!("(tables also written to results/)");
}
