//! Campaign throughput bench: cells/sec at 1 vs N worker threads on a
//! reduced-records matrix, tracking the parallel speedup across PRs.
//! Scale with SLOFETCH_BENCH_RECORDS (default 60k records/cell).

use slofetch::campaign::{runner, CampaignSpec};
use slofetch::util::timer::time_it;

fn main() {
    let records = std::env::var("SLOFETCH_BENCH_RECORDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60_000u64);
    let spec = CampaignSpec {
        name: "bench".into(),
        apps: vec![
            "websearch".into(),
            "admission".into(),
            "serde".into(),
            "crypto".into(),
        ],
        prefetchers: vec!["nl".into(), "eip256".into(), "ceip256".into(), "cheip2k".into()],
        records,
        seeds: vec![7],
        ml: vec![false],
        churn_scale: vec![1.0],
        traffic: vec!["none".into()],
        ..Default::default()
    };
    let cells: Vec<runner::Cell> =
        spec.expand().unwrap().into_iter().map(|c| c.cell).collect();
    let n = cells.len();
    let max_threads = runner::default_threads();
    println!("== campaign_micro: {n} cells x {records} records ==");

    let mut serial_secs = 0.0;
    let mut threads = 1usize;
    loop {
        let (out, secs) = time_it(|| runner::run_cells(&cells, threads));
        assert_eq!(out.len(), n);
        if threads == 1 {
            serial_secs = secs;
        }
        println!(
            "threads={threads:<3} {:>6.2} cells/s  ({secs:.2}s, speedup {:.2}x)",
            n as f64 / secs,
            serial_secs / secs
        );
        if threads >= max_threads {
            break;
        }
        threads = (threads * 2).min(max_threads);
    }
}
