//! Simulator micro-benchmarks: engine throughput (records/s) per
//! prefetcher config, cache probe cost, trace generation and codec rates.
//! §Perf target: ≥ 20 M records/s on the NL baseline path.

use slofetch::config::{ControllerCfg, PrefetcherKind, SimConfig};
use slofetch::sim::cache::Cache;
use slofetch::sim::engine;
use slofetch::trace::gen::{apps, generate_records};
use slofetch::trace::{codec, TraceMeta};
use slofetch::util::rng::Rng;
use slofetch::util::timer::{bench, time_it};

fn main() {
    println!("== sim_micro ==");

    // Trace generation rate.
    let spec = apps::app("websearch").unwrap();
    let n = 2_000_000u64;
    let (records, gen_s) = time_it(|| generate_records(&spec, 7, n));
    println!(
        "trace-gen: {n} records in {gen_s:.2}s ({:.1} Mrec/s)",
        n as f64 / gen_s / 1e6
    );

    // Codec rates.
    let meta = TraceMeta {
        app: "bench".into(),
        seed: 7,
        line_bytes: 64,
        records: records.len() as u64,
    };
    let mut buf = Vec::new();
    let (_, enc_s) = time_it(|| {
        codec::write_trace(&mut buf, &meta, records.iter().copied(), records.len() as u64)
            .unwrap()
    });
    println!(
        "codec-encode: {:.1} Mrec/s ({:.2} B/rec)",
        records.len() as f64 / enc_s / 1e6,
        buf.len() as f64 / records.len() as f64
    );
    let (decoded, dec_s) = time_it(|| {
        codec::TraceReader::new(std::io::Cursor::new(&buf[..]))
            .unwrap()
            .map(|r| r.unwrap())
            .count()
    });
    println!(
        "codec-decode: {:.1} Mrec/s ({decoded} records)",
        decoded as f64 / dec_s / 1e6
    );

    // Engine throughput per config.
    for (name, kind, ml) in [
        ("nl", PrefetcherKind::NextLineOnly, false),
        ("eip256", PrefetcherKind::Eip { entries: 4096 }, false),
        (
            "ceip256",
            PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            false,
        ),
        (
            "cheip2k",
            PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
            false,
        ),
        (
            "ceip256+ml",
            PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            true,
        ),
    ] {
        let cfg = SimConfig {
            prefetcher: kind,
            controller: ml.then(|| ControllerCfg {
                train_interval_cycles: 1_000_000,
                ..Default::default()
            }),
            ..Default::default()
        };
        let (r, s) = time_it(|| engine::run(&cfg, &records));
        println!(
            "engine[{name:>10}]: {:.2} Mrec/s ({:.2} Minstr/s, ipc {:.3})",
            records.len() as f64 / s / 1e6,
            r.stats.instrs as f64 / s / 1e6,
            r.ipc()
        );
    }

    // Raw cache probe cost.
    let mut cache = Cache::new(slofetch::config::HierarchyCfg::table1().l1i);
    let mut rng = Rng::new(3);
    let lines: Vec<u64> = (0..100_000).map(|_| rng.below(4096)).collect();
    let mut sink = 0u64;
    let r = bench("l1i access+insert", 2, 9, lines.len() as u64, || {
        for &l in &lines {
            if !cache.access(l) {
                cache.insert(l, false);
            }
            sink = sink.wrapping_add(l);
        }
    });
    println!("{}", r.report());
    std::hint::black_box(sink);
}
