//! Cluster event-loop throughput bench: events/sec at 1M+ requests on
//! synthetic topologies (no trace simulation — pure queueing), tracking
//! the hot path across PRs. Scale with SLOFETCH_BENCH_REQUESTS
//! (default 1M requests per scenario).

use slofetch::cluster::engine::{self, RunParams};
use slofetch::cluster::topology::{Candidate, ResolvedService, ResolvedTopology};
use slofetch::cluster::workload::TrafficShape;
use slofetch::util::timer::time_it;

fn chain(n: usize) -> ResolvedTopology {
    let services = (0..n)
        .map(|i| ResolvedService {
            name: format!("s{i}"),
            replicas: 2,
            cv: 0.35,
            candidates: vec![Candidate { label: "static".into(), mean_us: 5.0 }],
            children: if i + 1 < n { vec![(i + 1) as u32] } else { Vec::new() },
            indegree: u32::from(i > 0),
        })
        .collect();
    ResolvedTopology { services }
}

fn fanout() -> ResolvedTopology {
    let svc = |name: &str, mean: f64, replicas: u32, children: Vec<u32>, indegree: u32| {
        ResolvedService {
            name: name.into(),
            replicas,
            cv: 0.35,
            candidates: vec![Candidate { label: "static".into(), mean_us: mean }],
            children,
            indegree,
        }
    };
    ResolvedTopology {
        services: vec![
            svc("gateway", 4.0, 2, vec![1, 2, 3], 0),
            svc("search", 12.0, 3, vec![4], 1),
            svc("ads", 8.0, 2, vec![4], 1),
            svc("profile", 8.0, 2, vec![4], 1),
            svc("render", 5.0, 2, vec![], 3),
        ],
    }
}

fn bench(name: &str, topo: &ResolvedTopology, shape: &TrafficShape, requests: u64) {
    let params = RunParams {
        requests,
        seed: 17,
        slo_us: topo.zero_load_us() * 4.0,
        base_rate_per_us: topo.bottleneck_rate() * 0.7,
    };
    let (r, secs) = time_it(|| engine::run(topo, shape, &params, None));
    assert_eq!(r.requests, requests);
    println!(
        "{name:<22} {:>7.2}M events/s  ({} events, {:.2}s, p99 {:.1} µs)",
        r.events as f64 / secs / 1e6,
        r.events,
        secs,
        r.p99_us,
    );
}

fn main() {
    let requests = std::env::var("SLOFETCH_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000u64);
    println!("== cluster_micro: {requests} requests/scenario ==");
    bench("chain3/poisson", &chain(3), &TrafficShape::Poisson { util: 1.0 }, requests);
    bench(
        "chain3/burst",
        &chain(3),
        &TrafficShape::Burst { util: 0.7, mult: 1.8, period_us: 50_000.0, duty: 0.2 },
        requests,
    );
    bench("fanout5/poisson", &fanout(), &TrafficShape::Poisson { util: 1.0 }, requests);
    bench(
        "fanout5/diurnal",
        &fanout(),
        &TrafficShape::Diurnal { util: 0.8, amplitude: 0.3, period_us: 200_000.0 },
        requests,
    );
}
