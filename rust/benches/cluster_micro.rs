//! Cluster event-loop throughput bench: events/sec at 1M+ requests on
//! synthetic topologies (no trace simulation — pure queueing), tracking
//! the hot path across PRs. Every scenario runs under BOTH scheduler
//! backends (DESIGN.md §13): the calendar queue (default, reported under
//! the historical `events_per_sec` key) and the binary heap oracle
//! (`events_per_sec_heap`), with a bit-equality cross-check so a perf
//! win can never smuggle in a behavior change. Scale with
//! SLOFETCH_BENCH_REQUESTS (default 1M requests per scenario) and
//! SLOFETCH_BENCH_RUNS (default 3 timed runs per scenario, reported as
//! median with a p10/p90 spread); set SLOFETCH_BENCH_JSON=PATH to also
//! emit a machine-readable report including the engine's self-profiled
//! peak pending-event depth (the CI bench-smoke job uploads it as the
//! `BENCH_cluster.json` artifact and gates it against
//! `ci/BENCH_baseline.json`). The `chain3/faults` scenario runs the
//! same cross-check under an injected §14 fault schedule but reports
//! events/sec only — it stays out of the gated `speedup_vs_heap` map,
//! which encodes the healthy-path calendar-queue claim.

use slofetch::cluster::engine::{self, RunParams};
use slofetch::cluster::sched::SchedKind;
use slofetch::cluster::topology::{Candidate, ResolvedService, ResolvedTopology};
use slofetch::cluster::workload::TrafficShape;
use slofetch::cluster::{ClientPolicySpec, EdgePolicy, FaultsSpec};
use slofetch::obs::ObsCfg;
use slofetch::util::json::Json;
use slofetch::util::percentile::Digest;
use slofetch::util::timer::time_it;

fn chain(n: usize) -> ResolvedTopology {
    let services = (0..n)
        .map(|i| ResolvedService {
            name: format!("s{i}"),
            replicas: 2,
            cv: 0.35,
            candidates: vec![Candidate {
                label: "static".into(),
                mean_us: 5.0,
                metadata_bytes: 0,
                table: None,
            }],
            children: if i + 1 < n { vec![(i + 1) as u32] } else { Vec::new() },
            indegree: u32::from(i > 0),
        })
        .collect();
    ResolvedTopology { services }
}

fn fanout() -> ResolvedTopology {
    let svc = |name: &str, mean: f64, replicas: u32, children: Vec<u32>, indegree: u32| {
        ResolvedService {
            name: name.into(),
            replicas,
            cv: 0.35,
            candidates: vec![Candidate {
                label: "static".into(),
                mean_us: mean,
                metadata_bytes: 0,
                table: None,
            }],
            children,
            indegree,
        }
    };
    ResolvedTopology {
        services: vec![
            svc("gateway", 4.0, 2, vec![1, 2, 3], 0),
            svc("search", 12.0, 3, vec![4], 1),
            svc("ads", 8.0, 2, vec![4], 1),
            svc("profile", 8.0, 2, vec![4], 1),
            svc("render", 5.0, 2, vec![], 3),
        ],
    }
}

/// One backend's events/sec summary across timed runs.
struct BackendResult {
    events_per_sec: f64,
    p10: f64,
    p90: f64,
}

/// Per-scenario summary: both backends plus cross-checked run facts.
struct ScenarioResult {
    name: &'static str,
    calendar: BackendResult,
    heap: BackendResult,
    peak_heap: u64,
    /// Whether this scenario participates in the gated
    /// `speedup_vs_heap` map. Fault scenarios are cross-checked for
    /// bit-equality but tracked by events/sec floor only: their event
    /// mix (timers, stale discards) is not the §13 speedup claim.
    gate_speedup: bool,
}

/// Time one backend `runs` times; returns its summary plus the facts
/// used for the cross-backend bit-equality check.
fn time_backend(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    runs: usize,
    sched: SchedKind,
    faults: Option<&FaultsSpec>,
) -> (BackendResult, u64, u64, u64) {
    let mut d = Digest::new();
    let mut events = 0u64;
    let mut peak = 0u64;
    let mut p99_bits = 0u64;
    for _ in 0..runs {
        let (r, secs) = time_it(|| {
            engine::run_obs_sched_faults(
                topo,
                shape,
                params,
                None,
                &ObsCfg::off(),
                sched,
                faults,
            )
            .unwrap()
        });
        assert_eq!(r.requests, params.requests);
        d.add(r.events as f64 / secs);
        events = r.events;
        peak = r.peak_heap;
        p99_bits = r.p99_us.to_bits();
    }
    let out = BackendResult {
        events_per_sec: d.percentile(50.0),
        p10: d.percentile(10.0),
        p90: d.percentile(90.0),
    };
    (out, events, peak, p99_bits)
}

/// Run one scenario under both schedulers and summarize (also printed).
fn bench(
    name: &'static str,
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    requests: u64,
    runs: usize,
    faults: Option<&FaultsSpec>,
) -> ScenarioResult {
    let params = RunParams {
        requests,
        seed: 17,
        slo_us: topo.zero_load_us() * 4.0,
        base_rate_per_us: topo.bottleneck_rate() * 0.7,
    };
    let (heap, h_events, h_peak, h_p99) =
        time_backend(topo, shape, &params, runs, SchedKind::Heap, faults);
    let (calendar, c_events, c_peak, c_p99) =
        time_backend(topo, shape, &params, runs, SchedKind::Calendar, faults);
    // The §13 equivalence contract, enforced where it is cheapest to
    // notice a break: same events, same pending-depth peak, same p99 bits.
    assert_eq!(h_events, c_events, "{name}: backends disagree on event count");
    assert_eq!(h_peak, c_peak, "{name}: backends disagree on peak pending depth");
    assert_eq!(h_p99, c_p99, "{name}: backends disagree on p99 bits");
    let speedup = calendar.events_per_sec / heap.events_per_sec.max(1e-9);
    println!(
        "{name:<22} {:>7.2}M events/s  [p10 {:.2}M, p90 {:.2}M]  \
         (heap {:.2}M, {speedup:.2}x; {c_events} events, pending {c_peak})",
        calendar.events_per_sec / 1e6,
        calendar.p10 / 1e6,
        calendar.p90 / 1e6,
        heap.events_per_sec / 1e6,
    );
    ScenarioResult { name, calendar, heap, peak_heap: c_peak, gate_speedup: faults.is_none() }
}

/// Fault pressure for the `chain3/faults` scenario (DESIGN.md §14):
/// periodic rate-driven crashes plus a long gray window keep the
/// timeout/retry/hedge machinery and its stale discards on the hot
/// path, so the bench tracks the fault-handling cost across PRs.
fn chain_faults() -> FaultsSpec {
    FaultsSpec {
        events: vec!["downrate:s1:60000:8000".into(), "gray:s2:1:4:10000:400000".into()],
        client: vec![ClientPolicySpec {
            service: "s1".into(),
            policy: EdgePolicy {
                timeout_us: Some(80.0),
                retries: 1,
                backoff_us: 10.0,
                hedge_after_us: Some(25.0),
            },
        }],
    }
}

fn main() {
    let requests = std::env::var("SLOFETCH_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000u64);
    let runs = std::env::var("SLOFETCH_BENCH_RUNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3usize)
        .max(1);
    println!("== cluster_micro: {requests} requests/scenario, {runs} runs ==");
    let scenarios: [(&str, ResolvedTopology, TrafficShape); 4] = [
        ("chain3/poisson", chain(3), TrafficShape::Poisson { util: 1.0 }),
        (
            "chain3/burst",
            chain(3),
            TrafficShape::Burst { util: 0.7, mult: 1.8, period_us: 50_000.0, duty: 0.2 },
        ),
        ("fanout5/poisson", fanout(), TrafficShape::Poisson { util: 1.0 }),
        (
            "fanout5/diurnal",
            fanout(),
            TrafficShape::Diurnal { util: 0.8, amplitude: 0.3, period_us: 200_000.0 },
        ),
    ];
    let mut results: Vec<ScenarioResult> = Vec::new();
    for (name, topo, shape) in &scenarios {
        results.push(bench(name, topo, shape, requests, runs, None));
    }
    // Faulted variant of chain3: same topology and arrivals, with the
    // §14 schedule injecting crashes/gray slowness and the client policy
    // generating timeout/retry/hedge timer events and stale discards.
    results.push(bench(
        "chain3/faults",
        &chain(3),
        &TrafficShape::Poisson { util: 1.0 },
        requests,
        runs,
        Some(&chain_faults()),
    ));
    // Machine-readable trajectory point for CI: median events/sec per
    // scenario (stable key, calendar backend), the p10/p90 spread, the
    // heap-oracle median and the calendar/heap speedup, and the engine's
    // self-profiled peak pending-event depth (historical `peak_heap` key).
    if let Ok(path) = std::env::var("SLOFETCH_BENCH_JSON") {
        let per = |f: &dyn Fn(&ScenarioResult) -> f64| {
            Json::obj(results.iter().map(|r| (r.name, Json::num(f(r)))).collect())
        };
        let j = Json::obj(vec![
            ("bench", Json::str("cluster_micro")),
            ("requests", Json::num(requests as f64)),
            ("runs", Json::num(runs as f64)),
            ("scheduler", Json::str("calendar")),
            ("events_per_sec", per(&|r| r.calendar.events_per_sec)),
            ("events_per_sec_p10", per(&|r| r.calendar.p10)),
            ("events_per_sec_p90", per(&|r| r.calendar.p90)),
            ("events_per_sec_heap", per(&|r| r.heap.events_per_sec)),
            (
                // Fault scenarios are excluded: the gate's
                // `min_speedup_vs_heap` encodes the §13 healthy-path
                // claim, and their events/sec floor already tracks them.
                "speedup_vs_heap",
                Json::obj(
                    results
                        .iter()
                        .filter(|r| r.gate_speedup)
                        .map(|r| {
                            let s = r.calendar.events_per_sec / r.heap.events_per_sec.max(1e-9);
                            (r.name, Json::num(s))
                        })
                        .collect(),
                ),
            ),
            ("peak_heap", per(&|r| r.peak_heap as f64)),
        ]);
        std::fs::write(&path, j.pretty()).expect("write bench json");
        println!("(wrote {path})");
    }
}
