//! Cluster event-loop throughput bench: events/sec at 1M+ requests on
//! synthetic topologies (no trace simulation — pure queueing), tracking
//! the hot path across PRs. Scale with SLOFETCH_BENCH_REQUESTS
//! (default 1M requests per scenario); set SLOFETCH_BENCH_JSON=PATH to
//! also emit a machine-readable events/sec report (the CI bench-smoke
//! job uploads it as the `BENCH_cluster.json` artifact).

use slofetch::cluster::engine::{self, RunParams};
use slofetch::cluster::topology::{Candidate, ResolvedService, ResolvedTopology};
use slofetch::cluster::workload::TrafficShape;
use slofetch::util::json::Json;
use slofetch::util::timer::time_it;

fn chain(n: usize) -> ResolvedTopology {
    let services = (0..n)
        .map(|i| ResolvedService {
            name: format!("s{i}"),
            replicas: 2,
            cv: 0.35,
            candidates: vec![Candidate {
                label: "static".into(),
                mean_us: 5.0,
                metadata_bytes: 0,
                table: None,
            }],
            children: if i + 1 < n { vec![(i + 1) as u32] } else { Vec::new() },
            indegree: u32::from(i > 0),
        })
        .collect();
    ResolvedTopology { services }
}

fn fanout() -> ResolvedTopology {
    let svc = |name: &str, mean: f64, replicas: u32, children: Vec<u32>, indegree: u32| {
        ResolvedService {
            name: name.into(),
            replicas,
            cv: 0.35,
            candidates: vec![Candidate {
                label: "static".into(),
                mean_us: mean,
                metadata_bytes: 0,
                table: None,
            }],
            children,
            indegree,
        }
    };
    ResolvedTopology {
        services: vec![
            svc("gateway", 4.0, 2, vec![1, 2, 3], 0),
            svc("search", 12.0, 3, vec![4], 1),
            svc("ads", 8.0, 2, vec![4], 1),
            svc("profile", 8.0, 2, vec![4], 1),
            svc("render", 5.0, 2, vec![], 3),
        ],
    }
}

/// Run one scenario and return its events/sec (also printed).
fn bench(name: &str, topo: &ResolvedTopology, shape: &TrafficShape, requests: u64) -> f64 {
    let params = RunParams {
        requests,
        seed: 17,
        slo_us: topo.zero_load_us() * 4.0,
        base_rate_per_us: topo.bottleneck_rate() * 0.7,
    };
    let (r, secs) = time_it(|| engine::run(topo, shape, &params, None).unwrap());
    assert_eq!(r.requests, requests);
    let events_per_sec = r.events as f64 / secs;
    println!(
        "{name:<22} {:>7.2}M events/s  ({} events, {:.2}s, p99 {:.1} µs)",
        events_per_sec / 1e6,
        r.events,
        secs,
        r.p99_us,
    );
    events_per_sec
}

fn main() {
    let requests = std::env::var("SLOFETCH_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000u64);
    println!("== cluster_micro: {requests} requests/scenario ==");
    let scenarios: [(&str, ResolvedTopology, TrafficShape); 4] = [
        ("chain3/poisson", chain(3), TrafficShape::Poisson { util: 1.0 }),
        (
            "chain3/burst",
            chain(3),
            TrafficShape::Burst { util: 0.7, mult: 1.8, period_us: 50_000.0, duty: 0.2 },
        ),
        ("fanout5/poisson", fanout(), TrafficShape::Poisson { util: 1.0 }),
        (
            "fanout5/diurnal",
            fanout(),
            TrafficShape::Diurnal { util: 0.8, amplitude: 0.3, period_us: 200_000.0 },
        ),
    ];
    let mut results: Vec<(&str, f64)> = Vec::new();
    for (name, topo, shape) in &scenarios {
        results.push((*name, bench(name, topo, shape, requests)));
    }
    // Machine-readable trajectory point for CI (events/sec per scenario).
    if let Ok(path) = std::env::var("SLOFETCH_BENCH_JSON") {
        let j = Json::obj(vec![
            ("bench", Json::str("cluster_micro")),
            ("requests", Json::num(requests as f64)),
            (
                "events_per_sec",
                Json::obj(results.iter().map(|(n, e)| (*n, Json::num(*e))).collect()),
            ),
        ]);
        std::fs::write(&path, j.pretty()).expect("write bench json");
        println!("(wrote {path})");
    }
}
