//! Cluster event-loop throughput bench: events/sec at 1M+ requests on
//! synthetic topologies (no trace simulation — pure queueing), tracking
//! the hot path across PRs. Scale with SLOFETCH_BENCH_REQUESTS
//! (default 1M requests per scenario) and SLOFETCH_BENCH_RUNS (default 3
//! timed runs per scenario, reported as median with a p10/p90 spread);
//! set SLOFETCH_BENCH_JSON=PATH to also emit a machine-readable report
//! including the engine's self-profiled peak event-heap depth (the CI
//! bench-smoke job uploads it as the `BENCH_cluster.json` artifact).

use slofetch::cluster::engine::{self, RunParams};
use slofetch::cluster::topology::{Candidate, ResolvedService, ResolvedTopology};
use slofetch::cluster::workload::TrafficShape;
use slofetch::util::json::Json;
use slofetch::util::percentile::Digest;
use slofetch::util::timer::time_it;

fn chain(n: usize) -> ResolvedTopology {
    let services = (0..n)
        .map(|i| ResolvedService {
            name: format!("s{i}"),
            replicas: 2,
            cv: 0.35,
            candidates: vec![Candidate {
                label: "static".into(),
                mean_us: 5.0,
                metadata_bytes: 0,
                table: None,
            }],
            children: if i + 1 < n { vec![(i + 1) as u32] } else { Vec::new() },
            indegree: u32::from(i > 0),
        })
        .collect();
    ResolvedTopology { services }
}

fn fanout() -> ResolvedTopology {
    let svc = |name: &str, mean: f64, replicas: u32, children: Vec<u32>, indegree: u32| {
        ResolvedService {
            name: name.into(),
            replicas,
            cv: 0.35,
            candidates: vec![Candidate {
                label: "static".into(),
                mean_us: mean,
                metadata_bytes: 0,
                table: None,
            }],
            children,
            indegree,
        }
    };
    ResolvedTopology {
        services: vec![
            svc("gateway", 4.0, 2, vec![1, 2, 3], 0),
            svc("search", 12.0, 3, vec![4], 1),
            svc("ads", 8.0, 2, vec![4], 1),
            svc("profile", 8.0, 2, vec![4], 1),
            svc("render", 5.0, 2, vec![], 3),
        ],
    }
}

/// Per-scenario summary across timed runs.
struct ScenarioResult {
    name: &'static str,
    events_per_sec: f64,
    p10: f64,
    p90: f64,
    peak_heap: u64,
}

/// Run one scenario `runs` times and summarize its events/sec (also printed).
fn bench(
    name: &'static str,
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    requests: u64,
    runs: usize,
) -> ScenarioResult {
    let params = RunParams {
        requests,
        seed: 17,
        slo_us: topo.zero_load_us() * 4.0,
        base_rate_per_us: topo.bottleneck_rate() * 0.7,
    };
    let mut d = Digest::new();
    let mut events = 0u64;
    let mut peak_heap = 0u64;
    let mut p99 = 0.0f64;
    for _ in 0..runs {
        let (r, secs) = time_it(|| engine::run(topo, shape, &params, None).unwrap());
        assert_eq!(r.requests, requests);
        d.add(r.events as f64 / secs);
        events = r.events;
        peak_heap = r.peak_heap;
        p99 = r.p99_us;
    }
    let out = ScenarioResult {
        name,
        events_per_sec: d.percentile(50.0),
        p10: d.percentile(10.0),
        p90: d.percentile(90.0),
        peak_heap,
    };
    println!(
        "{name:<22} {:>7.2}M events/s  [p10 {:.2}M, p90 {:.2}M]  ({} events, heap {}, p99 {:.1} µs)",
        out.events_per_sec / 1e6,
        out.p10 / 1e6,
        out.p90 / 1e6,
        events,
        peak_heap,
        p99,
    );
    out
}

fn main() {
    let requests = std::env::var("SLOFETCH_BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000u64);
    let runs = std::env::var("SLOFETCH_BENCH_RUNS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3usize)
        .max(1);
    println!("== cluster_micro: {requests} requests/scenario, {runs} runs ==");
    let scenarios: [(&str, ResolvedTopology, TrafficShape); 4] = [
        ("chain3/poisson", chain(3), TrafficShape::Poisson { util: 1.0 }),
        (
            "chain3/burst",
            chain(3),
            TrafficShape::Burst { util: 0.7, mult: 1.8, period_us: 50_000.0, duty: 0.2 },
        ),
        ("fanout5/poisson", fanout(), TrafficShape::Poisson { util: 1.0 }),
        (
            "fanout5/diurnal",
            fanout(),
            TrafficShape::Diurnal { util: 0.8, amplitude: 0.3, period_us: 200_000.0 },
        ),
    ];
    let mut results: Vec<ScenarioResult> = Vec::new();
    for (name, topo, shape) in &scenarios {
        results.push(bench(name, topo, shape, requests, runs));
    }
    // Machine-readable trajectory point for CI: median events/sec per
    // scenario (stable key), the p10/p90 spread, and the engine's
    // self-profiled peak heap depth.
    if let Ok(path) = std::env::var("SLOFETCH_BENCH_JSON") {
        let j = Json::obj(vec![
            ("bench", Json::str("cluster_micro")),
            ("requests", Json::num(requests as f64)),
            ("runs", Json::num(runs as f64)),
            (
                "events_per_sec",
                Json::obj(results.iter().map(|r| (r.name, Json::num(r.events_per_sec))).collect()),
            ),
            (
                "events_per_sec_p10",
                Json::obj(results.iter().map(|r| (r.name, Json::num(r.p10))).collect()),
            ),
            (
                "events_per_sec_p90",
                Json::obj(results.iter().map(|r| (r.name, Json::num(r.p90))).collect()),
            ),
            (
                "peak_heap",
                Json::obj(results.iter().map(|r| (r.name, Json::num(r.peak_heap as f64))).collect()),
            ),
        ]);
        std::fs::write(&path, j.pretty()).expect("write bench json");
        println!("(wrote {path})");
    }
}
