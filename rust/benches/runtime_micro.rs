//! Runtime/controller micro-benchmarks (§Perf L1/L2 targets):
//! native-mirror scoring throughput, PJRT batched score/train latency,
//! and controller decision cost. Requires `make artifacts` for the PJRT
//! section (skipped with a notice otherwise).

use slofetch::config::ControllerCfg;
use slofetch::ml::controller::OnlineController;
use slofetch::ml::features::DIM;
use slofetch::ml::logistic::Weights;
use slofetch::prefetch::Candidate;
use slofetch::runtime::PjrtEngine;
use slofetch::util::rng::Rng;
use slofetch::util::timer::bench;

fn main() {
    println!("== runtime_micro ==");
    let mut rng = Rng::new(5);
    let wts = Weights::default();

    // Native mirror: single-decision scoring (the simulator hot path).
    let feats: Vec<[f32; DIM]> = (0..4096)
        .map(|_| {
            let mut f = [0.0f32; DIM];
            for v in f.iter_mut() {
                *v = rng.f32();
            }
            f
        })
        .collect();
    let mut acc = 0.0f32;
    let r = bench("native score (single)", 2, 9, feats.len() as u64 * 100, || {
        for _ in 0..100 {
            for f in &feats {
                acc += wts.score(f);
            }
        }
    });
    println!("{}", r.report());
    std::hint::black_box(acc);

    // Controller decision end-to-end (features + bandit + budget).
    let mut ctrl = OnlineController::new(
        ControllerCfg {
            train_interval_cycles: u64::MAX,
            ..Default::default()
        },
        1,
    );
    let cand = Candidate {
        line: 0x40_0010,
        src: 0x40_0000,
        conf: 3,
        offset: 2,
        window_density: 0.75,
        short_loop: false,
    };
    let ops = 1_000_000u64;
    let mut issued = 0u64;
    let r = bench("controller decide()", 1, 7, ops, || {
        for i in 0..ops {
            if ctrl.decide(&cand, i * 3) {
                issued += 1;
            }
        }
    });
    println!("{}", r.report());
    std::hint::black_box(issued);

    // PJRT batched paths.
    match PjrtEngine::load_default() {
        Err(e) => println!("pjrt: skipped (artifacts missing: {e})"),
        Ok(engine) => {
            let x: Vec<f32> = (0..256 * DIM).map(|_| rng.f32()).collect();
            let y: Vec<f32> = (0..256).map(|_| f32::from(rng.chance(0.5))).collect();
            let r = bench("pjrt score  (B=256)", 2, 9, 256, || {
                engine.score(&wts.w, wts.b, &x).unwrap();
            });
            println!("{}  [{:.1} µs/call]", r.report(), r.ns_per_op * 256.0 / 1000.0);
            let r = bench("pjrt train  (B=256)", 2, 9, 256, || {
                engine.train_step(&wts.w, wts.b, &x, &y, 0.05).unwrap();
            });
            println!("{}  [{:.1} µs/call]", r.report(), r.ns_per_op * 256.0 / 1000.0);
            let values = [0.5f32; 64];
            let r = bench("pjrt bandit (64 slots)", 2, 9, 1, || {
                engine.bandit_update(&values, 7, 1.0, 0.1).unwrap();
            });
            println!("{}", r.report());
        }
    }
}
