//! Campaign-store micro-bench (DESIGN.md §6): append throughput,
//! cold-open resume latency, and `contains()` probe latency on
//! synthetic cells, for the tiered store against the legacy JSONL log.
//! The tentpole claim gated in CI is cold-open resume: a tiered store
//! reopens from segment footers (no log replay), so it must be >=10x
//! faster than parsing the same records back out of a JSONL file.
//! Scale with SLOFETCH_BENCH_STORE_CELLS (comma-separated cell counts,
//! default "10000,100000" — add 1000000 for the million-cell sweep) and
//! set SLOFETCH_BENCH_JSON=PATH to emit the machine-readable report the
//! CI bench-smoke job gates against `ci/BENCH_baseline.json`.

use slofetch::campaign::store::CellRecord;
use slofetch::campaign::{ResultStore, StoreFormat};
use slofetch::util::json::Json;
use slofetch::util::timer::time_it;
use std::path::PathBuf;

/// Synthetic cell: unique key per `i`, realistic field widths.
fn rec(i: u64, n: u64) -> CellRecord {
    CellRecord {
        key: format!("syn{}|pf{}|r{n}|s{i}|c1", i % 8, i % 6),
        app: format!("syn{}", i % 8),
        label: format!("pf{}", i % 6),
        records: n,
        trace_seed: i,
        sim_seed: i.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        ml: false,
        churn_scale: 1.0,
        ipc: 1.0 + (i % 97) as f64 / 100.0,
        speedup: Some(1.0 + (i % 13) as f64 / 50.0),
        mpki: 12.0,
        l1d_mpki: 3.0,
        accuracy: 0.8,
        coverage: 0.6,
        timeliness: 0.9,
        metadata_bytes: 25_200,
        pf_issued: 100 + i,
        pf_timely: 70,
        pf_late: 10,
        pf_useless: 20,
        pf_skipped: 0,
        instrs: 16_000,
        cycles: 9_000.0,
        controller: None,
        tail: None,
    }
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("slofetch_store_bench").join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Probe keys alternating present/absent, in a seeded shuffle-ish order.
fn probe(store: &ResultStore, n: u64, probes: u64) -> u64 {
    let mut hits = 0u64;
    for p in 0..probes {
        let i = (p.wrapping_mul(0x2545_F491_4F6C_DD1D)) % (2 * n);
        let key = if p % 2 == 0 {
            format!("syn{}|pf{}|r{n}|s{i}|c1", i % 8, i % 6) // maybe present
        } else {
            format!("syn{}|pfX|r{n}|s{i}|c1", i % 8) // never present
        };
        hits += u64::from(store.contains(&key));
    }
    hits
}

fn fmt_cells(n: u64) -> String {
    match n {
        n if n % 1_000_000 == 0 => format!("{}M", n / 1_000_000),
        n if n % 1_000 == 0 => format!("{}k", n / 1_000),
        n => n.to_string(),
    }
}

struct SizeResult {
    label: String,
    append_per_sec: f64,
    cold_open_tiered_per_sec: f64,
    probe_per_sec: f64,
    cold_open_speedup_vs_jsonl: f64,
}

fn bench_size(n: u64) -> SizeResult {
    let label = fmt_cells(n);
    let dir = fresh_dir(&label);
    let tiered_path = dir.join("bench.store");
    let jsonl_path = dir.join("bench.jsonl");

    // Append throughput: tiered (WAL write-through + threshold flushes).
    let mut tiered = ResultStore::open_format(&tiered_path, StoreFormat::Tiered).unwrap();
    let (_, t_append) = time_it(|| {
        for i in 0..n {
            tiered.push(rec(i, n)).unwrap();
        }
        tiered.flush().unwrap();
    });
    let segments = tiered.segment_count();
    drop(tiered);

    // The same records as a legacy JSONL log, for the cold-open contrast.
    let mut jsonl = ResultStore::open_format(&jsonl_path, StoreFormat::Jsonl).unwrap();
    for i in 0..n {
        jsonl.push(rec(i, n)).unwrap();
    }
    drop(jsonl);

    // Cold-open resume latency: tiered opens read segment footers only;
    // jsonl opens replay and re-parse every line.
    let (tiered, t_open_tiered) = time_it(|| ResultStore::open(&tiered_path).unwrap());
    assert_eq!(tiered.len() as u64, n);
    let (jsonl, t_open_jsonl) = time_it(|| ResultStore::open(&jsonl_path).unwrap());
    assert_eq!(jsonl.len() as u64, n);

    // Membership probes (the per-cell resume check): bloom + sparse
    // index + one block read per positive, against a 50% miss mix.
    let probes = n.clamp(1, 20_000);
    let (hits, t_probe) = time_it(|| probe(&tiered, n, probes));
    assert!(hits > 0, "probe mix found no stored keys");

    let out = SizeResult {
        label,
        append_per_sec: n as f64 / t_append.max(1e-9),
        cold_open_tiered_per_sec: n as f64 / t_open_tiered.max(1e-9),
        probe_per_sec: probes as f64 / t_probe.max(1e-9),
        cold_open_speedup_vs_jsonl: t_open_jsonl / t_open_tiered.max(1e-9),
    };
    println!(
        "{:<6} cells: append {:>8.0}/s  cold-open tiered {:.1}ms vs jsonl {:.1}ms \
         ({:.1}x, {segments} segments)  probes {:>8.0}/s ({hits} hits)",
        out.label,
        out.append_per_sec,
        t_open_tiered * 1e3,
        t_open_jsonl * 1e3,
        out.cold_open_speedup_vs_jsonl,
        out.probe_per_sec,
    );
    std::fs::remove_dir_all(&dir).ok();
    out
}

fn main() {
    let sizes: Vec<u64> = std::env::var("SLOFETCH_BENCH_STORE_CELLS")
        .unwrap_or_else(|_| "10000,100000".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&n| n > 0)
        .collect();
    println!("== store_micro: {sizes:?} synthetic cells ==");
    let results: Vec<SizeResult> = sizes.iter().map(|&n| bench_size(n)).collect();

    // Machine-readable trajectory point for CI, in the same shape as
    // cluster_micro: a per-metric `events_per_sec` map (floors gated by
    // ci/check_bench.py) plus the jsonl-contrast speedup map gated by
    // the baseline's `min_speedup_vs_jsonl`.
    if let Ok(path) = std::env::var("SLOFETCH_BENCH_JSON") {
        let per = |f: &dyn Fn(&SizeResult) -> f64, tag: &str| -> Vec<(String, Json)> {
            results.iter().map(|r| (format!("store/{tag}@{}", r.label), Json::num(f(r)))).collect()
        };
        let mut eps = per(&|r| r.append_per_sec, "append");
        eps.extend(per(&|r| r.cold_open_tiered_per_sec, "cold_open_tiered"));
        eps.extend(per(&|r| r.probe_per_sec, "probe"));
        let speedups: Vec<(String, Json)> = results
            .iter()
            .map(|r| {
                (format!("cold_open@{}", r.label), Json::num(r.cold_open_speedup_vs_jsonl))
            })
            .collect();
        let j = Json::obj(vec![
            ("bench", Json::str("store_micro")),
            (
                "cells",
                Json::Arr(results.iter().map(|r| Json::str(&r.label)).collect()),
            ),
            ("events_per_sec", Json::Obj(eps.into_iter().collect())),
            ("speedup_vs_jsonl", Json::Obj(speedups.into_iter().collect())),
        ]);
        std::fs::write(&path, j.pretty()).expect("write bench json");
        println!("(wrote {path})");
    }
}
