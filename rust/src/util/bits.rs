//! Bit-field helpers used by the compressed-entry encodings (paper Fig 4)
//! and the metadata cost model (§V).

/// Extract `len` bits of `x` starting at bit `lo` (LSB = bit 0).
#[inline]
pub const fn field(x: u64, lo: u32, len: u32) -> u64 {
    (x >> lo) & mask(len)
}

/// Set `len` bits of `x` at `lo` to `v` (v is masked to width).
#[inline]
pub const fn set_field(x: u64, lo: u32, len: u32, v: u64) -> u64 {
    let m = mask(len) << lo;
    (x & !m) | ((v & mask(len)) << lo)
}

/// `len`-bit all-ones mask (len <= 64).
#[inline]
pub const fn mask(len: u32) -> u64 {
    if len >= 64 {
        u64::MAX
    } else {
        (1u64 << len) - 1
    }
}

/// Does the signed delta between two line addresses fit in `bits` bits of
/// *low-order* addressing, i.e. do the lines share all high-order bits above
/// `bits`? This is the paper's "delta fits within 20 LSBs" predicate
/// (§III-A, Fig 7): high bits are inherited from the source.
#[inline]
pub fn shares_high_bits(a: u64, b: u64, bits: u32) -> bool {
    (a >> bits) == (b >> bits)
}

/// Bytes needed for `n` bits, rounded up.
#[inline]
pub const fn bits_to_bytes(n: u64) -> u64 {
    n.div_ceil(8)
}

/// Saturating 2-bit counter ops (confidence counters in every prefetcher).
pub mod conf2 {
    pub const MAX: u8 = 3;

    #[inline]
    pub fn inc(c: u8) -> u8 {
        if c >= MAX {
            MAX
        } else {
            c + 1
        }
    }

    #[inline]
    pub fn dec(c: u8) -> u8 {
        c.saturating_sub(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_widths() {
        assert_eq!(mask(0), 0);
        assert_eq!(mask(1), 1);
        assert_eq!(mask(20), 0xF_FFFF);
        assert_eq!(mask(64), u64::MAX);
    }

    #[test]
    fn field_roundtrip() {
        let x = set_field(0, 4, 20, 0xABCDE);
        assert_eq!(field(x, 4, 20), 0xABCDE);
        // Adjacent fields untouched.
        let y = set_field(x, 24, 8, 0xFF);
        assert_eq!(field(y, 4, 20), 0xABCDE);
        assert_eq!(field(y, 24, 8), 0xFF);
    }

    #[test]
    fn set_field_masks_overwide_values() {
        let x = set_field(0, 0, 4, 0xFFFF);
        assert_eq!(x, 0xF);
    }

    #[test]
    fn high_bit_sharing() {
        assert!(shares_high_bits(0x10_00001, 0x10_FFFFF, 20));
        assert!(!shares_high_bits(0x10_00001, 0x11_00001, 20));
        assert!(shares_high_bits(5, 5, 0));
    }

    #[test]
    fn conf2_saturates() {
        use conf2::*;
        assert_eq!(inc(MAX), MAX);
        assert_eq!(inc(0), 1);
        assert_eq!(dec(0), 0);
        assert_eq!(dec(2), 1);
    }

    #[test]
    fn bytes_rounding() {
        assert_eq!(bits_to_bytes(0), 0);
        assert_eq!(bits_to_bytes(1), 1);
        assert_eq!(bits_to_bytes(8), 1);
        assert_eq!(bits_to_bytes(36 * 512), 2304);
    }
}
