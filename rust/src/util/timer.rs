//! Wall-clock measurement helpers for the custom bench harness
//! (no criterion offline). Median-of-runs with warmup, reporting
//! ns/op and ops/s.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub ns_per_op: f64,
    pub ops_per_s: f64,
    pub runs: usize,
    pub ops_per_run: u64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/op {:>14.0} ops/s  ({} runs x {} ops)",
            self.name, self.ns_per_op, self.ops_per_s, self.runs, self.ops_per_run
        )
    }
}

/// Run `f` (which performs `ops` operations per call) `runs` times after
/// `warmup` calls; report the median run.
pub fn bench(name: &str, warmup: usize, runs: usize, ops: u64, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let ns_per_op = median / ops as f64;
    BenchResult {
        name: name.to_string(),
        ns_per_op,
        ops_per_s: 1e9 / ns_per_op,
        runs,
        ops_per_run: ops,
    }
}

/// Measure one closure once, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut acc = 0u64;
        let r = bench("noop-loop", 1, 5, 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(r.ns_per_op > 0.0 && r.ns_per_op < 1e6);
        assert!(r.ops_per_s > 0.0);
        assert!(r.report().contains("noop-loop"));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
