//! Wall-clock measurement helpers for the custom bench harness
//! (no criterion offline). Median-of-runs with warmup, reporting
//! ns/op and ops/s plus a p10/p90 spread across runs.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub ns_per_op: f64,
    pub ops_per_s: f64,
    /// 10th percentile of per-run ns/op (fastest tail of the spread).
    pub p10_ns_per_op: f64,
    /// 90th percentile of per-run ns/op (slowest tail of the spread).
    pub p90_ns_per_op: f64,
    pub runs: usize,
    pub ops_per_run: u64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/op [p10 {:.1}, p90 {:.1}] {:>14.0} ops/s  ({} runs x {} ops)",
            self.name,
            self.ns_per_op,
            self.p10_ns_per_op,
            self.p90_ns_per_op,
            self.ops_per_s,
            self.runs,
            self.ops_per_run
        )
    }
}

/// Linear-interpolation percentile of an ascending-sorted slice.
/// `p` is in [0, 100]; the slice must be non-empty.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] + (sorted[hi] - sorted[lo]) * frac
    }
}

/// Run `f` (which performs `ops` operations per call) `runs` times after
/// `warmup` calls; report the median run with a p10/p90 spread.
pub fn bench(name: &str, warmup: usize, runs: usize, ops: u64, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    // total_cmp: Instant deltas are always finite, but never let a stray
    // NaN panic the harness mid-campaign.
    times.sort_unstable_by(f64::total_cmp);
    let ops_f = ops as f64;
    let ns_per_op = percentile(&times, 50.0) / ops_f;
    BenchResult {
        name: name.to_string(),
        ns_per_op,
        ops_per_s: 1e9 / ns_per_op,
        p10_ns_per_op: percentile(&times, 10.0) / ops_f,
        p90_ns_per_op: percentile(&times, 90.0) / ops_f,
        runs,
        ops_per_run: ops,
    }
}

/// Measure one closure once, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut acc = 0u64;
        let r = bench("noop-loop", 1, 5, 1000, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(r.ns_per_op > 0.0 && r.ns_per_op < 1e6);
        assert!(r.ops_per_s > 0.0);
        assert!(r.p10_ns_per_op <= r.ns_per_op && r.ns_per_op <= r.p90_ns_per_op);
        assert!(r.report().contains("noop-loop"));
    }

    #[test]
    fn percentile_interpolates_even_length() {
        // The old median took element len/2 (the upper of the two middle
        // values); the interpolated median of [1,2,3,4] is 2.5.
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 50.0), 2.5);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        let odd = [1.0, 2.0, 9.0];
        assert_eq!(percentile(&odd, 50.0), 2.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
