//! Minimal JSON parser/serializer (the offline environment has no serde).
//!
//! Supports the full JSON grammar minus exotic numeric edge cases we never
//! emit (numbers parse as f64; integers round-trip exactly up to 2^53).
//! Used for the AOT `manifest.json`, experiment configs, and results files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors (ergonomics for config/manifest reading) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["modules", "score", "hlo_bytes"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- construction helpers --

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: keep simple, replace lone
                            // surrogates (we never emit them).
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(j.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s",null,true],"m":{"x":-7}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nulll").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""A\t\"π""#).unwrap();
        assert_eq!(j.as_str(), Some("A\t\"π"));
        let round = Json::Str("π — тест".into());
        assert_eq!(Json::parse(&round.dump()).unwrap(), round);
    }

    #[test]
    fn integers_exact() {
        let j = Json::parse("9007199254740992").unwrap();
        assert_eq!(j.as_u64(), Some(9007199254740992));
        assert_eq!(j.dump(), "9007199254740992");
    }

    #[test]
    fn real_manifest_shape() {
        let m = Json::obj(vec![
            ("batch", Json::num(256.0)),
            (
                "modules",
                Json::obj(vec![(
                    "score",
                    Json::obj(vec![("file", Json::str("score.hlo.txt"))]),
                )]),
            ),
        ]);
        let text = m.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.path(&["modules", "score", "file"]).unwrap().as_str(),
            Some("score.hlo.txt")
        );
        assert_eq!(back.get("batch").unwrap().as_u64(), Some(256));
    }
}
