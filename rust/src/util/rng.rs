//! Deterministic PRNGs for simulation and testing.
//!
//! The offline build environment has no `rand` crate, so we implement the
//! two generators the project needs: SplitMix64 (seeding / stateless
//! hashing) and xoshiro256++ (the workhorse stream generator). Both are
//! public-domain algorithms (Blackman & Vigna). All simulation randomness
//! flows through [`Rng`] with explicit seeds, so every experiment in
//! EXPERIMENTS.md is bit-reproducible.

/// SplitMix64 step: the recommended seeder for xoshiro, also a good
/// stateless integer mixer (used for tag hashing in the prefetchers).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One-shot mix of a 64-bit value (stateless hash).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction; bias is
        // negligible for simulation n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed value with the given mean (Poisson
    /// inter-arrival times in the RPC simulator).
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (used for service-time jitter).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Geometric-ish run length: numbers of trials until failure with
    /// continue-probability `p`, capped at `max`.
    pub fn run_len(&mut self, p: f64, max: u32) -> u32 {
        let mut n = 1;
        while n < max && self.chance(p) {
            n += 1;
        }
        n
    }

    /// Zipf-like rank selection over `n` items with exponent `s` using
    /// rejection-free inverse-CDF approximation (good enough for workload
    /// popularity skew; exactness is irrelevant to the prefetcher).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        // Inverse-CDF of the continuous analogue: x = u^(-1/(s-1)) family.
        // For s near 1 fall back to exact discrete sampling on small n.
        let u = self.f64().max(1e-12);
        let exp = 1.0 / (1.0 - s.min(0.999_999));
        let x = (1.0 - u * (1.0 - (n as f64).powf(1.0 - s.min(0.999_999)))).powf(exp);
        (x as usize - 1).min(n - 1)
    }

    /// Pick an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mean_of_uniform_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exp(5.0)).sum();
        assert!((sum / n as f64 - 5.0).abs() < 0.15);
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(17);
        let mut counts = [0u32; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 0.9)] += 1;
        }
        assert!(counts[0] > counts[50].max(1) * 4);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..20_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(29);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
