//! Latency-percentile estimation for the RPC/tail-latency layer.
//!
//! Two tools: an exact [`Digest`] (sorted sample buffer — fine for the
//! request counts we simulate) and a streaming [`P2Quantile`] estimator
//! (Jain & Chlamtac's P² algorithm) used inside the coordinator where we
//! cannot afford to retain samples (per-cell online P95 regression
//! detection during canary rollout).

/// Exact percentile digest over retained samples.
#[derive(Clone, Debug, Default)]
pub struct Digest {
    samples: Vec<f64>,
    sorted: bool,
}

impl Digest {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-sized digest: the cluster event loop reserves its sample
    /// buffer up front so the completion hot path never reallocates.
    pub fn with_capacity(n: usize) -> Self {
        Digest { samples: Vec::with_capacity(n), sorted: false }
    }

    /// Drop all samples, keeping the allocation (windowed SLO tracking).
    pub fn clear(&mut self) {
        self.samples.clear();
        self.sorted = false;
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Percentile in [0, 100] with linear interpolation. An empty digest
    /// returns `f64::NAN`: an empty SLO window must never read as a
    /// perfect 0 µs tail, and NaN fails every threshold comparison, so
    /// forgetting to check emptiness can only make a caller *less*
    /// compliant, never more.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let n = self.samples.len();
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.samples[lo]
        } else {
            let frac = rank - lo as f64;
            self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
        }
    }

    /// Mean of the samples (`f64::NAN` when empty — see
    /// [`Digest::percentile`]).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            f64::NAN
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::MIN, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }
}

/// Streaming P² single-quantile estimator: O(1) memory, no samples kept.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    p: f64,
    // Marker heights and positions per Jain & Chlamtac 1985.
    q: [f64; 5],
    n: [f64; 5],
    np: [f64; 5],
    dn: [f64; 5],
    count: usize,
    init: Vec<f64>,
}

impl P2Quantile {
    /// `p` in (0, 1), e.g. 0.95 for P95.
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p < 1.0);
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [0.0; 5],
            np: [0.0; 5],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            init: Vec::with_capacity(5),
        }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if self.init.len() < 5 {
            self.init.push(x);
            if self.init.len() == 5 {
                self.init.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
                for i in 0..5 {
                    self.q[i] = self.init[i];
                    self.n[i] = (i + 1) as f64;
                }
                self.np = [
                    1.0,
                    1.0 + 2.0 * self.p,
                    1.0 + 4.0 * self.p,
                    3.0 + 2.0 * self.p,
                    5.0,
                ];
            }
            return;
        }
        // Find cell k containing x; clamp extremes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if x >= self.q[i] && x < self.q[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Adjust interior markers with parabolic interpolation.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let ds = d.signum();
                let qp = self.parabolic(i, ds);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, ds)
                };
                self.n[i] += ds;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let q = &self.q;
        let n = &self.n;
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = (i as f64 + d) as usize;
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    pub fn value(&self) -> f64 {
        if self.init.len() < 5 {
            if self.init.is_empty() {
                return 0.0;
            }
            let mut v = self.init.clone();
            v.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            let idx = ((v.len() - 1) as f64 * self.p).round() as usize;
            return v[idx];
        }
        self.q[2]
    }

    pub fn count(&self) -> usize {
        self.count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn digest_exact_percentiles() {
        let mut d = Digest::new();
        for i in 1..=100 {
            d.add(i as f64);
        }
        assert_eq!(d.percentile(0.0), 1.0);
        assert_eq!(d.percentile(100.0), 100.0);
        assert!((d.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((d.percentile(95.0) - 95.05).abs() < 1e-9);
        assert!((d.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn digest_clear_keeps_capacity_and_resets_stats() {
        let mut d = Digest::with_capacity(64);
        for i in 0..50 {
            d.add(i as f64);
        }
        assert_eq!(d.len(), 50);
        d.clear();
        assert!(d.is_empty());
        assert!(d.percentile(99.0).is_nan(), "cleared digest must not read as 0 µs");
        d.add(3.0);
        assert_eq!(d.percentile(50.0), 3.0);
    }

    #[test]
    fn digest_empty_is_nan_not_zero() {
        // Regression: an empty SLO window used to report a perfect p99
        // of 0 µs and a 0 µs mean — indistinguishable from an actually
        // instant window. NaN fails every threshold comparison instead.
        let mut d = Digest::new();
        assert!(d.percentile(95.0).is_nan());
        assert!(d.mean().is_nan());
        // NaN is incomparable: no SLO threshold can read it as compliant.
        assert_eq!(d.percentile(99.0).partial_cmp(&100.0), None);
    }

    #[test]
    fn p2_tracks_uniform_p95() {
        let mut est = P2Quantile::new(0.95);
        let mut r = Rng::new(5);
        for _ in 0..200_000 {
            est.add(r.f64() * 100.0);
        }
        assert!((est.value() - 95.0).abs() < 1.0, "got {}", est.value());
    }

    #[test]
    fn p2_tracks_exponential_p99() {
        let mut est = P2Quantile::new(0.99);
        let mut r = Rng::new(6);
        for _ in 0..300_000 {
            est.add(r.exp(10.0));
        }
        // True P99 of Exp(mean 10) = -10 ln(0.01) ≈ 46.05.
        assert!((est.value() - 46.05).abs() < 3.0, "got {}", est.value());
    }

    #[test]
    fn p2_small_counts_fall_back_to_exact() {
        let mut est = P2Quantile::new(0.5);
        for x in [3.0, 1.0, 2.0] {
            est.add(x);
        }
        assert_eq!(est.value(), 2.0);
        assert_eq!(est.count(), 3);
    }

    #[test]
    fn p2_matches_digest_on_normal_data() {
        let mut est = P2Quantile::new(0.95);
        let mut d = Digest::new();
        let mut r = Rng::new(9);
        for _ in 0..100_000 {
            let x = 50.0 + 10.0 * r.normal();
            est.add(x);
            d.add(x);
        }
        let exact = d.percentile(95.0);
        assert!((est.value() - exact).abs() / exact < 0.02);
    }
}
