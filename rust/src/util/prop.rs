//! Tiny property-testing harness (the offline environment has no proptest).
//!
//! Design: generators are closures `Fn(&mut Rng, usize) -> T` where the
//! second argument is a *size budget* that grows over the run, so the first
//! failing case is usually near-minimal (growth replaces shrinking). On
//! failure the harness panics with the seed + case index, which reproduces
//! the exact input deterministically.
//!
//! ```
//! use slofetch::util::prop::{check, u64_in};
//! check("halving never grows", 200, u64_in(0, 1000), |&x| x / 2 <= x);
//! ```

use crate::util::rng::Rng;

/// Run `cases` property checks. `gen` makes an input from (rng, size);
/// `prop` returns true when the property holds. Panics with reproduction
/// info on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    // Fixed base seed: failures reproduce across runs; vary inputs by case.
    let base_seed = 0x510F_E7C4u64;
    for case in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add(case as u64));
        // Size budget ramps from 1 to 100 over the first half of the run.
        let size = 1 + (case * 2).min(100);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {base_seed}+{case}, size {size}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property can also assert internally (returns ()).
pub fn check_unit<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T),
) {
    check(name, cases, &mut gen, |input| {
        prop(input);
        true
    });
}

// ---- stock generators ----

/// Uniform u64 in [lo, hi].
pub fn u64_in(lo: u64, hi: u64) -> impl FnMut(&mut Rng, usize) -> u64 {
    move |r, _| r.range(lo, hi + 1)
}

/// Size-scaled vector of u64 line addresses (clustered: mimics code layout
/// by mixing short sequential runs with jumps — useful for prefetcher
/// properties).
pub fn addr_stream() -> impl FnMut(&mut Rng, usize) -> Vec<u64> {
    move |r, size| {
        let mut out = Vec::with_capacity(size * 4);
        let mut pc = r.range(0x1000, 0x10_0000);
        for _ in 0..size {
            let run = r.run_len(0.7, 12);
            for _ in 0..run {
                out.push(pc);
                pc += 1;
            }
            if r.chance(0.3) {
                pc = r.range(0x1000, 0x10_0000);
            } else {
                pc = pc.wrapping_add(r.range(0, 64)).saturating_sub(r.range(0, 64));
            }
        }
        out
    }
}

/// Vector of f32 in [-bound, bound], size-scaled length.
pub fn f32_vec(bound: f32) -> impl FnMut(&mut Rng, usize) -> Vec<f32> {
    move |r, size| {
        (0..size.max(1))
            .map(|_| (r.f32() * 2.0 - 1.0) * bound)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("reverse twice is identity", 100, addr_stream(), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn fails_loudly() {
        check("always false", 10, u64_in(0, 5), |_| false);
    }

    #[test]
    fn sizes_grow() {
        let mut max_len = 0;
        check_unit("observe sizes", 120, addr_stream(), |v| {
            max_len = max_len.max(v.len());
        });
        assert!(max_len > 50, "size budget never grew: {max_len}");
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut first: Vec<Vec<u64>> = Vec::new();
        check_unit("collect A", 20, addr_stream(), |v| first.push(v.clone()));
        let mut second: Vec<Vec<u64>> = Vec::new();
        check_unit("collect B", 20, addr_stream(), |v| second.push(v.clone()));
        assert_eq!(first, second);
    }
}
