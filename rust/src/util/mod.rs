//! Foundation substrates built in-repo for the offline environment:
//! deterministic RNG, bit-field helpers, JSON, streaming percentiles,
//! a mini property-testing harness, and a wall-clock bench timer.

pub mod bits;
pub mod hashfx;
pub mod json;
pub mod percentile;
pub mod prop;
pub mod rng;
pub mod timer;
