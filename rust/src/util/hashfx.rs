//! Fast non-cryptographic hasher for the simulator's hot maps (the
//! default SipHash RandomState cost ~18% of engine time in the §Perf
//! profile). Multiply-xorshift over 8-byte chunks (fxhash/splitmix
//! family); keys here are line addresses under our control, so HashDoS
//! resistance is irrelevant.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const K: u64 = 0x9E37_79B9_7F4A_7C15;

#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

impl FxHasher {
    #[inline]
    fn mix(&mut self, v: u64) {
        let mut z = (self.state ^ v).wrapping_mul(K);
        z ^= z >> 32;
        self.state = z;
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.mix(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.mix(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.mix(v as u64);
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.mix(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.mix(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basic_ops() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i * 64, i as u32);
        }
        assert_eq!(m.len(), 10_000);
        for i in 0..10_000u64 {
            assert_eq!(m.get(&(i * 64)), Some(&(i as u32)));
        }
        assert!(m.remove(&(42 * 64)).is_some());
        assert!(!m.contains_key(&(42 * 64)));
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        // Line addresses differing in low bits must spread well.
        use std::hash::BuildHasher;
        let bh = FxBuildHasher::default();
        let mut buckets = [0u32; 64];
        for i in 0..64_000u64 {
            let h = bh.hash_one(i);
            buckets[(h % 64) as usize] += 1;
        }
        let min = *buckets.iter().min().unwrap();
        let max = *buckets.iter().max().unwrap();
        assert!(min > 500 && max < 1500, "poor spread: {min}..{max}");
    }

    #[test]
    fn stable_within_process() {
        use std::hash::BuildHasher;
        let a = FxBuildHasher::default().hash_one(12345u64);
        let b = FxBuildHasher::default().hash_one(12345u64);
        assert_eq!(a, b);
    }
}
