//! Instruction prefetchers: the paper's CEIP/CHEIP plus every baseline the
//! evaluation compares against (next-line, EIP, perfect-oracle — the last
//! is engine-integrated because it needs trace lookahead).
//!
//! All prefetchers speak the [`Prefetcher`] trait; the engine feeds demand
//! fetches/misses in and receives [`Candidate`]s out, optionally gated by
//! the ML controller (`ml::controller`).

pub mod budget;
pub mod centry;
pub mod ceip;
pub mod cheip;
pub mod eip;
pub mod history;
pub mod next_line;
pub mod vtable;

use crate::config::{PrefetcherKind, SimConfig};

/// A prefetch candidate produced by a prefetcher, carrying the context
/// features the ML controller scores (paper §IV-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Candidate {
    /// Line to prefetch.
    pub line: u64,
    /// Trigger (source) line.
    pub src: u64,
    /// Confidence 0..=3 of this destination.
    pub conf: u8,
    /// Offset within the window (0 when not window-based).
    pub offset: u8,
    /// Fraction of window offsets marked (0 when not window-based).
    pub window_density: f32,
    /// Source was a short-loop trigger (repeated recent fetch).
    pub short_loop: bool,
}

/// What ultimately happened to an issued prefetch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Demanded after fill completed.
    Timely,
    /// Demanded while still in flight.
    Late,
    /// Evicted before any demand.
    Useless,
}

/// Feedback routed from the engine back to the prefetcher.
#[derive(Clone, Copy, Debug)]
pub struct Feedback {
    pub src: u64,
    pub line: u64,
    pub outcome: Outcome,
}

/// Instrumentation counters behind Figs 7, 8, and 10.
#[derive(Clone, Copy, Debug, Default)]
pub struct PairStats {
    /// Entangle attempts (source, destination pairs observed).
    pub pairs_total: u64,
    /// Pairs whose delta fits within 20 low-order bits (Fig 7).
    pub pairs_fit20: u64,
    /// Destinations offered to a window entry.
    pub dests_total: u64,
    /// Destinations representable in the current window (Fig 8).
    pub dests_in_window: u64,
    /// Destinations dropped (window slide loss + >20-bit) (Fig 10).
    pub dests_dropped: u64,
}

impl PairStats {
    pub fn fit20_frac(&self) -> f64 {
        if self.pairs_total == 0 {
            0.0
        } else {
            self.pairs_fit20 as f64 / self.pairs_total as f64
        }
    }

    pub fn window_frac(&self) -> f64 {
        if self.dests_total == 0 {
            0.0
        } else {
            self.dests_in_window as f64 / self.dests_total as f64
        }
    }

    pub fn uncovered_frac(&self) -> f64 {
        if self.dests_total == 0 {
            0.0
        } else {
            self.dests_dropped as f64 / self.dests_total as f64
        }
    }
}

/// The prefetcher interface driven by `sim::engine`.
pub trait Prefetcher {
    fn name(&self) -> String;

    /// Called on every demand instruction fetch (hit or miss); candidates
    /// are appended to `out`.
    fn on_fetch(&mut self, line: u64, cycle: u64, out: &mut Vec<Candidate>);

    /// Called when a demand miss is issued (history-buffer push).
    fn on_demand_miss(&mut self, line: u64, cycle: u64);

    /// Called when a demand miss resolves; `fetch_cycle` is when the fetch
    /// stalled, `latency` what it cost — the entangling moment (§II-B).
    fn on_miss_resolved(&mut self, line: u64, fetch_cycle: u64, latency: u64);

    /// Outcome feedback for an issued prefetch.
    fn feedback(&mut self, fb: &Feedback);

    /// L1-I fill/evict hooks (CHEIP metadata migration, §III-B).
    fn on_l1i_fill(&mut self, _line: u64, _cycle: u64) {}
    fn on_l1i_evict(&mut self, _line: u64) {}

    /// Anomalous-miss-burst guardrail (§VII: "confidence decay and rapid
    /// eviction on anomalous miss bursts"): decay learned confidence so a
    /// rollout/phase flip cannot keep steering stale prefetches.
    fn on_anomaly(&mut self) {}

    /// On-chip metadata cost in bytes (Fig 13 / §V).
    fn metadata_bytes(&self) -> u64;

    /// Fig 7/8/10 instrumentation.
    fn pair_stats(&self) -> PairStats {
        PairStats::default()
    }
}

/// A no-op prefetcher (the NextLineOnly baseline: NL lives in the engine).
pub struct Null;

impl Prefetcher for Null {
    fn name(&self) -> String {
        "null".into()
    }
    fn on_fetch(&mut self, _: u64, _: u64, _: &mut Vec<Candidate>) {}
    fn on_demand_miss(&mut self, _: u64, _: u64) {}
    fn on_miss_resolved(&mut self, _: u64, _: u64, _: u64) {}
    fn feedback(&mut self, _: &Feedback) {}
    fn metadata_bytes(&self) -> u64 {
        0
    }
}

/// Build the configured prefetcher. `Perfect` also returns `Null` — the
/// engine implements the oracle natively via trace lookahead.
pub fn build(cfg: &SimConfig) -> Box<dyn Prefetcher> {
    match &cfg.prefetcher {
        PrefetcherKind::NextLineOnly | PrefetcherKind::Perfect => Box::new(Null),
        PrefetcherKind::Eip { entries } => {
            Box::new(eip::Eip::new(*entries, cfg.conf_threshold))
        }
        PrefetcherKind::Ceip { entries, window, whole_window } => Box::new(ceip::Ceip::new(
            *entries,
            *window,
            *whole_window,
            cfg.conf_threshold,
        )),
        PrefetcherKind::Cheip { vt_entries, window, whole_window } => {
            Box::new(cheip::Cheip::new(
                *vt_entries,
                *window,
                *whole_window,
                cfg.conf_threshold,
                cfg.hierarchy.l1i.lines(),
                cfg.hierarchy.l2.latency,
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_stats_fractions() {
        let ps = PairStats {
            pairs_total: 100,
            pairs_fit20: 90,
            dests_total: 80,
            dests_in_window: 60,
            dests_dropped: 20,
        };
        assert!((ps.fit20_frac() - 0.9).abs() < 1e-12);
        assert!((ps.window_frac() - 0.75).abs() < 1e-12);
        assert!((ps.uncovered_frac() - 0.25).abs() < 1e-12);
        assert_eq!(PairStats::default().fit20_frac(), 0.0);
    }

    #[test]
    fn factory_builds_each_kind() {
        let mut cfg = SimConfig::default();
        for (kind, name) in [
            (PrefetcherKind::NextLineOnly, "null"),
            (PrefetcherKind::Eip { entries: 64 }, "eip64"),
            (
                PrefetcherKind::Ceip { entries: 64, window: 8, whole_window: true },
                "ceip64",
            ),
            (
                PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
                "cheip2048",
            ),
            (PrefetcherKind::Perfect, "null"),
        ] {
            cfg.prefetcher = kind;
            let p = build(&cfg);
            assert!(
                p.name().starts_with(name),
                "{} vs {}",
                p.name(),
                name
            );
        }
    }
}
