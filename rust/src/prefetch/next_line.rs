//! Next-line instruction prefetcher. Per the paper's methodology (§X-B)
//! "a next line prefetcher remains enabled for all variants" — the engine
//! embeds one unconditionally; this standalone impl exists for unit tests
//! and the NL-only baseline ablation.

use super::{Candidate, Feedback, Prefetcher};

pub struct NextLine {
    /// How many sequential lines to issue per fetch (degree).
    pub degree: u8,
    last_line: u64,
}

impl NextLine {
    pub fn new(degree: u8) -> Self {
        NextLine {
            degree,
            last_line: u64::MAX,
        }
    }
}

impl Prefetcher for NextLine {
    fn name(&self) -> String {
        format!("nl{}", self.degree)
    }

    fn on_fetch(&mut self, line: u64, _cycle: u64, out: &mut Vec<Candidate>) {
        // Suppress re-issue while streaming through the same line.
        if line == self.last_line {
            return;
        }
        self.last_line = line;
        for d in 1..=self.degree as u64 {
            out.push(Candidate {
                line: line + d,
                src: line,
                conf: 3,
                offset: d as u8,
                window_density: 0.0,
                short_loop: false,
            });
        }
    }

    fn on_demand_miss(&mut self, _: u64, _: u64) {}
    fn on_miss_resolved(&mut self, _: u64, _: u64, _: u64) {}
    fn feedback(&mut self, _: &Feedback) {}

    fn metadata_bytes(&self) -> u64 {
        8 // one line register
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_next_lines() {
        let mut nl = NextLine::new(2);
        let mut out = Vec::new();
        nl.on_fetch(100, 0, &mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].line, 101);
        assert_eq!(out[1].line, 102);
        assert_eq!(out[0].src, 100);
    }

    #[test]
    fn suppresses_duplicate_trigger() {
        let mut nl = NextLine::new(1);
        let mut out = Vec::new();
        nl.on_fetch(100, 0, &mut out);
        nl.on_fetch(100, 1, &mut out);
        assert_eq!(out.len(), 1);
        nl.on_fetch(101, 2, &mut out);
        assert_eq!(out.len(), 2);
    }
}
