//! EIP history buffer (paper §V): a 64-entry FIFO of recent demand misses
//! used to pick the *entangling source* for a resolved miss — the youngest
//! source old enough that a prefetch triggered by it would have arrived on
//! time (§II-B, Fig 3).
//!
//! The hardware entry is a 58-bit tag + 20-bit timestamp (624 B total);
//! the simulator stores full values and charges the paper's bit budget in
//! [`super::budget`].

use std::collections::VecDeque;

#[derive(Clone, Copy, Debug)]
pub struct HistEntry {
    pub line: u64,
    /// Cycle at which the miss was issued.
    pub ts: u64,
}

#[derive(Clone, Debug)]
pub struct HistoryBuffer {
    buf: VecDeque<HistEntry>,
    cap: usize,
}

impl HistoryBuffer {
    pub fn new(cap: usize) -> Self {
        HistoryBuffer {
            buf: VecDeque::with_capacity(cap),
            cap,
        }
    }

    /// Paper configuration: 64 entries.
    pub fn paper() -> Self {
        Self::new(64)
    }

    pub fn push(&mut self, line: u64, ts: u64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(HistEntry { line, ts });
    }

    /// Find the entangling source for a miss of `dst` that stalled at
    /// `fetch_cycle` and cost `latency`: the *youngest* entry whose
    /// timestamp satisfies `ts + latency <= fetch_cycle` (a prefetch
    /// issued then would have completed in time). Falls back to the oldest
    /// entry when none is old enough; never returns `dst` itself.
    pub fn find_source(&self, dst: u64, fetch_cycle: u64, latency: u64) -> Option<HistEntry> {
        let deadline = fetch_cycle.saturating_sub(latency);
        let mut fallback: Option<HistEntry> = None;
        for e in self.buf.iter().rev() {
            if e.line == dst {
                continue;
            }
            if e.ts <= deadline {
                return Some(*e);
            }
            fallback = Some(*e); // oldest-so-far that isn't dst
        }
        fallback
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Paper bit budget: entries * (58-bit tag + 20-bit timestamp).
    pub fn metadata_bytes(&self) -> u64 {
        crate::util::bits::bits_to_bytes(self.cap as u64 * (58 + 20))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_budget_is_624_bytes() {
        assert_eq!(HistoryBuffer::paper().metadata_bytes(), 624);
    }

    #[test]
    fn fifo_capacity() {
        let mut h = HistoryBuffer::new(3);
        for i in 0..5 {
            h.push(i, i * 10);
        }
        assert_eq!(h.len(), 3);
        // Oldest remaining is line 2.
        let src = h.find_source(99, 1000, 10).unwrap();
        assert_eq!(src.line, 4, "youngest satisfying entry wins");
    }

    #[test]
    fn picks_youngest_timely_source() {
        let mut h = HistoryBuffer::new(8);
        h.push(1, 100);
        h.push(2, 200);
        h.push(3, 290);
        // Miss at 300 with latency 50: deadline 250. Entries 1 (100) and
        // 2 (200) qualify; youngest is 2.
        let src = h.find_source(9, 300, 50).unwrap();
        assert_eq!(src.line, 2);
    }

    #[test]
    fn falls_back_to_oldest_when_none_timely() {
        let mut h = HistoryBuffer::new(8);
        h.push(1, 295);
        h.push(2, 298);
        let src = h.find_source(9, 300, 50).unwrap();
        assert_eq!(src.line, 1);
    }

    #[test]
    fn never_entangles_self() {
        let mut h = HistoryBuffer::new(8);
        h.push(7, 10);
        assert!(h.find_source(7, 300, 50).is_none());
        h.push(8, 20);
        assert_eq!(h.find_source(7, 300, 50).unwrap().line, 8);
    }

    #[test]
    fn empty_returns_none() {
        let h = HistoryBuffer::new(8);
        assert!(h.find_source(1, 100, 10).is_none());
    }
}
