//! CHEIP: CEIP + Hierarchical Metadata Storage (paper §III-B, Fig 5).
//!
//! One compressed entry is *attached* to every L1-I line (512 × 36 b =
//! 2304 B); the bulk entangle table is virtualized into L2/L3 (the
//! [`VTable`]). Metadata migrates with the cache line: on L1 fill the
//! entry is fetched from the virtual table (paying L2-class latency —
//! modeled as delayed availability), on L1 evict it is written back.
//! Entries for resident sources are therefore served at L1 latency, and
//! lower-yield entries persist until source eviction (§X-C).

use super::centry::{CEntry, Mark};
use super::history::HistoryBuffer;
use super::vtable::VTable;
use super::{Candidate, Feedback, Outcome, PairStats, Prefetcher};
use crate::util::bits;
use crate::util::hashfx::FxHashMap;

struct Attached {
    centry: CEntry,
    /// Cycle at which the migrated metadata becomes usable (the virtual-
    /// table fetch latency, §III-B timeliness cost).
    available_at: u64,
}

pub struct Cheip {
    /// L1-attached entries: one per resident L1-I line (bounded by the
    /// engine's fill/evict callbacks to l1_lines entries).
    l1: FxHashMap<u64, Attached>,
    l1_lines: u32,
    vtable: VTable,
    history: HistoryBuffer,
    window: u8,
    whole_window: bool,
    conf_threshold: u8,
    /// Metadata-fetch latency charged on migration (L2 latency).
    migrate_latency: u64,
    stats: PairStats,
    recent_srcs: [u64; 4],
    /// Diagnostics.
    pub migrations_in: u64,
    pub migrations_out: u64,
}

impl Cheip {
    pub fn new(
        vt_entries: u32,
        window: u8,
        whole_window: bool,
        conf_threshold: u8,
        l1_lines: u32,
        migrate_latency: u64,
    ) -> Self {
        Cheip {
            l1: FxHashMap::with_capacity_and_hasher(l1_lines as usize, Default::default()),
            l1_lines,
            vtable: VTable::new(vt_entries, window),
            history: HistoryBuffer::paper(),
            window,
            whole_window,
            conf_threshold,
            migrate_latency,
            stats: PairStats::default(),
            recent_srcs: [u64::MAX; 4],
            migrations_in: 0,
            migrations_out: 0,
        }
    }

    fn account_mark(&mut self, m: Mark) {
        match m {
            Mark::InWindow => self.stats.dests_in_window += 1,
            Mark::Rebased { dropped } => {
                self.stats.dests_in_window += 1;
                self.stats.dests_dropped += dropped as u64;
            }
            Mark::TooFar => unreachable!(),
        }
    }

    fn entangle(&mut self, src: u64, dst: u64) {
        self.stats.pairs_total += 1;
        self.stats.dests_total += 1;
        if !bits::shares_high_bits(src, dst, 20) {
            self.stats.dests_dropped += 1;
            return;
        }
        self.stats.pairs_fit20 += 1;
        // Resident source: update the attached entry (L1-speed update).
        if let Some(a) = self.l1.get_mut(&src) {
            let m = a.centry.mark(src, dst);
            self.account_mark(m);
            return;
        }
        // Cold source: learn into the virtual table.
        if let Some(e) = self.vtable.get_mut(src) {
            let m = e.mark(src, dst);
            self.account_mark(m);
        } else {
            self.vtable.put(src, CEntry::new(self.window, dst));
            self.stats.dests_in_window += 1;
        }
    }

    fn is_short_loop(&self, src: u64) -> bool {
        self.recent_srcs.contains(&src)
    }
}

impl Prefetcher for Cheip {
    fn name(&self) -> String {
        format!(
            "cheip{}w{}{}",
            self.vtable.metadata_bytes() * 8 / (51 + CEntry::storage_bits(self.window) as u64),
            self.window,
            if self.whole_window { "" } else { "s" }
        )
    }

    fn on_fetch(&mut self, line: u64, cycle: u64, out: &mut Vec<Candidate>) {
        let short_loop = self.is_short_loop(line);
        if let Some(a) = self.l1.get(&line) {
            // Only fire once the migrated metadata has arrived (§III-B).
            if cycle >= a.available_at {
                super::ceip::Ceip::emit(
                    &a.centry,
                    line,
                    self.whole_window,
                    self.conf_threshold,
                    short_loop,
                    out,
                );
            }
        }
        self.recent_srcs.rotate_right(1);
        self.recent_srcs[0] = line;
    }

    fn on_demand_miss(&mut self, line: u64, cycle: u64) {
        self.history.push(line, cycle);
    }

    fn on_miss_resolved(&mut self, line: u64, fetch_cycle: u64, latency: u64) {
        if let Some(src) = self.history.find_source(line, fetch_cycle, latency) {
            self.entangle(src.line, line);
        }
    }

    fn feedback(&mut self, fb: &Feedback) {
        let centry = if let Some(a) = self.l1.get_mut(&fb.src) {
            Some(&mut a.centry)
        } else {
            self.vtable.get_mut(fb.src)
        };
        if let Some(e) = centry {
            let base = e.line_at(fb.src, 0);
            if fb.line >= base && fb.line < base + e.window() as u64 {
                let off = (fb.line - base) as u8;
                match fb.outcome {
                    Outcome::Timely | Outcome::Late => e.reinforce(off),
                    Outcome::Useless => e.decay(off),
                }
            }
        }
    }

    /// L1 fill: migrate metadata in from the virtual table (if any).
    fn on_l1i_fill(&mut self, line: u64, cycle: u64) {
        debug_assert!(self.l1.len() <= self.l1_lines as usize);
        if let Some(e) = self.vtable.take(line) {
            self.migrations_in += 1;
            self.l1.insert(
                line,
                Attached {
                    centry: e,
                    available_at: cycle + self.migrate_latency,
                },
            );
        } else {
            // Fresh attachment slot (no virtualized history): subsequent
            // entangles to this resident source update it at L1 speed.
            self.l1.insert(
                line,
                Attached {
                    centry: CEntry::empty(self.window),
                    available_at: cycle,
                },
            );
        }
    }

    /// L1 evict: write the attached entry back to the virtual table.
    fn on_l1i_evict(&mut self, line: u64) {
        if let Some(a) = self.l1.remove(&line) {
            if a.centry.marked() > 0 {
                self.migrations_out += 1;
                self.vtable.put(line, a.centry);
            }
        }
    }

    /// §VII guardrail: decay attached-entry confidences (the hot set that
    /// actively steers prefetches); the virtual table ages via its LRU.
    fn on_anomaly(&mut self) {
        for a in self.l1.values_mut() {
            for off in 0..a.centry.window() {
                a.centry.decay(off);
            }
        }
    }

    /// §V budget: L1-attached (lines × 36 b = 2304 B for 512 lines) +
    /// virtualized table (21.75 / 43.5 KB) + history (624 B) ⇒ 24.75 /
    /// 46.5 KB totals. Note the virtual table occupies *shared L2/L3*
    /// capacity, but the paper's §V budget counts it, so we do too.
    fn metadata_bytes(&self) -> u64 {
        bits::bits_to_bytes(self.l1_lines as u64 * CEntry::storage_bits(self.window) as u64)
            + self.vtable.metadata_bytes()
            + self.history.metadata_bytes()
    }

    fn pair_stats(&self) -> PairStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: u64 = 0x0040_2000;

    fn mk() -> Cheip {
        Cheip::new(2048, 8, true, 2, 512, 15)
    }

    fn drive_miss(c: &mut Cheip, src: u64, sc: u64, dst: u64, dc: u64, lat: u64) {
        c.on_demand_miss(src, sc);
        c.on_demand_miss(dst, dc);
        c.on_miss_resolved(dst, dc, lat);
    }

    #[test]
    fn paper_budget_24_75_kb_and_46_5_kb() {
        // §V components: history 624 B; L1-attach 512×36 b = 2304 B
        // (2.25 KB); vtable 2K×87 b = 21.75 KB or 4K×87 b = 43.5 KB.
        // Totals 25 200 B ≈ the paper's "24.75 KB" and 47 472 B ≈
        // "46.5 KB" (the paper rounds the 624 B history to 0.75 KB).
        let c2k = mk();
        assert_eq!(c2k.metadata_bytes(), 2304 + 22_272 + 624);
        assert!((c2k.metadata_bytes() as f64 / 1024.0 - 24.75).abs() < 0.2);
        let c4k = Cheip::new(4096, 8, true, 2, 512, 15);
        assert_eq!(c4k.metadata_bytes(), 2304 + 44_544 + 624);
        assert!((c4k.metadata_bytes() as f64 / 1024.0 - 46.5).abs() < 0.2);
    }

    #[test]
    fn resident_source_fires_after_migration_latency() {
        let mut c = mk();
        // Learn while cold → entry in vtable.
        drive_miss(&mut c, SRC, 0, SRC + 3, 500, 100);
        drive_miss(&mut c, SRC, 900, SRC + 3, 1400, 100);
        assert!(!c.vtable.is_empty());
        // Line fills into L1 at cycle 2000: metadata migrates, usable at
        // 2000 + 15.
        c.on_l1i_fill(SRC, 2000);
        assert_eq!(c.migrations_in, 1);
        let mut out = Vec::new();
        c.on_fetch(SRC, 2005, &mut out);
        assert!(out.is_empty(), "metadata still in flight");
        c.on_fetch(SRC, 2015, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, SRC + 3);
    }

    #[test]
    fn cold_source_does_not_fire() {
        let mut c = mk();
        drive_miss(&mut c, SRC, 0, SRC + 3, 500, 100);
        let mut out = Vec::new();
        // Source never filled into L1: vtable is not queried on fetch.
        c.on_fetch(SRC, 1000, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn evict_writes_back_and_later_refill_restores() {
        let mut c = mk();
        drive_miss(&mut c, SRC, 0, SRC + 2, 500, 100);
        c.on_l1i_fill(SRC, 1000);
        // Update while resident.
        drive_miss(&mut c, SRC, 2000, SRC + 4, 2500, 100);
        c.on_l1i_evict(SRC);
        assert_eq!(c.migrations_out, 1);
        assert!(c.l1.is_empty());
        // Refill: both marks must survive the round trip.
        c.on_l1i_fill(SRC, 5000);
        let mut out = Vec::new();
        c.on_fetch(SRC, 5100, &mut out);
        let lines: Vec<u64> = out.iter().map(|x| x.line).collect();
        assert!(lines.contains(&(SRC + 2)) && lines.contains(&(SRC + 4)));
    }

    #[test]
    fn resident_entry_updates_at_l1() {
        let mut c = mk();
        c.on_l1i_fill(SRC, 100);
        drive_miss(&mut c, SRC, 200, SRC + 1, 700, 100);
        let mut out = Vec::new();
        c.on_fetch(SRC, 800, &mut out);
        assert_eq!(out.len(), 1, "entangle to resident source is immediately usable");
    }

    #[test]
    fn feedback_reaches_both_levels() {
        let mut c = mk();
        // Cold: feedback via vtable.
        drive_miss(&mut c, SRC, 0, SRC + 2, 500, 100);
        c.feedback(&Feedback {
            src: SRC,
            line: SRC + 2,
            outcome: Outcome::Timely,
        });
        c.on_l1i_fill(SRC, 1000);
        let mut out = Vec::new();
        c.on_fetch(SRC, 1100, &mut out);
        assert_eq!(out[0].conf, 2, "vtable feedback persisted through migration");
        // Resident: feedback via attached entry.
        c.feedback(&Feedback {
            src: SRC,
            line: SRC + 2,
            outcome: Outcome::Useless,
        });
        out.clear();
        c.on_fetch(SRC, 1200, &mut out);
        assert_eq!(out[0].conf, 1);
    }

    #[test]
    fn unmarked_attached_entries_not_written_back() {
        let mut c = mk();
        c.on_l1i_fill(SRC, 100); // nothing to migrate
        c.on_l1i_evict(SRC);
        assert_eq!(c.migrations_out, 0);
        assert!(c.vtable.is_empty());
    }
}
