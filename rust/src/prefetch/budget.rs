//! Metadata cost model (paper §V and Fig 13): on-chip bits for every
//! prefetcher variant, centralized so the storage-vs-speedup figure and
//! the per-prefetcher `metadata_bytes()` impls agree.

use super::centry::CEntry;

/// History buffer: 64 × (58-bit tag + 20-bit timestamp) = 624 B (§V).
pub const HISTORY_BYTES: u64 = 64 * (58 + 20) / 8;

/// One EIP table entry: 58-bit source tag + 8 × (38-bit destination line +
/// 2-bit confidence).
pub const EIP_ENTRY_BITS: u64 = 58 + 8 * (38 + 2);

/// One flat-CEIP table entry: 51-bit tag + compressed payload.
pub fn ceip_entry_bits(window: u8) -> u64 {
    51 + CEntry::storage_bits(window) as u64
}

/// Total bytes for an EIP-K configuration.
pub fn eip_bytes(entries: u32) -> u64 {
    (entries as u64 * EIP_ENTRY_BITS).div_ceil(8) + HISTORY_BYTES
}

/// Total bytes for a flat CEIP-K configuration.
pub fn ceip_bytes(entries: u32, window: u8) -> u64 {
    (entries as u64 * ceip_entry_bits(window)).div_ceil(8) + HISTORY_BYTES
}

/// Total bytes for CHEIP with `l1_lines` attached entries and a `vt`
/// entry virtual table.
pub fn cheip_bytes(l1_lines: u32, vt: u32, window: u8) -> u64 {
    (l1_lines as u64 * CEntry::storage_bits(window) as u64).div_ceil(8)
        + (vt as u64 * ceip_entry_bits(window)).div_ceil(8)
        + HISTORY_BYTES
}

/// Of CHEIP's budget, the part that competes for *private L1-adjacent*
/// storage (the paper's headline: only L1-resident metadata stays on the
/// critical silicon; the vtable lives in shared L2/L3 capacity).
pub fn cheip_l1_resident_bytes(l1_lines: u32, window: u8) -> u64 {
    (l1_lines as u64 * CEntry::storage_bits(window) as u64).div_ceil(8) + HISTORY_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_is_624_bytes() {
        assert_eq!(HISTORY_BYTES, 624);
    }

    #[test]
    fn paper_section_v_numbers() {
        // 512 L1 lines × 36 b = 2304 B.
        assert_eq!(512 * 36 / 8, 2304);
        // 2K/4K × 87 b = 21.75 / 43.5 KB.
        assert_eq!(2048 * ceip_entry_bits(8) / 8, 22_272);
        assert_eq!((22_272) as f64 / 1024.0, 21.75);
        assert_eq!(4096 * ceip_entry_bits(8) / 8, 44_544);
        assert_eq!((44_544) as f64 / 1024.0, 43.5);
    }

    #[test]
    fn cheip_totals() {
        let b2k = cheip_bytes(512, 2048, 8);
        assert_eq!(b2k, 2304 + 22_272 + 624);
        let l1_only = cheip_l1_resident_bytes(512, 8);
        assert_eq!(l1_only, 2304 + 624);
        assert!(l1_only * 8 < b2k, "L1-resident share is small");
    }

    #[test]
    fn compression_ratio_vs_eip() {
        // Same entry count: CEIP entry (87 b) vs EIP entry (378 b).
        assert!(EIP_ENTRY_BITS > 4 * ceip_entry_bits(8));
        assert!(eip_bytes(256) > 3 * ceip_bytes(256, 8));
    }
}
