//! The Compressed Entry (paper §III-A, Fig 4): a 20-bit base holding the
//! low-order line-address bits of a destination window (high bits are
//! inherited from the source) plus one 2-bit confidence per window offset.
//! For the paper's 8-line window this is exactly 36 bits.
//!
//! Updates slide the window along linear memory to cover the most marked
//! lines, breaking ties toward the window that includes the new block
//! (§III-A). Destinations whose delta does not fit in the 20 LSBs cannot
//! be represented and are dropped — the loss Figs 7/10 quantify.

use crate::util::bits::{self, conf2};

/// Low-order bits kept for the base (paper: 20).
pub const BASE_BITS: u32 = 20;

/// Result of offering a destination to the entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mark {
    /// Destination was inside the current window; confidence bumped.
    InWindow,
    /// Window slid to a new base; `dropped` previously-marked lines fell
    /// outside the new window and were lost.
    Rebased { dropped: u32 },
    /// Delta exceeds `BASE_BITS` low-order bits — not representable.
    TooFar,
}

/// A compressed destination entry with window size `W` (4, 8, or 12 —
/// §IV-B lets the bandit choose; 8 is the paper's operating point).
#[derive(Clone, Debug, PartialEq)]
pub struct CEntry {
    /// Window base in low-order line-address space (`BASE_BITS` wide).
    base_lsb: u32,
    /// 2-bit confidence per offset; `len() == window`.
    conf: Vec<u8>,
}

impl CEntry {
    /// New entry whose window starts at the destination that created it.
    pub fn new(window: u8, dst: u64) -> Self {
        let mut e = CEntry {
            base_lsb: Self::clamp_base(bits::field(dst, 0, BASE_BITS) as u32, window),
            conf: vec![0; window as usize],
        };
        let off = (bits::field(dst, 0, BASE_BITS) as u32 - e.base_lsb) as usize;
        e.conf[off] = 1;
        e
    }

    /// Empty entry (no marks yet) — the fresh L1-attached slot CHEIP
    /// creates when a line fills with no virtualized metadata behind it.
    pub fn empty(window: u8) -> Self {
        CEntry {
            base_lsb: 0,
            conf: vec![0; window as usize],
        }
    }

    fn clamp_base(pos: u32, window: u8) -> u32 {
        let max_base = (1u32 << BASE_BITS) - window as u32;
        pos.min(max_base)
    }

    pub fn window(&self) -> u8 {
        self.conf.len() as u8
    }

    pub fn base_lsb(&self) -> u32 {
        self.base_lsb
    }

    pub fn conf_at(&self, offset: u8) -> u8 {
        self.conf[offset as usize]
    }

    /// Marked offsets (confidence > 0).
    pub fn marked(&self) -> u32 {
        self.conf.iter().filter(|&&c| c > 0).count() as u32
    }

    /// Fraction of the window that is marked (the controller's
    /// window-density feature, §IV-A).
    pub fn density(&self) -> f32 {
        self.marked() as f32 / self.conf.len() as f32
    }

    /// Storage cost in bits: 20-bit base + 2 bits per offset (36 bits for
    /// the paper's 8-line window).
    pub fn storage_bits(window: u8) -> u32 {
        BASE_BITS + 2 * window as u32
    }

    /// Absolute line address of `offset`, inheriting high bits from `src`
    /// (§III-A: "inheriting high bits from the source").
    pub fn line_at(&self, src: u64, offset: u8) -> u64 {
        (src >> BASE_BITS << BASE_BITS) | (self.base_lsb + offset as u32) as u64
    }

    /// Does `dst` share the high-order bits with `src` (representable)?
    pub fn representable(src: u64, dst: u64) -> bool {
        bits::shares_high_bits(src, dst, BASE_BITS)
    }

    /// Offer destination `dst` (same high bits as the source — caller
    /// checks [`Self::representable`] and counts `TooFar` otherwise).
    pub fn mark(&mut self, src: u64, dst: u64) -> Mark {
        if !Self::representable(src, dst) {
            return Mark::TooFar;
        }
        let w = self.conf.len() as u32;
        let pos = bits::field(dst, 0, BASE_BITS) as u32;
        // Inside current window?
        if pos >= self.base_lsb && pos < self.base_lsb + w {
            let off = (pos - self.base_lsb) as usize;
            self.conf[off] = conf2::inc(self.conf[off]);
            return Mark::InWindow;
        }
        // Slide: choose the window covering the most marked lines, ties
        // prefer covering the new block, then retaining confidence mass,
        // then staying near the old base.
        let mut marked: Vec<(u32, u8)> = self
            .conf
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (self.base_lsb + i as u32, c))
            .collect();
        marked.push((pos, 1)); // the new block, weak confidence
        // Candidate bases: windows anchored at each marked point's start or
        // end (a maximum-coverage window can always be shifted to touch a
        // point), plus the old base. O(|marked|²) with |marked| <= W+1.
        let mut cands: Vec<u32> = Vec::with_capacity(2 * marked.len() + 1);
        for &(p, _) in &marked {
            cands.push(Self::clamp_base(p, w as u8));
            cands.push(Self::clamp_base(p.saturating_sub(w - 1), w as u8));
        }
        cands.push(self.base_lsb);
        cands.sort_unstable();
        cands.dedup();
        let mut best: Option<(u32, u32, u32, bool)> = None; // (count, mass, base, covers_new)
        for cand in cands {
            let count = marked
                .iter()
                .filter(|&&(p, _)| p >= cand && p < cand + w)
                .count() as u32;
            let mass: u32 = marked
                .iter()
                .filter(|&&(p, _)| p >= cand && p < cand + w)
                .map(|&(_, c)| c as u32)
                .sum();
            let covers_new = pos >= cand && pos < cand + w;
            let better = match &best {
                None => true,
                Some((bc, bm, bb, bn)) => {
                    (count, covers_new as u32, mass, std::cmp::Reverse(cand.abs_diff(self.base_lsb)))
                        > (*bc, *bn as u32, *bm, std::cmp::Reverse(bb.abs_diff(self.base_lsb)))
                }
            };
            if better {
                best = Some((count, mass, cand, covers_new));
            }
        }
        let (_count, _mass, new_base, _covers) = best.unwrap();
        // Rebase: translate surviving confidences.
        let mut new_conf = vec![0u8; w as usize];
        let mut dropped = 0u32;
        for (i, &c) in self.conf.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let p = self.base_lsb + i as u32;
            if p >= new_base && p < new_base + w {
                new_conf[(p - new_base) as usize] = c;
            } else {
                dropped += 1;
            }
        }
        if pos >= new_base && pos < new_base + w {
            let off = (pos - new_base) as usize;
            new_conf[off] = conf2::inc(new_conf[off]);
        } else {
            dropped += 1; // new block itself not representable in best window
        }
        self.base_lsb = new_base;
        self.conf = new_conf;
        Mark::Rebased { dropped }
    }

    /// Confidence feedback on an offset.
    pub fn reinforce(&mut self, offset: u8) {
        let c = &mut self.conf[offset as usize];
        *c = conf2::inc(*c);
    }

    pub fn decay(&mut self, offset: u8) {
        let c = &mut self.conf[offset as usize];
        *c = conf2::dec(*c);
    }

    /// Pack into the paper's bit layout (Fig 4): base in the low 20 bits,
    /// then 2-bit confidences ascending. Only defined for window <= 12
    /// (catalogued encodings); 8 → exactly 36 bits.
    pub fn pack(&self) -> u64 {
        let mut v = self.base_lsb as u64;
        for (i, &c) in self.conf.iter().enumerate() {
            v = bits::set_field(v, BASE_BITS + 2 * i as u32, 2, c as u64);
        }
        v
    }

    pub fn unpack(v: u64, window: u8) -> Self {
        let base = bits::field(v, 0, BASE_BITS) as u32;
        let conf = (0..window)
            .map(|i| bits::field(v, BASE_BITS + 2 * i as u32, 2) as u8)
            .collect();
        CEntry {
            base_lsb: base,
            conf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    const SRC: u64 = 0x0040_1234; // arbitrary source line

    fn same_region(lsb: u32) -> u64 {
        (SRC >> BASE_BITS << BASE_BITS) | lsb as u64
    }

    #[test]
    fn paper_entry_is_36_bits() {
        assert_eq!(CEntry::storage_bits(8), 36);
        assert_eq!(CEntry::storage_bits(4), 28);
        assert_eq!(CEntry::storage_bits(12), 44);
    }

    #[test]
    fn new_entry_marks_creator() {
        let e = CEntry::new(8, same_region(100));
        assert_eq!(e.base_lsb(), 100);
        assert_eq!(e.conf_at(0), 1);
        assert_eq!(e.marked(), 1);
    }

    #[test]
    fn in_window_bumps_confidence() {
        let mut e = CEntry::new(8, same_region(100));
        assert_eq!(e.mark(SRC, same_region(105)), Mark::InWindow);
        assert_eq!(e.conf_at(5), 1);
        assert_eq!(e.mark(SRC, same_region(105)), Mark::InWindow);
        assert_eq!(e.conf_at(5), 2);
        assert_eq!(e.density(), 2.0 / 8.0);
    }

    #[test]
    fn too_far_rejected() {
        let mut e = CEntry::new(8, same_region(100));
        let far = SRC + (1 << BASE_BITS); // different high bits
        assert_eq!(e.mark(SRC, far), Mark::TooFar);
    }

    #[test]
    fn slide_prefers_dense_region() {
        // Window at 100 with marks at 100..103 (4 marks); new dst at 96.
        // Best window covering {96,100,101,102,103}: base 96 covers all 5.
        let mut e = CEntry::new(8, same_region(100));
        e.mark(SRC, same_region(101));
        e.mark(SRC, same_region(102));
        e.mark(SRC, same_region(103));
        let m = e.mark(SRC, same_region(96));
        assert_eq!(m, Mark::Rebased { dropped: 0 });
        assert_eq!(e.base_lsb(), 96);
        assert_eq!(e.marked(), 5);
    }

    #[test]
    fn slide_tie_break_prefers_new_block() {
        // Marks at {100}; new dst at 120 (disjoint). Candidate windows
        // covering one mark each — tie on count; must pick one containing
        // the new block.
        let mut e = CEntry::new(8, same_region(100));
        let m = e.mark(SRC, same_region(120));
        match m {
            Mark::Rebased { .. } => {}
            other => panic!("expected rebase, got {other:?}"),
        }
        let base = e.base_lsb();
        assert!(
            (base..base + 8).contains(&120),
            "window [{base}, {}) must cover the new block",
            base + 8
        );
    }

    #[test]
    fn slide_keeps_majority_drops_minority() {
        // Dense cluster at 200..206 (7 marks), then one at 100: the dense
        // region must win and the outlier be dropped.
        let mut e = CEntry::new(8, same_region(200));
        for p in 201..=206 {
            e.mark(SRC, same_region(p));
        }
        let m = e.mark(SRC, same_region(100));
        assert_eq!(m, Mark::Rebased { dropped: 1 });
        // Tie between bases 199/200 (both cover all 7) resolves toward the
        // old base.
        assert_eq!(e.base_lsb(), 200);
        assert_eq!(e.marked(), 7);
    }

    #[test]
    fn line_at_inherits_high_bits() {
        let e = CEntry::new(8, same_region(100));
        assert_eq!(e.line_at(SRC, 3), same_region(103));
        // A source in another region projects the same LSBs there.
        let other_src = SRC + (5 << BASE_BITS);
        assert_eq!(e.line_at(other_src, 0) & 0xF_FFFF, 100);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut e = CEntry::new(8, same_region(77));
        e.mark(SRC, same_region(80));
        e.mark(SRC, same_region(80));
        e.mark(SRC, same_region(83));
        let packed = e.pack();
        assert!(packed < (1u64 << 36), "must fit 36 bits");
        assert_eq!(CEntry::unpack(packed, 8), e);
    }

    #[test]
    fn base_clamped_at_region_edge() {
        let edge = (1u64 << BASE_BITS) - 2;
        let e = CEntry::new(8, same_region(edge as u32));
        assert!(e.base_lsb() as u64 + 8 <= (1 << BASE_BITS));
        // The creating mark must still be inside.
        let off = edge as u32 - e.base_lsb();
        assert!(off < 8);
        assert_eq!(e.conf_at(off as u8), 1);
    }

    #[test]
    fn reinforce_and_decay_saturate() {
        let mut e = CEntry::new(8, same_region(10));
        for _ in 0..10 {
            e.reinforce(0);
        }
        assert_eq!(e.conf_at(0), 3);
        for _ in 0..10 {
            e.decay(0);
        }
        assert_eq!(e.conf_at(0), 0);
    }

    #[test]
    fn prop_pack_roundtrip_and_budget() {
        for window in [4u8, 8, 12] {
            prop::check_unit(
                "centry pack roundtrip",
                60,
                move |r: &mut Rng, size| {
                    let mut e = CEntry::new(window, same_region(r.below(1 << BASE_BITS) as u32));
                    for _ in 0..size {
                        let lsb = r.below(1 << BASE_BITS) as u32;
                        e.mark(SRC, same_region(lsb));
                    }
                    e
                },
                move |e| {
                    let p = e.pack();
                    assert!(p < 1u64 << CEntry::storage_bits(window));
                    assert_eq!(&CEntry::unpack(p, window), e);
                    // Base always leaves the whole window representable.
                    assert!(e.base_lsb() as u64 + window as u64 <= 1 << BASE_BITS);
                },
            );
        }
    }

    #[test]
    fn prop_window_always_covers_max_marked() {
        // Invariant: after any mark, no alternative window position covers
        // strictly more currently-marked lines than the chosen one. (The
        // chosen window maximizes coverage of lines marked at slide time;
        // since marks only accumulate inside the window afterwards, the
        // current marked set is always optimally covered or tied.)
        prop::check_unit(
            "window local-optimality",
            80,
            |r: &mut Rng, size| {
                let mut e = CEntry::new(8, same_region(r.below(1000) as u32 + 500));
                let cluster = r.below(900) as u32 + 500;
                for _ in 0..size {
                    // Mostly clustered marks, occasional outliers.
                    let lsb = if r.chance(0.8) {
                        cluster + r.below(10) as u32
                    } else {
                        r.below(1 << BASE_BITS) as u32
                    };
                    e.mark(SRC, same_region(lsb));
                }
                e
            },
            |e| {
                let w = e.window() as u32;
                let marked: Vec<u32> = (0..w)
                    .filter(|&i| e.conf_at(i as u8) > 0)
                    .map(|i| e.base_lsb() + i)
                    .collect();
                if marked.is_empty() {
                    return;
                }
                let span = marked.last().unwrap() - marked.first().unwrap();
                assert!(span < w, "marked lines span beyond the window");
            },
        );
    }
}
