//! EIP baseline: the Entangling Instruction Prefetcher (Ros & Jimborean,
//! ISCA'21 — paper ref [4]) with *uncompressed* destination storage. This
//! is the comparator for every CEIP/CHEIP result (Figs 6, 9–13).
//!
//! Learning: on a resolved L1-I miss of destination D (stalled at cycle t,
//! latency ℓ), the history buffer supplies the youngest source S fetched
//! early enough (ts + ℓ ≤ t) and D is entangled to S. Triggering: on any
//! fetch of S, destinations with confidence ≥ threshold issue.

use super::history::HistoryBuffer;
use super::{Candidate, Feedback, Outcome, PairStats, Prefetcher};
use crate::util::bits::{self, conf2};
use crate::util::hashfx::FxHashMap;

/// Max destinations per entangled entry (matches the compressed entry's
/// 8 slots so capacity comparisons are fair).
pub const MAX_DESTS: usize = 8;

struct Entry {
    dests: Vec<(u64, u8)>, // (line, confidence)
    lru: u64,
}

/// Set-associative entangled table with full-address destinations.
pub struct Eip {
    /// Set → (source line → entry); associativity enforced per set.
    sets: Vec<FxHashMap<u64, Entry>>,
    ways: usize,
    n_sets: u64,
    history: HistoryBuffer,
    conf_threshold: u8,
    clock: u64,
    entries_cfg: u32,
    stats: PairStats,
    /// Short-loop detection: last few trigger sources.
    recent_srcs: [u64; 4],
}

impl Eip {
    /// `entries` = total table entries, 16-way set-associative (the
    /// paper's table geometry, §V). The paper's "EIP-128"/"EIP-256" name
    /// the *set* count: EIP-256 ⇒ 256 sets × 16 ways = 4096 entries (this
    /// is what makes CEIP-128/256 land exactly on §V's 21.75/43.5 KB).
    pub fn new(entries: u32, conf_threshold: u8) -> Self {
        let ways = 16usize.min(entries as usize).max(1);
        let n_sets = (entries as usize / ways).max(1) as u64;
        Eip {
            sets: (0..n_sets).map(|_| FxHashMap::default()).collect(),
            ways,
            n_sets,
            history: HistoryBuffer::paper(),
            conf_threshold,
            clock: 0,
            entries_cfg: entries,
            stats: PairStats::default(),
            recent_srcs: [u64::MAX; 4],
        }
    }

    #[inline]
    fn set_of(&self, src: u64) -> usize {
        (src % self.n_sets) as usize
    }

    /// Insert/update the entangling S→D.
    fn entangle(&mut self, src: u64, dst: u64) {
        self.clock += 1;
        let clock = self.clock;
        self.stats.pairs_total += 1;
        if bits::shares_high_bits(src, dst, 20) {
            self.stats.pairs_fit20 += 1;
        }
        // EIP keeps full addresses: every destination is representable.
        self.stats.dests_total += 1;
        self.stats.dests_in_window += 1;
        let ways = self.ways;
        let set_idx = self.set_of(src);
        let set = &mut self.sets[set_idx];
        if let Some(e) = set.get_mut(&src) {
            e.lru = clock;
            if let Some(d) = e.dests.iter_mut().find(|(l, _)| *l == dst) {
                d.1 = conf2::inc(d.1);
            } else if e.dests.len() < MAX_DESTS {
                e.dests.push((dst, 1));
            } else {
                // Replace the weakest destination if it's weaker than new.
                let (idx, &(_, c)) = e
                    .dests
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &(_, c))| c)
                    .unwrap();
                if c <= 1 {
                    e.dests[idx] = (dst, 1);
                }
            }
            return;
        }
        // New entry; evict LRU if the set is full.
        if set.len() >= ways {
            let victim = *set
                .iter()
                .min_by_key(|(_, e)| e.lru)
                .map(|(k, _)| k)
                .unwrap();
            set.remove(&victim);
        }
        set.insert(
            src,
            Entry {
                dests: vec![(dst, 1)],
                lru: clock,
            },
        );
    }

    fn is_short_loop(&self, src: u64) -> bool {
        self.recent_srcs.contains(&src)
    }
}

impl Prefetcher for Eip {
    fn name(&self) -> String {
        format!("eip{}", self.entries_cfg)
    }

    fn on_fetch(&mut self, line: u64, _cycle: u64, out: &mut Vec<Candidate>) {
        self.clock += 1;
        let clock = self.clock;
        let short_loop = self.is_short_loop(line);
        let set_idx = self.set_of(line);
        let threshold = self.conf_threshold;
        if let Some(e) = self.sets[set_idx].get_mut(&line) {
            e.lru = clock;
            for &(dst, conf) in &e.dests {
                if conf >= threshold {
                    out.push(Candidate {
                        line: dst,
                        src: line,
                        conf,
                        offset: 0,
                        window_density: e.dests.len() as f32 / MAX_DESTS as f32,
                        short_loop,
                    });
                }
            }
        }
        self.recent_srcs.rotate_right(1);
        self.recent_srcs[0] = line;
    }

    fn on_demand_miss(&mut self, line: u64, cycle: u64) {
        self.history.push(line, cycle);
    }

    fn on_miss_resolved(&mut self, line: u64, fetch_cycle: u64, latency: u64) {
        if let Some(src) = self.history.find_source(line, fetch_cycle, latency) {
            self.entangle(src.line, line);
        }
    }

    fn feedback(&mut self, fb: &Feedback) {
        let set_idx = self.set_of(fb.src);
        if let Some(e) = self.sets[set_idx].get_mut(&fb.src) {
            if let Some(d) = e.dests.iter_mut().find(|(l, _)| *l == fb.line) {
                match fb.outcome {
                    Outcome::Timely | Outcome::Late => d.1 = conf2::inc(d.1),
                    Outcome::Useless => d.1 = conf2::dec(d.1),
                }
            }
            e.dests.retain(|&(_, c)| c > 0);
        }
    }

    /// §VII guardrail (symmetric with CEIP/CHEIP so Figs 9/10 compare the
    /// *encoding*, not the guardrail): decay destination confidences.
    fn on_anomaly(&mut self) {
        for set in &mut self.sets {
            for e in set.values_mut() {
                for d in &mut e.dests {
                    d.1 = conf2::dec(d.1);
                }
                e.dests.retain(|&(_, c)| c > 0);
            }
        }
    }

    /// Uncompressed cost (§V cost model for Fig 13): 58-bit tag + 8 ×
    /// (38-bit destination line + 2-bit confidence) per entry + history.
    fn metadata_bytes(&self) -> u64 {
        let entry_bits = 58 + MAX_DESTS as u64 * (38 + 2);
        bits::bits_to_bytes(self.entries_cfg as u64 * entry_bits) + self.history.metadata_bytes()
    }

    /// Fig 7 counters are accumulated; Fig 8's "share of destinations
    /// covered within an 8-line window" is computed from the *uncompressed*
    /// table: for each entry, the best 8-line window over its destination
    /// set (what a compressed entry could have retained).
    fn pair_stats(&self) -> PairStats {
        let mut s = self.stats;
        let mut total = 0u64;
        let mut covered = 0u64;
        for set in &self.sets {
            for e in set.values() {
                if e.dests.is_empty() {
                    continue;
                }
                let mut lines: Vec<u64> = e.dests.iter().map(|&(l, _)| l).collect();
                lines.sort_unstable();
                total += lines.len() as u64;
                let best = lines
                    .iter()
                    .map(|&start| {
                        lines
                            .iter()
                            .filter(|&&l| l >= start && l < start + 8)
                            .count() as u64
                    })
                    .max()
                    .unwrap_or(0);
                covered += best;
            }
        }
        s.dests_total = total;
        s.dests_in_window = covered;
        s.dests_dropped = total - covered;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive_miss(e: &mut Eip, src: u64, src_cycle: u64, dst: u64, dst_cycle: u64, lat: u64) {
        e.on_demand_miss(src, src_cycle);
        e.on_demand_miss(dst, dst_cycle);
        e.on_miss_resolved(dst, dst_cycle, lat);
    }

    #[test]
    fn learns_and_triggers() {
        let mut e = Eip::new(256, 1);
        // src at cycle 100, dst misses at 400 with latency 100 →
        // deadline 300; src (100) qualifies.
        drive_miss(&mut e, 1000, 100, 2000, 400, 100);
        let mut out = Vec::new();
        e.on_fetch(1000, 500, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 2000);
        assert_eq!(out[0].src, 1000);
    }

    #[test]
    fn threshold_gates_low_confidence() {
        let mut e = Eip::new(256, 2);
        drive_miss(&mut e, 1000, 100, 2000, 400, 100);
        let mut out = Vec::new();
        e.on_fetch(1000, 500, &mut out);
        assert!(out.is_empty(), "conf 1 < threshold 2");
        // Entangle again → conf 2.
        drive_miss(&mut e, 1000, 600, 2000, 900, 100);
        e.on_fetch(1000, 1000, &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn feedback_adjusts_confidence() {
        let mut e = Eip::new(256, 1);
        drive_miss(&mut e, 1000, 100, 2000, 400, 100);
        e.feedback(&Feedback {
            src: 1000,
            line: 2000,
            outcome: Outcome::Useless,
        });
        // conf 1 → 0 → destination dropped.
        let mut out = Vec::new();
        e.on_fetch(1000, 500, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn capacity_bounded_per_set() {
        let mut e = Eip::new(32, 1); // 2 sets x 16 ways
        for i in 0..100u64 {
            // All sources map to set (2i)%2=0.
            drive_miss(&mut e, 2 * i + 2, i * 10, 9_000 + i, i * 10 + 5, 1);
        }
        for set in &e.sets {
            assert!(set.len() <= 16);
        }
    }

    #[test]
    fn dest_slots_bounded() {
        let mut e = Eip::new(256, 1);
        for d in 0..20u64 {
            drive_miss(&mut e, 1000, d * 100, 2000 + d, d * 100 + 50, 10);
        }
        let set = e.set_of(1000);
        let entry = e.sets[set].get(&1000).unwrap();
        assert!(entry.dests.len() <= MAX_DESTS);
    }

    #[test]
    fn metadata_budget_matches_cost_model() {
        let e = Eip::new(256, 1);
        // 256 * (58 + 8*40) = 96768 bits = 12096 B, + 624 B history.
        assert_eq!(e.metadata_bytes(), 12096 + 624);
    }

    #[test]
    fn pair_stats_count_fit20() {
        let mut e = Eip::new(256, 1);
        drive_miss(&mut e, 0x100, 100, 0x105, 400, 100); // fits
        drive_miss(&mut e, 0x100, 500, 0x100 + (1 << 21), 900, 100); // far
        let ps = e.pair_stats();
        assert_eq!(ps.pairs_total, 2);
        assert_eq!(ps.pairs_fit20, 1);
        // EIP stores both (full addresses), but Fig 8's window metric says
        // only one of the two would fit an 8-line window.
        assert_eq!(ps.dests_total, 2);
        assert_eq!(ps.dests_in_window, 1);
    }
}
