//! The virtualized entangle table (paper §III-B, §V): a set-associative
//! metadata store logically resident in L2/L3 ("predictor virtualization",
//! paper ref [6]). 16 ways; 2K or 4K entries; each entry a 51-bit tag +
//! 36-bit compressed payload (21.75 KB / 43.5 KB).

use super::centry::CEntry;
use crate::util::bits;
use crate::util::hashfx::FxHashMap;

pub const WAYS: usize = 16;
pub const TAG_BITS: u64 = 51;

pub struct VTable {
    sets: Vec<FxHashMap<u64, (CEntry, u64)>>, // src → (entry, lru)
    n_sets: u64,
    entries_cfg: u32,
    window: u8,
    clock: u64,
    pub evictions: u64,
}

impl VTable {
    pub fn new(entries: u32, window: u8) -> Self {
        let n_sets = (entries as usize / WAYS).max(1) as u64;
        VTable {
            sets: (0..n_sets).map(|_| FxHashMap::default()).collect(),
            n_sets,
            entries_cfg: entries,
            window,
            clock: 0,
            evictions: 0,
        }
    }

    #[inline]
    fn set_of(&self, src: u64) -> usize {
        (src % self.n_sets) as usize
    }

    /// Look up (and LRU-touch) the entry for `src`.
    pub fn get_mut(&mut self, src: u64) -> Option<&mut CEntry> {
        self.clock += 1;
        let clock = self.clock;
        let set = self.set_of(src);
        self.sets[set].get_mut(&src).map(|(e, lru)| {
            *lru = clock;
            e
        })
    }

    /// Remove and return the entry for `src` (metadata migration to L1).
    pub fn take(&mut self, src: u64) -> Option<CEntry> {
        let set = self.set_of(src);
        self.sets[set].remove(&src).map(|(e, _)| e)
    }

    /// Insert (metadata migration from L1, or cold learning). Evicts the
    /// set's LRU entry when full.
    pub fn put(&mut self, src: u64, entry: CEntry) {
        self.clock += 1;
        let clock = self.clock;
        let set_idx = self.set_of(src);
        let set = &mut self.sets[set_idx];
        if let Some(slot) = set.get_mut(&src) {
            *slot = (entry, clock);
            return;
        }
        if set.len() >= WAYS {
            let victim = *set.iter().min_by_key(|(_, (_, lru))| *lru).map(|(k, _)| k).unwrap();
            set.remove(&victim);
            self.evictions += 1;
        }
        set.insert(src, (entry, clock));
    }

    /// Get-or-create for learning updates that miss both levels.
    pub fn get_or_insert(&mut self, src: u64, dst: u64) -> &mut CEntry {
        let set_idx = self.set_of(src);
        if !self.sets[set_idx].contains_key(&src) {
            let e = CEntry::new(self.window, dst);
            self.put(src, e);
        }
        self.get_mut(src).unwrap()
    }

    pub fn len(&self) -> usize {
        self.sets.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Paper §V: entries × (51-bit tag + payload bits).
    pub fn metadata_bytes(&self) -> u64 {
        let payload = CEntry::storage_bits(self.window) as u64;
        bits::bits_to_bytes(self.entries_cfg as u64 * (TAG_BITS + payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: u64 = 0x0040_0000;

    #[test]
    fn paper_sizes_2k_and_4k() {
        // §V: 2K entries → 21.75 KB; 4K → 43.5 KB (51+36 = 87 bits/entry).
        assert_eq!(VTable::new(2048, 8).metadata_bytes(), 22_272); // 21.75 KB
        assert_eq!(VTable::new(4096, 8).metadata_bytes(), 44_544); // 43.5 KB
        assert_eq!(22_272, (21.75 * 1024.0) as u64);
        assert_eq!(44_544, (43.5 * 1024.0) as u64);
    }

    #[test]
    fn put_get_take_roundtrip() {
        let mut vt = VTable::new(2048, 8);
        let e = CEntry::new(8, SRC + 5);
        vt.put(SRC, e.clone());
        assert_eq!(vt.get_mut(SRC).map(|x| x.clone()), Some(e.clone()));
        assert_eq!(vt.take(SRC), Some(e));
        assert!(vt.get_mut(SRC).is_none());
        assert!(vt.is_empty());
    }

    #[test]
    fn set_associativity_evicts_lru() {
        let mut vt = VTable::new(16, 8); // one set of 16 ways
        for i in 0..17u64 {
            vt.put(SRC + i, CEntry::new(8, SRC + i));
            // Touch early entries except the very first to make it LRU.
            if i > 0 && i < 16 {
                vt.get_mut(SRC + i);
            }
        }
        assert_eq!(vt.len(), 16);
        assert_eq!(vt.evictions, 1);
        assert!(vt.get_mut(SRC).is_none(), "LRU (first, untouched) evicted");
    }

    #[test]
    fn get_or_insert_creates_once() {
        let mut vt = VTable::new(2048, 8);
        {
            let e = vt.get_or_insert(SRC, SRC + 3);
            assert_eq!(e.marked(), 1);
            e.reinforce(3);
        }
        let e2 = vt.get_or_insert(SRC, SRC + 9);
        assert!(e2.conf_at(3) >= 1, "existing entry reused, not recreated");
        assert_eq!(vt.len(), 1);
    }
}
