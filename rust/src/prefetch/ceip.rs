//! CEIP: the Compressed-Entry entangling prefetcher (paper §III-A).
//! Same learning loop as EIP (history buffer → entangle on resolved miss)
//! but destinations live in a 36-bit [`CEntry`] — a 20-bit base plus eight
//! 2-bit confidences. Destinations outside the 20-bit region or squeezed
//! out by window slides are lost; Figs 7/8/10 quantify exactly that loss
//! via [`PairStats`].

use super::centry::{CEntry, Mark};
use super::history::HistoryBuffer;
use super::{Candidate, Feedback, Outcome, PairStats, Prefetcher};
use crate::util::bits;
use crate::util::hashfx::FxHashMap;

struct Entry {
    centry: CEntry,
    lru: u64,
}

pub struct Ceip {
    sets: Vec<FxHashMap<u64, Entry>>,
    ways: usize,
    n_sets: u64,
    history: HistoryBuffer,
    window: u8,
    /// Issue every marked offset (paper §XIII: whole-window beat
    /// selective); when false only conf ≥ threshold offsets issue.
    whole_window: bool,
    conf_threshold: u8,
    clock: u64,
    entries_cfg: u32,
    stats: PairStats,
    recent_srcs: [u64; 4],
}

impl Ceip {
    /// `entries` = total table entries, 16-way (see [`super::eip::Eip::new`]
    /// on the paper's set-count naming).
    pub fn new(entries: u32, window: u8, whole_window: bool, conf_threshold: u8) -> Self {
        let ways = 16usize.min(entries as usize).max(1);
        let n_sets = (entries as usize / ways).max(1) as u64;
        Ceip {
            sets: (0..n_sets).map(|_| FxHashMap::default()).collect(),
            ways,
            n_sets,
            history: HistoryBuffer::paper(),
            window,
            whole_window,
            conf_threshold,
            clock: 0,
            entries_cfg: entries,
            stats: PairStats::default(),
            recent_srcs: [u64::MAX; 4],
        }
    }

    #[inline]
    fn set_of(&self, src: u64) -> usize {
        (src % self.n_sets) as usize
    }

    fn entangle(&mut self, src: u64, dst: u64) {
        self.clock += 1;
        let clock = self.clock;
        self.stats.pairs_total += 1;
        self.stats.dests_total += 1;
        let fits = bits::shares_high_bits(src, dst, 20);
        if fits {
            self.stats.pairs_fit20 += 1;
        } else {
            // Not representable by the compressed entry at all.
            self.stats.dests_dropped += 1;
            return;
        }
        let window = self.window;
        let ways = self.ways;
        let set_idx = self.set_of(src);
        let set = &mut self.sets[set_idx];
        if let Some(e) = set.get_mut(&src) {
            e.lru = clock;
            match e.centry.mark(src, dst) {
                Mark::InWindow => self.stats.dests_in_window += 1,
                Mark::Rebased { dropped } => {
                    // The new destination landed (or not) after a slide;
                    // count it plus collateral marks lost.
                    self.stats.dests_in_window += 1;
                    self.stats.dests_dropped += dropped as u64;
                }
                Mark::TooFar => unreachable!("checked above"),
            }
            return;
        }
        if set.len() >= ways {
            let victim = *set.iter().min_by_key(|(_, e)| e.lru).map(|(k, _)| k).unwrap();
            set.remove(&victim);
        }
        set.insert(
            src,
            Entry {
                centry: CEntry::new(window, dst),
                lru: clock,
            },
        );
        self.stats.dests_in_window += 1;
    }

    fn is_short_loop(&self, src: u64) -> bool {
        self.recent_srcs.contains(&src)
    }

    /// Emit candidates from a compressed entry (shared with CHEIP).
    pub(crate) fn emit(
        centry: &CEntry,
        src: u64,
        whole_window: bool,
        conf_threshold: u8,
        short_loop: bool,
        out: &mut Vec<Candidate>,
    ) {
        let density = centry.density();
        let min_conf = if whole_window { 1 } else { conf_threshold };
        for off in 0..centry.window() {
            let conf = centry.conf_at(off);
            if conf >= min_conf {
                out.push(Candidate {
                    line: centry.line_at(src, off),
                    src,
                    conf,
                    offset: off,
                    window_density: density,
                    short_loop,
                });
            }
        }
    }
}

impl Prefetcher for Ceip {
    fn name(&self) -> String {
        format!(
            "ceip{}w{}{}",
            self.entries_cfg,
            self.window,
            if self.whole_window { "" } else { "s" }
        )
    }

    fn on_fetch(&mut self, line: u64, _cycle: u64, out: &mut Vec<Candidate>) {
        self.clock += 1;
        let clock = self.clock;
        let short_loop = self.is_short_loop(line);
        let whole = self.whole_window;
        let threshold = self.conf_threshold;
        let set_idx = self.set_of(line);
        if let Some(e) = self.sets[set_idx].get_mut(&line) {
            e.lru = clock;
            Self::emit(&e.centry, line, whole, threshold, short_loop, out);
        }
        self.recent_srcs.rotate_right(1);
        self.recent_srcs[0] = line;
    }

    fn on_demand_miss(&mut self, line: u64, cycle: u64) {
        self.history.push(line, cycle);
    }

    fn on_miss_resolved(&mut self, line: u64, fetch_cycle: u64, latency: u64) {
        if let Some(src) = self.history.find_source(line, fetch_cycle, latency) {
            self.entangle(src.line, line);
        }
    }

    fn feedback(&mut self, fb: &Feedback) {
        let set_idx = self.set_of(fb.src);
        if let Some(e) = self.sets[set_idx].get_mut(&fb.src) {
            // Recover the offset from the line address.
            let base = e.centry.line_at(fb.src, 0);
            if fb.line >= base && fb.line < base + e.centry.window() as u64 {
                let off = (fb.line - base) as u8;
                match fb.outcome {
                    Outcome::Timely | Outcome::Late => e.centry.reinforce(off),
                    Outcome::Useless => e.centry.decay(off),
                }
            }
        }
    }

    /// §VII guardrail: decay every confidence by one step; offsets at 0
    /// disappear from the issue set ("rapid eviction" of stale marks).
    fn on_anomaly(&mut self) {
        for set in &mut self.sets {
            for e in set.values_mut() {
                for off in 0..e.centry.window() {
                    e.centry.decay(off);
                }
            }
        }
    }

    /// §V cost model: entries × (51-bit tag + compressed payload) + history.
    fn metadata_bytes(&self) -> u64 {
        let payload = CEntry::storage_bits(self.window) as u64;
        bits::bits_to_bytes(self.entries_cfg as u64 * (51 + payload))
            + self.history.metadata_bytes()
    }

    fn pair_stats(&self) -> PairStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: u64 = 0x0040_1000;

    fn drive_miss(c: &mut Ceip, src: u64, sc: u64, dst: u64, dc: u64, lat: u64) {
        c.on_demand_miss(src, sc);
        c.on_demand_miss(dst, dc);
        c.on_miss_resolved(dst, dc, lat);
    }

    #[test]
    fn learns_clustered_dests_and_triggers_window() {
        let mut c = Ceip::new(256, 8, true, 2);
        for (i, d) in [3u64, 4, 5].iter().enumerate() {
            drive_miss(&mut c, SRC, 1000 * i as u64, SRC + d, 1000 * i as u64 + 500, 100);
        }
        let mut out = Vec::new();
        c.on_fetch(SRC, 10_000, &mut out);
        let lines: Vec<u64> = out.iter().map(|c| c.line).collect();
        assert!(lines.contains(&(SRC + 3)));
        assert!(lines.contains(&(SRC + 4)));
        assert!(lines.contains(&(SRC + 5)));
        assert!(out.iter().all(|c| c.window_density > 0.3));
    }

    #[test]
    fn selective_mode_gates_on_confidence() {
        let mut c = Ceip::new(256, 8, false, 2);
        drive_miss(&mut c, SRC, 0, SRC + 3, 500, 100);
        let mut out = Vec::new();
        c.on_fetch(SRC, 1000, &mut out);
        assert!(out.is_empty(), "conf 1 < 2 in selective mode");
        drive_miss(&mut c, SRC, 2000, SRC + 3, 2500, 100);
        c.on_fetch(SRC, 3000, &mut out);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn far_destination_dropped_and_counted() {
        let mut c = Ceip::new(256, 8, true, 2);
        drive_miss(&mut c, SRC, 0, SRC + (1 << 21), 500, 100);
        let ps = c.pair_stats();
        assert_eq!(ps.pairs_total, 1);
        assert_eq!(ps.pairs_fit20, 0);
        assert_eq!(ps.dests_dropped, 1);
        let mut out = Vec::new();
        c.on_fetch(SRC, 1000, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn feedback_decays_useless_offsets() {
        let mut c = Ceip::new(256, 8, true, 2);
        drive_miss(&mut c, SRC, 0, SRC + 2, 500, 100);
        let mut out = Vec::new();
        c.on_fetch(SRC, 1000, &mut out);
        assert_eq!(out.len(), 1);
        c.feedback(&Feedback {
            src: SRC,
            line: out[0].line,
            outcome: Outcome::Useless,
        });
        out.clear();
        c.on_fetch(SRC, 2000, &mut out);
        assert!(out.is_empty(), "conf decayed to 0");
    }

    #[test]
    fn metadata_smaller_than_eip_at_same_entries() {
        let ceip = Ceip::new(256, 8, true, 2);
        let eip = super::super::eip::Eip::new(256, 2);
        assert!(ceip.metadata_bytes() < eip.metadata_bytes() / 3);
        // 256 * 87 bits = 2784 B + 624.
        assert_eq!(ceip.metadata_bytes(), 2784 + 624);
    }

    #[test]
    fn window_4_and_12_work() {
        for w in [4u8, 12] {
            let mut c = Ceip::new(128, w, true, 2);
            drive_miss(&mut c, SRC, 0, SRC + 1, 500, 100);
            let mut out = Vec::new();
            c.on_fetch(SRC, 1000, &mut out);
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].line, SRC + 1);
        }
    }
}
