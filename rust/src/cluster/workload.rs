//! Open-loop arrival processes for the cluster simulator: stationary
//! Poisson plus the time-varying shapes cloud frontends actually see —
//! diurnal sinusoid, MMPP-style on/off bursts, and linear ramps.
//!
//! A shape is a utilization curve `util_at(t)` in units of the scenario's
//! reference capacity; the generator turns it into arrival instants by
//! thinning a Poisson process at the peak rate (Lewis & Shedler), which
//! keeps the draw sequence — and therefore the whole event loop — a pure
//! function of the seed.

use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// A time-varying offered-load curve. Utilization is relative to a
/// reference service rate supplied at run time (`ArrivalGen::new`), so
/// the same shape can be replayed against any topology. Burst peaks may
/// exceed 1.0 — transient overload is exactly the scenario the SLO
/// control loop exists for.
#[derive(Clone, Debug, PartialEq)]
pub enum TrafficShape {
    /// Stationary Poisson arrivals at `util` × reference rate.
    Poisson { util: f64 },
    /// Diurnal sinusoid: `util × (1 + amplitude · sin(2πt/period))`.
    Diurnal { util: f64, amplitude: f64, period_us: f64 },
    /// MMPP-style on/off: `util × mult` for the first `duty` fraction of
    /// each period, `util` otherwise.
    Burst { util: f64, mult: f64, period_us: f64, duty: f64 },
    /// Linear ramp from `from` to `to` over `duration_us`, then hold.
    Ramp { from: f64, to: f64, duration_us: f64 },
}

impl TrafficShape {
    /// Parse a colon-separated shape spec:
    /// `poisson[:U]`, `diurnal[:U[:A[:P]]]`, `burst[:U[:M[:P[:D]]]]`,
    /// `ramp[:U0[:U1[:T]]]` (times in µs).
    pub fn parse(spec: &str) -> Result<TrafficShape> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("").to_lowercase();
        let mut nums = Vec::new();
        for p in parts {
            match p.parse::<f64>() {
                Ok(v) if v.is_finite() => nums.push(v),
                _ => bail!("traffic shape '{spec}': '{p}' is not a finite number"),
            }
        }
        let arg = |i: usize, default: f64| nums.get(i).copied().unwrap_or(default);
        let (shape, max_args) = match kind.as_str() {
            "poisson" => (TrafficShape::Poisson { util: arg(0, 0.65) }, 1),
            "diurnal" => (
                TrafficShape::Diurnal {
                    util: arg(0, 0.6),
                    amplitude: arg(1, 0.4),
                    period_us: arg(2, 200_000.0),
                },
                3,
            ),
            "burst" => (
                TrafficShape::Burst {
                    util: arg(0, 0.5),
                    mult: arg(1, 3.0),
                    period_us: arg(2, 50_000.0),
                    duty: arg(3, 0.2),
                },
                4,
            ),
            "ramp" => (
                TrafficShape::Ramp {
                    from: arg(0, 0.3),
                    to: arg(1, 0.9),
                    duration_us: arg(2, 200_000.0),
                },
                3,
            ),
            other => bail!(
                "unknown traffic shape '{other}' \
                 (try poisson:0.65|diurnal:0.6:0.4:200000|burst:0.5:3:50000:0.2|ramp:0.3:0.9)"
            ),
        };
        // Surplus fields are a typo (e.g. burst params on a poisson
        // spec), not something to silently drop.
        if nums.len() > max_args {
            bail!("traffic shape '{spec}': {kind} takes at most {max_args} numeric fields");
        }
        shape.validate(spec)?;
        Ok(shape)
    }

    fn validate(&self, spec: &str) -> Result<()> {
        let positive = |v: f64, what: &str| -> Result<()> {
            if v <= 0.0 || !v.is_finite() {
                bail!("traffic shape '{spec}': {what} must be > 0, got {v}");
            }
            Ok(())
        };
        match self {
            TrafficShape::Poisson { util } => positive(*util, "util")?,
            TrafficShape::Diurnal { util, amplitude, period_us } => {
                positive(*util, "util")?;
                positive(*period_us, "period")?;
                if !(0.0..1.0).contains(amplitude) {
                    bail!("traffic shape '{spec}': amplitude must be in [0, 1), got {amplitude}");
                }
            }
            TrafficShape::Burst { util, mult, period_us, duty } => {
                positive(*util, "util")?;
                positive(*period_us, "period")?;
                if *mult < 1.0 || !mult.is_finite() {
                    bail!("traffic shape '{spec}': mult must be ≥ 1, got {mult}");
                }
                if !(0.0..=1.0).contains(duty) {
                    bail!("traffic shape '{spec}': duty must be in [0, 1], got {duty}");
                }
            }
            TrafficShape::Ramp { from, to, duration_us } => {
                // A ramp may *start* from idle (cold-start scenario:
                // `ramp:0:0.9:…`) — thinning handles the transient
                // zero-rate region. It must *end* above zero, though:
                // max(from, to) > 0 alone would admit a terminal rate of
                // 0, where an open-loop run waiting for its next arrival
                // rejects every thinning draw forever.
                if !from.is_finite() || *from < 0.0 {
                    bail!("traffic shape '{spec}': start util must be ≥ 0, got {from}");
                }
                if !to.is_finite() || *to <= 0.0 {
                    bail!(
                        "traffic shape '{spec}': end util must be > 0, got {to} \
                         (a terminal rate of 0 can never complete an open-loop run; \
                         ramping *from* 0 is allowed)"
                    );
                }
                positive(*duration_us, "duration")?;
            }
        }
        Ok(())
    }

    /// Canonical label used in cell keys and report rows.
    pub fn label(&self) -> String {
        match self {
            TrafficShape::Poisson { util } => format!("poisson:{util}"),
            TrafficShape::Diurnal { util, amplitude, period_us } => {
                format!("diurnal:{util}:{amplitude}:{period_us}")
            }
            TrafficShape::Burst { util, mult, period_us, duty } => {
                format!("burst:{util}:{mult}:{period_us}:{duty}")
            }
            TrafficShape::Ramp { from, to, duration_us } => {
                format!("ramp:{from}:{to}:{duration_us}")
            }
        }
    }

    /// Instantaneous utilization at time `t` (µs).
    pub fn util_at(&self, t: f64) -> f64 {
        match self {
            TrafficShape::Poisson { util } => *util,
            TrafficShape::Diurnal { util, amplitude, period_us } => {
                util * (1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_us).sin())
            }
            TrafficShape::Burst { util, mult, period_us, duty } => {
                let phase = (t / period_us).fract();
                if phase < *duty {
                    util * mult
                } else {
                    *util
                }
            }
            TrafficShape::Ramp { from, to, duration_us } => {
                if t >= *duration_us {
                    *to
                } else {
                    from + (to - from) * (t / duration_us)
                }
            }
        }
    }

    /// Peak utilization over all time (the thinning envelope).
    pub fn peak_util(&self) -> f64 {
        match self {
            TrafficShape::Poisson { util } => *util,
            TrafficShape::Diurnal { util, amplitude, .. } => util * (1.0 + amplitude),
            // duty = 0 means the on-phase never happens (`util_at` never
            // exceeds `util`): the envelope must match the curve, or
            // every thinning draw is wasted against a rate the process
            // never reaches and the RNG stream is skewed.
            TrafficShape::Burst { util, mult, duty, .. } => {
                if *duty > 0.0 {
                    util * mult
                } else {
                    *util
                }
            }
            TrafficShape::Ramp { from, to, .. } => from.max(*to),
        }
    }
}

/// Arrival-instant generator: thinning against the shape's peak rate.
/// `rate_per_us` is the reference capacity that utilization 1.0 maps to.
#[derive(Clone, Debug)]
pub struct ArrivalGen {
    shape: TrafficShape,
    rate_per_us: f64,
    peak_rate: f64,
    t: f64,
    rng: Rng,
}

impl ArrivalGen {
    /// Build a generator. Fails on a non-positive (or non-finite)
    /// reference rate or peak rate: either would make [`Self::next_arrival`]
    /// spin forever — a `debug_assert!` used to be the only guard, so
    /// release builds hung instead of erroring.
    pub fn new(shape: TrafficShape, rate_per_us: f64, seed: u64) -> Result<ArrivalGen> {
        if !rate_per_us.is_finite() || rate_per_us <= 0.0 {
            bail!("arrival generator: reference rate must be > 0, got {rate_per_us}");
        }
        let peak_rate = shape.peak_util() * rate_per_us;
        if !peak_rate.is_finite() || peak_rate <= 0.0 {
            bail!(
                "arrival generator: shape '{}' has peak rate {peak_rate} — \
                 next_arrival would never accept a draw",
                shape.label()
            );
        }
        Ok(ArrivalGen { shape, rate_per_us, peak_rate, t: 0.0, rng: Rng::new(seed) })
    }

    /// Next arrival instant (µs, strictly increasing).
    pub fn next_arrival(&mut self) -> f64 {
        loop {
            self.t += self.rng.exp(1.0 / self.peak_rate);
            let lambda = self.shape.util_at(self.t) * self.rate_per_us;
            // Accept with probability λ(t)/λmax; the draw is taken even
            // for stationary shapes so all shapes share one code path
            // (and one RNG consumption pattern).
            if self.rng.f64() * self.peak_rate < lambda {
                return self.t;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_defaults_and_full_forms() {
        assert_eq!(TrafficShape::parse("poisson").unwrap(), TrafficShape::Poisson { util: 0.65 });
        assert_eq!(
            TrafficShape::parse("poisson:0.8").unwrap(),
            TrafficShape::Poisson { util: 0.8 }
        );
        assert_eq!(
            TrafficShape::parse("burst:0.5:3:40000:0.25").unwrap(),
            TrafficShape::Burst { util: 0.5, mult: 3.0, period_us: 40_000.0, duty: 0.25 }
        );
        assert_eq!(
            TrafficShape::parse("diurnal:0.6:0.4:100000").unwrap(),
            TrafficShape::Diurnal { util: 0.6, amplitude: 0.4, period_us: 100_000.0 }
        );
        assert_eq!(
            TrafficShape::parse("ramp:0.3:0.9:50000").unwrap(),
            TrafficShape::Ramp { from: 0.3, to: 0.9, duration_us: 50_000.0 }
        );
        // Cold start from idle is expressible (regression: `from > 0`
        // used to be required, so `ramp:0:…` was rejected).
        assert_eq!(
            TrafficShape::parse("ramp:0:0.9:50000").unwrap(),
            TrafficShape::Ramp { from: 0.0, to: 0.9, duration_us: 50_000.0 }
        );
        // Uppercase kinds parse like the prefetcher specs do.
        assert!(TrafficShape::parse("POISSON:0.5").is_ok());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(TrafficShape::parse("tsunami").is_err());
        assert!(TrafficShape::parse("poisson:abc").is_err());
        assert!(TrafficShape::parse("poisson:0").is_err());
        assert!(TrafficShape::parse("poisson:-0.5").is_err());
        assert!(TrafficShape::parse("burst:0.5:0.5").is_err(), "mult < 1");
        assert!(TrafficShape::parse("burst:0.5:3:1000:1.5").is_err(), "duty > 1");
        assert!(TrafficShape::parse("diurnal:0.6:1.5").is_err(), "amplitude ≥ 1");
        // Surplus fields are rejected, not silently dropped.
        assert!(
            TrafficShape::parse("poisson:0.65:3:50000:0.2").is_err(),
            "burst params on a poisson spec must not be dropped"
        );
        assert!(TrafficShape::parse("ramp:0.3:0.9:1000:7").is_err());
        // A ramp ending at rate 0 can never complete an open-loop run.
        assert!(TrafficShape::parse("ramp:0.9:0:1000").is_err(), "terminal rate 0");
        assert!(TrafficShape::parse("ramp:0:0:1000").is_err(), "flat-zero ramp");
        assert!(TrafficShape::parse("ramp:-0.1:0.9:1000").is_err(), "negative start");
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for spec in
            ["poisson:0.65", "diurnal:0.6:0.4:200000", "burst:0.5:3:50000:0.2", "ramp:0:0.9:50000"]
        {
            let shape = TrafficShape::parse(spec).unwrap();
            assert_eq!(TrafficShape::parse(&shape.label()).unwrap(), shape);
        }
    }

    #[test]
    fn util_curves_match_definitions() {
        let b = TrafficShape::Burst { util: 0.5, mult: 3.0, period_us: 100.0, duty: 0.2 };
        assert_eq!(b.util_at(10.0), 1.5); // on-phase
        assert_eq!(b.util_at(50.0), 0.5); // off-phase
        assert_eq!(b.util_at(110.0), 1.5); // periodic
        assert_eq!(b.peak_util(), 1.5);

        let r = TrafficShape::Ramp { from: 0.2, to: 0.8, duration_us: 100.0 };
        assert!((r.util_at(50.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.util_at(1000.0), 0.8);

        let d = TrafficShape::Diurnal { util: 0.5, amplitude: 0.4, period_us: 100.0 };
        assert!((d.util_at(25.0) - 0.7).abs() < 1e-12); // sin peak
        assert!((d.peak_util() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn arrivals_are_increasing_and_deterministic() {
        let shape = TrafficShape::Burst { util: 0.5, mult: 3.0, period_us: 1000.0, duty: 0.2 };
        let mut a = ArrivalGen::new(shape.clone(), 0.2, 42).unwrap();
        let mut b = ArrivalGen::new(shape, 0.2, 42).unwrap();
        let mut last = 0.0;
        for _ in 0..5_000 {
            let ta = a.next_arrival();
            assert_eq!(ta, b.next_arrival());
            assert!(ta > last);
            last = ta;
        }
    }

    #[test]
    fn poisson_rate_is_respected() {
        // util 0.5 × rate 0.2/µs = 0.1 arrivals/µs → mean IAT 10 µs.
        let mut g = ArrivalGen::new(TrafficShape::Poisson { util: 0.5 }, 0.2, 7).unwrap();
        let n = 50_000;
        let mut t = 0.0;
        for _ in 0..n {
            t = g.next_arrival();
        }
        let mean_iat = t / n as f64;
        assert!((mean_iat - 10.0).abs() < 0.3, "mean IAT {mean_iat}");
    }

    #[test]
    fn burst_concentrates_arrivals_in_on_phase() {
        let shape = TrafficShape::Burst { util: 0.4, mult: 4.0, period_us: 1000.0, duty: 0.25 };
        let mut g = ArrivalGen::new(shape, 0.1, 9).unwrap();
        let mut on = 0u32;
        let mut total = 0u32;
        for _ in 0..20_000 {
            let t = g.next_arrival();
            total += 1;
            if (t / 1000.0).fract() < 0.25 {
                on += 1;
            }
        }
        // On-phase carries mult×duty/(mult×duty + (1−duty)) = 4/7 ≈ 57%.
        let frac = on as f64 / total as f64;
        assert!((0.47..0.67).contains(&frac), "on-phase fraction {frac}");
    }

    #[test]
    fn zero_rate_is_an_error_not_a_release_mode_hang() {
        // Regression: `rate_per_us = 0` (or a zero peak) was guarded only
        // by a debug_assert!, so release builds spun forever inside
        // next_arrival. Now construction fails up front.
        assert!(ArrivalGen::new(TrafficShape::Poisson { util: 0.5 }, 0.0, 1).is_err());
        assert!(ArrivalGen::new(TrafficShape::Poisson { util: 0.5 }, -1.0, 1).is_err());
        assert!(ArrivalGen::new(TrafficShape::Poisson { util: 0.5 }, f64::NAN, 1).is_err());
        // A shape whose peak_util is 0 is equally unrunnable, whatever
        // the reference rate (unreachable via parse, but the constructor
        // is public API).
        let flat = TrafficShape::Ramp { from: 0.0, to: 0.0, duration_us: 100.0 };
        assert!(ArrivalGen::new(flat, 1.0, 1).is_err());
    }

    #[test]
    fn burst_duty_zero_envelope_matches_the_curve() {
        // Regression: duty = 0 means the on-phase never happens, but
        // peak_util() still reported util × mult — a 3× inflated thinning
        // envelope that skewed (and wasted 2/3 of) the RNG draws.
        let b = TrafficShape::Burst { util: 0.5, mult: 3.0, period_us: 1000.0, duty: 0.0 };
        assert_eq!(b.peak_util(), 0.5);
        for t in [0.0, 1.0, 250.0, 999.9, 1000.0] {
            assert_eq!(b.util_at(t), 0.5, "duty-0 burst must stay flat at t={t}");
        }
        // The generated process is plain Poisson at util × rate:
        // util 0.5 × rate 0.2/µs = 0.1 arrivals/µs → mean IAT 10 µs.
        let mut g = ArrivalGen::new(b, 0.2, 7).unwrap();
        let n = 50_000;
        let mut t = 0.0;
        for _ in 0..n {
            t = g.next_arrival();
        }
        let mean_iat = t / n as f64;
        assert!((mean_iat - 10.0).abs() < 0.3, "mean IAT {mean_iat}");
    }

    #[test]
    fn ramp_from_idle_generates_a_cold_start() {
        // Regression: `validate` required from > 0, so the cold-start
        // shape could not be expressed at all.
        let r = TrafficShape::parse("ramp:0:0.8:1000").unwrap();
        assert_eq!(r.util_at(0.0), 0.0);
        assert!((r.util_at(500.0) - 0.4).abs() < 1e-12);
        assert_eq!(r.util_at(5000.0), 0.8);
        assert_eq!(r.peak_util(), 0.8);
        let mut g = ArrivalGen::new(r, 0.5, 11).unwrap();
        let mut last = 0.0;
        let mut first = f64::INFINITY;
        for _ in 0..5_000 {
            let t = g.next_arrival();
            assert!(t > last);
            first = first.min(t);
            last = t;
        }
        // Thinning rejects the zero-rate region: no arrival lands at the
        // very start, and the stream still makes progress.
        assert!(first > 0.0);
        assert!(last > 1000.0, "ramp never left the cold-start region");
    }
}
