//! Pluggable event schedulers for the cluster engine (DESIGN.md §13).
//!
//! The discrete-event engine needs one operation pair — `push(t, seq, ev)`
//! and `pop() -> (t, seq, ev)` — delivered in a *contractual* total order:
//! ascending [`event_key`] `(t.to_bits(), seq)`, where `seq` is the
//! engine's monotone schedule counter. Because the key is explicit, every
//! implementation of [`Scheduler`] is interchangeable bit-for-bit: the
//! binary heap ([`HeapQueue`], the original engine core, kept alive as a
//! cross-check oracle) and the calendar queue ([`CalendarQueue`], the
//! default) produce byte-identical `slofetch cluster` stdout, which the
//! CI determinism gate (`ci/determinism.sh`) enforces on every example
//! spec.
//!
//! ## Monotonicity contract
//!
//! Schedulers may assume pushes never go backwards in time: a `push(t, ..)`
//! after a `pop()` that returned time `p` satisfies `t >= p` (in `to_bits`
//! order; all simulation times are non-negative and finite). Pushing *at*
//! the frontier (`t == p`) is explicitly allowed — the engine does it for
//! zero-length service draws and zero-backoff retries. The engine
//! guarantees the contract — service times are non-negative, arrival
//! streams are non-decreasing, and every fault/timeout/hedge event is
//! scheduled at or after the current simulation time — and the calendar
//! queue exploits it to keep its wheel window anchored at the current
//! tick. A `debug_assert!` checks the contract on every push.
//!
//! ## Stale events (lazy cancellation)
//!
//! Schedulers never remove or reorder an event once pushed: there is no
//! `cancel` operation, by design. A consumer that needs to cancel work —
//! a timed-out attempt, the losing half of a hedged request, work
//! requeued off a crashed replica — instead stamps each event with a
//! generation counter at push time and *discards stale events at pop*,
//! when the stamped generation no longer matches the current one (see
//! the engine's per-`(slot, service)` attempt generations, DESIGN.md
//! §14). Both backends therefore deliver cancelled events exactly like
//! live ones — in ascending [`event_key`] order — which keeps the two
//! implementations interchangeable bit-for-bit and keeps cancellation
//! O(1) regardless of queue depth.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

/// The contractual total order on events: ascending `(t.to_bits(), seq)`.
///
/// `f64::to_bits` is order-preserving for the non-negative finite times the
/// engine produces, and `seq` (the engine's monotone schedule counter)
/// breaks ties so simultaneous events pop in schedule order — never in
/// container-internal order.
#[inline]
pub fn event_key(t: f64, seq: u64) -> (u64, u64) {
    (t.to_bits(), seq)
}

/// A pending-event queue delivering items in ascending [`event_key`] order.
pub trait Scheduler<T> {
    /// Create an empty scheduler sized for roughly `cap` pending events.
    fn with_capacity(cap: usize) -> Self
    where
        Self: Sized;
    /// Insert an event. `seq` must be strictly monotone across pushes and
    /// `t` must not precede the last popped time (see the module docs).
    fn push(&mut self, t: f64, seq: u64, item: T);
    /// Remove and return the minimum event by [`event_key`].
    fn pop(&mut self) -> Option<(f64, u64, T)>;
    /// Number of pending events.
    fn len(&self) -> usize;
    /// True when no events are pending.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Which [`Scheduler`] backend a cluster run uses (`ClusterSpec.scheduler`
/// / `slofetch cluster --scheduler`). The knob only serializes when
/// non-default, so pre-existing spec JSON and campaign-store content
/// hashes are unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SchedKind {
    /// The original `BinaryHeap` core: the cross-check oracle.
    Heap,
    /// Bucketed timing wheel with an overflow ladder (the default).
    #[default]
    Calendar,
}

impl SchedKind {
    /// Parse the spec/CLI spelling (`"heap"` / `"calendar"`).
    pub fn parse(s: &str) -> Result<SchedKind> {
        match s {
            "heap" => Ok(SchedKind::Heap),
            "calendar" => Ok(SchedKind::Calendar),
            other => bail!("unknown scheduler '{other}' (expected 'heap' or 'calendar')"),
        }
    }

    /// Canonical spelling (inverse of [`SchedKind::parse`]).
    pub fn label(self) -> &'static str {
        match self {
            SchedKind::Heap => "heap",
            SchedKind::Calendar => "calendar",
        }
    }
}

struct HeapNode<T> {
    t_bits: u64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for HeapNode<T> {
    fn eq(&self, other: &Self) -> bool {
        (self.t_bits, self.seq) == (other.t_bits, other.seq)
    }
}
impl<T> Eq for HeapNode<T> {}
impl<T> PartialOrd for HeapNode<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapNode<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t_bits, self.seq).cmp(&(other.t_bits, other.seq))
    }
}

/// The original engine core: a `BinaryHeap<Reverse<_>>` min-heap on
/// [`event_key`]. O(log n) per operation, zero tuning. Kept as the
/// cross-check oracle for the calendar queue.
pub struct HeapQueue<T> {
    heap: BinaryHeap<Reverse<HeapNode<T>>>,
}

impl<T> Scheduler<T> for HeapQueue<T> {
    fn with_capacity(cap: usize) -> Self {
        HeapQueue {
            heap: BinaryHeap::with_capacity(cap),
        }
    }

    fn push(&mut self, t: f64, seq: u64, item: T) {
        self.heap.push(Reverse(HeapNode {
            t_bits: t.to_bits(),
            seq,
            item,
        }));
    }

    fn pop(&mut self) -> Option<(f64, u64, T)> {
        self.heap
            .pop()
            .map(|Reverse(n)| (f64::from_bits(n.t_bits), n.seq, n.item))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 20;
/// Ticks are clamped here so `t * inv_width as u64` can never overflow
/// into nonsense; anything at or beyond the clamp lives in the ladder
/// until the wheel advances close enough to place it exactly.
const MAX_TICK: u64 = 1 << 62;

struct Node<T> {
    t_bits: u64,
    seq: u64,
    /// Cached `tick_of(t)` so refill sweeps never touch the float.
    tick: u64,
    item: T,
}

/// Calendar queue: a power-of-two bucketed timing wheel with a single-rung
/// overflow ladder, O(1) amortized push/pop under the monotone-push
/// contract.
///
/// Geometry: `buckets.len()` consecutive ticks starting at `cur_tick` map
/// bijectively onto the bucket array via `tick & mask`; events further out
/// go to the `ladder` (an unsorted spill vector with a cached minimum
/// tick) and migrate into the wheel when `cur_tick` catches up. Equal
/// `(tick, t_bits)` groups drain in one batch sorted by `seq`, so the
/// per-event cost of simultaneous completions (fan-out joins, burst
/// arrivals) is one `Vec::pop`. The bucket vectors double as node arenas:
/// resizes move nodes between them but recycle every allocation through
/// `pool`, so a steady-state run stops allocating entirely.
///
/// Resize policy (live event density): grow 2× when the wheel holds more
/// than 2 events per bucket, shrink 2× when total pending drops below
/// an eighth of the bucket count; each resize re-derives the bucket
/// `width` from the live span (`span / n * 2`, clamped to `[1e-9, 1e18]`
/// microseconds) and re-anchors `cur_tick` at the earliest pending event.
pub struct CalendarQueue<T> {
    buckets: Vec<Vec<Node<T>>>,
    mask: u64,
    width: f64,
    inv_width: f64,
    /// The wheel window is ticks `[cur_tick, cur_tick + buckets.len())`.
    cur_tick: u64,
    /// Nodes currently in `buckets` (excludes ladder and batch).
    wheel_len: usize,
    ladder: Vec<Node<T>>,
    ladder_min_tick: u64,
    /// The current equal-`(tick, t_bits)` group, sorted by descending
    /// `seq` so `pop` serves ascending `seq` from the back.
    batch: Vec<Node<T>>,
    /// Spare bucket vectors recycled across resizes.
    pool: Vec<Vec<Node<T>>>,
    len: usize,
    /// Last popped `t.to_bits()`, for the monotonicity `debug_assert!`.
    last_bits: u64,
}

impl<T> CalendarQueue<T> {
    #[inline]
    fn tick_of(&self, t: f64) -> u64 {
        let x = t * self.inv_width;
        if x >= MAX_TICK as f64 {
            MAX_TICK
        } else {
            x as u64
        }
    }

    /// Move every pending node into a geometry with `new_nb` buckets,
    /// adapting `width` to the live density and re-anchoring `cur_tick`.
    fn resize(&mut self, new_nb: usize) {
        let new_nb = new_nb.clamp(MIN_BUCKETS, MAX_BUCKETS);
        let mut all: Vec<Node<T>> = Vec::with_capacity(self.wheel_len + self.ladder.len());
        for b in &mut self.buckets {
            all.append(b);
        }
        all.append(&mut self.ladder);
        while self.buckets.len() > new_nb {
            let b = self.buckets.pop().expect("length checked");
            debug_assert!(b.is_empty());
            self.pool.push(b);
        }
        while self.buckets.len() < new_nb {
            self.buckets.push(self.pool.pop().unwrap_or_default());
        }
        self.mask = new_nb as u64 - 1;
        if all.len() >= 2 {
            let mut min_bits = u64::MAX;
            let mut max_bits = 0u64;
            for n in &all {
                min_bits = min_bits.min(n.t_bits);
                max_bits = max_bits.max(n.t_bits);
            }
            let span = f64::from_bits(max_bits) - f64::from_bits(min_bits);
            if span > 0.0 && span.is_finite() {
                let w = (span / all.len() as f64 * 2.0).clamp(1e-9, 1e18);
                self.width = w;
                self.inv_width = 1.0 / w;
            }
        }
        self.wheel_len = 0;
        self.ladder_min_tick = u64::MAX;
        if let Some(min_bits) = all.iter().map(|n| n.t_bits).min() {
            self.cur_tick = self.tick_of(f64::from_bits(min_bits));
        }
        let nb = new_nb as u64;
        for mut n in all {
            n.tick = self.tick_of(f64::from_bits(n.t_bits)).max(self.cur_tick);
            if n.tick >= self.cur_tick + nb {
                self.ladder_min_tick = self.ladder_min_tick.min(n.tick);
                self.ladder.push(n);
            } else {
                self.wheel_len += 1;
                self.buckets[(n.tick & self.mask) as usize].push(n);
            }
        }
    }

    /// Refill `batch` with the minimum `(tick, t_bits)` group. Caller
    /// guarantees at least one event is pending outside the batch.
    fn refill(&mut self) {
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 8 {
            let target = (self.buckets.len() / 2).max(MIN_BUCKETS);
            self.resize(target);
        }
        loop {
            let nb = self.buckets.len() as u64;
            // Migrate ladder nodes that now fall inside the wheel window.
            if self.ladder_min_tick < self.cur_tick + nb {
                let horizon = self.cur_tick + nb;
                let mut i = 0;
                while i < self.ladder.len() {
                    if self.ladder[i].tick < horizon {
                        let n = self.ladder.swap_remove(i);
                        self.wheel_len += 1;
                        self.buckets[(n.tick & self.mask) as usize].push(n);
                    } else {
                        i += 1;
                    }
                }
                self.ladder_min_tick =
                    self.ladder.iter().map(|n| n.tick).min().unwrap_or(u64::MAX);
            }
            if self.wheel_len == 0 {
                // Far-future jump: everything pending lives in the ladder.
                debug_assert!(!self.ladder.is_empty());
                self.cur_tick = self.ladder_min_tick;
                continue;
            }
            // Sweep the window for the first occupied tick. Inside the
            // window the tick -> bucket map is a bijection, so a bucket is
            // either empty or holds exactly one tick's nodes.
            let mut due = None;
            for off in 0..nb {
                let tick = self.cur_tick + off;
                let b = &self.buckets[(tick & self.mask) as usize];
                if b.iter().any(|n| n.tick == tick) {
                    due = Some(tick);
                    break;
                }
            }
            // Defensive fallback: if a clamped tick ever escaped the
            // window invariant, serve the global minimum instead of
            // looping forever.
            let tick = match due {
                Some(t) => t,
                None => self
                    .buckets
                    .iter()
                    .flat_map(|b| b.iter().map(|n| n.tick))
                    .min()
                    .expect("wheel_len > 0"),
            };
            self.cur_tick = tick;
            let bucket = &mut self.buckets[(tick & self.mask) as usize];
            let mut min_bits = u64::MAX;
            for n in bucket.iter() {
                if n.tick == tick && n.t_bits < min_bits {
                    min_bits = n.t_bits;
                }
            }
            let mut i = 0;
            while i < bucket.len() {
                if bucket[i].tick == tick && bucket[i].t_bits == min_bits {
                    self.batch.push(bucket.swap_remove(i));
                    self.wheel_len -= 1;
                } else {
                    i += 1;
                }
            }
            // Serve ascending seq by popping from the back.
            self.batch.sort_unstable_by(|a, b| b.seq.cmp(&a.seq));
            return;
        }
    }
}

impl<T> Scheduler<T> for CalendarQueue<T> {
    fn with_capacity(cap: usize) -> Self {
        let nb = cap.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        CalendarQueue {
            buckets: (0..nb).map(|_| Vec::new()).collect(),
            mask: nb as u64 - 1,
            width: 1.0,
            inv_width: 1.0,
            cur_tick: 0,
            wheel_len: 0,
            ladder: Vec::new(),
            ladder_min_tick: u64::MAX,
            batch: Vec::new(),
            pool: Vec::new(),
            len: 0,
            last_bits: 0,
        }
    }

    fn push(&mut self, t: f64, seq: u64, item: T) {
        debug_assert!(
            t.to_bits() >= self.last_bits,
            "monotone-push contract violated: push at t={t} precedes the last pop"
        );
        let tick = self.tick_of(t).max(self.cur_tick);
        let nb = self.buckets.len() as u64;
        let node = Node {
            t_bits: t.to_bits(),
            seq,
            tick,
            item,
        };
        if tick >= self.cur_tick + nb {
            self.ladder_min_tick = self.ladder_min_tick.min(tick);
            self.ladder.push(node);
        } else {
            self.wheel_len += 1;
            self.buckets[(tick & self.mask) as usize].push(node);
        }
        self.len += 1;
        if self.wheel_len > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            let target = self.buckets.len() * 2;
            self.resize(target);
        }
    }

    fn pop(&mut self) -> Option<(f64, u64, T)> {
        if self.batch.is_empty() {
            if self.len == 0 {
                return None;
            }
            self.refill();
        }
        let n = self.batch.pop().expect("refill produced a batch");
        self.len -= 1;
        self.last_bits = n.t_bits;
        Some((f64::from_bits(n.t_bits), n.seq, n.item))
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain<S: Scheduler<u32>>(s: &mut S) -> Vec<(u64, u64, u32)> {
        let mut out = Vec::new();
        while let Some((t, seq, item)) = s.pop() {
            out.push((t.to_bits(), seq, item));
        }
        out
    }

    #[test]
    fn both_backends_agree_on_a_fixed_stream() {
        let times = [0.5, 0.5, 3.25, 0.5, 17.0, 3.25, 1e9, 2.0, 0.5, 42.0];
        let mut h = HeapQueue::with_capacity(4);
        let mut c = CalendarQueue::with_capacity(4);
        for (i, &t) in times.iter().enumerate() {
            h.push(t, i as u64, i as u32);
            c.push(t, i as u64, i as u32);
        }
        assert_eq!(h.len(), c.len());
        let a = drain(&mut h);
        let b = drain(&mut c);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "ascending event_key");
        assert!(h.is_empty() && c.is_empty());
    }

    #[test]
    fn equal_timestamps_pop_in_seq_order() {
        // Regression for the (time, seq) ordering contract: simultaneous
        // events must pop in schedule order on every backend.
        let mut c = CalendarQueue::with_capacity(16);
        let mut h = HeapQueue::with_capacity(16);
        for seq in 0..64u64 {
            c.push(7.0, seq, seq as u32);
            h.push(7.0, seq, seq as u32);
        }
        for want in 0..64u64 {
            let (tc, sc, ic) = c.pop().expect("calendar has events");
            let (th, sh, ih) = h.pop().expect("heap has events");
            assert_eq!((tc.to_bits(), sc, ic), (th.to_bits(), sh, ih));
            assert_eq!(sc, want);
        }
        assert!(c.pop().is_none() && h.pop().is_none());
    }

    #[test]
    fn interleaved_monotone_pushes_stay_ordered() {
        let mut c = CalendarQueue::with_capacity(4);
        let mut h = HeapQueue::with_capacity(4);
        let mut seq = 0u64;
        let mut now = 0.0f64;
        let mut push = |c: &mut CalendarQueue<u32>, h: &mut HeapQueue<u32>, t: f64| {
            c.push(t, seq, seq as u32);
            h.push(t, seq, seq as u32);
            seq += 1;
        };
        for round in 0..200 {
            let base = now;
            for k in 0..5u32 {
                // Quantized offsets force duplicate timestamps.
                push(&mut c, &mut h, base + f64::from(k % 3) * 0.25);
            }
            let (t, s, i) = c.pop().expect("pending");
            let (th, sh, ih) = h.pop().expect("pending");
            assert_eq!((t.to_bits(), s, i), (th.to_bits(), sh, ih), "round {round}");
            now = t;
        }
        assert_eq!(drain(&mut c), drain(&mut h));
    }

    #[test]
    fn pushes_at_the_pop_frontier_are_allowed_on_both_backends() {
        // The contract allows t == last-popped time (zero-length service
        // draws, zero-backoff retries). Neither backend may reorder or
        // reject them.
        let mut c = CalendarQueue::with_capacity(4);
        let mut h = HeapQueue::with_capacity(4);
        let mut seq = 0u64;
        for t in [1.0, 2.0, 3.0] {
            c.push(t, seq, seq as u32);
            h.push(t, seq, seq as u32);
            seq += 1;
        }
        let mut popped = Vec::new();
        while let Some((t, s, i)) = c.pop() {
            let hh = h.pop().expect("heap in lockstep");
            assert_eq!((t.to_bits(), s, i), (hh.0.to_bits(), hh.1, hh.2));
            popped.push((t.to_bits(), s, i));
            if popped.len() <= 3 {
                // Push exactly at the frontier; it must pop next-or-later
                // in seq order, never panic or vanish.
                c.push(t, seq, seq as u32);
                h.push(t, seq, seq as u32);
                seq += 1;
            }
        }
        assert!(h.pop().is_none());
        assert_eq!(popped.len(), 6);
        assert!(popped.windows(2).all(|w| w[0] < w[1]), "ascending event_key");
    }

    #[test]
    fn lazily_cancelled_events_drain_identically_on_both_backends() {
        // Stale-event semantics: there is no cancel operation — consumers
        // stamp events with a generation and discard mismatches at pop.
        // Both backends must deliver live AND stale events in the same
        // order, so the consumer-side discard is backend-invariant.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xFA_17);
        for round in 0..20 {
            let mut c = CalendarQueue::with_capacity(8);
            let mut h = HeapQueue::with_capacity(8);
            // Generation per logical item; bumping cancels pending events.
            let mut gen = [0u32; 16];
            let mut seq = 0u64;
            let mut now = 0.0f64;
            let mut live_c = Vec::new();
            let mut live_h = Vec::new();
            for _ in 0..300 {
                match rng.below(3) {
                    0 => {
                        let id = rng.below(16) as usize;
                        let t = now + rng.f64() * 50.0;
                        c.push(t, seq, (id as u32, gen[id]));
                        h.push(t, seq, (id as u32, gen[id]));
                        seq += 1;
                    }
                    1 => {
                        // Cancel: every pending event for this id goes stale.
                        let id = rng.below(16) as usize;
                        gen[id] += 1;
                    }
                    _ => {
                        let a = c.pop();
                        let b = h.pop();
                        match (a, b) {
                            (None, None) => {}
                            (Some((t, s, (id, g))), Some((th, sh, ih))) => {
                                assert_eq!(
                                    (t.to_bits(), s, (id, g)),
                                    (th.to_bits(), sh, ih),
                                    "round {round}"
                                );
                                now = t;
                                // Consumer-side discard of stale events.
                                if g == gen[id as usize] {
                                    live_c.push((t.to_bits(), s, id));
                                }
                                if ih.1 == gen[ih.0 as usize] {
                                    live_h.push((th.to_bits(), sh, ih.0));
                                }
                            }
                            (a, b) => panic!("backends disagree on emptiness: {a:?} vs {b:?}"),
                        }
                    }
                }
            }
            while let Some((t, s, i)) = c.pop() {
                let hh = h.pop().expect("heap in lockstep during final drain");
                assert_eq!((t.to_bits(), s, i), (hh.0.to_bits(), hh.1, hh.2));
            }
            assert!(h.pop().is_none());
            assert_eq!(live_c, live_h, "round {round}");
        }
    }

    #[test]
    fn parse_and_label_roundtrip() {
        assert_eq!(SchedKind::parse("heap").unwrap(), SchedKind::Heap);
        assert_eq!(SchedKind::parse("calendar").unwrap(), SchedKind::Calendar);
        assert_eq!(SchedKind::default(), SchedKind::Calendar);
        for k in [SchedKind::Heap, SchedKind::Calendar] {
            assert_eq!(SchedKind::parse(k.label()).unwrap(), k);
        }
        assert!(SchedKind::parse("splay").is_err());
    }

    #[test]
    fn event_key_orders_by_time_then_seq() {
        assert!(event_key(1.0, 9) < event_key(2.0, 0));
        assert!(event_key(2.0, 0) < event_key(2.0, 1));
        assert_eq!(event_key(0.0, 0), (0, 0));
    }
}
