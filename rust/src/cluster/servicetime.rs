//! Per-request service-time models for the cluster simulator
//! (DESIGN.md §8 "Service-time models").
//!
//! Two models behind one sampling interface ([`ServiceTimeModel`]):
//!
//! - **Analytic** — the original `instrs_per_req / IPC` mean with
//!   lognormal-flavored jitter (`cv`). This is the unchanged default:
//!   its RNG consumption and arithmetic are bit-identical to the
//!   pre-model engine, so existing analytic scenarios reproduce exactly.
//! - **Empirical** — trace-replayed per-request times: an instruction
//!   trace is segmented on the `ctx` tag ([`crate::trace::Record`]) into
//!   per-request cycle counts, and the resulting distribution is stored
//!   as a compact fixed-size [`QuantileTable`] sampled by inverse-CDF.
//!   The table is *normalized to unit mean*, so the service's measured
//!   `mean_us` (and therefore every load/SLO anchor) is shared with the
//!   analytic model — only the per-request *shape* (burstiness, tail
//!   weight) comes from the trace.
//!
//! Determinism (DESIGN.md §8): an empirical sample consumes exactly
//! **one** uniform draw mapped through the table — never a variable
//! number — so the engine's RNG stream stays a pure function of the
//! event order at any thread count.

use crate::util::rng::{mix64, Rng};
use anyhow::{bail, Result};

/// Points in a quantile table (64 intervals + both endpoints).
pub const QUANTILE_POINTS: usize = 65;

/// Minimum per-request trace segments required to fit an empirical
/// distribution; fewer means the trace has no usable `ctx` structure.
pub const MIN_SEGMENTS: usize = 16;

/// A compact fixed-size inverse-CDF table over a unit-mean distribution
/// of per-request service-time multipliers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantileTable {
    /// Quantile values at ranks i/(QUANTILE_POINTS−1), ascending.
    q: [f64; QUANTILE_POINTS],
}

impl QuantileTable {
    /// Fit a table to raw samples (e.g. per-request cycle counts),
    /// normalizing to unit mean. Non-finite and non-positive samples are
    /// dropped (zero-cycle `ctx` runs are segmentation artifacts, not
    /// requests); fitting fails below [`MIN_SEGMENTS`] usable samples.
    pub fn normalized(samples: &[f64]) -> Result<QuantileTable> {
        let mut xs: Vec<f64> =
            samples.iter().copied().filter(|x| x.is_finite() && *x > 0.0).collect();
        if xs.len() < MIN_SEGMENTS {
            bail!(
                "empirical service-time model needs ≥ {MIN_SEGMENTS} usable trace \
                 segments, got {} (does the trace carry ctx tags?)",
                xs.len()
            );
        }
        xs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mut q = [0.0f64; QUANTILE_POINTS];
        for (i, slot) in q.iter_mut().enumerate() {
            let rank = i as f64 / (QUANTILE_POINTS - 1) as f64 * (n - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            let frac = rank - lo as f64;
            *slot = xs[lo] * (1.0 - frac) + xs[hi] * frac;
        }
        // `sample` is piecewise-linear in a uniform draw, so its expected
        // value is the table's *trapezoid* mean (not the raw sample mean
        // — the 65-point linearization clips curvature in the tail).
        // Normalize by it so E[sample(U)] is exactly 1 and empirical
        // scenarios share the analytic model's mean service time — and
        // therefore every load/SLO anchor — by construction.
        let trapezoid: f64 = q.windows(2).map(|w| (w[0] + w[1]) * 0.5).sum::<f64>()
            / (QUANTILE_POINTS - 1) as f64;
        if !(trapezoid.is_finite() && trapezoid > 0.0) {
            bail!("empirical service-time distribution has non-positive mean");
        }
        for slot in &mut q {
            *slot /= trapezoid;
        }
        Ok(QuantileTable { q })
    }

    /// Inverse-CDF lookup: map one uniform draw `u ∈ [0, 1)` through the
    /// table with linear interpolation. Exactly one draw per sample —
    /// the §8 one-draw rule the determinism contract relies on.
    pub fn sample(&self, u: f64) -> f64 {
        let pos = u.clamp(0.0, 1.0) * (QUANTILE_POINTS - 1) as f64;
        let lo = pos as usize;
        if lo + 1 >= QUANTILE_POINTS {
            return self.q[QUANTILE_POINTS - 1];
        }
        let frac = pos - lo as f64;
        self.q[lo] * (1.0 - frac) + self.q[lo + 1] * frac
    }

    /// Smallest multiplier in the table.
    pub fn min(&self) -> f64 {
        self.q[0]
    }

    /// Largest multiplier in the table.
    pub fn max(&self) -> f64 {
        self.q[QUANTILE_POINTS - 1]
    }

    /// Stable content fingerprint of the table (diagnostics/tests; the
    /// campaign cell hash covers the *inputs* the table is a pure
    /// function of — spec JSON plus trace-file bytes — instead).
    pub fn fingerprint(&self) -> u64 {
        let mut h = mix64(QUANTILE_POINTS as u64);
        for v in &self.q {
            h = mix64(h ^ v.to_bits());
        }
        h
    }
}

/// How the engine draws one request's service time at a service.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServiceTimeModel {
    /// Mean service time with lognormal-flavored jitter (the original
    /// analytic path, unchanged bit-for-bit).
    Analytic { mean_us: f64, cv: f64 },
    /// Mean service time scaled by a trace-replayed unit-mean multiplier
    /// drawn from a [`QuantileTable`].
    Empirical { mean_us: f64, table: QuantileTable },
}

impl ServiceTimeModel {
    /// Mean service time (µs) — what capacity anchors and the bottleneck
    /// search use; identical across the two models by construction.
    pub fn mean_us(&self) -> f64 {
        match self {
            ServiceTimeModel::Analytic { mean_us, .. }
            | ServiceTimeModel::Empirical { mean_us, .. } => *mean_us,
        }
    }

    /// Draw one service time (µs). Analytic consumes one normal draw
    /// (two uniforms via Box–Muller, as before); empirical consumes
    /// exactly one uniform draw (inverse-CDF).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            ServiceTimeModel::Analytic { mean_us, cv } => {
                // Same lognormal-flavored jitter as the rpc tandem model.
                let jitter = (cv * rng.normal() - 0.5 * cv * cv).exp();
                mean_us * jitter.clamp(0.05, 8.0)
            }
            ServiceTimeModel::Empirical { mean_us, table } => mean_us * table.sample(rng.f64()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lognormal_samples(n: usize, seed: u64) -> Vec<f64> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| (0.4 * r.normal()).exp() * 1000.0).collect()
    }

    #[test]
    fn table_is_unit_mean_and_monotone() {
        let t = QuantileTable::normalized(&lognormal_samples(50_000, 7)).unwrap();
        assert!(t.min() > 0.0);
        assert!(t.min() <= t.max());
        for i in 1..QUANTILE_POINTS {
            assert!(t.q[i] >= t.q[i - 1], "table not monotone at {i}");
        }
        // E[sample(U)] is the table's trapezoid mean, renormalized to be
        // exactly 1 — empirical scenarios share the analytic model's
        // load/SLO anchors by construction.
        let trapezoid: f64 = t.q.windows(2).map(|w| (w[0] + w[1]) * 0.5).sum::<f64>()
            / (QUANTILE_POINTS - 1) as f64;
        assert!((trapezoid - 1.0).abs() < 1e-12, "trapezoid mean {trapezoid}");
        // And many inverse-CDF draws agree.
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| t.sample(r.f64())).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn sample_hits_endpoints_and_interpolates() {
        let t = QuantileTable::normalized(&lognormal_samples(10_000, 3)).unwrap();
        assert_eq!(t.sample(0.0), t.min());
        assert_eq!(t.sample(1.0), t.max());
        let mid = t.sample(0.5);
        assert!(t.min() <= mid && mid <= t.max());
        // Out-of-range draws clamp instead of indexing out of bounds.
        assert_eq!(t.sample(-0.5), t.min());
        assert_eq!(t.sample(2.0), t.max());
    }

    #[test]
    fn too_few_or_degenerate_samples_fail() {
        assert!(QuantileTable::normalized(&[]).is_err());
        assert!(QuantileTable::normalized(&[1.0; MIN_SEGMENTS - 1]).is_err());
        // Zeros and non-finite values are dropped before the count check.
        let mut xs = vec![0.0; 100];
        xs.push(f64::NAN);
        assert!(QuantileTable::normalized(&xs).is_err());
        // Exactly MIN_SEGMENTS usable samples fit.
        assert!(QuantileTable::normalized(&[2.0; MIN_SEGMENTS]).is_ok());
    }

    #[test]
    fn fingerprint_tracks_content() {
        let a = QuantileTable::normalized(&lognormal_samples(10_000, 3)).unwrap();
        let b = QuantileTable::normalized(&lognormal_samples(10_000, 3)).unwrap();
        let c = QuantileTable::normalized(&lognormal_samples(10_000, 4)).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn analytic_model_matches_legacy_jitter_formula() {
        // Exact reproduction of the pre-model engine arithmetic: same
        // draws, same clamp, same order.
        let model = ServiceTimeModel::Analytic { mean_us: 10.0, cv: 0.35 };
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            let got = model.sample(&mut a);
            let cv = 0.35f64;
            let jitter = (cv * b.normal() - 0.5 * cv * cv).exp();
            let want = 10.0 * jitter.clamp(0.05, 8.0);
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn empirical_model_scales_the_table_by_the_mean() {
        let t = QuantileTable::normalized(&lognormal_samples(20_000, 9)).unwrap();
        let model = ServiceTimeModel::Empirical { mean_us: 8.0, table: t };
        assert_eq!(model.mean_us(), 8.0);
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| model.sample(&mut r)).sum::<f64>() / n as f64;
        assert!((mean - 8.0).abs() < 0.2, "mean {mean}");
        // One uniform draw per sample: two generators in lockstep.
        let mut x = Rng::new(77);
        let mut y = Rng::new(77);
        for _ in 0..100 {
            model.sample(&mut x);
            y.f64();
        }
        assert_eq!(x.next_u64(), y.next_u64(), "empirical sample is not one draw");
    }
}
