//! Declarative cluster-scenario specs: a JSON document (serde-free, via
//! [`crate::util::json`], same discipline as `campaign::spec`) naming a
//! request-DAG topology, the prefetcher configs to evaluate, the traffic
//! shapes to offer, the SLO, and the autoscaler policies — expanded by
//! [`super::run_spec`] into (config × shape) scenarios plus one
//! control-loop scenario per (policy × shape).

use super::faults::FaultsSpec;
use super::slo::Policy;
use super::topology::{ServiceSpec, Topology};
use super::workload::TrafficShape;
use crate::cli::parse_prefetcher;
use crate::coordinator::tenant::WayPartition;
use crate::trace::gen::apps;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Default L1-I way count the tenant partition divides.
pub const DEFAULT_TOTAL_WAYS: u32 = 8;

/// Default interference dilation coefficient α (DESIGN.md §10).
pub const DEFAULT_INTERFERENCE: f64 = 0.8;

/// One tenant binding in a multi-tenant cluster spec (DESIGN.md §10): a
/// named, dep-closed sub-DAG of the shared topology plus the tenant's
/// own traffic shape, SLO target, and L1-I way partition share.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Service names this tenant's requests traverse. Must be
    /// *dep-closed* (every dependency of a member is a member); empty =
    /// every service.
    pub services: Vec<String>,
    /// Traffic-shape spec ([`TrafficShape::parse`]) driving this
    /// tenant's open-loop arrivals.
    pub traffic: String,
    /// Per-tenant latency SLO in µs; 0 = the scenario's derived SLO.
    pub slo_us: f64,
    /// L1-I ways locked to this tenant
    /// ([`WayPartition`] share; Σ over tenants must fit `total_ways`).
    pub ways: u32,
    /// Ways this tenant's working set actually wants. Demand beyond the
    /// locked share spills into co-runners: the interference dilation is
    /// derived from co-runners' overflow and the per-replica outstanding
    /// mix (see the engine's `dilation`).
    pub demand_ways: u32,
}

/// A complete cluster experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub topology: Topology,
    /// Prefetcher configs (CLI syntax). Listing order only sets report
    /// order: the load/SLO anchor is the slowest *measured* config, and
    /// the adaptive scenario orders its candidates by measured service
    /// time (slowest first) before upgrading rightwards.
    pub prefetchers: Vec<String>,
    /// Traffic-shape specs (see [`TrafficShape::parse`]).
    pub traffic: Vec<String>,
    /// Requests per scenario.
    pub requests: u64,
    /// Records per (app, prefetcher) IPC measurement cell.
    pub records: u64,
    pub seed: u64,
    /// Latency SLO in µs; 0 = derive as 4× the slowest config's
    /// zero-load critical path.
    pub slo_us: f64,
    /// Offered load as a fraction of the slowest measured (baseline)
    /// config's bottleneck rate; shapes scale relative to this.
    pub utilization: f64,
    /// Legacy flag: also run the reactive-control-loop scenario per
    /// traffic shape (shorthand for `policies: ["reactive"]`; mutually
    /// exclusive with an explicit `policies` list).
    pub adaptive: bool,
    /// Autoscaler policies ([`Policy::parse`] syntax) — one control-loop
    /// scenario per (policy × traffic shape).
    pub policies: Vec<String>,
    /// Per-request service-time model (DESIGN.md §8): `"analytic"` (the
    /// default — `instrs_per_req / IPC` mean with lognormal jitter) or
    /// `"empirical"` (trace-replayed: each measurement trace is
    /// segmented on its `ctx` tag into per-request cycle counts, and
    /// scenarios sample that distribution via an inverse-CDF quantile
    /// table). Empirical mode additionally runs an analytic twin of
    /// every static scenario so the cluster report can compare models.
    pub service_times: String,
    /// Multi-tenant co-location (DESIGN.md §10): 2+ named tenants whose
    /// requests share the same replica pool. Empty (the default) keeps
    /// the single-tenant path — and its output — bit-identical.
    pub tenants: Vec<TenantSpec>,
    /// Total L1-I ways the tenant [`WayPartition`] divides.
    pub total_ways: u32,
    /// Interference dilation coefficient α: a replica serving one
    /// tenant while co-runners' way demand exceeds their locked shares
    /// dilates its service time by up to `1 + α`.
    pub interference: f64,
    /// Per-cell sketch telemetry knob (DESIGN.md §12), forwarded to the
    /// IPC measurement cells' `SimConfig::telemetry`: `"exact"` (the
    /// default — nothing recorded, output byte-identical to pre-sketch
    /// builds), `"sketch[:GEOM]"`, or `"compare[:GEOM]"`. Non-exact
    /// runs additionally surface a merged fleet summary (tables +
    /// metrics JSONL).
    pub telemetry: String,
    /// Event-scheduler backend (DESIGN.md §13): `"calendar"` (the
    /// default timing wheel) or `"heap"` (the original binary heap,
    /// kept as a cross-check oracle). Both produce byte-identical
    /// output; the knob only serializes when non-default so existing
    /// spec JSON and campaign-store content hashes are unchanged.
    pub scheduler: String,
    /// Fault injection (DESIGN.md §14): a seeded schedule of replica
    /// crashes / gray failures / brownouts plus per-edge client
    /// policies (timeout, retries, hedging). Empty (the default) keeps
    /// every scenario on the exact pre-fault code path — and its output
    /// — bit-identical.
    pub faults: FaultsSpec,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            name: "cluster".into(),
            topology: Topology { services: Vec::new(), freq_ghz: 2.5 },
            prefetchers: Vec::new(),
            traffic: vec!["poisson:0.65".into()],
            requests: 100_000,
            records: 60_000,
            seed: 7,
            slo_us: 0.0,
            utilization: 1.0,
            adaptive: false,
            policies: Vec::new(),
            service_times: "analytic".into(),
            tenants: Vec::new(),
            total_ways: DEFAULT_TOTAL_WAYS,
            interference: DEFAULT_INTERFERENCE,
            telemetry: "exact".into(),
            scheduler: "calendar".into(),
            faults: FaultsSpec::default(),
        }
    }
}

impl ClusterSpec {
    /// Whether scenarios replay trace-measured (empirical) service times.
    pub fn empirical(&self) -> bool {
        self.service_times == "empirical"
    }

    /// Whether this spec co-locates multiple tenants (DESIGN.md §10).
    pub fn tenancy(&self) -> bool {
        !self.tenants.is_empty()
    }

    /// Resolve one tenant's member-service indexes (topology order).
    /// Empty `services` = every service. Errors on unknown names,
    /// duplicates, and sets that are not dep-closed (a member whose
    /// dependency is outside the set would deadlock its requests).
    pub fn tenant_services(&self, tenant: usize) -> Result<Vec<u32>> {
        let t = &self.tenants[tenant];
        let svc = &self.topology.services;
        let mut member = vec![false; svc.len()];
        if t.services.is_empty() {
            member.iter_mut().for_each(|m| *m = true);
        } else {
            for name in &t.services {
                let i = svc.iter().position(|s| &s.name == name).with_context(|| {
                    format!("tenant '{}': unknown service '{name}'", t.name)
                })?;
                if member[i] {
                    bail!("tenant '{}': duplicate service '{name}'", t.name);
                }
                member[i] = true;
            }
        }
        for (i, s) in svc.iter().enumerate() {
            if !member[i] {
                continue;
            }
            for d in &s.deps {
                let p = svc
                    .iter()
                    .position(|x| &x.name == d)
                    .with_context(|| format!("service '{}': unknown dep '{d}'", s.name))?;
                if !member[p] {
                    bail!(
                        "tenant '{}': service '{}' depends on '{d}', which is outside \
                         the tenant's set (tenant sub-DAGs must be dep-closed)",
                        t.name,
                        s.name
                    );
                }
            }
        }
        Ok((0..svc.len() as u32).filter(|&i| member[i as usize]).collect())
    }

    pub fn validate(&self) -> Result<()> {
        if self.prefetchers.is_empty() {
            bail!("cluster '{}' lists no prefetchers", self.name);
        }
        if self.traffic.is_empty() {
            bail!("cluster '{}' lists no traffic shapes", self.name);
        }
        if self.requests == 0 || self.records == 0 {
            bail!("cluster '{}' has requests = 0 or records = 0", self.name);
        }
        if self.utilization <= 0.0 || !self.utilization.is_finite() {
            bail!("cluster '{}': utilization must be > 0", self.name);
        }
        if self.slo_us < 0.0 {
            bail!("cluster '{}': slo_us must be ≥ 0 (0 = derived)", self.name);
        }
        self.topology.validate().with_context(|| format!("in cluster '{}'", self.name))?;
        for s in &self.topology.services {
            apps::app(&s.app).with_context(|| {
                format!("service '{}': unknown app '{}' (see `slofetch apps`)", s.name, s.app)
            })?;
        }
        let mut seen = std::collections::HashSet::new();
        for pf in &self.prefetchers {
            parse_prefetcher(pf).with_context(|| format!("in cluster '{}'", self.name))?;
            if !seen.insert(pf.to_lowercase()) {
                bail!("cluster '{}': duplicate prefetcher '{pf}'", self.name);
            }
        }
        let mut seen = std::collections::HashSet::new();
        for t in &self.traffic {
            let shape =
                TrafficShape::parse(t).with_context(|| format!("in cluster '{}'", self.name))?;
            if !seen.insert(shape.label()) {
                bail!("cluster '{}': duplicate traffic shape '{t}'", self.name);
            }
        }
        if self.adaptive && !self.policies.is_empty() {
            bail!(
                "cluster '{}': set either 'adaptive' or 'policies', not both \
                 (adaptive is shorthand for policies = [\"reactive\"])",
                self.name
            );
        }
        let mut seen = std::collections::HashSet::new();
        for p in &self.policies {
            let policy = Policy::parse(p).with_context(|| format!("in cluster '{}'", self.name))?;
            if !seen.insert(policy.label()) {
                bail!("cluster '{}': duplicate policy '{p}'", self.name);
            }
        }
        if !matches!(self.service_times.as_str(), "analytic" | "empirical") {
            bail!(
                "cluster '{}': service_times must be 'analytic' or 'empirical', got '{}'",
                self.name,
                self.service_times
            );
        }
        if !self.empirical() {
            if let Some(s) = self.topology.services.iter().find(|s| s.trace.is_some()) {
                bail!(
                    "cluster '{}': service '{}' names a trace file but service_times \
                     is '{}' — set service_times to 'empirical' (traces are ignored \
                     by the analytic model, which would silently drop them)",
                    self.name,
                    s.name,
                    self.service_times
                );
            }
        }
        crate::obs::telemetry::TelemetryCfg::parse(&self.telemetry)
            .with_context(|| format!("in cluster '{}'", self.name))?;
        super::sched::SchedKind::parse(&self.scheduler)
            .with_context(|| format!("in cluster '{}'", self.name))?;
        if !self.faults.is_empty() {
            if !self.tenants.is_empty() {
                bail!(
                    "cluster '{}': faults and tenants are mutually exclusive for now \
                     (the tenant engine path has no fault axis yet)",
                    self.name
                );
            }
            let names: Vec<String> =
                self.topology.services.iter().map(|s| s.name.clone()).collect();
            let replicas: Vec<u32> =
                self.topology.services.iter().map(|s| s.replicas).collect();
            self.faults
                .validate(&names, &replicas)
                .with_context(|| format!("in cluster '{}'", self.name))?;
        }
        if !self.interference.is_finite() || self.interference < 0.0 {
            bail!(
                "cluster '{}': interference must be finite and ≥ 0, got {}",
                self.name,
                self.interference
            );
        }
        if self.total_ways == 0 {
            bail!("cluster '{}': total_ways must be ≥ 1", self.name);
        }
        if !self.tenants.is_empty() {
            self.validate_tenants()?;
        }
        Ok(())
    }

    /// Tenant-section validation (called with ≥ 1 tenant declared).
    fn validate_tenants(&self) -> Result<()> {
        if self.tenants.len() < 2 {
            bail!(
                "cluster '{}': tenant co-location needs ≥ 2 tenants (got {})",
                self.name,
                self.tenants.len()
            );
        }
        if self.tenants.len() > u8::MAX as usize {
            bail!("cluster '{}': at most {} tenants", self.name, u8::MAX);
        }
        if self.empirical() {
            bail!(
                "cluster '{}': tenants currently require the analytic service-time \
                 model (drop service_times = \"empirical\")",
                self.name
            );
        }
        if self.adaptive || !self.policies.is_empty() {
            bail!(
                "cluster '{}': tenants run their own control loop (per-tenant burn \
                 arbitrating repartition/upgrade/add-replica) — drop 'adaptive' and \
                 'policies'",
                self.name
            );
        }
        let mut partition = WayPartition::new(self.total_ways);
        let mut seen = std::collections::HashSet::new();
        for (ti, t) in self.tenants.iter().enumerate() {
            if t.name.is_empty() {
                bail!("cluster '{}': tenant #{ti} has an empty name", self.name);
            }
            // Reserved: "coloc" would make a tenant's solo-scenario
            // label collide with the co-located run's "{config}@coloc",
            // and both modes name campaign cell key segments.
            if matches!(t.name.to_lowercase().as_str(), "coloc" | "solo") {
                bail!(
                    "cluster '{}': tenant name '{}' is reserved (scenario labels)",
                    self.name,
                    t.name
                );
            }
            if !seen.insert(t.name.to_lowercase()) {
                bail!("cluster '{}': duplicate tenant name '{}'", self.name, t.name);
            }
            TrafficShape::parse(&t.traffic)
                .with_context(|| format!("tenant '{}' in cluster '{}'", t.name, self.name))?;
            if t.slo_us < 0.0 {
                bail!("tenant '{}': slo_us must be ≥ 0 (0 = derived)", t.name);
            }
            if t.ways == 0 || t.demand_ways == 0 {
                bail!("tenant '{}': ways and demand_ways must be ≥ 1", t.name);
            }
            partition
                .assign(ti as u8, t.ways)
                .map_err(|e| anyhow::anyhow!("tenant '{}': way partition {e}", t.name))?;
            let members = self
                .tenant_services(ti)
                .with_context(|| format!("in cluster '{}'", self.name))?;
            if members.is_empty() {
                bail!("tenant '{}': empty service set", t.name);
            }
        }
        Ok(())
    }

    /// Parsed autoscaler policies: the explicit `policies` list, or the
    /// legacy `adaptive` flag mapped to a single reactive policy.
    pub fn effective_policies(&self) -> Result<Vec<Policy>> {
        if !self.policies.is_empty() {
            self.policies.iter().map(|p| Policy::parse(p)).collect()
        } else if self.adaptive {
            Ok(vec![Policy::Reactive])
        } else {
            Ok(Vec::new())
        }
    }

    /// Distinct (measurement source, prefetcher-label) pairs needing a
    /// simulation: the source is a service's app preset name, or its
    /// `.slft` trace path when one overrides it ([`ServiceSpec::source`]).
    pub fn ipc_cells(&self) -> Vec<(String, String)> {
        let mut sources_seen: Vec<String> = Vec::new();
        for s in &self.topology.services {
            let src = s.source();
            if !sources_seen.contains(&src) {
                sources_seen.push(src);
            }
        }
        let mut out = Vec::new();
        for src in &sources_seen {
            for pf in &self.prefetchers {
                out.push((src.clone(), pf.to_lowercase()));
            }
        }
        out
    }

    /// Scenario count: prefetchers × shapes (×2 in empirical mode — each
    /// static scenario runs under both service-time models so the report
    /// can compare them), plus shapes again per autoscaler policy. In
    /// tenant mode (DESIGN.md §10): one solo run per (config, tenant),
    /// one co-located run per config, plus the adaptive tenant-control
    /// scenario (tenant shapes replace the `traffic` axis).
    pub fn scenario_count(&self) -> usize {
        if self.tenancy() {
            return self.prefetchers.len() * (self.tenants.len() + 1) + 1;
        }
        let n_pol = if self.policies.is_empty() {
            usize::from(self.adaptive)
        } else {
            self.policies.len()
        };
        let models = if self.empirical() { 2 } else { 1 };
        (self.prefetchers.len() * models + n_pol) * self.traffic.len()
    }

    // ---------- JSON (de)serialization ----------

    pub fn to_json(&self) -> Json {
        let services = self
            .topology
            .services
            .iter()
            .map(|s| {
                let mut fields = vec![
                    ("name", Json::str(&s.name)),
                    ("app", Json::str(&s.app)),
                    ("replicas", Json::num(s.replicas as f64)),
                    ("instrs_per_req", Json::num(s.instrs_per_req)),
                    ("cv", Json::num(s.cv)),
                    (
                        "deps",
                        Json::Arr(s.deps.iter().map(|d| Json::str(d)).collect()),
                    ),
                ];
                if let Some(t) = &s.trace {
                    fields.push(("trace", Json::str(t)));
                }
                Json::obj(fields)
            })
            .collect();
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("services", Json::Arr(services)),
            ("freq_ghz", Json::num(self.topology.freq_ghz)),
            (
                "prefetchers",
                Json::Arr(self.prefetchers.iter().map(|p| Json::str(p)).collect()),
            ),
            (
                "traffic",
                Json::Arr(self.traffic.iter().map(|t| Json::str(t)).collect()),
            ),
            ("requests", Json::num(self.requests as f64)),
            ("records", Json::num(self.records as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("slo_us", Json::num(self.slo_us)),
            ("utilization", Json::num(self.utilization)),
            ("adaptive", Json::Bool(self.adaptive)),
            (
                "policies",
                Json::Arr(self.policies.iter().map(|p| Json::str(p)).collect()),
            ),
        ];
        // Emitted only when non-default (as with per-service `trace`):
        // the canonical JSON of an analytic spec stays byte-identical to
        // pre-empirical builds, so campaign cluster-cell content hashes
        // — and therefore store resume — are unchanged for existing
        // analytic campaigns.
        if self.service_times != "analytic" {
            fields.push(("service_times", Json::str(&self.service_times)));
        }
        // Same discipline for the tenant section: a tenant-less spec
        // serializes exactly as pre-tenancy builds did, so old campaign
        // stores keep resuming with 0 recomputed cells, and a tenant
        // cell's content hash moves only when a tenant binding (or the
        // partition geometry) changes.
        if !self.tenants.is_empty() {
            let tenants = self
                .tenants
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("name", Json::str(&t.name)),
                        (
                            "services",
                            Json::Arr(t.services.iter().map(|s| Json::str(s)).collect()),
                        ),
                        ("traffic", Json::str(&t.traffic)),
                        ("slo_us", Json::num(t.slo_us)),
                        ("ways", Json::num(t.ways as f64)),
                        ("demand_ways", Json::num(t.demand_ways as f64)),
                    ])
                })
                .collect();
            fields.push(("tenants", Json::Arr(tenants)));
        }
        if self.total_ways != DEFAULT_TOTAL_WAYS {
            fields.push(("total_ways", Json::num(self.total_ways as f64)));
        }
        if self.interference != DEFAULT_INTERFERENCE {
            fields.push(("interference", Json::num(self.interference)));
        }
        // Non-default only, like service_times: the knob is absent from
        // exact-mode spec JSON, keeping campaign content hashes (and
        // store resume) unchanged for every existing campaign.
        if self.telemetry != "exact" {
            fields.push(("telemetry", Json::str(&self.telemetry)));
        }
        // Same discipline for the scheduler backend: both backends give
        // byte-identical results, so only the non-default oracle request
        // is worth writing down.
        if self.scheduler != "calendar" {
            fields.push(("scheduler", Json::str(&self.scheduler)));
        }
        // And for the fault section: fault-free specs — i.e. every spec
        // written before the fault axis existed — serialize byte-for-byte
        // as they always did, so campaign content hashes and store resume
        // are untouched.
        if !self.faults.is_empty() {
            fields.push(("faults", self.faults.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<ClusterSpec> {
        let mut spec = ClusterSpec::default();
        if let Some(n) = j.get("name").and_then(Json::as_str) {
            spec.name = n.to_string();
        }
        let services = j
            .get("services")
            .and_then(Json::as_arr)
            .context("cluster spec: 'services' must be an array")?;
        for (i, s) in services.iter().enumerate() {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| format!("service #{i}: missing 'name'"))?;
            let app = s
                .get("app")
                .and_then(Json::as_str)
                .with_context(|| format!("service '{name}': missing 'app'"))?;
            let deps = match s.get("deps") {
                None => Vec::new(),
                Some(d) => d
                    .as_arr()
                    .with_context(|| format!("service '{name}': 'deps' must be an array"))?
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .map(str::to_string)
                            .with_context(|| format!("service '{name}': deps must be strings"))
                    })
                    .collect::<Result<_>>()?,
            };
            spec.topology.services.push(ServiceSpec {
                name: name.to_string(),
                app: app.to_string(),
                replicas: s.get("replicas").and_then(Json::as_u64).unwrap_or(1) as u32,
                instrs_per_req: s
                    .get("instrs_per_req")
                    .and_then(Json::as_f64)
                    .unwrap_or(25_000.0),
                cv: s.get("cv").and_then(Json::as_f64).unwrap_or(0.35),
                deps,
                trace: s.get("trace").and_then(Json::as_str).map(str::to_string),
            });
        }
        if let Some(f) = j.get("freq_ghz").and_then(Json::as_f64) {
            spec.topology.freq_ghz = f;
        }
        let strings = |key: &str| -> Result<Option<Vec<String>>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_arr()
                    .with_context(|| format!("cluster spec: '{key}' must be an array"))?
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .map(str::to_string)
                            .with_context(|| format!("'{key}' entries must be strings"))
                    })
                    .collect::<Result<_>>()
                    .map(Some),
            }
        };
        spec.prefetchers = strings("prefetchers")?.unwrap_or_default();
        if let Some(t) = strings("traffic")? {
            spec.traffic = t;
        }
        if let Some(v) = j.get("requests").and_then(Json::as_u64) {
            spec.requests = v;
        }
        if let Some(v) = j.get("records").and_then(Json::as_u64) {
            spec.records = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            spec.seed = v;
        }
        if let Some(v) = j.get("slo_us").and_then(Json::as_f64) {
            spec.slo_us = v;
        }
        if let Some(v) = j.get("utilization").and_then(Json::as_f64) {
            spec.utilization = v;
        }
        if let Some(v) = j.get("adaptive").and_then(Json::as_bool) {
            spec.adaptive = v;
        }
        if let Some(p) = strings("policies")? {
            spec.policies = p;
        }
        if let Some(v) = j.get("service_times").and_then(Json::as_str) {
            spec.service_times = v.to_string();
        }
        if let Some(arr) = j.get("tenants").and_then(Json::as_arr) {
            for (i, t) in arr.iter().enumerate() {
                let name = t
                    .get("name")
                    .and_then(Json::as_str)
                    .with_context(|| format!("tenant #{i}: missing 'name'"))?;
                let traffic = t
                    .get("traffic")
                    .and_then(Json::as_str)
                    .with_context(|| format!("tenant '{name}': missing 'traffic'"))?;
                let services = match t.get("services") {
                    None => Vec::new(),
                    Some(v) => v
                        .as_arr()
                        .with_context(|| format!("tenant '{name}': 'services' must be an array"))?
                        .iter()
                        .map(|s| {
                            s.as_str().map(str::to_string).with_context(|| {
                                format!("tenant '{name}': services must be strings")
                            })
                        })
                        .collect::<Result<_>>()?,
                };
                // Way counts are load-bearing (they set the interference
                // shield/overflow): a missing or malformed value must
                // error, never silently default — and an out-of-range
                // one must not truncate through `as u32`.
                let ways_of = |key: &str| -> Result<Option<u32>> {
                    match t.get(key) {
                        None => Ok(None),
                        Some(v) => {
                            let w = v.as_u64().with_context(|| {
                                format!("tenant '{name}': '{key}' must be an integer")
                            })?;
                            u32::try_from(w).map(Some).map_err(|_| {
                                anyhow::anyhow!("tenant '{name}': '{key}' = {w} out of range")
                            })
                        }
                    }
                };
                let ways = ways_of("ways")?
                    .with_context(|| format!("tenant '{name}': missing 'ways'"))?;
                // The SLO target is as load-bearing as the way counts:
                // absent means "derived", but a wrong-typed value is an
                // error, never a silent fallback.
                let slo_us = match t.get("slo_us") {
                    None => 0.0,
                    Some(v) => v.as_f64().with_context(|| {
                        format!("tenant '{name}': 'slo_us' must be a number")
                    })?,
                };
                spec.tenants.push(TenantSpec {
                    name: name.to_string(),
                    services,
                    traffic: traffic.to_string(),
                    slo_us,
                    ways,
                    demand_ways: ways_of("demand_ways")?.unwrap_or(ways),
                });
            }
        }
        if let Some(v) = j.get("total_ways").and_then(Json::as_u64) {
            spec.total_ways = u32::try_from(v)
                .map_err(|_| anyhow::anyhow!("cluster spec: total_ways = {v} out of range"))?;
        }
        if let Some(v) = j.get("interference").and_then(Json::as_f64) {
            spec.interference = v;
        }
        if let Some(v) = j.get("telemetry").and_then(Json::as_str) {
            spec.telemetry = v.to_string();
        }
        if let Some(v) = j.get("scheduler").and_then(Json::as_str) {
            spec.scheduler = v.to_string();
        }
        if let Some(f) = j.get("faults") {
            spec.faults = FaultsSpec::from_json(f)?;
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn load(path: &Path) -> Result<ClusterSpec> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        Self::from_json(&j).with_context(|| format!("in {path:?}"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("write {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ClusterSpec {
        ClusterSpec {
            name: "t".into(),
            topology: Topology {
                services: vec![
                    ServiceSpec {
                        name: "gw".into(),
                        app: "admission".into(),
                        replicas: 2,
                        instrs_per_req: 25_000.0,
                        cv: 0.35,
                        deps: vec![],
                        trace: None,
                    },
                    ServiceSpec {
                        name: "search".into(),
                        app: "websearch".into(),
                        replicas: 2,
                        instrs_per_req: 40_000.0,
                        cv: 0.4,
                        deps: vec!["gw".into()],
                        trace: None,
                    },
                ],
                freq_ghz: 2.5,
            },
            prefetchers: vec!["nl".into(), "ceip256".into()],
            traffic: vec!["poisson:0.6".into(), "burst:0.5:3:40000:0.25".into()],
            requests: 10_000,
            records: 5_000,
            seed: 3,
            slo_us: 0.0,
            utilization: 1.0,
            adaptive: true,
            policies: Vec::new(),
            service_times: "analytic".into(),
            tenants: Vec::new(),
            total_ways: DEFAULT_TOTAL_WAYS,
            interference: DEFAULT_INTERFERENCE,
            telemetry: "exact".into(),
            scheduler: "calendar".into(),
            faults: FaultsSpec::default(),
        }
    }

    #[test]
    fn validates_and_counts() {
        let s = small();
        assert!(s.validate().is_ok());
        // (2 prefetchers + adaptive) × 2 shapes.
        assert_eq!(s.scenario_count(), 6);
        // 2 apps × 2 prefetchers.
        assert_eq!(s.ipc_cells().len(), 4);
    }

    #[test]
    fn json_roundtrip() {
        let s = small();
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn rejects_bad_specs() {
        let mut bad = small();
        bad.prefetchers = vec!["bogus9".into()];
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.traffic = vec!["tsunami".into()];
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.topology.services[1].app = "nope".into();
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.topology.services[1].deps = vec!["missing".into()];
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.prefetchers = vec!["nl".into(), "NL".into()];
        assert!(bad.validate().is_err(), "case-normalized duplicate not caught");

        let mut bad = small();
        bad.adaptive = false;
        bad.policies = vec!["chaos-monkey".into()];
        assert!(bad.validate().is_err(), "unknown policy not caught");

        let mut bad = small();
        bad.policies = vec!["reactive".into()];
        assert!(bad.validate().is_err(), "adaptive + policies must conflict");

        let mut bad = small();
        bad.adaptive = false;
        bad.policies = vec!["reactive".into(), "REACTIVE".into()];
        assert!(bad.validate().is_err(), "duplicate policy not caught");
    }

    #[test]
    fn policy_axis_counts_and_roundtrips() {
        let mut s = small();
        s.adaptive = false;
        s.policies =
            vec!["reactive".into(), "hysteresis".into(), "cost-aware:262144".into()];
        assert!(s.validate().is_ok());
        // (2 prefetchers + 3 policies) × 2 shapes.
        assert_eq!(s.scenario_count(), 10);
        assert_eq!(s.effective_policies().unwrap().len(), 3);
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Legacy adaptive flag maps to one reactive policy.
        let legacy = small();
        assert_eq!(legacy.effective_policies().unwrap(), vec![Policy::Reactive]);
    }

    #[test]
    fn empirical_mode_roundtrips_counts_and_validates() {
        let mut s = small();
        s.service_times = "empirical".into();
        assert!(s.validate().is_ok());
        assert!(s.empirical());
        // Statics double (analytic twin per config), adaptive stays 1×:
        // (2 prefetchers × 2 models + 1 policy) × 2 shapes.
        assert_eq!(s.scenario_count(), 10);
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);

        // Per-service trace files ride along and key the IPC cells.
        s.topology.services[1].trace = Some("/tmp/ws.slft".into());
        assert!(s.validate().is_ok());
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        let cells = s.ipc_cells();
        assert!(cells.iter().any(|(src, _)| src == "file:/tmp/ws.slft"), "{cells:?}");
        assert!(cells.iter().any(|(src, _)| src == "admission"));

        // Unknown model names and analytic-mode traces are rejected.
        let mut bad = small();
        bad.service_times = "psychic".into();
        assert!(bad.validate().is_err(), "unknown service_times not caught");
        let mut bad = small();
        bad.topology.services[0].trace = Some("/tmp/x.slft".into());
        assert!(bad.validate().is_err(), "trace without empirical mode not caught");
    }

    fn two_tenants() -> Vec<TenantSpec> {
        vec![
            TenantSpec {
                name: "web".into(),
                services: vec!["gw".into(), "search".into()],
                traffic: "poisson:0.5".into(),
                slo_us: 0.0,
                ways: 4,
                demand_ways: 6,
            },
            TenantSpec {
                name: "batch".into(),
                services: Vec::new(), // all services
                traffic: "burst:0.3:3:40000:0.25".into(),
                slo_us: 120.0,
                ways: 4,
                demand_ways: 4,
            },
        ]
    }

    fn tenant_spec() -> ClusterSpec {
        ClusterSpec { tenants: two_tenants(), adaptive: false, ..small() }
    }

    #[test]
    fn tenant_spec_validates_counts_and_roundtrips() {
        let s = tenant_spec();
        assert!(s.validate().is_ok());
        assert!(s.tenancy());
        // 2 configs × (2 solos + 1 coloc) + the tenant-ctrl scenario.
        assert_eq!(s.scenario_count(), 7);
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Member resolution: explicit subset vs the empty-means-all form.
        assert_eq!(s.tenant_services(0).unwrap(), vec![0, 1]);
        assert_eq!(s.tenant_services(1).unwrap(), vec![0, 1]);
        // demand_ways defaults to ways when the JSON omits it.
        let j = Json::parse(
            r#"{
                "services": [{"name": "a", "app": "crypto"}],
                "prefetchers": ["nl"],
                "tenants": [
                    {"name": "t0", "traffic": "poisson:0.4", "ways": 3},
                    {"name": "t1", "traffic": "poisson:0.4", "ways": 5}
                ]
            }"#,
        )
        .unwrap();
        let s = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(s.tenants[0].demand_ways, 3);
        assert_eq!(s.total_ways, DEFAULT_TOTAL_WAYS);
        assert_eq!(s.interference, DEFAULT_INTERFERENCE);
    }

    #[test]
    fn tenantless_spec_serializes_exactly_as_before() {
        // The tenant fields must not leak into a single-tenant spec's
        // canonical JSON: campaign cluster-cell content hashes — and
        // therefore store resume — depend on it byte-for-byte.
        let dump = small().to_json().dump();
        assert!(!dump.contains("tenants"), "tenant key leaked: {dump}");
        assert!(!dump.contains("total_ways"), "total_ways leaked: {dump}");
        assert!(!dump.contains("interference"), "interference leaked: {dump}");
        assert!(!dump.contains("telemetry"), "telemetry key leaked: {dump}");
        assert!(!dump.contains("scheduler"), "scheduler key leaked: {dump}");
        assert!(!dump.contains("faults"), "faults key leaked: {dump}");
        // Non-default partition geometry still round-trips.
        let s = ClusterSpec { total_ways: 16, interference: 0.5, ..tenant_spec() };
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn telemetry_knob_validates_and_roundtrips() {
        // Non-default knob round-trips through JSON.
        let s = ClusterSpec { telemetry: "compare:w256d4p10k16".into(), ..small() };
        assert!(s.validate().is_ok());
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert!(s.to_json().dump().contains("\"telemetry\":\"compare:w256d4p10k16\""));
        // Default geometry forms are accepted too.
        for ok in ["exact", "sketch", "compare", "sketch:w64d2p8k4"] {
            let s = ClusterSpec { telemetry: ok.into(), ..small() };
            assert!(s.validate().is_ok(), "rejected '{ok}'");
        }
        // Garbage modes and geometries are rejected at validate().
        for bad in ["psychic", "sketch:128x4", "compare:w0d4p10k16", "exact:w64d4p10k16"] {
            let s = ClusterSpec { telemetry: bad.into(), ..small() };
            assert!(s.validate().is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn scheduler_knob_validates_and_roundtrips() {
        // The non-default oracle request round-trips through JSON.
        let s = ClusterSpec { scheduler: "heap".into(), ..small() };
        assert!(s.validate().is_ok());
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert!(s.to_json().dump().contains("\"scheduler\":\"heap\""));
        // The default spelling validates but never serializes (checked
        // byte-for-byte by tenantless_spec_serializes_exactly_as_before).
        let dflt = ClusterSpec { scheduler: "calendar".into(), ..small() };
        assert!(dflt.validate().is_ok());
        // Unknown backends are rejected at validate().
        for bad in ["splay", "ladder", "", "Heap"] {
            let s = ClusterSpec { scheduler: bad.into(), ..small() };
            assert!(s.validate().is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn fault_section_validates_and_roundtrips() {
        use super::super::faults::{ClientPolicySpec, EdgePolicy};
        let faulted = |events: Vec<&str>, client: Vec<ClientPolicySpec>| ClusterSpec {
            faults: FaultsSpec {
                events: events.into_iter().map(str::to_string).collect(),
                client,
            },
            ..small()
        };
        let s = faulted(
            vec!["down:gw:0:20000:5000", "gray:search:1:3:10000:40000"],
            vec![ClientPolicySpec {
                service: "search".into(),
                policy: EdgePolicy {
                    timeout_us: Some(400.0),
                    retries: 2,
                    backoff_us: 50.0,
                    hedge_after_us: Some(120.0),
                },
            }],
        );
        assert!(s.validate().is_ok());
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert!(s.to_json().dump().contains("\"faults\""));

        // Schedules and policies are validated against the topology.
        let bad = faulted(vec!["down:nope:0:100:100"], vec![]);
        assert!(bad.validate().is_err(), "unknown fault service accepted");
        let bad = faulted(vec!["down:gw:7:100:100"], vec![]);
        assert!(bad.validate().is_err(), "out-of-range replica accepted");
        let bad = faulted(vec!["meteor:gw"], vec![]);
        assert!(bad.validate().is_err(), "unknown fault kind accepted");
        let bad = faulted(
            vec![],
            vec![ClientPolicySpec {
                service: "nope".into(),
                policy: EdgePolicy { retries: 1, ..EdgePolicy::default() },
            }],
        );
        assert!(bad.validate().is_err(), "unknown client-policy service accepted");

        // Faults and tenants are mutually exclusive for now.
        let mut both = tenant_spec();
        both.faults.events = vec!["down:gw:0:100:100".into()];
        assert!(both.validate().is_err(), "faults + tenants must conflict");
    }

    #[test]
    fn tenant_misconfigurations_are_rejected() {
        let mut one = tenant_spec();
        one.tenants.truncate(1);
        assert!(one.validate().is_err(), "a single tenant is not co-location");

        let mut dup = tenant_spec();
        dup.tenants[1].name = "WEB".into();
        assert!(dup.validate().is_err(), "case-normalized duplicate tenant");

        let mut over = tenant_spec();
        over.tenants[1].ways = 5; // 4 + 5 > 8
        assert!(over.validate().is_err(), "oversubscribed way partition");

        let mut unclosed = tenant_spec();
        unclosed.tenants[0].services = vec!["search".into()]; // dep gw missing
        assert!(unclosed.validate().is_err(), "non-dep-closed tenant set");

        let mut unknown = tenant_spec();
        unknown.tenants[0].services = vec!["nope".into()];
        assert!(unknown.validate().is_err(), "unknown tenant service");

        let mut shaped = tenant_spec();
        shaped.tenants[0].traffic = "tsunami".into();
        assert!(shaped.validate().is_err(), "bad tenant traffic shape");

        let mut emp = tenant_spec();
        emp.service_times = "empirical".into();
        assert!(emp.validate().is_err(), "tenants + empirical must be rejected");

        let mut pol = tenant_spec();
        pol.policies = vec!["reactive".into()];
        assert!(pol.validate().is_err(), "tenants + policies must conflict");

        let mut adaptive = tenant_spec();
        adaptive.adaptive = true;
        assert!(adaptive.validate().is_err(), "tenants + adaptive must conflict");

        let mut zero = tenant_spec();
        zero.tenants[0].ways = 0;
        assert!(zero.validate().is_err(), "0-way tenant");

        let mut reserved = tenant_spec();
        reserved.tenants[0].name = "coloc".into();
        assert!(reserved.validate().is_err(), "reserved tenant name 'coloc'");
        reserved.tenants[0].name = "SOLO".into();
        assert!(reserved.validate().is_err(), "reserved tenant name 'solo'");

        // Way counts are load-bearing: missing, malformed, or
        // out-of-range values must error, never default or truncate.
        let parse = |body: &str| {
            ClusterSpec::from_json(
                &Json::parse(&format!(
                    r#"{{
                        "services": [{{"name": "a", "app": "crypto"}}],
                        "prefetchers": ["nl"],
                        "tenants": [
                            {{"name": "t0", "traffic": "poisson:0.4"{body}}},
                            {{"name": "t1", "traffic": "poisson:0.4", "ways": 4}}
                        ]
                    }}"#
                ))
                .unwrap(),
            )
        };
        assert!(parse("").is_err(), "missing 'ways' silently defaulted");
        assert!(parse(r#", "ways": "4""#).is_err(), "string 'ways' accepted");
        assert!(parse(r#", "ways": 4294967297"#).is_err(), "oversized 'ways' truncated");
        assert!(parse(r#", "ways": 4"#).is_ok());
        assert!(
            parse(r#", "ways": 4, "slo_us": "120""#).is_err(),
            "wrong-typed slo_us silently fell back to the derived SLO"
        );

        let mut alpha = tenant_spec();
        alpha.interference = f64::NAN;
        assert!(alpha.validate().is_err(), "NaN interference");
    }

    #[test]
    fn defaults_fill_optional_fields() {
        let j = Json::parse(
            r#"{
                "services": [{"name": "a", "app": "crypto"}],
                "prefetchers": ["nl"]
            }"#,
        )
        .unwrap();
        let s = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(s.topology.services[0].replicas, 1);
        assert_eq!(s.topology.services[0].instrs_per_req, 25_000.0);
        assert_eq!(s.traffic, vec!["poisson:0.65".to_string()]);
        assert!(!s.adaptive);
        assert_eq!(s.service_times, "analytic");
        assert!(!s.empirical());
        assert_eq!(s.topology.services[0].trace, None);
    }
}
