//! Declarative cluster-scenario specs: a JSON document (serde-free, via
//! [`crate::util::json`], same discipline as `campaign::spec`) naming a
//! request-DAG topology, the prefetcher configs to evaluate, the traffic
//! shapes to offer, the SLO, and the autoscaler policies — expanded by
//! [`super::run_spec`] into (config × shape) scenarios plus one
//! control-loop scenario per (policy × shape).

use super::slo::Policy;
use super::topology::{ServiceSpec, Topology};
use super::workload::TrafficShape;
use crate::cli::parse_prefetcher;
use crate::trace::gen::apps;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A complete cluster experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub topology: Topology,
    /// Prefetcher configs (CLI syntax). Listing order only sets report
    /// order: the load/SLO anchor is the slowest *measured* config, and
    /// the adaptive scenario orders its candidates by measured service
    /// time (slowest first) before upgrading rightwards.
    pub prefetchers: Vec<String>,
    /// Traffic-shape specs (see [`TrafficShape::parse`]).
    pub traffic: Vec<String>,
    /// Requests per scenario.
    pub requests: u64,
    /// Records per (app, prefetcher) IPC measurement cell.
    pub records: u64,
    pub seed: u64,
    /// Latency SLO in µs; 0 = derive as 4× the slowest config's
    /// zero-load critical path.
    pub slo_us: f64,
    /// Offered load as a fraction of the slowest measured (baseline)
    /// config's bottleneck rate; shapes scale relative to this.
    pub utilization: f64,
    /// Legacy flag: also run the reactive-control-loop scenario per
    /// traffic shape (shorthand for `policies: ["reactive"]`; mutually
    /// exclusive with an explicit `policies` list).
    pub adaptive: bool,
    /// Autoscaler policies ([`Policy::parse`] syntax) — one control-loop
    /// scenario per (policy × traffic shape).
    pub policies: Vec<String>,
    /// Per-request service-time model (DESIGN.md §8): `"analytic"` (the
    /// default — `instrs_per_req / IPC` mean with lognormal jitter) or
    /// `"empirical"` (trace-replayed: each measurement trace is
    /// segmented on its `ctx` tag into per-request cycle counts, and
    /// scenarios sample that distribution via an inverse-CDF quantile
    /// table). Empirical mode additionally runs an analytic twin of
    /// every static scenario so the cluster report can compare models.
    pub service_times: String,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            name: "cluster".into(),
            topology: Topology { services: Vec::new(), freq_ghz: 2.5 },
            prefetchers: Vec::new(),
            traffic: vec!["poisson:0.65".into()],
            requests: 100_000,
            records: 60_000,
            seed: 7,
            slo_us: 0.0,
            utilization: 1.0,
            adaptive: false,
            policies: Vec::new(),
            service_times: "analytic".into(),
        }
    }
}

impl ClusterSpec {
    /// Whether scenarios replay trace-measured (empirical) service times.
    pub fn empirical(&self) -> bool {
        self.service_times == "empirical"
    }

    pub fn validate(&self) -> Result<()> {
        if self.prefetchers.is_empty() {
            bail!("cluster '{}' lists no prefetchers", self.name);
        }
        if self.traffic.is_empty() {
            bail!("cluster '{}' lists no traffic shapes", self.name);
        }
        if self.requests == 0 || self.records == 0 {
            bail!("cluster '{}' has requests = 0 or records = 0", self.name);
        }
        if self.utilization <= 0.0 || !self.utilization.is_finite() {
            bail!("cluster '{}': utilization must be > 0", self.name);
        }
        if self.slo_us < 0.0 {
            bail!("cluster '{}': slo_us must be ≥ 0 (0 = derived)", self.name);
        }
        self.topology.validate().with_context(|| format!("in cluster '{}'", self.name))?;
        for s in &self.topology.services {
            apps::app(&s.app).with_context(|| {
                format!("service '{}': unknown app '{}' (see `slofetch apps`)", s.name, s.app)
            })?;
        }
        let mut seen = std::collections::HashSet::new();
        for pf in &self.prefetchers {
            parse_prefetcher(pf).with_context(|| format!("in cluster '{}'", self.name))?;
            if !seen.insert(pf.to_lowercase()) {
                bail!("cluster '{}': duplicate prefetcher '{pf}'", self.name);
            }
        }
        let mut seen = std::collections::HashSet::new();
        for t in &self.traffic {
            let shape =
                TrafficShape::parse(t).with_context(|| format!("in cluster '{}'", self.name))?;
            if !seen.insert(shape.label()) {
                bail!("cluster '{}': duplicate traffic shape '{t}'", self.name);
            }
        }
        if self.adaptive && !self.policies.is_empty() {
            bail!(
                "cluster '{}': set either 'adaptive' or 'policies', not both \
                 (adaptive is shorthand for policies = [\"reactive\"])",
                self.name
            );
        }
        let mut seen = std::collections::HashSet::new();
        for p in &self.policies {
            let policy = Policy::parse(p).with_context(|| format!("in cluster '{}'", self.name))?;
            if !seen.insert(policy.label()) {
                bail!("cluster '{}': duplicate policy '{p}'", self.name);
            }
        }
        if !matches!(self.service_times.as_str(), "analytic" | "empirical") {
            bail!(
                "cluster '{}': service_times must be 'analytic' or 'empirical', got '{}'",
                self.name,
                self.service_times
            );
        }
        if !self.empirical() {
            if let Some(s) = self.topology.services.iter().find(|s| s.trace.is_some()) {
                bail!(
                    "cluster '{}': service '{}' names a trace file but service_times \
                     is '{}' — set service_times to 'empirical' (traces are ignored \
                     by the analytic model, which would silently drop them)",
                    self.name,
                    s.name,
                    self.service_times
                );
            }
        }
        Ok(())
    }

    /// Parsed autoscaler policies: the explicit `policies` list, or the
    /// legacy `adaptive` flag mapped to a single reactive policy.
    pub fn effective_policies(&self) -> Result<Vec<Policy>> {
        if !self.policies.is_empty() {
            self.policies.iter().map(|p| Policy::parse(p)).collect()
        } else if self.adaptive {
            Ok(vec![Policy::Reactive])
        } else {
            Ok(Vec::new())
        }
    }

    /// Distinct (measurement source, prefetcher-label) pairs needing a
    /// simulation: the source is a service's app preset name, or its
    /// `.slft` trace path when one overrides it ([`ServiceSpec::source`]).
    pub fn ipc_cells(&self) -> Vec<(String, String)> {
        let mut sources_seen: Vec<String> = Vec::new();
        for s in &self.topology.services {
            let src = s.source();
            if !sources_seen.contains(&src) {
                sources_seen.push(src);
            }
        }
        let mut out = Vec::new();
        for src in &sources_seen {
            for pf in &self.prefetchers {
                out.push((src.clone(), pf.to_lowercase()));
            }
        }
        out
    }

    /// Scenario count: prefetchers × shapes (×2 in empirical mode — each
    /// static scenario runs under both service-time models so the report
    /// can compare them), plus shapes again per autoscaler policy.
    pub fn scenario_count(&self) -> usize {
        let n_pol = if self.policies.is_empty() {
            usize::from(self.adaptive)
        } else {
            self.policies.len()
        };
        let models = if self.empirical() { 2 } else { 1 };
        (self.prefetchers.len() * models + n_pol) * self.traffic.len()
    }

    // ---------- JSON (de)serialization ----------

    pub fn to_json(&self) -> Json {
        let services = self
            .topology
            .services
            .iter()
            .map(|s| {
                let mut fields = vec![
                    ("name", Json::str(&s.name)),
                    ("app", Json::str(&s.app)),
                    ("replicas", Json::num(s.replicas as f64)),
                    ("instrs_per_req", Json::num(s.instrs_per_req)),
                    ("cv", Json::num(s.cv)),
                    (
                        "deps",
                        Json::Arr(s.deps.iter().map(|d| Json::str(d)).collect()),
                    ),
                ];
                if let Some(t) = &s.trace {
                    fields.push(("trace", Json::str(t)));
                }
                Json::obj(fields)
            })
            .collect();
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("services", Json::Arr(services)),
            ("freq_ghz", Json::num(self.topology.freq_ghz)),
            (
                "prefetchers",
                Json::Arr(self.prefetchers.iter().map(|p| Json::str(p)).collect()),
            ),
            (
                "traffic",
                Json::Arr(self.traffic.iter().map(|t| Json::str(t)).collect()),
            ),
            ("requests", Json::num(self.requests as f64)),
            ("records", Json::num(self.records as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("slo_us", Json::num(self.slo_us)),
            ("utilization", Json::num(self.utilization)),
            ("adaptive", Json::Bool(self.adaptive)),
            (
                "policies",
                Json::Arr(self.policies.iter().map(|p| Json::str(p)).collect()),
            ),
        ];
        // Emitted only when non-default (as with per-service `trace`):
        // the canonical JSON of an analytic spec stays byte-identical to
        // pre-empirical builds, so campaign cluster-cell content hashes
        // — and therefore store resume — are unchanged for existing
        // analytic campaigns.
        if self.service_times != "analytic" {
            fields.push(("service_times", Json::str(&self.service_times)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<ClusterSpec> {
        let mut spec = ClusterSpec::default();
        if let Some(n) = j.get("name").and_then(Json::as_str) {
            spec.name = n.to_string();
        }
        let services = j
            .get("services")
            .and_then(Json::as_arr)
            .context("cluster spec: 'services' must be an array")?;
        for (i, s) in services.iter().enumerate() {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| format!("service #{i}: missing 'name'"))?;
            let app = s
                .get("app")
                .and_then(Json::as_str)
                .with_context(|| format!("service '{name}': missing 'app'"))?;
            let deps = match s.get("deps") {
                None => Vec::new(),
                Some(d) => d
                    .as_arr()
                    .with_context(|| format!("service '{name}': 'deps' must be an array"))?
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .map(str::to_string)
                            .with_context(|| format!("service '{name}': deps must be strings"))
                    })
                    .collect::<Result<_>>()?,
            };
            spec.topology.services.push(ServiceSpec {
                name: name.to_string(),
                app: app.to_string(),
                replicas: s.get("replicas").and_then(Json::as_u64).unwrap_or(1) as u32,
                instrs_per_req: s
                    .get("instrs_per_req")
                    .and_then(Json::as_f64)
                    .unwrap_or(25_000.0),
                cv: s.get("cv").and_then(Json::as_f64).unwrap_or(0.35),
                deps,
                trace: s.get("trace").and_then(Json::as_str).map(str::to_string),
            });
        }
        if let Some(f) = j.get("freq_ghz").and_then(Json::as_f64) {
            spec.topology.freq_ghz = f;
        }
        let strings = |key: &str| -> Result<Option<Vec<String>>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_arr()
                    .with_context(|| format!("cluster spec: '{key}' must be an array"))?
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .map(str::to_string)
                            .with_context(|| format!("'{key}' entries must be strings"))
                    })
                    .collect::<Result<_>>()
                    .map(Some),
            }
        };
        spec.prefetchers = strings("prefetchers")?.unwrap_or_default();
        if let Some(t) = strings("traffic")? {
            spec.traffic = t;
        }
        if let Some(v) = j.get("requests").and_then(Json::as_u64) {
            spec.requests = v;
        }
        if let Some(v) = j.get("records").and_then(Json::as_u64) {
            spec.records = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            spec.seed = v;
        }
        if let Some(v) = j.get("slo_us").and_then(Json::as_f64) {
            spec.slo_us = v;
        }
        if let Some(v) = j.get("utilization").and_then(Json::as_f64) {
            spec.utilization = v;
        }
        if let Some(v) = j.get("adaptive").and_then(Json::as_bool) {
            spec.adaptive = v;
        }
        if let Some(p) = strings("policies")? {
            spec.policies = p;
        }
        if let Some(v) = j.get("service_times").and_then(Json::as_str) {
            spec.service_times = v.to_string();
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn load(path: &Path) -> Result<ClusterSpec> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        Self::from_json(&j).with_context(|| format!("in {path:?}"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("write {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ClusterSpec {
        ClusterSpec {
            name: "t".into(),
            topology: Topology {
                services: vec![
                    ServiceSpec {
                        name: "gw".into(),
                        app: "admission".into(),
                        replicas: 2,
                        instrs_per_req: 25_000.0,
                        cv: 0.35,
                        deps: vec![],
                        trace: None,
                    },
                    ServiceSpec {
                        name: "search".into(),
                        app: "websearch".into(),
                        replicas: 2,
                        instrs_per_req: 40_000.0,
                        cv: 0.4,
                        deps: vec!["gw".into()],
                        trace: None,
                    },
                ],
                freq_ghz: 2.5,
            },
            prefetchers: vec!["nl".into(), "ceip256".into()],
            traffic: vec!["poisson:0.6".into(), "burst:0.5:3:40000:0.25".into()],
            requests: 10_000,
            records: 5_000,
            seed: 3,
            slo_us: 0.0,
            utilization: 1.0,
            adaptive: true,
            policies: Vec::new(),
            service_times: "analytic".into(),
        }
    }

    #[test]
    fn validates_and_counts() {
        let s = small();
        assert!(s.validate().is_ok());
        // (2 prefetchers + adaptive) × 2 shapes.
        assert_eq!(s.scenario_count(), 6);
        // 2 apps × 2 prefetchers.
        assert_eq!(s.ipc_cells().len(), 4);
    }

    #[test]
    fn json_roundtrip() {
        let s = small();
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn rejects_bad_specs() {
        let mut bad = small();
        bad.prefetchers = vec!["bogus9".into()];
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.traffic = vec!["tsunami".into()];
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.topology.services[1].app = "nope".into();
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.topology.services[1].deps = vec!["missing".into()];
        assert!(ClusterSpec::from_json(&bad.to_json()).is_err());

        let mut bad = small();
        bad.prefetchers = vec!["nl".into(), "NL".into()];
        assert!(bad.validate().is_err(), "case-normalized duplicate not caught");

        let mut bad = small();
        bad.adaptive = false;
        bad.policies = vec!["chaos-monkey".into()];
        assert!(bad.validate().is_err(), "unknown policy not caught");

        let mut bad = small();
        bad.policies = vec!["reactive".into()];
        assert!(bad.validate().is_err(), "adaptive + policies must conflict");

        let mut bad = small();
        bad.adaptive = false;
        bad.policies = vec!["reactive".into(), "REACTIVE".into()];
        assert!(bad.validate().is_err(), "duplicate policy not caught");
    }

    #[test]
    fn policy_axis_counts_and_roundtrips() {
        let mut s = small();
        s.adaptive = false;
        s.policies =
            vec!["reactive".into(), "hysteresis".into(), "cost-aware:262144".into()];
        assert!(s.validate().is_ok());
        // (2 prefetchers + 3 policies) × 2 shapes.
        assert_eq!(s.scenario_count(), 10);
        assert_eq!(s.effective_policies().unwrap().len(), 3);
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Legacy adaptive flag maps to one reactive policy.
        let legacy = small();
        assert_eq!(legacy.effective_policies().unwrap(), vec![Policy::Reactive]);
    }

    #[test]
    fn empirical_mode_roundtrips_counts_and_validates() {
        let mut s = small();
        s.service_times = "empirical".into();
        assert!(s.validate().is_ok());
        assert!(s.empirical());
        // Statics double (analytic twin per config), adaptive stays 1×:
        // (2 prefetchers × 2 models + 1 policy) × 2 shapes.
        assert_eq!(s.scenario_count(), 10);
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);

        // Per-service trace files ride along and key the IPC cells.
        s.topology.services[1].trace = Some("/tmp/ws.slft".into());
        assert!(s.validate().is_ok());
        let back = ClusterSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        let cells = s.ipc_cells();
        assert!(cells.iter().any(|(src, _)| src == "file:/tmp/ws.slft"), "{cells:?}");
        assert!(cells.iter().any(|(src, _)| src == "admission"));

        // Unknown model names and analytic-mode traces are rejected.
        let mut bad = small();
        bad.service_times = "psychic".into();
        assert!(bad.validate().is_err(), "unknown service_times not caught");
        let mut bad = small();
        bad.topology.services[0].trace = Some("/tmp/x.slft".into());
        assert!(bad.validate().is_err(), "trace without empirical mode not caught");
    }

    #[test]
    fn defaults_fill_optional_fields() {
        let j = Json::parse(
            r#"{
                "services": [{"name": "a", "app": "crypto"}],
                "prefetchers": ["nl"]
            }"#,
        )
        .unwrap();
        let s = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(s.topology.services[0].replicas, 1);
        assert_eq!(s.topology.services[0].instrs_per_req, 25_000.0);
        assert_eq!(s.traffic, vec!["poisson:0.65".to_string()]);
        assert!(!s.adaptive);
        assert_eq!(s.service_times, "analytic");
        assert!(!s.empirical());
        assert_eq!(s.topology.services[0].trace, None);
    }
}
