//! Request-DAG topologies: services with parallel fan-out/fan-in edges
//! and per-service replica pools, generalizing the linear `rpc/` chain
//! (which is recovered exactly as a DAG whose every node has one parent).
//!
//! Two levels: [`ServiceSpec`]/[`Topology`] are the declarative form the
//! JSON spec deserializes into (app preset + prefetcher names), and
//! [`ResolvedTopology`] is the runnable form where each service carries
//! concrete mean service times derived from `sim::engine` IPC
//! measurements — one candidate per prefetcher config, so the SLO
//! control loop can switch between them at run time.

use super::servicetime::{QuantileTable, ServiceTimeModel};
use anyhow::{bail, Result};

/// One service in the declarative DAG.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceSpec {
    pub name: String,
    /// App preset whose instruction stream this service executes
    /// (see `slofetch apps`); supplies the per-prefetcher IPC.
    pub app: String,
    pub replicas: u32,
    /// Mean instructions executed per request at this service.
    pub instrs_per_req: f64,
    /// Coefficient of variation of per-request work (analytic
    /// service-time model; empirical models take their shape from the
    /// trace instead).
    pub cv: f64,
    /// Upstream services (parents): this service starts for a request
    /// once all of them have completed it. Empty = entry point.
    pub deps: Vec<String>,
    /// Optional `.slft` trace file replacing the generated trace for
    /// this service's measurements (empirical service-time mode only;
    /// `None` = generate from the `app` preset).
    pub trace: Option<String>,
}

impl ServiceSpec {
    /// The measurement source this service's (source × config) cells are
    /// keyed by: `file:{path}` when replaying a `.slft` trace, the bare
    /// app preset name otherwise. The prefix keeps the two namespaces
    /// apart (a trace file whose path spells an app name must not merge
    /// with that app's generated-trace cells) while leaving app-keyed
    /// cell seeds byte-identical to pre-trace builds; no app preset can
    /// collide with it (`file:…` is not a valid preset name).
    pub fn source(&self) -> String {
        match &self.trace {
            Some(path) => format!("file:{path}"),
            None => self.app.clone(),
        }
    }
}

/// A declarative request DAG.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    pub services: Vec<ServiceSpec>,
    pub freq_ghz: f64,
}

impl Topology {
    /// A linear chain (the degenerate DAG the `rpc/` tandem model is a
    /// special case of): service i depends on service i−1.
    pub fn linear(names_apps: &[(&str, &str)], instrs_per_req: f64, freq_ghz: f64) -> Topology {
        let services = names_apps
            .iter()
            .enumerate()
            .map(|(i, (name, app))| ServiceSpec {
                name: name.to_string(),
                app: app.to_string(),
                replicas: 1,
                instrs_per_req,
                cv: 0.35,
                deps: if i == 0 {
                    Vec::new()
                } else {
                    vec![names_apps[i - 1].0.to_string()]
                },
                trace: None,
            })
            .collect();
        Topology { services, freq_ghz }
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.services.iter().position(|s| s.name == name)
    }

    /// Structural validation: unique names, known deps, ≥1 replica,
    /// positive work, at least one entry point, and acyclicity.
    pub fn validate(&self) -> Result<()> {
        if self.services.is_empty() {
            bail!("topology has no services");
        }
        if self.freq_ghz <= 0.0 {
            bail!("topology freq_ghz must be > 0, got {}", self.freq_ghz);
        }
        let mut seen = std::collections::HashSet::new();
        for s in &self.services {
            if !seen.insert(s.name.as_str()) {
                bail!("duplicate service name '{}'", s.name);
            }
            if s.replicas == 0 {
                bail!("service '{}' has 0 replicas", s.name);
            }
            if s.instrs_per_req <= 0.0 {
                bail!("service '{}' has non-positive instrs_per_req", s.name);
            }
            if s.cv < 0.0 {
                bail!("service '{}' has negative cv", s.name);
            }
            if s.trace.as_deref() == Some("") {
                bail!("service '{}' has an empty trace path", s.name);
            }
            for d in &s.deps {
                if self.index_of(d).is_none() {
                    bail!("service '{}' depends on unknown service '{d}'", s.name);
                }
                if d == &s.name {
                    bail!("service '{}' depends on itself", s.name);
                }
            }
        }
        self.topo_order()?; // acyclicity + entry-point check
        Ok(())
    }

    /// Kahn topological order over service indexes; errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let n = self.services.len();
        let mut indegree = vec![0u32; n];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, s) in self.services.iter().enumerate() {
            for d in &s.deps {
                let p = self
                    .index_of(d)
                    .ok_or_else(|| anyhow::anyhow!("unknown dep '{d}'"))?;
                children[p].push(i);
                indegree[i] += 1;
            }
        }
        let mut queue: Vec<usize> =
            (0..n).filter(|&i| indegree[i] == 0).collect();
        if queue.is_empty() {
            bail!("topology has no entry point (every service has deps)");
        }
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &c in &children[u] {
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if order.len() != n {
            bail!("topology contains a dependency cycle");
        }
        Ok(order)
    }

    /// Resolve into a runnable topology. `measure_of(source, label)`
    /// returns the measured [`Measure`] (IPC + metadata footprint +
    /// optional empirical quantile table) for a ([`ServiceSpec::source`],
    /// prefetcher config) pair; one candidate service time is derived
    /// per label, in `labels` order (the engine starts every service at
    /// candidate 0, and the SLO control loop may advance to later —
    /// faster — candidates).
    pub fn resolve<F>(&self, labels: &[String], measure_of: F) -> Result<ResolvedTopology>
    where
        F: Fn(&str, &str) -> Option<Measure>,
    {
        self.validate()?;
        if labels.is_empty() {
            bail!("resolve: no prefetcher labels");
        }
        let n = self.services.len();
        let mut services = Vec::with_capacity(n);
        for s in &self.services {
            let source = s.source();
            let mut candidates = Vec::with_capacity(labels.len());
            for label in labels {
                let m = measure_of(&source, label).ok_or_else(|| {
                    anyhow::anyhow!("no IPC measurement for ({source}, {label})")
                })?;
                if m.ipc <= 0.0 {
                    bail!("non-positive IPC for ({source}, {label})");
                }
                let cycles = s.instrs_per_req / m.ipc;
                candidates.push(Candidate {
                    label: label.clone(),
                    mean_us: cycles / (self.freq_ghz * 1000.0),
                    metadata_bytes: m.metadata_bytes,
                    table: m.table,
                });
            }
            services.push(ResolvedService {
                name: s.name.clone(),
                replicas: s.replicas,
                cv: s.cv,
                candidates,
                children: Vec::new(),
                indegree: 0,
            });
        }
        for (i, s) in self.services.iter().enumerate() {
            for d in &s.deps {
                let p = self.index_of(d).unwrap();
                services[p].children.push(i as u32);
                services[i].indegree += 1;
            }
        }
        Ok(ResolvedTopology { services })
    }
}

/// One measured cell for a (source, config) pair — IPC, metadata
/// footprint, and (in empirical mode) the trace-replayed per-request
/// distribution — what [`Topology::resolve`] turns into a [`Candidate`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measure {
    pub ipc: f64,
    /// Prefetcher metadata bytes per replica running this config.
    pub metadata_bytes: u64,
    /// Unit-mean per-request service-time distribution segmented from
    /// the measurement trace (`None` = analytic model).
    pub table: Option<QuantileTable>,
}

impl Measure {
    /// An IPC-only measurement (no metadata cost), for call sites that
    /// predate the cost-aware policies (figures, tail evaluation).
    pub fn ipc_only(ipc: f64) -> Measure {
        Measure { ipc, metadata_bytes: 0, table: None }
    }

    /// The same measurement with its empirical table dropped (resolving
    /// an analytic twin of an empirical topology).
    pub fn analytic(self) -> Measure {
        Measure { table: None, ..self }
    }
}

/// One runnable service-time option (a prefetcher config's effect).
#[derive(Clone, Debug)]
pub struct Candidate {
    pub label: String,
    pub mean_us: f64,
    /// Metadata footprint per replica at this config (cost-aware
    /// policies budget against the sum across live replicas).
    pub metadata_bytes: u64,
    /// Empirical per-request distribution (`None` = analytic jitter).
    pub table: Option<QuantileTable>,
}

impl Candidate {
    /// The service-time model this candidate drives the engine with:
    /// empirical when a quantile table rode along from measurement,
    /// analytic (with the service's `cv`) otherwise.
    pub fn model(&self, cv: f64) -> ServiceTimeModel {
        match self.table {
            Some(table) => ServiceTimeModel::Empirical { mean_us: self.mean_us, table },
            None => ServiceTimeModel::Analytic { mean_us: self.mean_us, cv },
        }
    }
}

/// A service ready for the event loop.
#[derive(Clone, Debug)]
pub struct ResolvedService {
    pub name: String,
    pub replicas: u32,
    pub cv: f64,
    /// Service-time options in spec order; the engine starts at index 0.
    pub candidates: Vec<Candidate>,
    /// Downstream service indexes (fan-out edges).
    pub children: Vec<u32>,
    /// Number of upstream services (fan-in width; 0 = entry point).
    pub indegree: u32,
}

/// A runnable request DAG with per-service timing candidates.
#[derive(Clone, Debug)]
pub struct ResolvedTopology {
    pub services: Vec<ResolvedService>,
}

impl ResolvedTopology {
    /// Build a chain directly from (name, IPC) pairs — the degenerate
    /// linear DAG the figure harness routes the paper's §XI table
    /// through. One candidate per service, one replica each.
    pub fn chain_from_ipcs(
        ipcs: &[(String, f64)],
        instrs_per_req: f64,
        cv: f64,
        freq_ghz: f64,
    ) -> ResolvedTopology {
        let n = ipcs.len();
        let services = ipcs
            .iter()
            .enumerate()
            .map(|(i, (name, ipc))| ResolvedService {
                name: name.clone(),
                replicas: 1,
                cv,
                candidates: vec![Candidate {
                    label: "static".into(),
                    mean_us: instrs_per_req / ipc / (freq_ghz * 1000.0),
                    metadata_bytes: 0,
                    table: None,
                }],
                children: if i + 1 < n { vec![(i + 1) as u32] } else { Vec::new() },
                indegree: u32::from(i > 0),
            })
            .collect();
        ResolvedTopology { services }
    }

    /// Aggregate service rate (req/µs) of the bottleneck service at the
    /// given candidate index (clamped per service): `replicas / mean`.
    pub fn bottleneck_rate_at(&self, candidate: usize) -> f64 {
        self.services
            .iter()
            .map(|s| {
                let c = candidate.min(s.candidates.len() - 1);
                s.replicas as f64 / s.candidates[c].mean_us
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Bottleneck rate at every service's starting (slowest) candidate.
    pub fn bottleneck_rate(&self) -> f64 {
        self.bottleneck_rate_at(0)
    }

    /// Zero-load latency: the critical (longest mean) path through the
    /// DAG at candidate 0.
    pub fn zero_load_us(&self) -> f64 {
        // Longest path via one pass in topological order. The resolved
        // edges are acyclic by construction (Topology::resolve validated
        // them; chain_from_ipcs builds a chain).
        let n = self.services.len();
        let mut indegree: Vec<u32> = self.services.iter().map(|s| s.indegree).collect();
        let mut finish = vec![0.0f64; n];
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        for i in &queue {
            finish[*i] = self.services[*i].candidates[0].mean_us;
        }
        let mut head = 0;
        let mut best: f64 = 0.0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            best = best.max(finish[u]);
            for &c in &self.services[u].children {
                let c = c as usize;
                let cand = finish[u] + self.services[c].candidates[0].mean_us;
                if cand > finish[c] {
                    finish[c] = cand;
                }
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    queue.push(c);
                }
            }
        }
        best
    }

    /// Entry-point service indexes.
    pub fn roots(&self) -> Vec<u32> {
        (0..self.services.len() as u32)
            .filter(|&i| self.services[i as usize].indegree == 0)
            .collect()
    }

    /// A tenant's view of this topology restricted to `members` (a
    /// dep-closed service subset, `ClusterSpec::tenant_services`): its
    /// requests traverse only member services, so fan-in, children, and
    /// entry points are all recomputed over the induced sub-DAG. Errors
    /// on an empty set or one with no entry point (which a dep-closed
    /// subset of an acyclic DAG cannot actually produce — belt and
    /// braces for hand-built callers).
    pub fn sub_dag(&self, members: &[u32]) -> Result<SubDag> {
        let n = self.services.len();
        let mut member = vec![false; n];
        for &s in members {
            if s as usize >= n {
                bail!("sub_dag: service index {s} out of range");
            }
            member[s as usize] = true;
        }
        if members.is_empty() {
            bail!("sub_dag: empty service subset");
        }
        let mut indegrees = vec![0u32; n];
        let mut children: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, s) in self.services.iter().enumerate() {
            if !member[i] {
                continue;
            }
            for &c in &s.children {
                if member[c as usize] {
                    children[i].push(c);
                    indegrees[c as usize] += 1;
                }
            }
        }
        let roots: Vec<u32> = (0..n as u32)
            .filter(|&i| member[i as usize] && indegrees[i as usize] == 0)
            .collect();
        if roots.is_empty() {
            bail!("sub_dag: subset has no entry point");
        }
        let nsvc = member.iter().filter(|&&m| m).count() as u32;
        Ok(SubDag { member, indegrees, children, roots, nsvc })
    }
}

/// One tenant's induced sub-DAG over a shared [`ResolvedTopology`]
/// (DESIGN.md §10): what the multi-tenant engine routes that tenant's
/// requests through.
#[derive(Clone, Debug)]
pub struct SubDag {
    /// Membership per service index.
    pub member: Vec<bool>,
    /// Fan-in per service within the subset (0 for non-members).
    pub indegrees: Vec<u32>,
    /// Children per service within the subset.
    pub children: Vec<Vec<u32>>,
    /// Entry points of the sub-DAG.
    pub roots: Vec<u32>,
    /// Member count.
    pub nsvc: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// gateway → {search, ads} → render.
    fn diamond() -> Topology {
        Topology {
            services: vec![
                ServiceSpec {
                    name: "gateway".into(),
                    app: "admission".into(),
                    replicas: 2,
                    instrs_per_req: 25_000.0,
                    cv: 0.3,
                    deps: vec![],
                    trace: None,
                },
                ServiceSpec {
                    name: "search".into(),
                    app: "websearch".into(),
                    replicas: 3,
                    instrs_per_req: 50_000.0,
                    cv: 0.4,
                    deps: vec!["gateway".into()],
                    trace: None,
                },
                ServiceSpec {
                    name: "ads".into(),
                    app: "mlserve".into(),
                    replicas: 2,
                    instrs_per_req: 40_000.0,
                    cv: 0.4,
                    deps: vec!["gateway".into()],
                    trace: None,
                },
                ServiceSpec {
                    name: "render".into(),
                    app: "serde".into(),
                    replicas: 2,
                    instrs_per_req: 20_000.0,
                    cv: 0.3,
                    deps: vec!["search".into(), "ads".into()],
                    trace: None,
                },
            ],
            freq_ghz: 2.5,
        }
    }

    fn resolved() -> ResolvedTopology {
        // IPC 2.0 everywhere, one candidate.
        diamond().resolve(&["nl".into()], |_, _| Some(Measure::ipc_only(2.0))).unwrap()
    }

    #[test]
    fn validation_catches_structural_errors() {
        assert!(diamond().validate().is_ok());
        let mut dup = diamond();
        dup.services[1].name = "gateway".into();
        assert!(dup.validate().is_err());

        let mut unknown = diamond();
        unknown.services[3].deps = vec!["nope".into()];
        assert!(unknown.validate().is_err());

        let mut cycle = diamond();
        cycle.services[0].deps = vec!["render".into()];
        assert!(cycle.validate().is_err(), "cycle not caught");

        let mut zero = diamond();
        zero.services[2].replicas = 0;
        assert!(zero.validate().is_err());
    }

    #[test]
    fn topo_order_respects_deps() {
        let t = diamond();
        let order = t.topo_order().unwrap();
        let pos = |n: usize| order.iter().position(|&x| x == n).unwrap();
        assert!(pos(0) < pos(1) && pos(0) < pos(2));
        assert!(pos(1) < pos(3) && pos(2) < pos(3));
    }

    #[test]
    fn resolve_sets_edges_and_service_times() {
        let r = resolved();
        // gateway: 25k instrs / IPC 2.0 / 2.5 GHz = 5 µs.
        assert!((r.services[0].candidates[0].mean_us - 5.0).abs() < 1e-9);
        assert_eq!(r.services[0].children, vec![1, 2]);
        assert_eq!(r.services[3].indegree, 2);
        assert_eq!(r.roots(), vec![0]);
    }

    #[test]
    fn bottleneck_and_zero_load() {
        let r = resolved();
        // Rates: gw 2/5, search 3/10, ads 2/8, render 2/4 → bottleneck 0.25 (ads).
        assert!((r.bottleneck_rate() - 0.25).abs() < 1e-9);
        // Critical path: gateway 5 + search 10 + render 4 = 19 µs.
        assert!((r.zero_load_us() - 19.0).abs() < 1e-9);
    }

    #[test]
    fn faster_candidate_raises_bottleneck_rate() {
        let t = diamond();
        let r = t
            .resolve(&["nl".into(), "ceip256".into()], |_, label| {
                Some(if label == "nl" {
                    Measure { ipc: 2.0, metadata_bytes: 64, table: None }
                } else {
                    Measure { ipc: 2.4, metadata_bytes: 25_000, table: None }
                })
            })
            .unwrap();
        assert!(r.bottleneck_rate_at(1) > r.bottleneck_rate_at(0));
        // Metadata footprints ride along per candidate.
        assert_eq!(r.services[0].candidates[0].metadata_bytes, 64);
        assert_eq!(r.services[0].candidates[1].metadata_bytes, 25_000);
    }

    #[test]
    fn resolve_fails_on_missing_ipc() {
        let t = diamond();
        assert!(t
            .resolve(&["nl".into()], |app, _| {
                (app != "serde").then_some(Measure::ipc_only(2.0))
            })
            .is_err());
    }

    #[test]
    fn linear_chain_matches_rpc_special_case() {
        let t = Topology::linear(
            &[("admission", "admission"), ("fs", "featurestore-go"), ("ml", "mlserve")],
            25_000.0,
            2.5,
        );
        assert!(t.validate().is_ok());
        let r = t.resolve(&["nl".into()], |_, _| Some(Measure::ipc_only(2.0))).unwrap();
        // Chain: zero-load = sum of node means, bottleneck = slowest node.
        assert!((r.zero_load_us() - 15.0).abs() < 1e-9);
        assert!((r.bottleneck_rate() - 0.2).abs() < 1e-9);
        assert_eq!(r.roots(), vec![0]);
        assert_eq!(r.services[1].indegree, 1);
    }

    #[test]
    fn trace_override_keys_the_measurement_source() {
        // A service with a `.slft` trace resolves against the trace
        // path, not the app preset.
        let mut t = diamond();
        t.services[1].trace = Some("/tmp/search.slft".into());
        assert_eq!(t.services[1].source(), "file:/tmp/search.slft");
        assert_eq!(t.services[0].source(), "admission");
        let r = t
            .resolve(&["nl".into()], |source, _| {
                Some(if source == "file:/tmp/search.slft" {
                    Measure { ipc: 1.0, metadata_bytes: 0, table: None }
                } else {
                    Measure::ipc_only(2.0)
                })
            })
            .unwrap();
        // search: 50k instrs / IPC 1.0 / 2.5 GHz = 20 µs (vs 10 analytic).
        assert!((r.services[1].candidates[0].mean_us - 20.0).abs() < 1e-9);
        assert!((r.services[0].candidates[0].mean_us - 5.0).abs() < 1e-9);
        // A trace path that *spells* an app name must not merge with
        // that app's generated-trace cells (namespace prefix).
        let mut aliased = diamond();
        aliased.services[1].trace = Some("websearch".into());
        assert_eq!(aliased.services[1].source(), "file:websearch");
        assert_ne!(aliased.services[1].source(), aliased.services[1].app);
        // Empty trace paths are caught structurally.
        let mut bad = diamond();
        bad.services[0].trace = Some(String::new());
        assert!(bad.validate().is_err(), "empty trace path not rejected");
    }

    #[test]
    fn candidate_model_selects_empirical_when_a_table_rides_along() {
        use crate::cluster::servicetime::QuantileTable;
        let table = QuantileTable::normalized(&[1.0; 32]).unwrap();
        let t = diamond();
        let r = t
            .resolve(&["nl".into()], |_, _| {
                Some(Measure { ipc: 2.0, metadata_bytes: 0, table: Some(table) })
            })
            .unwrap();
        let c = &r.services[0].candidates[0];
        assert_eq!(c.table, Some(table));
        match c.model(0.3) {
            ServiceTimeModel::Empirical { mean_us, .. } => {
                assert!((mean_us - 5.0).abs() < 1e-9)
            }
            other => panic!("expected empirical model, got {other:?}"),
        }
        // Stripping the table gives back the analytic model.
        match (Candidate { table: None, ..c.clone() }).model(0.3) {
            ServiceTimeModel::Analytic { mean_us, cv } => {
                assert!((mean_us - 5.0).abs() < 1e-9);
                assert_eq!(cv, 0.3);
            }
            other => panic!("expected analytic model, got {other:?}"),
        }
    }

    #[test]
    fn sub_dag_restricts_edges_roots_and_counts() {
        // diamond: gateway → {search, ads} → render.
        let r = resolved();
        // A tenant that only touches gateway → search.
        let sub = r.sub_dag(&[0, 1]).unwrap();
        assert_eq!(sub.nsvc, 2);
        assert_eq!(sub.roots, vec![0]);
        assert_eq!(sub.children[0], vec![1], "non-member edge kept");
        assert!(sub.children[1].is_empty(), "render leaked into the sub-DAG");
        assert_eq!(sub.indegrees[1], 1);
        assert_eq!(sub.indegrees[3], 0, "non-member fan-in must stay 0");
        assert!(!sub.member[2] && !sub.member[3]);
        // The full set reproduces the topology's own view.
        let full = r.sub_dag(&[0, 1, 2, 3]).unwrap();
        assert_eq!(full.roots, r.roots());
        assert_eq!(full.indegrees[3], 2);
        assert_eq!(full.nsvc, 4);
        // Degenerate subsets are errors, not silent empties.
        assert!(r.sub_dag(&[]).is_err(), "empty subset accepted");
        assert!(r.sub_dag(&[9]).is_err(), "out-of-range index accepted");
        // A non-dep-closed subset (render without its parents) has no
        // entry point among its waiting members only when fan-in
        // survives; {render} alone re-roots — the dep-closure guard
        // lives in ClusterSpec::tenant_services, not here.
        assert!(r.sub_dag(&[3]).is_ok());
    }

    #[test]
    fn chain_from_ipcs_is_the_degenerate_dag() {
        let r = ResolvedTopology::chain_from_ipcs(
            &[("a".into(), 2.0), ("b".into(), 1.5), ("c".into(), 2.5)],
            25_000.0,
            0.35,
            2.5,
        );
        // Same math as rpc::ServiceChain::{base_latency_us, bottleneck_rate}.
        let expect_zero =
            25_000.0 / 2.0 / 2500.0 + 25_000.0 / 1.5 / 2500.0 + 25_000.0 / 2.5 / 2500.0;
        assert!((r.zero_load_us() - expect_zero).abs() < 1e-9);
        assert!((r.bottleneck_rate() - 1.0 / (25_000.0 / 1.5 / 2500.0)).abs() < 1e-9);
    }
}
