//! Discrete-event microservice-cluster simulator (DESIGN.md §8/§9):
//! request DAGs with fan-out/fan-in and per-service replicas
//! ([`topology`]), time-varying open-loop traffic ([`workload`]), a
//! binary-heap event loop ([`engine`]), and a windowed SLO tracker
//! driving an autoscaler policy suite ([`slo`]: reactive, hysteresis
//! scale-down, predictive, cost-aware). The linear `rpc/` tandem chain
//! is the degenerate case
//! (every node one parent, one replica); this module is what the
//! ROADMAP's "heavy traffic, many scenarios" north star plugs into.
//!
//! Per-service timing comes from the same place as every other figure:
//! `sim::engine` IPC measurements per (app preset, prefetcher config),
//! resolved once per spec through the campaign runner and shared by all
//! scenarios. Scenario runs are independent and deterministically
//! seeded, so [`run_spec`] output is identical at any `--threads` value.

pub mod engine;
pub mod slo;
pub mod spec;
pub mod topology;
pub mod workload;

pub use engine::{ClusterResult, RunParams};
pub use slo::{EngineView, Policy, SloCfg};
pub use spec::ClusterSpec;
pub use topology::{Measure, ResolvedTopology, ServiceSpec, Topology};
pub use workload::TrafficShape;

use crate::campaign::runner::{self, Cell};
use crate::campaign::spec::cell_seed;
use crate::cli::parse_prefetcher;
use crate::config::SimConfig;
use crate::figures::report::{f2, kb, pct, Table};
use crate::trace::gen::apps;
use anyhow::Result;
use std::collections::HashMap;

/// Everything one [`run_spec`] invocation produced.
pub struct ClusterOutcome {
    /// Scenario results in deterministic expansion order
    /// (configs ▸ traffic shapes, then policies ▸ traffic shapes).
    pub scenarios: Vec<ClusterResult>,
    pub total_requests: u64,
    pub total_events: u64,
    /// (app, prefetcher) IPC measurement cells that were simulated.
    pub ipc_cells: usize,
    /// The SLO every scenario was held to (spec value or derived).
    pub slo_us: f64,
}

struct ScenarioDef {
    label: String,
    shape: TrafficShape,
    topo: ResolvedTopology,
    params: RunParams,
    ctrl: Option<SloCfg>,
}

/// A cluster spec with its (app × prefetcher) matrix measured and its
/// load/SLO anchors derived — everything scenario runs share. Built
/// once per spec ([`prepare_spec`]) and reused by every (config |
/// policy) × shape scenario, including campaign cluster cells.
pub struct PreparedSpec {
    /// Normalized prefetcher labels, spec order.
    pub labels: Vec<String>,
    /// One single-candidate topology per static config.
    pub static_topos: Vec<ResolvedTopology>,
    /// Multi-candidate topology for policy scenarios: every service
    /// carries all configs, sorted by measured service time (slowest
    /// first), so the Upgrade lever is always a strict improvement.
    pub policy_topo: ResolvedTopology,
    /// Absolute offered-load anchor (req/µs at utilization 1.0).
    pub base_rate: f64,
    /// The SLO every scenario is held to (spec value or derived).
    pub slo_us: f64,
    /// (app, prefetcher) cells that were simulated.
    pub ipc_cells: usize,
}

/// Measure the (app × config) IPC/metadata matrix through the campaign
/// runner and resolve the spec's topologies and load/SLO anchors.
pub fn prepare_spec(spec: &ClusterSpec, threads: usize) -> Result<PreparedSpec> {
    spec.validate()?;
    let labels: Vec<String> = spec.prefetchers.iter().map(|p| p.to_lowercase()).collect();
    let pairs = spec.ipc_cells();
    let cells: Vec<Cell> = pairs
        .iter()
        .map(|(app, pf)| {
            let key = format!("cluster|{app}|{pf}|r{}|s{}", spec.records, spec.seed);
            Cell {
                app: apps::app(app).expect("validated app"),
                label: pf.clone(),
                cfg: SimConfig {
                    prefetcher: parse_prefetcher(pf).expect("validated prefetcher"),
                    seed: cell_seed(spec.seed, &key),
                    ..Default::default()
                },
                records: spec.records,
                trace_seed: spec.seed,
            }
        })
        .collect();
    let sims = runner::run_cells(&cells, threads);
    let mut measures: HashMap<(String, String), Measure> = HashMap::new();
    for ((app, pf), r) in pairs.iter().zip(&sims) {
        measures.insert(
            (app.clone(), pf.clone()),
            Measure { ipc: r.ipc(), metadata_bytes: r.metadata_bytes },
        );
    }
    let lookup =
        |app: &str, label: &str| measures.get(&(app.to_string(), label.to_string())).copied();

    let static_topos: Vec<ResolvedTopology> = labels
        .iter()
        .map(|l| spec.topology.resolve(std::slice::from_ref(l), lookup))
        .collect::<Result<_>>()?;
    // Offered load and the derived SLO are anchored on the *slowest
    // measured* config (the baseline — typically `nl`), so every
    // scenario sees the same absolute arrival process and an achievable
    // SLO regardless of the spec's listing order. Ties break to the
    // lowest index, deterministically.
    let base_idx = (0..static_topos.len())
        .min_by(|&a, &b| {
            static_topos[a]
                .bottleneck_rate()
                .partial_cmp(&static_topos[b].bottleneck_rate())
                .unwrap()
        })
        .unwrap();
    let base_rate = static_topos[base_idx].bottleneck_rate() * spec.utilization;
    let slo_us = if spec.slo_us > 0.0 {
        spec.slo_us
    } else {
        static_topos[base_idx].zero_load_us() * 4.0
    };
    let mut policy_topo = spec.topology.resolve(&labels, lookup)?;
    // Order each service's candidates by *measured* service time,
    // slowest first, so the control loop's Upgrade lever is always a
    // strict improvement (e.g. cheip2k can measure slower than ceip256
    // on some apps). Stable sort keeps ties deterministic.
    for s in &mut policy_topo.services {
        s.candidates.sort_by(|a, b| b.mean_us.partial_cmp(&a.mean_us).unwrap());
    }
    Ok(PreparedSpec {
        labels,
        static_topos,
        policy_topo,
        base_rate,
        slo_us,
        ipc_cells: cells.len(),
    })
}

/// Label, run knobs, and control-loop config for one (policy × shape)
/// scenario — the single source of the determinism-critical seed
/// formulas, shared by [`run_spec`] and [`run_policy_scenario`] so
/// campaign cluster cells always reproduce `slofetch cluster` rows.
fn policy_scenario_cfg(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    policy: &Policy,
    shape: &TrafficShape,
) -> (String, RunParams, SloCfg) {
    let label = policy.label();
    let params = RunParams {
        requests: spec.requests,
        seed: cell_seed(spec.seed, &format!("{label}|{}", shape.label())),
        slo_us: prep.slo_us,
        base_rate_per_us: prep.base_rate,
    };
    let ctrl_seed = cell_seed(spec.seed, &format!("policy|{label}|{}", shape.label()));
    let cfg = SloCfg::new(prep.slo_us, ctrl_seed)
        .with_policy(policy.clone())
        .with_shape(shape.clone());
    (label, params, cfg)
}

/// Run one (policy × shape) control-loop scenario against a prepared
/// spec — the campaign cluster axis runs through here. Self-seeded per
/// (policy, shape): equal inputs give bit-equal results at any thread
/// count.
pub fn run_policy_scenario(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    policy: &Policy,
    shape: &TrafficShape,
) -> ClusterResult {
    let (label, params, cfg) = policy_scenario_cfg(prep, spec, policy, shape);
    let mut r = engine::run(&prep.policy_topo, shape, &params, Some(cfg));
    r.label = label;
    r
}

/// Expand and run a cluster spec: measure the (app × prefetcher) IPC
/// matrix through the campaign runner, then run every static
/// (config × traffic) scenario plus one control-loop scenario per
/// (policy × traffic) — sharded across `threads` workers (0 = auto)
/// with byte-identical results at any thread count.
pub fn run_spec(spec: &ClusterSpec, threads: usize) -> Result<ClusterOutcome> {
    let prep = prepare_spec(spec, threads)?;
    let policies = spec.effective_policies()?;
    let shapes: Vec<TrafficShape> = spec
        .traffic
        .iter()
        .map(|t| TrafficShape::parse(t))
        .collect::<Result<_>>()?;

    // Deterministic scenario expansion: configs ▸ shapes, then policies
    // ▸ shapes.
    let mut defs = Vec::new();
    for (label, topo) in prep.labels.iter().zip(&prep.static_topos) {
        for shape in &shapes {
            let seed = cell_seed(spec.seed, &format!("{label}|{}", shape.label()));
            defs.push(ScenarioDef {
                label: label.clone(),
                shape: shape.clone(),
                topo: topo.clone(),
                params: RunParams {
                    requests: spec.requests,
                    seed,
                    slo_us: prep.slo_us,
                    base_rate_per_us: prep.base_rate,
                },
                ctrl: None,
            });
        }
    }
    for policy in &policies {
        for shape in &shapes {
            let (label, params, cfg) = policy_scenario_cfg(&prep, spec, policy, shape);
            defs.push(ScenarioDef {
                label,
                shape: shape.clone(),
                topo: prep.policy_topo.clone(),
                params,
                ctrl: Some(cfg),
            });
        }
    }

    // Shard scenarios across workers; collect by index (scenario runs
    // are independent and self-seeded, so order of completion is
    // irrelevant to the result).
    let scenarios = run_scenarios(&defs, threads);
    let total_requests = scenarios.iter().map(|s| s.requests).sum();
    let total_events = scenarios.iter().map(|s| s.events).sum();
    Ok(ClusterOutcome {
        scenarios,
        total_requests,
        total_events,
        ipc_cells: prep.ipc_cells,
        slo_us: prep.slo_us,
    })
}

fn run_scenarios(defs: &[ScenarioDef], threads: usize) -> Vec<ClusterResult> {
    runner::parallel_map(defs.len(), threads, |i| {
        let d = &defs[i];
        let mut r = engine::run(&d.topo, &d.shape, &d.params, d.ctrl.clone());
        r.label = d.label.clone();
        r
    })
}

/// Scenario summary table (deterministic: pure function of the outcome).
pub fn report(out: &ClusterOutcome) -> Table {
    let mut t = Table::new(
        "cluster",
        &format!("Cluster scenarios (SLO {} µs)", f2(out.slo_us)),
        &[
            "config",
            "traffic",
            "P50 µs",
            "P95 µs",
            "P99 µs",
            "compliance",
            "burn",
            "actions",
            "replicas",
            "replica·s",
            "metadata",
        ],
    );
    for s in &out.scenarios {
        let replicas: Vec<String> = s.final_replicas.iter().map(|r| r.to_string()).collect();
        let mean_meta = if s.duration_us > 0.0 { s.meta_byte_us / s.duration_us } else { 0.0 };
        t.row(vec![
            s.label.clone(),
            s.traffic.clone(),
            f2(s.p50_us),
            f2(s.p95_us),
            f2(s.p99_us),
            pct(s.compliance),
            format!("{}/{}", s.violated_windows, s.windows),
            s.actions.len().to_string(),
            replicas.join(","),
            f2(s.replica_us / 1e6),
            kb(mean_meta as u64),
        ]);
    }
    t.note(
        "burn = windows below target compliance / windows evaluated; replica·s = \
         ∫ provisioned replicas dt; metadata = time-averaged footprint; offered load \
         is anchored on the slowest config's bottleneck",
    );
    t
}

/// Control-action trace table for adaptive scenarios (empty-safe).
pub fn action_report(out: &ClusterOutcome) -> Option<Table> {
    let mut t = Table::new(
        "cluster_actions",
        "SLO control-loop actions",
        &["config", "traffic", "t µs", "service", "action"],
    );
    for s in &out.scenarios {
        for a in &s.actions {
            t.row(vec![
                s.label.clone(),
                s.traffic.clone(),
                f2(a.t_us),
                a.service.clone(),
                a.action.clone(),
            ]);
        }
    }
    if t.rows.is_empty() {
        None
    } else {
        Some(t)
    }
}

/// Tail summary of one campaign cell under a traffic shape: the cell's
/// measured IPC sets the service time of a single-service cluster
/// (1 replica, 25k instrs/req, cv 0.35 at 2.5 GHz) and the shape drives
/// arrivals. SLO = 5× the zero-load service time.
#[derive(Clone, Copy, Debug)]
pub struct TailSummary {
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub compliance: f64,
    pub slo_us: f64,
}

/// Requests simulated per campaign-cell tail evaluation.
pub const TAIL_EVAL_REQUESTS: u64 = 30_000;

pub fn evaluate_tail(ipc: f64, shape: &TrafficShape, seed: u64) -> TailSummary {
    let topo = ResolvedTopology::chain_from_ipcs(
        &[("svc".to_string(), ipc)],
        25_000.0,
        0.35,
        2.5,
    );
    let slo_us = topo.zero_load_us() * 5.0;
    let params = RunParams {
        requests: TAIL_EVAL_REQUESTS,
        seed,
        slo_us,
        base_rate_per_us: topo.bottleneck_rate(),
    };
    let r = engine::run(&topo, shape, &params, None);
    TailSummary {
        p50_us: r.p50_us,
        p95_us: r.p95_us,
        p99_us: r.p99_us,
        compliance: r.compliance,
        slo_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ClusterSpec {
        ClusterSpec {
            name: "tiny".into(),
            topology: Topology {
                services: vec![
                    ServiceSpec {
                        // Clear bottleneck (1 replica, prefetch-sensitive app).
                        name: "gw".into(),
                        app: "websearch".into(),
                        replicas: 1,
                        instrs_per_req: 30_000.0,
                        cv: 0.35,
                        deps: vec![],
                    },
                    ServiceSpec {
                        name: "be".into(),
                        app: "serde".into(),
                        replicas: 2,
                        instrs_per_req: 20_000.0,
                        cv: 0.35,
                        deps: vec!["gw".into()],
                    },
                ],
                freq_ghz: 2.5,
            },
            prefetchers: vec!["nl".into(), "ceip256".into()],
            traffic: vec!["poisson:0.6".into()],
            requests: 8_000,
            records: 10_000,
            seed: 5,
            slo_us: 0.0,
            utilization: 1.0,
            adaptive: true,
            policies: Vec::new(),
        }
    }

    #[test]
    fn run_spec_is_thread_count_invariant() {
        let spec = tiny_spec();
        let a = run_spec(&spec, 1).unwrap();
        let b = run_spec(&spec, 4).unwrap();
        assert_eq!(a.scenarios.len(), spec.scenario_count());
        assert_eq!(a.total_requests, spec.requests * spec.scenario_count() as u64);
        assert_eq!(report(&a).markdown(), report(&b).markdown());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits());
            assert_eq!(x.events, y.events);
        }
    }

    #[test]
    fn faster_config_orders_p99_in_run_spec() {
        let spec = ClusterSpec { adaptive: false, requests: 25_000, ..tiny_spec() };
        let out = run_spec(&spec, 0).unwrap();
        let p99 = |label: &str| {
            out.scenarios.iter().find(|s| s.label == label).unwrap().p99_us
        };
        // Same offered load; the faster prefetcher tightens the tail.
        assert!(p99("ceip256") < p99("nl"), "ceip {} !< nl {}", p99("ceip256"), p99("nl"));
    }

    #[test]
    fn evaluate_tail_is_deterministic_and_sane() {
        let shape = TrafficShape::Poisson { util: 0.65 };
        let a = evaluate_tail(2.0, &shape, 9);
        let b = evaluate_tail(2.0, &shape, 9);
        assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits());
        assert!(a.p50_us <= a.p95_us && a.p95_us <= a.p99_us);
        assert!(a.compliance > 0.0 && a.compliance <= 1.0);
        // Faster core ⇒ shorter absolute tail (same utilization).
        let fast = evaluate_tail(2.4, &shape, 9);
        assert!(fast.p99_us < a.p99_us);
    }

    #[test]
    fn report_contains_every_scenario_row() {
        let spec = ClusterSpec { adaptive: false, requests: 4_000, ..tiny_spec() };
        let out = run_spec(&spec, 2).unwrap();
        let t = report(&out);
        assert_eq!(t.rows.len(), out.scenarios.len());
        assert!(t.markdown().contains("ceip256"));
    }

    #[test]
    fn policy_suite_runs_one_scenario_per_policy_and_shape() {
        let spec = ClusterSpec {
            adaptive: false,
            policies: vec![
                "reactive".into(),
                "hysteresis".into(),
                "cost-aware:262144".into(),
            ],
            requests: 6_000,
            ..tiny_spec()
        };
        let out = run_spec(&spec, 2).unwrap();
        // (2 prefetchers + 3 policies) × 1 shape.
        assert_eq!(out.scenarios.len(), 5);
        for policy in &spec.policies {
            let label = Policy::parse(policy).unwrap().label();
            let s = out
                .scenarios
                .iter()
                .find(|s| s.label == label)
                .unwrap_or_else(|| panic!("missing policy scenario '{label}'"));
            assert_eq!(s.requests, spec.requests);
            assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us);
            assert!(s.replica_us > 0.0);
        }
        // run_policy_scenario is the same computation the sweep did.
        let prep = prepare_spec(&spec, 1).unwrap();
        let shape = TrafficShape::parse(&spec.traffic[0]).unwrap();
        let direct = run_policy_scenario(&prep, &spec, &Policy::Reactive, &shape);
        let swept = out.scenarios.iter().find(|s| s.label == "reactive").unwrap();
        assert_eq!(direct.p99_us.to_bits(), swept.p99_us.to_bits());
        assert_eq!(direct.events, swept.events);
    }
}
