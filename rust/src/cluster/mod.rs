//! Discrete-event microservice-cluster simulator (DESIGN.md §8/§9):
//! request DAGs with fan-out/fan-in and per-service replicas
//! ([`topology`]), time-varying open-loop traffic ([`workload`]), a
//! pluggable-scheduler event loop ([`engine`] over [`sched`]: calendar
//! queue by default, binary-heap oracle), and a windowed SLO tracker
//! driving an autoscaler policy suite ([`slo`]: reactive, hysteresis
//! scale-down, predictive, cost-aware). The linear `rpc/` tandem chain
//! is the degenerate case
//! (every node one parent, one replica); this module is what the
//! ROADMAP's "heavy traffic, many scenarios" north star plugs into.
//!
//! Per-service timing comes from the same place as every other figure:
//! `sim::engine` measurements per (source, prefetcher config) — where a
//! source is an app preset's generated trace or a `.slft` trace file —
//! resolved once per spec through the campaign runner and shared by all
//! scenarios. In `"empirical"` service-time mode ([`ClusterSpec`]) each
//! measurement additionally segments its trace on the `ctx` tag into
//! per-request cycle counts, and scenarios replay that distribution
//! through a quantile table ([`servicetime`]) instead of the analytic
//! mean+cv model. Scenario runs are independent and deterministically
//! seeded, so [`run_spec`] output is identical at any `--threads` value.
//!
//! Multi-tenant co-location (DESIGN.md §10): a spec's `tenants` section
//! binds 2+ named tenants — each a dep-closed sub-DAG, traffic shape,
//! SLO target, and L1-I way share — onto the same replica pool. The
//! way partition and per-tenant rate limiters (`coordinator/tenant.rs`)
//! are the live interference model; every tenant also runs solo with
//! the same arrival seed, so [`tenant_report`] is a paired comparison.

pub mod engine;
pub mod faults;
pub mod sched;
pub mod servicetime;
pub mod slo;
pub mod spec;
pub mod topology;
pub mod workload;

pub use engine::{ClusterResult, RunParams, TenancyParams, TenantRun, TenantStat};
pub use faults::{ClientPolicySpec, EdgePolicy, FaultsSpec};
pub use sched::SchedKind;
pub use servicetime::{QuantileTable, ServiceTimeModel};
pub use slo::{EngineView, Policy, SloCfg, TenantCtrlCfg};
pub use spec::{ClusterSpec, TenantSpec};
pub use topology::{Measure, ResolvedTopology, ServiceSpec, Topology};
pub use workload::TrafficShape;

use crate::campaign::runner::{self, Cell};
use crate::campaign::spec::cell_seed;
use crate::cli::parse_prefetcher;
use crate::config::SimConfig;
use crate::figures::report::{f2, kb, pct, Table};
use crate::obs::telemetry::Telemetry;
use crate::obs::{trace as obs_trace, ObsCfg};
use crate::trace::gen::apps;
use crate::trace::{codec, Record};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

/// Suffix distinguishing an empirical (trace-replayed) static scenario
/// from its analytic twin in labels and report rows. Twins deliberately
/// share the *base* label's scenario seed: the arrival generator draws
/// from its own RNG stream, so equal seeds give both models the
/// bit-identical offered-load realization and the `cluster_models`
/// comparison is genuinely paired (the delta is model shape, not a
/// different arrival sample).
pub const EMPIRICAL_SUFFIX: &str = "~emp";

/// Everything one [`run_spec`] invocation produced.
pub struct ClusterOutcome {
    /// Scenario results in deterministic expansion order
    /// (configs ▸ traffic shapes, then policies ▸ traffic shapes).
    pub scenarios: Vec<ClusterResult>,
    pub total_requests: u64,
    pub total_events: u64,
    /// (app, prefetcher) IPC measurement cells that were simulated.
    pub ipc_cells: usize,
    /// The SLO every scenario was held to (spec value or derived).
    pub slo_us: f64,
    /// Sketch telemetry from the measurement cells (DESIGN.md §12);
    /// `None` under the default `telemetry: "exact"` knob.
    pub fleet: Option<FleetTelemetry>,
}

/// Sketch telemetry harvested from a spec's (source × config)
/// measurement cells: one bounded summary per cell plus their
/// associative merge — the fleet view a coordinator would hold.
#[derive(Clone, Debug)]
pub struct FleetTelemetry {
    /// (source, prefetcher, telemetry), measurement-cell expansion order.
    pub cells: Vec<(String, String, Telemetry)>,
    /// Merge of every cell summary ([`Telemetry::merged`]).
    pub merged: Telemetry,
}

struct ScenarioDef {
    label: String,
    shape: TrafficShape,
    topo: ResolvedTopology,
    params: RunParams,
    ctrl: Option<SloCfg>,
}

/// The spec's fault section as the engine wants it: `None` when empty,
/// so fault-free specs take the exact pre-fault entry points.
fn spec_faults(spec: &ClusterSpec) -> Option<&FaultsSpec> {
    (!spec.faults.is_empty()).then_some(&spec.faults)
}

/// A cluster spec with its (app × prefetcher) matrix measured and its
/// load/SLO anchors derived — everything scenario runs share. Built
/// once per spec ([`prepare_spec`]) and reused by every (config |
/// policy) × shape scenario, including campaign cluster cells.
pub struct PreparedSpec {
    /// Normalized prefetcher labels, spec order.
    pub labels: Vec<String>,
    /// One single-candidate topology per static config (analytic
    /// service times — the load/SLO anchor and, in empirical mode, the
    /// comparison twins).
    pub static_topos: Vec<ResolvedTopology>,
    /// Trace-replayed twins of `static_topos` (same means, per-request
    /// shape from the measurement traces); empty in analytic mode.
    pub empirical_topos: Vec<ResolvedTopology>,
    /// Multi-candidate topology for policy scenarios: every service
    /// carries all configs, sorted by measured service time (slowest
    /// first), so the Upgrade lever is always a strict improvement.
    /// Carries empirical tables when the spec asks for them.
    pub policy_topo: ResolvedTopology,
    /// Absolute offered-load anchor (req/µs at utilization 1.0).
    pub base_rate: f64,
    /// The SLO every scenario is held to (spec value or derived).
    pub slo_us: f64,
    /// (source, prefetcher) cells that were simulated.
    pub ipc_cells: usize,
    /// Whether scenarios replay empirical service times.
    pub empirical: bool,
    /// Per-cell + merged sketch telemetry when the spec's `telemetry`
    /// knob is not `"exact"`.
    pub fleet: Option<FleetTelemetry>,
    /// Event-scheduler backend every scenario runs on (DESIGN.md §13);
    /// byte-identical output either way.
    pub sched: SchedKind,
}

/// Measure the (source × config) IPC/metadata matrix through the
/// campaign runner — where a source is an app preset or a per-service
/// `.slft` trace file — and resolve the spec's topologies and load/SLO
/// anchors. In empirical mode each measurement also segments its trace
/// on the `ctx` tag into per-request cycle counts and fits the
/// unit-mean quantile table the scenarios replay.
pub fn prepare_spec(spec: &ClusterSpec, threads: usize) -> Result<PreparedSpec> {
    spec.validate()?;
    let empirical = spec.empirical();
    let labels: Vec<String> = spec.prefetchers.iter().map(|p| p.to_lowercase()).collect();
    // One record set per distinct source: loaded once for `.slft` files
    // (codec round-trip), generated per cell for app presets.
    let mut traces: HashMap<String, Arc<Vec<Record>>> = HashMap::new();
    for s in &spec.topology.services {
        if let Some(path) = &s.trace {
            let src = s.source();
            if !traces.contains_key(&src) {
                let (_meta, records) = codec::read_trace_file(std::path::Path::new(path))
                    .with_context(|| format!("service '{}': loading trace '{path}'", s.name))?;
                if records.is_empty() {
                    bail!("service '{}': trace '{path}' holds no records", s.name);
                }
                traces.insert(src, Arc::new(records));
            }
        }
    }
    let app_of = |src: &str| {
        let s = spec
            .topology
            .services
            .iter()
            .find(|s| s.source() == src)
            .expect("ipc_cells sources come from the services");
        apps::app(&s.app).expect("validated app")
    };
    let pairs = spec.ipc_cells();
    let cells: Vec<Cell> = pairs
        .iter()
        .map(|(src, pf)| {
            let trace = traces.get(src.as_str()).cloned();
            let records = trace.as_ref().map(|t| t.len() as u64).unwrap_or(spec.records);
            let key = format!("cluster|{src}|{pf}|r{records}|s{}", spec.seed);
            Cell {
                app: app_of(src),
                label: pf.clone(),
                cfg: SimConfig {
                    prefetcher: parse_prefetcher(pf).expect("validated prefetcher"),
                    seed: cell_seed(spec.seed, &key),
                    track_segments: empirical,
                    telemetry: spec.telemetry.clone(),
                    ..Default::default()
                },
                records,
                trace_seed: spec.seed,
                trace,
            }
        })
        .collect();
    let sims = runner::run_cells(&cells, threads);
    let mut measures: HashMap<(String, String), Measure> = HashMap::new();
    for ((src, pf), r) in pairs.iter().zip(&sims) {
        let table = if empirical {
            let segments = r.segments.as_deref().unwrap_or(&[]);
            Some(
                QuantileTable::normalized(segments)
                    .with_context(|| format!("empirical service times for ({src}, {pf})"))?,
            )
        } else {
            None
        };
        measures.insert(
            (src.clone(), pf.clone()),
            Measure { ipc: r.ipc(), metadata_bytes: r.metadata_bytes, table },
        );
    }
    // Harvest per-cell sketch telemetry (deterministic: `sims` is in
    // cell expansion order) and fold the fleet view once per spec.
    let tel_cells: Vec<(String, String, Telemetry)> = pairs
        .iter()
        .zip(sims)
        .filter_map(|((src, pf), r)| r.telemetry.map(|t| (src.clone(), pf.clone(), *t)))
        .collect();
    let fleet = crate::coordinator::fleet::merge_telemetry(tel_cells.iter().map(|(_, _, t)| t))
        .map(|merged| FleetTelemetry { cells: tel_cells, merged });
    let lookup =
        |src: &str, label: &str| measures.get(&(src.to_string(), label.to_string())).copied();
    let analytic = |src: &str, label: &str| lookup(src, label).map(Measure::analytic);

    let static_topos: Vec<ResolvedTopology> = labels
        .iter()
        .map(|l| spec.topology.resolve(std::slice::from_ref(l), analytic))
        .collect::<Result<_>>()?;
    let empirical_topos: Vec<ResolvedTopology> = if empirical {
        labels
            .iter()
            .map(|l| spec.topology.resolve(std::slice::from_ref(l), lookup))
            .collect::<Result<_>>()?
    } else {
        Vec::new()
    };
    // Offered load and the derived SLO are anchored on the *slowest
    // measured* config (the baseline — typically `nl`), so every
    // scenario sees the same absolute arrival process and an achievable
    // SLO regardless of the spec's listing order. Ties break to the
    // lowest index, deterministically. Empirical tables are unit-mean,
    // so both models share these anchors exactly.
    let base_idx = (0..static_topos.len())
        .min_by(|&a, &b| {
            static_topos[a]
                .bottleneck_rate()
                .partial_cmp(&static_topos[b].bottleneck_rate())
                .unwrap()
        })
        .unwrap();
    let base_rate = static_topos[base_idx].bottleneck_rate() * spec.utilization;
    let slo_us = if spec.slo_us > 0.0 {
        spec.slo_us
    } else {
        static_topos[base_idx].zero_load_us() * 4.0
    };
    // In analytic mode every Measure already carries `table: None`, so
    // the full lookup is the analytic lookup — one resolution serves
    // both modes.
    let mut policy_topo = spec.topology.resolve(&labels, lookup)?;
    // Order each service's candidates by *measured* service time,
    // slowest first, so the control loop's Upgrade lever is always a
    // strict improvement (e.g. cheip2k can measure slower than ceip256
    // on some apps). Stable sort keeps ties deterministic.
    for s in &mut policy_topo.services {
        s.candidates.sort_by(|a, b| b.mean_us.partial_cmp(&a.mean_us).unwrap());
    }
    Ok(PreparedSpec {
        labels,
        static_topos,
        empirical_topos,
        policy_topo,
        base_rate,
        slo_us,
        ipc_cells: cells.len(),
        empirical,
        fleet,
        sched: SchedKind::parse(&spec.scheduler).expect("validated scheduler"),
    })
}

/// Label, run knobs, and control-loop config for one (policy × shape)
/// scenario — the single source of the determinism-critical seed
/// formulas, shared by [`run_spec`] and [`run_policy_scenario`] so
/// campaign cluster cells always reproduce `slofetch cluster` rows.
fn policy_scenario_cfg(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    policy: &Policy,
    shape: &TrafficShape,
) -> (String, RunParams, SloCfg) {
    let label = policy.label();
    let params = RunParams {
        requests: spec.requests,
        seed: cell_seed(spec.seed, &format!("{label}|{}", shape.label())),
        slo_us: prep.slo_us,
        base_rate_per_us: prep.base_rate,
    };
    let ctrl_seed = cell_seed(spec.seed, &format!("policy|{label}|{}", shape.label()));
    let cfg = SloCfg::new(prep.slo_us, ctrl_seed)
        .with_policy(policy.clone())
        .with_shape(shape.clone());
    (label, params, cfg)
}

/// Run one (policy × shape) control-loop scenario against a prepared
/// spec — the campaign cluster axis runs through here. Self-seeded per
/// (policy, shape): equal inputs give bit-equal results at any thread
/// count.
pub fn run_policy_scenario(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    policy: &Policy,
    shape: &TrafficShape,
) -> Result<ClusterResult> {
    run_policy_scenario_faults(prep, spec, policy, shape, spec_faults(spec))
}

/// [`run_policy_scenario`] under an explicit fault regime — the
/// campaign `faults` axis runs through here so one prepared spec can be
/// swept across regimes. `None` (and the empty spec) is bit-identical
/// to the fault-free run: same seeds, same event stream.
pub fn run_policy_scenario_faults(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    policy: &Policy,
    shape: &TrafficShape,
    faults: Option<&FaultsSpec>,
) -> Result<ClusterResult> {
    let (label, params, cfg) = policy_scenario_cfg(prep, spec, policy, shape);
    let mut r = engine::run_obs_sched_faults(
        &prep.policy_topo,
        shape,
        &params,
        Some(cfg),
        &ObsCfg::off(),
        prep.sched,
        faults,
    )?;
    r.label = label;
    Ok(r)
}

// ---------- Multi-tenant scenarios (DESIGN.md §10) ----------

/// One tenant's runtime binding under one config label. The arrival
/// seed hashes (label, tenant, shape) — *not* whether the tenant runs
/// solo or co-located — so a tenant's solo and coloc runs replay the
/// identical offered-load realization and their comparison is paired.
fn tenant_run(spec: &ClusterSpec, label: &str, tenant: usize) -> Result<TenantRun> {
    let t = &spec.tenants[tenant];
    let shape = TrafficShape::parse(&t.traffic)?;
    Ok(TenantRun {
        name: t.name.clone(),
        arrival_seed: cell_seed(
            spec.seed,
            &format!("tenant|{label}|{}|{}", t.name, shape.label()),
        ),
        shape,
        requests: spec.requests,
        slo_us: t.slo_us,
        ways: t.ways,
        demand_ways: t.demand_ways,
        services: spec.tenant_services(tenant)?,
    })
}

/// Every tenant's binding, spec order (the co-located runs).
fn tenant_runs(spec: &ClusterSpec, label: &str) -> Result<Vec<TenantRun>> {
    (0..spec.tenants.len()).map(|ti| tenant_run(spec, label, ti)).collect()
}

fn tenancy_params(spec: &ClusterSpec, adaptive: bool) -> TenancyParams {
    TenancyParams {
        total_ways: spec.total_ways,
        alpha: spec.interference,
        adaptive,
        ctrl: TenantCtrlCfg::default(),
    }
}

/// Run one tenant alone under config `label_idx` — the paired baseline
/// its co-located twin is compared against. Self-seeded: campaign
/// tenant cells reproduce `slofetch cluster` rows exactly.
pub fn run_tenant_solo(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    label_idx: usize,
    tenant: usize,
) -> Result<ClusterResult> {
    run_tenant_solo_obs(prep, spec, label_idx, tenant, &ObsCfg::off())
}

fn run_tenant_solo_obs(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    label_idx: usize,
    tenant: usize,
    obs: &ObsCfg,
) -> Result<ClusterResult> {
    let label = &prep.labels[label_idx];
    let solo = vec![tenant_run(spec, label, tenant)?];
    let params = RunParams {
        requests: spec.requests,
        seed: cell_seed(
            spec.seed,
            &format!("tenant-solo|{label}|{}", spec.tenants[tenant].name),
        ),
        slo_us: prep.slo_us,
        base_rate_per_us: prep.base_rate,
    };
    let mut r = engine::run_tenants_obs_sched(
        &prep.static_topos[label_idx],
        &solo,
        &params,
        &tenancy_params(spec, false),
        obs,
        prep.sched,
    )?;
    r.label = format!("{label}@{}", spec.tenants[tenant].name);
    Ok(r)
}

/// Run every tenant co-located on the shared replica pool under config
/// `label_idx` (static: per-tenant burn is tracked, no control
/// actions). The interference dilation is live — this is the run the
/// solo baselines are paired against.
pub fn run_tenant_coloc(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    label_idx: usize,
) -> Result<ClusterResult> {
    run_tenant_coloc_obs(prep, spec, label_idx, &ObsCfg::off())
}

fn run_tenant_coloc_obs(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    label_idx: usize,
    obs: &ObsCfg,
) -> Result<ClusterResult> {
    let label = &prep.labels[label_idx];
    let runs = tenant_runs(spec, label)?;
    let params = RunParams {
        requests: spec.requests * spec.tenants.len() as u64,
        seed: cell_seed(spec.seed, &format!("tenant-coloc|{label}")),
        slo_us: prep.slo_us,
        base_rate_per_us: prep.base_rate,
    };
    let mut r = engine::run_tenants_obs_sched(
        &prep.static_topos[label_idx],
        &runs,
        &params,
        &tenancy_params(spec, false),
        obs,
        prep.sched,
    )?;
    r.label = format!("{label}@coloc");
    Ok(r)
}

/// The adaptive co-located scenario: per-tenant SLO burn arbitrates the
/// way-repartition / upgrade / add-replica levers on the multi-candidate
/// policy topology, under one shared action budget.
pub fn run_tenant_ctrl(prep: &PreparedSpec, spec: &ClusterSpec) -> Result<ClusterResult> {
    run_tenant_ctrl_obs(prep, spec, &ObsCfg::off())
}

fn run_tenant_ctrl_obs(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    obs: &ObsCfg,
) -> Result<ClusterResult> {
    let runs = tenant_runs(spec, "ctrl")?;
    let params = RunParams {
        requests: spec.requests * spec.tenants.len() as u64,
        seed: cell_seed(spec.seed, "tenant-ctrl"),
        slo_us: prep.slo_us,
        base_rate_per_us: prep.base_rate,
    };
    let mut r = engine::run_tenants_obs_sched(
        &prep.policy_topo,
        &runs,
        &params,
        &tenancy_params(spec, true),
        obs,
        prep.sched,
    )?;
    r.label = "tenant-ctrl".into();
    Ok(r)
}

/// Expand and run a multi-tenant spec: per config, one solo run per
/// tenant plus the co-located run; then the adaptive tenant-control
/// scenario. Scenario runs are independent and self-seeded — results
/// are byte-identical at any `--threads` value.
fn run_tenant_spec(
    prep: &PreparedSpec,
    spec: &ClusterSpec,
    threads: usize,
    obs: &ObsCfg,
) -> Result<ClusterOutcome> {
    #[derive(Clone, Copy)]
    enum Def {
        Solo(usize, usize),
        Coloc(usize),
        Ctrl,
    }
    let mut defs = Vec::new();
    for li in 0..prep.labels.len() {
        for ti in 0..spec.tenants.len() {
            defs.push(Def::Solo(li, ti));
        }
        defs.push(Def::Coloc(li));
    }
    defs.push(Def::Ctrl);
    let scenarios: Vec<ClusterResult> = runner::parallel_map(defs.len(), threads, |i| {
        match defs[i] {
            Def::Solo(li, ti) => run_tenant_solo_obs(prep, spec, li, ti, obs),
            Def::Coloc(li) => run_tenant_coloc_obs(prep, spec, li, obs),
            Def::Ctrl => run_tenant_ctrl_obs(prep, spec, obs),
        }
    })
    .into_iter()
    .collect::<Result<_>>()?;
    let total_requests = scenarios.iter().map(|s| s.requests).sum();
    let total_events = scenarios.iter().map(|s| s.events).sum();
    Ok(ClusterOutcome {
        scenarios,
        total_requests,
        total_events,
        ipc_cells: prep.ipc_cells,
        slo_us: prep.slo_us,
        fleet: prep.fleet.clone(),
    })
}

/// Expand and run a cluster spec: measure the (app × prefetcher) IPC
/// matrix through the campaign runner, then run every static
/// (config × traffic) scenario plus one control-loop scenario per
/// (policy × traffic) — sharded across `threads` workers (0 = auto)
/// with byte-identical results at any thread count.
pub fn run_spec(spec: &ClusterSpec, threads: usize) -> Result<ClusterOutcome> {
    run_spec_obs(spec, threads, &ObsCfg::off())
}

/// [`run_spec`] with an observability configuration (DESIGN.md §11):
/// every scenario records spans/metrics when `obs.enabled`. Disabled is
/// exactly [`run_spec`] — byte-identical outputs.
pub fn run_spec_obs(spec: &ClusterSpec, threads: usize, obs: &ObsCfg) -> Result<ClusterOutcome> {
    let prep = prepare_spec(spec, threads)?;
    if spec.tenancy() {
        return run_tenant_spec(&prep, spec, threads, obs);
    }
    let policies = spec.effective_policies()?;
    let shapes: Vec<TrafficShape> = spec
        .traffic
        .iter()
        .map(|t| TrafficShape::parse(t))
        .collect::<Result<_>>()?;

    // Deterministic scenario expansion: analytic configs ▸ shapes, then
    // (empirical mode) trace-replayed configs ▸ shapes, then policies ▸
    // shapes. Analytic statics come first so an analytic spec's output
    // is unchanged from pre-empirical builds.
    let mut defs = Vec::new();
    // Seeds derive from the *base* label for both models — see
    // [`EMPIRICAL_SUFFIX`]: twins share the exact arrival realization.
    let mut push_static = |label: String, seed_label: &str, topo: &ResolvedTopology| {
        for shape in &shapes {
            let seed = cell_seed(spec.seed, &format!("{seed_label}|{}", shape.label()));
            defs.push(ScenarioDef {
                label: label.clone(),
                shape: shape.clone(),
                topo: topo.clone(),
                params: RunParams {
                    requests: spec.requests,
                    seed,
                    slo_us: prep.slo_us,
                    base_rate_per_us: prep.base_rate,
                },
                ctrl: None,
            });
        }
    };
    for (label, topo) in prep.labels.iter().zip(&prep.static_topos) {
        push_static(label.clone(), label, topo);
    }
    for (label, topo) in prep.labels.iter().zip(&prep.empirical_topos) {
        push_static(format!("{label}{EMPIRICAL_SUFFIX}"), label, topo);
    }
    for policy in &policies {
        for shape in &shapes {
            let (label, params, cfg) = policy_scenario_cfg(&prep, spec, policy, shape);
            defs.push(ScenarioDef {
                label,
                shape: shape.clone(),
                topo: prep.policy_topo.clone(),
                params,
                ctrl: Some(cfg),
            });
        }
    }

    // Shard scenarios across workers; collect by index (scenario runs
    // are independent and self-seeded, so order of completion is
    // irrelevant to the result).
    let scenarios = run_scenarios(&defs, threads, obs, prep.sched, spec_faults(spec))?;
    let total_requests = scenarios.iter().map(|s| s.requests).sum();
    let total_events = scenarios.iter().map(|s| s.events).sum();
    Ok(ClusterOutcome {
        scenarios,
        total_requests,
        total_events,
        ipc_cells: prep.ipc_cells,
        slo_us: prep.slo_us,
        fleet: prep.fleet,
    })
}

fn run_scenarios(
    defs: &[ScenarioDef],
    threads: usize,
    obs: &ObsCfg,
    sched: SchedKind,
    faults: Option<&FaultsSpec>,
) -> Result<Vec<ClusterResult>> {
    runner::parallel_map(defs.len(), threads, |i| {
        let d = &defs[i];
        engine::run_obs_sched_faults(&d.topo, &d.shape, &d.params, d.ctrl.clone(), obs, sched, faults)
            .map(|mut r| {
                r.label = d.label.clone();
                r
            })
    })
    .into_iter()
    .collect()
}

/// Scenario summary table (deterministic: pure function of the outcome).
pub fn report(out: &ClusterOutcome) -> Table {
    let mut t = Table::new(
        "cluster",
        &format!("Cluster scenarios (SLO {} µs)", f2(out.slo_us)),
        &[
            "config",
            "traffic",
            "P50 µs",
            "P95 µs",
            "P99 µs",
            "compliance",
            "burn",
            "actions",
            "replicas",
            "replica·s",
            "metadata",
        ],
    );
    for s in &out.scenarios {
        let replicas: Vec<String> = s.final_replicas.iter().map(|r| r.to_string()).collect();
        let mean_meta = if s.duration_us > 0.0 { s.meta_byte_us / s.duration_us } else { 0.0 };
        t.row(vec![
            s.label.clone(),
            s.traffic.clone(),
            f2(s.p50_us),
            f2(s.p95_us),
            f2(s.p99_us),
            pct(s.compliance),
            format!("{}/{}", s.violated_windows, s.windows),
            s.actions.len().to_string(),
            replicas.join(","),
            f2(s.replica_us / 1e6),
            kb(mean_meta as u64),
        ]);
    }
    t.note(
        "burn = windows below target compliance / windows evaluated; replica·s = \
         ∫ provisioned replicas dt; metadata = time-averaged footprint; offered load \
         is anchored on the slowest config's bottleneck",
    );
    t
}

/// Analytic-vs-empirical comparison for static scenarios: one row per
/// (config, traffic) pairing the analytic twin with its trace-replayed
/// (`~emp`) counterpart. `None` when the outcome has no empirical
/// scenarios (analytic specs). Deterministic: a pure function of the
/// outcome, rows in scenario-expansion order.
pub fn model_report(out: &ClusterOutcome) -> Option<Table> {
    let mut t = Table::new(
        "cluster_models",
        "Service-time models: analytic vs trace-replayed (empirical)",
        &[
            "config",
            "traffic",
            "P50 µs (ana)",
            "P50 µs (emp)",
            "P99 µs (ana)",
            "P99 µs (emp)",
            "Δ P99",
        ],
    );
    for emp in &out.scenarios {
        let base = match emp.label.strip_suffix(EMPIRICAL_SUFFIX) {
            Some(b) => b,
            None => continue,
        };
        let ana = out
            .scenarios
            .iter()
            .find(|s| s.label == base && s.traffic == emp.traffic);
        let ana = match ana {
            Some(a) => a,
            None => continue,
        };
        let delta = (emp.p99_us - ana.p99_us) / ana.p99_us * 100.0;
        t.row(vec![
            base.to_string(),
            emp.traffic.clone(),
            f2(ana.p50_us),
            f2(emp.p50_us),
            f2(ana.p99_us),
            f2(emp.p99_us),
            format!("{delta:+.1}%"),
        ]);
    }
    if t.rows.is_empty() {
        return None;
    }
    t.note(
        "paired runs: twins share the arrival realization (same seed, independent \
         arrival RNG stream) and the measured mean service time per (service, \
         config); the empirical rows replay the per-request distribution segmented \
         from the instruction trace (ctx-tag boundaries), so the delta is pure \
         shape — the variance a mean+cv model cannot see",
    );
    Some(t)
}

/// Paired solo-vs-co-located comparison per (config, tenant): the
/// interference-induced tail delta, per-tenant SLO burn, and final way
/// shares (DESIGN.md §10). `None` for single-tenant outcomes.
/// Deterministic: a pure function of the outcome, rows in
/// scenario-expansion order.
pub fn tenant_report(out: &ClusterOutcome) -> Option<Table> {
    let mut t = Table::new(
        "cluster_tenants",
        "Multi-tenant co-location: solo vs co-located (paired arrival streams)",
        &[
            "config",
            "tenant",
            "traffic",
            "P50 µs (solo)",
            "P50 µs (coloc)",
            "P99 µs (solo)",
            "P99 µs (coloc)",
            "Δ P99",
            "burn",
            "ways",
        ],
    );
    for coloc in &out.scenarios {
        let base = match coloc.label.strip_suffix("@coloc") {
            Some(b) => b,
            None => continue,
        };
        for ts in &coloc.tenants {
            let solo_label = format!("{base}@{}", ts.name);
            let solo = match out.scenarios.iter().find(|s| s.label == solo_label) {
                Some(s) => s,
                None => continue,
            };
            let delta = (ts.p99_us - solo.p99_us) / solo.p99_us * 100.0;
            t.row(vec![
                base.to_string(),
                ts.name.clone(),
                ts.traffic.clone(),
                f2(solo.p50_us),
                f2(ts.p50_us),
                f2(solo.p99_us),
                f2(ts.p99_us),
                format!("{delta:+.1}%"),
                format!("{}/{}", ts.violated_windows, ts.windows),
                ts.final_ways.to_string(),
            ]);
        }
    }
    if t.rows.is_empty() {
        return None;
    }
    t.note(
        "paired runs: a tenant's solo and co-located scenarios share the arrival \
         seed, so Δ P99 is pure co-location (shared queues + way-overflow \
         dilation); burn = the tenant's burned/evaluated SLO windows in the \
         co-located run; a coloc row's compliance in the main cluster table \
         judges each request against its own tenant's SLO",
    );
    Some(t)
}

/// Control-action trace table for adaptive scenarios (empty-safe).
pub fn action_report(out: &ClusterOutcome) -> Option<Table> {
    let mut t = Table::new(
        "cluster_actions",
        "SLO control-loop actions",
        &["config", "traffic", "t µs", "service", "action"],
    );
    for s in &out.scenarios {
        for a in &s.actions {
            t.row(vec![
                s.label.clone(),
                s.traffic.clone(),
                f2(a.t_us),
                a.service.clone(),
                a.action.clone(),
            ]);
        }
    }
    if t.rows.is_empty() {
        None
    } else {
        Some(t)
    }
}

/// Fault and client-response accounting per scenario (DESIGN.md §14):
/// crash/retry/hedge/timeout counts, failed stages, and lazily-cancelled
/// (stale) events. `None` when no scenario saw a fault or policy fire —
/// fault-free outcomes never grow the report byte-stream. Deterministic:
/// a pure function of the outcome, rows in scenario-expansion order.
pub fn fault_report(out: &ClusterOutcome) -> Option<Table> {
    let mut t = Table::new(
        "cluster_faults",
        "Fault injection: crashes, client responses, cancelled events",
        &[
            "config",
            "traffic",
            "crashes",
            "retries",
            "hedges",
            "timeouts",
            "failed",
            "stale",
        ],
    );
    for s in &out.scenarios {
        if s.fault_stats.is_zero() {
            continue;
        }
        let f = &s.fault_stats;
        t.row(vec![
            s.label.clone(),
            s.traffic.clone(),
            f.crashes.to_string(),
            f.retries.to_string(),
            f.hedges.to_string(),
            f.timeouts.to_string(),
            f.failed.to_string(),
            f.stale_events.to_string(),
        ]);
    }
    if t.rows.is_empty() {
        return None;
    }
    t.note(
        "crashes = replica-down events; retries counts every re-dispatch (timeout \
         retries and crash requeues); failed = stages that exhausted their retry \
         budget and completed as SLO misses; stale = lazily-cancelled events the \
         scheduler discarded (lost hedge twins, cancelled timeouts, drained queue \
         entries)",
    );
    Some(t)
}

/// Critical-path attribution over the sampled request spans: per
/// (scenario, service), P50/P99 of the queue / service / fan-in /
/// interference latency components (DESIGN.md §11). `None` when no
/// scenario carries observability data (obs-off runs — so the baseline
/// report byte-stream never gains a table). Deterministic: a pure
/// function of the outcome, rows in scenario-expansion order.
pub fn critical_path_report(out: &ClusterOutcome) -> Option<Table> {
    let mut t = Table::new(
        "cluster_critical_path",
        "Critical-path attribution over sampled request spans",
        &[
            "config",
            "traffic",
            "service",
            "spans",
            "queue P50",
            "queue P99",
            "service P50",
            "service P99",
            "fan-in P50",
            "fan-in P99",
            "interf P50",
            "interf P99",
        ],
    );
    for s in &out.scenarios {
        let data = match &s.obs {
            Some(d) => d,
            None => continue,
        };
        for st in &data.span_stats {
            t.row(vec![
                s.label.clone(),
                s.traffic.clone(),
                st.service.clone(),
                st.samples.to_string(),
                f2(st.queue_p50_us),
                f2(st.queue_p99_us),
                f2(st.service_p50_us),
                f2(st.service_p99_us),
                f2(st.fanin_p50_us),
                f2(st.fanin_p99_us),
                f2(st.interference_p50_us),
                f2(st.interference_p99_us),
            ]);
        }
    }
    if t.rows.is_empty() {
        return None;
    }
    t.note(
        "all values µs over hash-sampled requests (1 in 2^shift by arrival index — \
         no RNG draws): queue = dispatchable→start, service = start→complete, \
         fan-in = first→last upstream dependency clearing, interf = service time \
         added by tenant-interference dilation",
    );
    Some(t)
}

/// Fleet sketch-telemetry summary: one row per (source, config)
/// measurement cell plus the merged fleet view (DESIGN.md §12). `None`
/// under the default `telemetry: "exact"` knob, so the baseline report
/// byte-stream never gains a table. Deterministic: cells are in
/// measurement expansion order and the merge is order-invariant.
pub fn fleet_report(out: &ClusterOutcome) -> Option<Table> {
    let fleet = out.fleet.as_ref()?;
    let mut t = Table::new(
        "cluster_fleet",
        &format!("Fleet sketch telemetry ({})", fleet.merged.cfg.label()),
        &[
            "source",
            "config",
            "issued",
            "useful",
            "useless",
            "ctx≈",
            "fill",
            "bytes",
            "agree",
        ],
    );
    let mut row = |src: &str, pf: &str, tel: &Telemetry| {
        t.row(vec![
            src.to_string(),
            pf.to_string(),
            tel.issued.total().to_string(),
            tel.useful.total().to_string(),
            tel.useless.total().to_string(),
            format!("{:.0}", tel.contexts.estimate()),
            pct(tel.issued.fill_ratio()),
            kb(tel.bytes()),
            tel.agreement().map(pct).unwrap_or_else(|| "—".into()),
        ]);
    };
    for (src, pf, tel) in &fleet.cells {
        row(src, pf, tel);
    }
    row("fleet", "·merged", &fleet.merged);
    t.note(
        "bounded-memory streaming summaries per measurement cell: issued/useful/\
         useless are count-min totals, ctx≈ the HLL distinct-context estimate, \
         fill the occupied fraction of the issue sketch, agree the exact-vs-\
         sketch decision agreement (compare mode only); the fleet row is the \
         associative merge of every cell",
    );
    Some(t)
}

/// Hottest source contexts across the fleet (space-saving top-K over
/// the merged issue stream). `None` without sketch telemetry.
pub fn fleet_topk_report(out: &ClusterOutcome) -> Option<Table> {
    let fleet = out.fleet.as_ref()?;
    let mut t = Table::new(
        "cluster_fleet_topk",
        "Fleet heavy hitters (source contexts by estimated issue count)",
        &["rank", "context", "issues≈"],
    );
    for (rank, (ctx, est)) in fleet.merged.hot.top().into_iter().enumerate() {
        t.row(vec![(rank + 1).to_string(), format!("{ctx:#x}"), est.to_string()]);
    }
    t.note(
        "space-saving estimates are upper bounds (≤ true count + table error); \
         the union of per-cell tables is truncated once, so ranks are invariant \
         to cell order and thread count",
    );
    Some(t)
}

/// Chrome trace-event / Perfetto-compatible document over every
/// scenario's sampled spans and control actions (DESIGN.md §11): one
/// process per (scenario, service) plus a controller process per
/// scenario, one thread per replica, spans as complete slices, lever
/// applications as instants. Timestamps are simulated µs — the dump is
/// byte-identical across `--threads` values and reruns.
pub fn trace_json(out: &ClusterOutcome) -> Json {
    let mut events = Vec::new();
    for (si, s) in out.scenarios.iter().enumerate() {
        let data = match &s.obs {
            Some(d) => d,
            None => continue,
        };
        let base = si as u64 * 1000;
        let ctrl_pid = base + data.services.len() as u64;
        for (svc, name) in data.services.iter().enumerate() {
            events.push(obs_trace::process_meta(
                base + svc as u64,
                &format!("{}|{}/{}", s.label, s.traffic, name),
            ));
        }
        events.push(obs_trace::process_meta(
            ctrl_pid,
            &format!("{}|{}/controller", s.label, s.traffic),
        ));
        let tracks: BTreeSet<(u32, u32)> =
            data.trace_spans.iter().map(|sp| (sp.svc, sp.rep)).collect();
        for &(svc, rep) in &tracks {
            events.push(obs_trace::thread_meta(
                base + svc as u64,
                rep as u64 + 1,
                &format!("replica {rep}"),
            ));
        }
        for sp in &data.trace_spans {
            events.push(obs_trace::slice(
                base + sp.svc as u64,
                sp.rep as u64 + 1,
                sp.start_us,
                sp.end_us - sp.start_us,
                &format!("req {}", sp.req),
                vec![
                    ("req", Json::num(sp.req as f64)),
                    ("tenant", Json::num(sp.tenant as f64)),
                    ("queue_us", Json::num(sp.queue_us)),
                    ("fanin_us", Json::num(sp.fanin_us)),
                    ("interference_us", Json::num(sp.interference_us)),
                ],
            ));
        }
        for a in &s.actions {
            events.push(obs_trace::instant(
                ctrl_pid,
                0,
                a.t_us,
                &format!("{}: {}", a.service, a.action),
            ));
        }
    }
    obs_trace::trace_doc(events)
}

/// Windowed metrics timeseries as JSONL: one compact-JSON line per
/// (scenario, SLO-window snapshot), tagged with the scenario label and
/// traffic shape. Sorted-key objects and simulated-µs timestamps keep
/// the byte stream thread-count invariant.
pub fn metrics_jsonl(out: &ClusterOutcome) -> String {
    let mut text = String::new();
    for s in &out.scenarios {
        let data = match &s.obs {
            Some(d) => d,
            None => continue,
        };
        for snap in &data.snapshots {
            let mut map = match snap.clone() {
                Json::Obj(m) => m,
                _ => continue,
            };
            map.insert("scenario".to_string(), Json::str(&s.label));
            map.insert("traffic".to_string(), Json::str(&s.traffic));
            text.push_str(&Json::Obj(map).dump());
            text.push('\n');
        }
    }
    // Sketch-telemetry summaries ride the same stream: one line per
    // measurement cell plus the merged fleet view, tagged so consumers
    // can filter them from the windowed scenario snapshots.
    if let Some(fleet) = &out.fleet {
        let mut push = |cell: String, tel: &Telemetry| {
            if let Json::Obj(mut map) = tel.summary_json() {
                map.insert("scenario".to_string(), Json::str("fleet"));
                map.insert("cell".to_string(), Json::str(&cell));
                text.push_str(&Json::Obj(map).dump());
                text.push('\n');
            }
        };
        for (src, pf, tel) in &fleet.cells {
            push(format!("{src}|{pf}"), tel);
        }
        push("merged".to_string(), &fleet.merged);
    }
    text
}

/// Tail summary of one campaign cell under a traffic shape: the cell's
/// measured IPC sets the service time of a single-service cluster
/// (1 replica, 25k instrs/req, cv 0.35 at 2.5 GHz) and the shape drives
/// arrivals. SLO = 5× the zero-load service time.
#[derive(Clone, Copy, Debug)]
pub struct TailSummary {
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub compliance: f64,
    pub slo_us: f64,
}

/// Requests simulated per campaign-cell tail evaluation.
pub const TAIL_EVAL_REQUESTS: u64 = 30_000;

pub fn evaluate_tail(ipc: f64, shape: &TrafficShape, seed: u64) -> Result<TailSummary> {
    let topo = ResolvedTopology::chain_from_ipcs(
        &[("svc".to_string(), ipc)],
        25_000.0,
        0.35,
        2.5,
    );
    let slo_us = topo.zero_load_us() * 5.0;
    let params = RunParams {
        requests: TAIL_EVAL_REQUESTS,
        seed,
        slo_us,
        base_rate_per_us: topo.bottleneck_rate(),
    };
    let r = engine::run(&topo, shape, &params, None)?;
    Ok(TailSummary {
        p50_us: r.p50_us,
        p95_us: r.p95_us,
        p99_us: r.p99_us,
        compliance: r.compliance,
        slo_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ClusterSpec {
        ClusterSpec {
            name: "tiny".into(),
            topology: Topology {
                services: vec![
                    ServiceSpec {
                        // Clear bottleneck (1 replica, prefetch-sensitive app).
                        name: "gw".into(),
                        app: "websearch".into(),
                        replicas: 1,
                        instrs_per_req: 30_000.0,
                        cv: 0.35,
                        deps: vec![],
                        trace: None,
                    },
                    ServiceSpec {
                        name: "be".into(),
                        app: "serde".into(),
                        replicas: 2,
                        instrs_per_req: 20_000.0,
                        cv: 0.35,
                        deps: vec!["gw".into()],
                        trace: None,
                    },
                ],
                freq_ghz: 2.5,
            },
            prefetchers: vec!["nl".into(), "ceip256".into()],
            traffic: vec!["poisson:0.6".into()],
            requests: 8_000,
            records: 10_000,
            seed: 5,
            slo_us: 0.0,
            utilization: 1.0,
            adaptive: true,
            policies: Vec::new(),
            service_times: "analytic".into(),
            tenants: Vec::new(),
            total_ways: 8,
            interference: 0.8,
            telemetry: "exact".into(),
            scheduler: "calendar".into(),
            faults: FaultsSpec::default(),
        }
    }

    fn tiny_tenant_spec() -> ClusterSpec {
        ClusterSpec {
            adaptive: false,
            requests: 3_000,
            tenants: vec![
                spec::TenantSpec {
                    name: "web".into(),
                    services: vec!["gw".into()],
                    traffic: "poisson:0.45".into(),
                    slo_us: 0.0,
                    ways: 4,
                    demand_ways: 6,
                },
                spec::TenantSpec {
                    name: "batch".into(),
                    services: Vec::new(),
                    traffic: "poisson:0.3".into(),
                    slo_us: 0.0,
                    ways: 4,
                    demand_ways: 5,
                },
            ],
            ..tiny_spec()
        }
    }

    #[test]
    fn run_spec_is_thread_count_invariant() {
        let spec = tiny_spec();
        let a = run_spec(&spec, 1).unwrap();
        let b = run_spec(&spec, 4).unwrap();
        assert_eq!(a.scenarios.len(), spec.scenario_count());
        assert_eq!(a.total_requests, spec.requests * spec.scenario_count() as u64);
        assert_eq!(report(&a).markdown(), report(&b).markdown());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits());
            assert_eq!(x.events, y.events);
        }
    }

    #[test]
    fn faster_config_orders_p99_in_run_spec() {
        let spec = ClusterSpec { adaptive: false, requests: 25_000, ..tiny_spec() };
        let out = run_spec(&spec, 0).unwrap();
        let p99 = |label: &str| {
            out.scenarios.iter().find(|s| s.label == label).unwrap().p99_us
        };
        // Same offered load; the faster prefetcher tightens the tail.
        assert!(p99("ceip256") < p99("nl"), "ceip {} !< nl {}", p99("ceip256"), p99("nl"));
    }

    #[test]
    fn evaluate_tail_is_deterministic_and_sane() {
        let shape = TrafficShape::Poisson { util: 0.65 };
        let a = evaluate_tail(2.0, &shape, 9).unwrap();
        let b = evaluate_tail(2.0, &shape, 9).unwrap();
        assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits());
        assert!(a.p50_us <= a.p95_us && a.p95_us <= a.p99_us);
        assert!(a.compliance > 0.0 && a.compliance <= 1.0);
        // Faster core ⇒ shorter absolute tail (same utilization).
        let fast = evaluate_tail(2.4, &shape, 9).unwrap();
        assert!(fast.p99_us < a.p99_us);
    }

    #[test]
    fn report_contains_every_scenario_row() {
        let spec = ClusterSpec { adaptive: false, requests: 4_000, ..tiny_spec() };
        let out = run_spec(&spec, 2).unwrap();
        let t = report(&out);
        assert_eq!(t.rows.len(), out.scenarios.len());
        assert!(t.markdown().contains("ceip256"));
    }

    #[test]
    fn policy_suite_runs_one_scenario_per_policy_and_shape() {
        let spec = ClusterSpec {
            adaptive: false,
            policies: vec![
                "reactive".into(),
                "hysteresis".into(),
                "cost-aware:262144".into(),
            ],
            requests: 6_000,
            ..tiny_spec()
        };
        let out = run_spec(&spec, 2).unwrap();
        // (2 prefetchers + 3 policies) × 1 shape.
        assert_eq!(out.scenarios.len(), 5);
        for policy in &spec.policies {
            let label = Policy::parse(policy).unwrap().label();
            let s = out
                .scenarios
                .iter()
                .find(|s| s.label == label)
                .unwrap_or_else(|| panic!("missing policy scenario '{label}'"));
            assert_eq!(s.requests, spec.requests);
            assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us);
            assert!(s.replica_us > 0.0);
        }
        // run_policy_scenario is the same computation the sweep did.
        let prep = prepare_spec(&spec, 1).unwrap();
        let shape = TrafficShape::parse(&spec.traffic[0]).unwrap();
        let direct = run_policy_scenario(&prep, &spec, &Policy::Reactive, &shape).unwrap();
        let swept = out.scenarios.iter().find(|s| s.label == "reactive").unwrap();
        assert_eq!(direct.p99_us.to_bits(), swept.p99_us.to_bits());
        assert_eq!(direct.events, swept.events);
    }

    #[test]
    fn empirical_mode_replays_traces_and_stays_thread_invariant() {
        let spec = ClusterSpec {
            service_times: "empirical".into(),
            requests: 6_000,
            ..tiny_spec()
        };
        let a = run_spec(&spec, 1).unwrap();
        let b = run_spec(&spec, 4).unwrap();
        // (2 configs × 2 models + 1 adaptive) × 1 shape.
        assert_eq!(a.scenarios.len(), spec.scenario_count());
        assert_eq!(a.scenarios.len(), 5);
        assert_eq!(report(&a).markdown(), report(&b).markdown());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}", x.label);
            assert_eq!(x.events, y.events);
        }
        // The comparison table pairs every (config, shape).
        let models = model_report(&a).expect("empirical run must emit the model table");
        assert_eq!(models.rows.len(), 2);
        assert!(models.markdown().contains("ceip256"));
        // Empirical twins exist, are distinct runs, and share the
        // analytic anchor (same offered load, finite sane percentiles).
        let emp = a.scenarios.iter().find(|s| s.label == "nl~emp").unwrap();
        let ana = a.scenarios.iter().find(|s| s.label == "nl").unwrap();
        assert_eq!(emp.requests, ana.requests);
        assert!(emp.p50_us.is_finite() && emp.p99_us > emp.p50_us);
        assert_ne!(emp.p99_us.to_bits(), ana.p99_us.to_bits(), "twins ran the same model");
        // Analytic specs emit no model table.
        let plain = run_spec(&tiny_spec(), 2).unwrap();
        assert!(model_report(&plain).is_none());
    }

    #[test]
    fn tenant_spec_expands_pairs_and_stays_thread_invariant() {
        let spec = tiny_tenant_spec();
        let a = run_spec(&spec, 1).unwrap();
        let b = run_spec(&spec, 4).unwrap();
        // 2 configs × (2 solos + 1 coloc) + tenant-ctrl.
        assert_eq!(a.scenarios.len(), spec.scenario_count());
        assert_eq!(a.scenarios.len(), 7);
        assert_eq!(report(&a).markdown(), report(&b).markdown());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}", x.label);
            assert_eq!(x.events, y.events);
        }
        // The paired table has one row per (config, tenant).
        let t = tenant_report(&a).expect("tenant table missing");
        let tb = tenant_report(&b).expect("tenant table missing");
        assert_eq!(t.markdown(), tb.markdown());
        assert_eq!(t.rows.len(), 4);
        assert!(t.markdown().contains("nl"));
        assert!(t.markdown().contains("web"));
        // Single-tenant outcomes emit no tenant table.
        let plain = run_spec(&tiny_spec(), 2).unwrap();
        assert!(tenant_report(&plain).is_none());
        // Solo scenarios carry exactly one tenant, coloc both, and the
        // coloc run serves each tenant the full request count.
        let coloc = a.scenarios.iter().find(|s| s.label == "nl@coloc").unwrap();
        assert_eq!(coloc.tenants.len(), 2);
        assert_eq!(coloc.requests, spec.requests * 2);
        for ts in &coloc.tenants {
            assert_eq!(ts.requests, spec.requests);
        }
        let solo = a.scenarios.iter().find(|s| s.label == "nl@web").unwrap();
        assert_eq!(solo.tenants.len(), 1);
        assert_eq!(solo.requests, spec.requests);
        // Co-location can only hurt a tenant: shared queues plus
        // way-overflow dilation (both tenants overflow their shares).
        let web = coloc.tenants.iter().find(|t| t.name == "web").unwrap();
        assert!(
            web.p99_us > solo.p99_us,
            "co-location tightened the tail?! coloc {} vs solo {}",
            web.p99_us,
            solo.p99_us
        );
        // The adaptive scenario ran on the policy topology.
        assert!(a.scenarios.iter().any(|s| s.label == "tenant-ctrl"));
    }

    #[test]
    fn faulted_spec_runs_thread_invariantly_and_reports() {
        let spec = ClusterSpec {
            adaptive: false,
            policies: vec!["reactive".into()],
            requests: 6_000,
            faults: FaultsSpec {
                events: vec!["down:be:0:20000:30000".into()],
                client: vec![ClientPolicySpec {
                    service: "be".into(),
                    policy: EdgePolicy {
                        timeout_us: Some(60.0),
                        retries: 2,
                        backoff_us: 20.0,
                        hedge_after_us: Some(25.0),
                    },
                }],
            },
            ..tiny_spec()
        };
        let a = run_spec(&spec, 1).unwrap();
        let b = run_spec(&spec, 4).unwrap();
        assert_eq!(report(&a).markdown(), report(&b).markdown());
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}", x.label);
            assert_eq!(x.events, y.events);
            assert_eq!(x.fault_stats, y.fault_stats, "{}", x.label);
        }
        // Every request still completes — budget exhaustion is an SLO
        // miss, never a hang.
        for s in &a.scenarios {
            assert_eq!(s.requests, spec.requests, "{}", s.label);
        }
        let t = fault_report(&a).expect("faulted run must emit the fault table");
        assert_eq!(t.markdown(), fault_report(&b).unwrap().markdown());
        assert!(a.scenarios.iter().any(|s| s.fault_stats.crashes > 0));
        // Fault-free outcomes never grow the report byte-stream.
        let plain = run_spec(&tiny_spec(), 2).unwrap();
        assert!(fault_report(&plain).is_none());
        assert!(plain.scenarios.iter().all(|s| s.fault_stats.is_zero()));
    }

    #[test]
    fn obs_runs_match_baseline_and_artifacts_are_thread_invariant() {
        let spec = ClusterSpec { adaptive: false, requests: 6_000, ..tiny_spec() };
        let base = run_spec(&spec, 2).unwrap();
        // Obs-off through the obs entry point IS the baseline.
        let off = run_spec_obs(&spec, 2, &ObsCfg::off()).unwrap();
        assert_eq!(report(&base).markdown(), report(&off).markdown());
        assert!(critical_path_report(&off).is_none(), "obs-off must not grow the report");
        // Obs-on: simulation outputs unchanged, artifacts thread-invariant.
        let a = run_spec_obs(&spec, 1, &ObsCfg::on(5)).unwrap();
        let b = run_spec_obs(&spec, 4, &ObsCfg::on(5)).unwrap();
        assert_eq!(report(&a).markdown(), report(&base).markdown(), "obs perturbed the run");
        assert_eq!(report(&a).markdown(), report(&b).markdown());
        assert_eq!(trace_json(&a).dump(), trace_json(&b).dump());
        assert_eq!(metrics_jsonl(&a), metrics_jsonl(&b));
        let t = critical_path_report(&a).expect("obs run must emit the critical-path table");
        assert_eq!(t.markdown(), critical_path_report(&b).unwrap().markdown());
        assert!(t.markdown().contains("gw") && t.markdown().contains("be"));
        // The artifacts are non-trivial and well-formed.
        let doc = trace_json(&a).dump();
        assert!(doc.contains("\"ph\":\"X\"") && doc.contains("\"process_name\""));
        let lines: Vec<&str> = metrics_jsonl(&a).lines().collect();
        assert!(!lines.is_empty(), "6k requests at window 2000 must close windows");
        for line in &lines {
            let snap = Json::parse(line).expect("metrics line must parse");
            assert!(snap.dump().contains("\"scenario\""));
        }
    }

    #[test]
    fn prepare_spec_fits_unit_mean_tables_in_empirical_mode() {
        let spec = ClusterSpec { service_times: "empirical".into(), ..tiny_spec() };
        let prep = prepare_spec(&spec, 2).unwrap();
        assert!(prep.empirical);
        assert_eq!(prep.empirical_topos.len(), prep.labels.len());
        for (topo, ana) in prep.empirical_topos.iter().zip(&prep.static_topos) {
            for (s, sa) in topo.services.iter().zip(&ana.services) {
                for (c, ca) in s.candidates.iter().zip(&sa.candidates) {
                    let t = c.table.expect("empirical candidate lost its table");
                    assert!(t.min() > 0.0 && t.min() <= t.max());
                    // Unit-mean table ⇒ identical mean service time, so
                    // load/SLO anchors are shared across models.
                    assert_eq!(c.mean_us.to_bits(), ca.mean_us.to_bits());
                    assert!(ca.table.is_none(), "analytic twin carries a table");
                }
            }
        }
        // The policy topology replays the tables too.
        assert!(prep
            .policy_topo
            .services
            .iter()
            .all(|s| s.candidates.iter().all(|c| c.table.is_some())));
        // Analytic mode is untouched: no tables anywhere.
        let plain = prepare_spec(&tiny_spec(), 2).unwrap();
        assert!(!plain.empirical);
        assert!(plain.empirical_topos.is_empty());
        assert!(plain
            .policy_topo
            .services
            .iter()
            .all(|s| s.candidates.iter().all(|c| c.table.is_none())));
    }

    #[test]
    fn fleet_telemetry_rides_the_spec_thread_invariantly() {
        let spec = ClusterSpec {
            adaptive: false,
            requests: 4_000,
            telemetry: "sketch:w128d4p10k8".into(),
            ..tiny_spec()
        };
        let a = run_spec(&spec, 1).unwrap();
        let b = run_spec(&spec, 4).unwrap();
        // Sketching the measurement cells must not move the scenarios.
        let base = run_spec(&ClusterSpec { telemetry: "exact".into(), ..spec.clone() }, 2)
            .unwrap();
        assert_eq!(report(&a).markdown(), report(&base).markdown());
        assert!(base.fleet.is_none());
        assert!(fleet_report(&base).is_none() && fleet_topk_report(&base).is_none());
        // Fleet view: one summary per (source, config) cell + the merge.
        let fleet = a.fleet.as_ref().expect("sketch spec must carry fleet telemetry");
        assert_eq!(fleet.cells.len(), a.ipc_cells);
        let per_cell: u64 = fleet.cells.iter().map(|(_, _, t)| t.issued.total()).sum();
        assert_eq!(fleet.merged.issued.total(), per_cell);
        assert!(per_cell > 0, "measurement cells issued no prefetches");
        // Tables and JSONL are byte-identical across thread counts.
        let ta = fleet_report(&a).expect("fleet table missing");
        assert_eq!(ta.markdown(), fleet_report(&b).unwrap().markdown());
        assert_eq!(ta.rows.len(), a.ipc_cells + 1);
        let ka = fleet_topk_report(&a).expect("topk table missing");
        assert_eq!(ka.markdown(), fleet_topk_report(&b).unwrap().markdown());
        assert_eq!(metrics_jsonl(&a), metrics_jsonl(&b));
        // The JSONL stream carries one tagged line per cell + merged,
        // and every fleet line parses with the documented keys.
        let jsonl = metrics_jsonl(&a);
        let fleet_lines: Vec<&str> = jsonl.lines().filter(|l| l.contains("\"cell\"")).collect();
        assert_eq!(fleet_lines.len(), a.ipc_cells + 1);
        for line in &fleet_lines {
            let snap = Json::parse(line).expect("fleet line must parse");
            let d = snap.dump();
            assert!(d.contains("\"contexts_est\"") && d.contains("\"scenario\":\"fleet\""));
        }
    }
}
