//! Windowed SLO tracking and the burn-driven control loop (paper §XI /
//! §VI): per-window P95/P99/compliance over completed requests, plus a
//! controller that reacts to SLO burn by either switching the bottleneck
//! service to a faster prefetcher config or adding a replica.
//!
//! Reuses the repo's existing adaptation machinery: arm selection is the
//! contextual bandit ([`crate::ml::bandit::Bandit`], rewarded with the
//! next window's compliance) and action frequency is bounded by the
//! deployment token bucket ([`crate::coordinator::budget::TokenBucket`],
//! reinterpreted over completions instead of cycles).

use crate::coordinator::budget::TokenBucket;
use crate::ml::bandit::{Bandit, Context};
use crate::util::percentile::Digest;

/// Control-loop configuration.
#[derive(Clone, Debug)]
pub struct SloCfg {
    /// Latency target (µs).
    pub slo_us: f64,
    /// Completions per evaluation window.
    pub window: u32,
    /// Compliance target: a window with a smaller met-fraction burns.
    pub target: f64,
    /// Per-service replica cap for scale-out actions.
    pub max_replicas: u32,
    /// Control actions per 1000 completions (token-bucket rate).
    pub action_rate_per_kreq: f64,
    /// Token-bucket burst (actions available immediately).
    pub action_burst: f64,
    /// Bandit RNG seed (derived from the scenario seed by the caller).
    pub seed: u64,
}

impl SloCfg {
    pub fn new(slo_us: f64, seed: u64) -> SloCfg {
        SloCfg {
            slo_us,
            window: 2_000,
            target: 0.99,
            max_replicas: 8,
            action_rate_per_kreq: 2.0,
            action_burst: 2.0,
            seed,
        }
    }
}

/// What the controller asks the engine to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloAction {
    /// Switch the bottleneck service to its next faster candidate config.
    Upgrade,
    /// Add one replica to the bottleneck service.
    AddReplica,
}

/// One window's summary (diagnostics and tests).
#[derive(Clone, Copy, Debug)]
pub struct WindowStats {
    pub p95_us: f64,
    pub p99_us: f64,
    pub compliance: f64,
}

/// Windowed SLO burn tracker + bandit-arbitrated control loop.
pub struct SloController {
    pub cfg: SloCfg,
    win: Digest,
    met: u32,
    bandit: Bandit,
    bucket: TokenBucket,
    completions: u64,
    /// Windows evaluated so far.
    pub windows: u32,
    /// Windows that burned (compliance below target).
    pub violated: u32,
    last_p99: f64,
    /// Bandit slot awaiting its reward (next window's compliance),
    /// plus the context base it was chosen in — [`Self::settle_applied`]
    /// re-points the slot when the engine executes the other lever.
    pending_slot: Option<usize>,
    pending_base: Option<usize>,
    pub last_window: Option<WindowStats>,
}

fn arm_of(act: SloAction) -> usize {
    match act {
        SloAction::Upgrade => 0,
        SloAction::AddReplica => 1,
    }
}

impl SloController {
    pub fn new(cfg: SloCfg) -> SloController {
        let bandit = Bandit::new(0.1, 0.3, cfg.seed);
        let bucket = TokenBucket::new(cfg.action_rate_per_kreq, cfg.action_burst);
        SloController {
            win: Digest::with_capacity(cfg.window as usize),
            met: 0,
            bandit,
            bucket,
            completions: 0,
            windows: 0,
            violated: 0,
            last_p99: 0.0,
            pending_slot: None,
            pending_base: None,
            last_window: None,
            cfg,
        }
    }

    /// Feed one completed request. At window boundaries, evaluates burn
    /// and may return an action; `headroom` tells the bandit whether the
    /// engine still has a faster config or spare replica slot to apply.
    pub fn on_complete(&mut self, latency_us: f64, headroom: bool) -> Option<SloAction> {
        self.completions += 1;
        self.win.add(latency_us);
        if latency_us <= self.cfg.slo_us {
            self.met += 1;
        }
        if self.win.len() < self.cfg.window as usize {
            return None;
        }
        let compliance = self.met as f64 / self.cfg.window as f64;
        let stats = WindowStats {
            p95_us: self.win.percentile(95.0),
            p99_us: self.win.percentile(99.0),
            compliance,
        };
        self.windows += 1;
        let burned = compliance < self.cfg.target;
        if burned {
            self.violated += 1;
        }
        // Settle the previous action's reward with this window's
        // compliance: the arm that restored the SLO gets reinforced.
        if let Some(slot) = self.pending_slot.take() {
            self.bandit.update(slot, compliance.clamp(0.0, 1.0) as f32);
        }
        self.pending_base = None;
        let growing = stats.p99_us > self.last_p99;
        self.last_p99 = stats.p99_us;
        self.last_window = Some(stats);
        self.win.clear();
        self.met = 0;
        if burned && headroom && self.bucket.try_take(self.completions) {
            let severe = compliance < self.cfg.target - 0.05;
            let ctx = Context::from_signals(severe, headroom, growing);
            let (arm, slot) = self.bandit.choose_arm(ctx, 2);
            self.pending_slot = Some(slot);
            self.pending_base = Some(slot - arm);
            return Some(if arm == 0 { SloAction::Upgrade } else { SloAction::AddReplica });
        }
        None
    }

    /// Tell the controller what the engine actually did with the last
    /// proposed action. The engine may fall back to the other lever when
    /// the chosen one is exhausted for the bottleneck service — the next
    /// window's reward must then land on the arm that *executed*, and a
    /// dropped action must not be rewarded at all.
    pub fn settle_applied(&mut self, applied: Option<SloAction>) {
        match (applied, self.pending_base) {
            (Some(act), Some(base)) => self.pending_slot = Some(base + arm_of(act)),
            _ => self.pending_slot = None,
        }
        self.pending_base = None;
    }

    /// Burn rate: fraction of evaluated windows below target compliance.
    pub fn burn_rate(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.violated as f64 / self.windows as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(window: u32) -> SloCfg {
        SloCfg { window, ..SloCfg::new(10.0, 42) }
    }

    #[test]
    fn no_action_before_a_full_window() {
        let mut c = SloController::new(cfg(100));
        for _ in 0..99 {
            assert_eq!(c.on_complete(50.0, true), None);
        }
        assert_eq!(c.windows, 0);
    }

    #[test]
    fn compliant_windows_do_not_act() {
        let mut c = SloController::new(cfg(100));
        for _ in 0..500 {
            assert_eq!(c.on_complete(1.0, true), None, "action on a healthy window");
        }
        assert_eq!(c.windows, 5);
        assert_eq!(c.violated, 0);
        assert_eq!(c.burn_rate(), 0.0);
    }

    #[test]
    fn burned_window_triggers_an_action() {
        let mut c = SloController::new(cfg(100));
        let mut acted = false;
        for _ in 0..100 {
            // Every request misses the 10 µs SLO.
            if c.on_complete(100.0, true).is_some() {
                acted = true;
            }
        }
        assert!(acted, "no action after a fully-burned window");
        assert_eq!(c.violated, 1);
        assert!((c.burn_rate() - 1.0).abs() < 1e-9);
        assert!(c.last_window.unwrap().compliance < 1e-9);
    }

    #[test]
    fn no_headroom_means_no_action() {
        let mut c = SloController::new(cfg(100));
        for _ in 0..300 {
            assert_eq!(c.on_complete(100.0, false), None);
        }
        assert_eq!(c.violated, 3, "burn is still tracked without headroom");
    }

    #[test]
    fn token_bucket_bounds_action_rate() {
        // Burst 2, refill 2/kreq: 10 consecutive burned 100-req windows
        // can fire at most burst + refilled ≈ 2 + 2 actions.
        let mut c = SloController::new(cfg(100));
        let mut actions = 0;
        for _ in 0..1000 {
            if c.on_complete(100.0, true).is_some() {
                actions += 1;
            }
        }
        assert!(actions >= 2, "bucket burst unused: {actions}");
        assert!(actions <= 4, "bucket failed to bound actions: {actions}");
    }

    #[test]
    fn settle_applied_repoints_or_clears_the_reward() {
        // Drive the controller to a proposal, then tell it the engine
        // fell back to the other lever: the pending reward must follow.
        let propose = |c: &mut SloController| -> SloAction {
            loop {
                if let Some(a) = c.on_complete(100.0, true) {
                    return a;
                }
            }
        };
        let mut c = SloController::new(cfg(100));
        let chosen = propose(&mut c);
        let other = match chosen {
            SloAction::Upgrade => SloAction::AddReplica,
            SloAction::AddReplica => SloAction::Upgrade,
        };
        c.settle_applied(Some(other));
        let base = c.pending_base; // cleared by settle
        assert_eq!(base, None);
        let slot = c.pending_slot.expect("reward slot lost");
        assert_eq!(slot % crate::ml::bandit::THRESHOLDS.len(), arm_of(other));

        // A dropped action must not be rewarded at all.
        let mut c = SloController::new(cfg(100));
        propose(&mut c);
        c.settle_applied(None);
        assert_eq!(c.pending_slot, None);
    }

    #[test]
    fn deterministic_for_seed() {
        let run = || {
            let mut c = SloController::new(cfg(50));
            let mut log = Vec::new();
            for i in 0..2000u64 {
                let lat = if i % 3 == 0 { 100.0 } else { 1.0 };
                log.push(c.on_complete(lat, true));
            }
            log
        };
        assert_eq!(run(), run());
    }
}
