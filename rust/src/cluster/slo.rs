//! Windowed SLO tracking and the autoscaler policy suite (paper §XI /
//! §VI): per-window P95/P99/compliance over completed requests, plus a
//! family of controllers that react to SLO burn — or anticipate it —
//! by reconfiguring the cluster.
//!
//! Four policies ([`Policy`]):
//!
//! - **reactive** — the original burn-driven loop: on a burned window, a
//!   contextual bandit ([`crate::ml::bandit::Bandit`], rewarded with the
//!   next window's compliance) chooses between switching the bottleneck
//!   service to a faster prefetcher config and adding a replica.
//! - **hysteresis** — reactive, plus scale *down* on sustained headroom:
//!   after `idle_windows` consecutive windows whose P99 stays under
//!   `headroom × SLO`, one replica is released; the streak then re-arms,
//!   so burst-induced oscillation can never flap replicas up and down.
//! - **predictive** — hysteresis, plus pre-provisioning against the
//!   known traffic shape: the controller forecasts offered load
//!   `lead_us` ahead and adds capacity *before* the diurnal peak
//!   arrives, while windows are still healthy.
//! - **cost-aware** — reactive, but every scale-up must keep the total
//!   prefetcher-metadata footprint under `budget_bytes`: the cheaper
//!   lever wins, an action that would bust the budget is withheld, and
//!   sustained headroom reclaims bytes (downgrade or release).
//!
//! Action frequency for every policy is bounded by the deployment token
//! bucket ([`crate::coordinator::budget::TokenBucket`], reinterpreted
//! over completions instead of cycles).

use super::workload::TrafficShape;
use crate::coordinator::budget::TokenBucket;
use crate::coordinator::tenant::TenantLimiter;
use crate::ml::bandit::{Bandit, Context};
use crate::util::percentile::Digest;
use anyhow::{bail, Result};

/// Autoscaler policy selector (see the module docs for semantics).
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// Burn-driven bandit loop (upgrade | add-replica only).
    Reactive,
    /// Reactive plus scale-down after `idle_windows` consecutive windows
    /// with P99 below `headroom × SLO`.
    Hysteresis { idle_windows: u32, headroom: f64 },
    /// Hysteresis plus shape-forecast pre-provisioning `lead_us` ahead.
    Predictive { lead_us: f64, idle_windows: u32 },
    /// Reactive under a metadata budget, reclaiming on headroom.
    CostAware { budget_bytes: u64, idle_windows: u32 },
}

impl Policy {
    /// Parse a colon-separated policy spec: `reactive`,
    /// `hysteresis[:IDLE_WINDOWS[:HEADROOM]]`,
    /// `predictive[:LEAD_US[:IDLE_WINDOWS]]`,
    /// `cost-aware[:BUDGET_BYTES[:IDLE_WINDOWS]]`.
    ///
    /// Integer fields (`IDLE_WINDOWS`, `BUDGET_BYTES`) must be written as
    /// non-negative integers: `hysteresis:2.7` and `cost-aware:-1:4` are
    /// errors, not silent truncations.
    pub fn parse(spec: &str) -> Result<Policy> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("").to_lowercase();
        let mut nums = Vec::new();
        for p in parts {
            match p.parse::<f64>() {
                Ok(v) if v.is_finite() => nums.push(v),
                _ => bail!("policy '{spec}': '{p}' is not a finite number"),
            }
        }
        let arg = |i: usize, default: f64| nums.get(i).copied().unwrap_or(default);
        // Integer fields reject fractional and negative input instead of
        // coercing through `as` (which truncates 2.7 → 2 and -1 → 0).
        let int = |i: usize, default: u64, field: &str, max: u64| -> Result<u64> {
            let v = nums.get(i).copied().unwrap_or(default as f64);
            if !(v >= 0.0 && v.fract() == 0.0 && v <= max as f64) {
                bail!(
                    "policy '{spec}': {field} must be a non-negative integer \
                     (at most {max}), got {v}"
                );
            }
            Ok(v as u64)
        };
        let (policy, max_args) = match kind.as_str() {
            "reactive" => (Policy::Reactive, 0),
            "hysteresis" => (
                Policy::Hysteresis {
                    idle_windows: int(0, 4, "idle_windows", u32::MAX as u64)? as u32,
                    headroom: arg(1, 0.7),
                },
                2,
            ),
            "predictive" => (
                Policy::Predictive {
                    lead_us: arg(0, 30_000.0),
                    idle_windows: int(1, 4, "idle_windows", u32::MAX as u64)? as u32,
                },
                2,
            ),
            "cost-aware" => (
                Policy::CostAware {
                    budget_bytes: int(0, 524_288, "budget_bytes", u64::MAX)?,
                    idle_windows: int(1, 4, "idle_windows", u32::MAX as u64)? as u32,
                },
                2,
            ),
            other => bail!(
                "unknown policy '{other}' \
                 (try reactive|hysteresis:4:0.7|predictive:30000:4|cost-aware:524288:4)"
            ),
        };
        if nums.len() > max_args {
            bail!("policy '{spec}': {kind} takes at most {max_args} numeric fields");
        }
        match &policy {
            Policy::Hysteresis { idle_windows, headroom } => {
                if *idle_windows == 0 {
                    bail!("policy '{spec}': idle_windows must be ≥ 1");
                }
                if !(0.0 < *headroom && *headroom <= 1.0) {
                    bail!("policy '{spec}': headroom must be in (0, 1], got {headroom}");
                }
            }
            Policy::Predictive { lead_us, idle_windows } => {
                if *lead_us <= 0.0 {
                    bail!("policy '{spec}': lead_us must be > 0");
                }
                if *idle_windows == 0 {
                    bail!("policy '{spec}': idle_windows must be ≥ 1");
                }
            }
            Policy::CostAware { budget_bytes, idle_windows } => {
                if *budget_bytes == 0 {
                    bail!("policy '{spec}': budget_bytes must be > 0");
                }
                if *idle_windows == 0 {
                    bail!("policy '{spec}': idle_windows must be ≥ 1");
                }
            }
            Policy::Reactive => {}
        }
        Ok(policy)
    }

    /// Canonical label used in scenario keys and report rows; round-trips
    /// through [`Policy::parse`].
    pub fn label(&self) -> String {
        match self {
            Policy::Reactive => "reactive".into(),
            Policy::Hysteresis { idle_windows, headroom } => {
                format!("hysteresis:{idle_windows}:{headroom}")
            }
            Policy::Predictive { lead_us, idle_windows } => {
                format!("predictive:{lead_us}:{idle_windows}")
            }
            Policy::CostAware { budget_bytes, idle_windows } => {
                format!("cost-aware:{budget_bytes}:{idle_windows}")
            }
        }
    }
}

/// Control-loop configuration.
#[derive(Clone, Debug)]
pub struct SloCfg {
    /// Latency target (µs).
    pub slo_us: f64,
    /// Completions per evaluation window.
    pub window: u32,
    /// Compliance target: a window with a smaller met-fraction burns.
    pub target: f64,
    /// Per-service replica cap for scale-out actions.
    pub max_replicas: u32,
    /// Control actions per 1000 completions (token-bucket rate).
    pub action_rate_per_kreq: f64,
    /// Token-bucket burst (actions available immediately).
    pub action_burst: f64,
    /// Bandit RNG seed (derived from the scenario seed by the caller).
    pub seed: u64,
    /// Which autoscaler policy drives the loop.
    pub policy: Policy,
    /// Traffic shape the predictive policy forecasts against (`None`
    /// degrades predictive to its reactive/hysteresis parts).
    pub shape: Option<TrafficShape>,
}

impl SloCfg {
    pub fn new(slo_us: f64, seed: u64) -> SloCfg {
        SloCfg {
            slo_us,
            window: 2_000,
            target: 0.99,
            max_replicas: 8,
            action_rate_per_kreq: 2.0,
            action_burst: 2.0,
            seed,
            policy: Policy::Reactive,
            shape: None,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> SloCfg {
        self.policy = policy;
        self
    }

    pub fn with_shape(mut self, shape: TrafficShape) -> SloCfg {
        self.shape = Some(shape);
        self
    }
}

/// What the controller asks the engine to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloAction {
    /// Switch the bottleneck service to its next faster candidate config.
    Upgrade,
    /// Add one replica to the bottleneck service.
    AddReplica,
    /// Release one replica from the most-overprovisioned service.
    RemoveReplica,
    /// Switch a non-bottleneck service to its next slower (cheaper)
    /// config, reclaiming metadata bytes.
    Downgrade,
}

/// Engine-side facts the policy decides against, snapshotted at the
/// completion that closes a window. Deltas are *additional* bytes an
/// action would cost (0 when it would shrink the footprint).
#[derive(Clone, Copy, Debug)]
pub struct EngineView {
    /// Simulated time of the completion (µs).
    pub now_us: f64,
    /// The bottleneck service has a faster candidate left.
    pub can_upgrade: bool,
    /// The bottleneck service is below the replica cap.
    pub can_scale_up: bool,
    /// Some service can release a replica (≥ 2 active).
    pub can_scale_down: bool,
    /// Some non-bottleneck service can move to a cheaper config.
    pub can_downgrade: bool,
    /// Current prefetcher-metadata footprint across all replicas.
    pub metadata_bytes: u64,
    /// Extra bytes if the bottleneck upgrades (all its replicas).
    pub upgrade_meta_delta: u64,
    /// Extra bytes if the bottleneck adds a replica.
    pub scale_up_meta_delta: u64,
    /// Replicas currently crashed (fault injection): capacity the
    /// cluster believes it has but does not. Non-zero suppresses every
    /// voluntary scale-down/reclaim lever.
    pub failed_replicas: u32,
    /// Replicas currently running degraded (gray failure / brownout
    /// dilation > 1): nominal capacity delivering less than it claims.
    pub degraded_replicas: u32,
}

impl EngineView {
    /// A view advertising no levers — static scenarios track burn
    /// through the controller but can never act.
    pub fn frozen(now_us: f64) -> EngineView {
        EngineView {
            now_us,
            can_upgrade: false,
            can_scale_up: false,
            can_scale_down: false,
            can_downgrade: false,
            metadata_bytes: 0,
            upgrade_meta_delta: 0,
            scale_up_meta_delta: 0,
            failed_replicas: 0,
            degraded_replicas: 0,
        }
    }
}

/// One window's summary (diagnostics and tests).
#[derive(Clone, Copy, Debug)]
pub struct WindowStats {
    pub p95_us: f64,
    pub p99_us: f64,
    pub compliance: f64,
}

/// Windowed SLO burn tracker + policy-driven control loop.
pub struct SloController {
    pub cfg: SloCfg,
    win: Digest,
    met: u32,
    bandit: Bandit,
    bucket: TokenBucket,
    completions: u64,
    /// Windows evaluated so far.
    pub windows: u32,
    /// Windows that burned (compliance below target).
    pub violated: u32,
    last_p99: f64,
    /// Consecutive healthy windows with deep P99 headroom (scale-down
    /// hysteresis state).
    healthy_streak: u32,
    /// Highest offered-load utilization the predictive policy has
    /// provisioned for so far.
    provisioned_util: Option<f64>,
    /// Bandit slot awaiting its reward (next window's compliance),
    /// plus the context base it was chosen in — [`Self::settle_applied`]
    /// re-points the slot when the engine executes the other lever.
    pending_slot: Option<usize>,
    pending_base: Option<usize>,
    pub last_window: Option<WindowStats>,
}

fn arm_of(act: SloAction) -> usize {
    match act {
        SloAction::Upgrade => 0,
        SloAction::AddReplica => 1,
        // Scale-downs are deterministic policy rules, never bandit arms.
        SloAction::RemoveReplica | SloAction::Downgrade => {
            unreachable!("bandit arms cover only scale-up levers")
        }
    }
}

impl SloController {
    pub fn new(mut cfg: SloCfg) -> SloController {
        // Window-evaluation audit (see `Digest::percentile`'s NaN
        // contract): every evaluation happens inside `on_complete`,
        // *after* the completion was added, so the window digest is
        // never empty when percentiles are read — provided the window
        // length is at least 1. Clamp `window = 0` (which would also
        // divide compliance by zero and make every window read as
        // non-burning) instead of trusting callers.
        cfg.window = cfg.window.max(1);
        let bandit = Bandit::new(0.1, 0.3, cfg.seed);
        let bucket = TokenBucket::new(cfg.action_rate_per_kreq, cfg.action_burst);
        SloController {
            win: Digest::with_capacity(cfg.window as usize),
            met: 0,
            bandit,
            bucket,
            completions: 0,
            windows: 0,
            violated: 0,
            last_p99: 0.0,
            healthy_streak: 0,
            provisioned_util: None,
            pending_slot: None,
            pending_base: None,
            last_window: None,
            cfg,
        }
    }

    /// Feed one completed request. At window boundaries, evaluates burn
    /// and may return an action; `view` carries the engine-side facts
    /// (available levers, metadata footprint, simulated time) the
    /// policy decides against.
    pub fn on_complete(&mut self, latency_us: f64, view: &EngineView) -> Option<SloAction> {
        self.completions += 1;
        self.win.add(latency_us);
        if latency_us <= self.cfg.slo_us {
            self.met += 1;
        }
        if self.win.len() < self.cfg.window as usize {
            return None;
        }
        let compliance = self.met as f64 / self.cfg.window as f64;
        let stats = WindowStats {
            p95_us: self.win.percentile(95.0),
            p99_us: self.win.percentile(99.0),
            compliance,
        };
        self.windows += 1;
        let burned = compliance < self.cfg.target;
        if burned {
            self.violated += 1;
            self.healthy_streak = 0;
        } else {
            self.healthy_streak += 1;
        }
        // Settle the previous action's reward with this window's
        // compliance: the arm that restored the SLO gets reinforced.
        if let Some(slot) = self.pending_slot.take() {
            self.bandit.update(slot, compliance.clamp(0.0, 1.0) as f32);
        }
        self.pending_base = None;
        let growing = stats.p99_us > self.last_p99;
        self.last_p99 = stats.p99_us;
        self.last_window = Some(stats);
        self.win.clear();
        self.met = 0;
        self.decide(burned, growing, compliance, &stats, view)
    }

    /// Policy dispatch at a window boundary.
    fn decide(
        &mut self,
        burned: bool,
        growing: bool,
        compliance: f64,
        stats: &WindowStats,
        view: &EngineView,
    ) -> Option<SloAction> {
        match self.cfg.policy.clone() {
            Policy::Reactive => {
                if burned {
                    self.reactive_action(compliance, growing, view, None)
                } else {
                    None
                }
            }
            Policy::Hysteresis { idle_windows, headroom } => {
                if burned {
                    self.reactive_action(compliance, growing, view, None)
                } else {
                    self.try_scale_down(idle_windows, headroom, stats, view)
                }
            }
            Policy::Predictive { lead_us, idle_windows } => {
                if burned {
                    return self.reactive_action(compliance, growing, view, None);
                }
                let shape = match self.cfg.shape.clone() {
                    Some(s) => s,
                    // Nothing to forecast against: degrade to the
                    // hysteresis parts (reactive scale-up + streak-gated
                    // scale-down), as the `SloCfg::shape` docs promise.
                    None => return self.try_scale_down(idle_windows, 0.7, stats, view),
                };
                let now_util = shape.util_at(view.now_us);
                let ahead = shape.util_at(view.now_us + lead_us);
                let provisioned = *self.provisioned_util.get_or_insert(now_util);
                // Rising edge: add capacity before the forecast load
                // exceeds what we've provisioned for.
                if ahead > provisioned * 1.05 && view.can_scale_up {
                    if self.bucket.try_take(self.completions) {
                        self.provisioned_util = Some(ahead);
                        return Some(SloAction::AddReplica);
                    }
                    return None;
                }
                // Falling edge: release through the hysteresis path and
                // remember the lower watermark.
                if ahead < provisioned * 0.8 {
                    let act = self.try_scale_down(idle_windows, 0.9, stats, view);
                    if act.is_some() {
                        self.provisioned_util = Some(ahead);
                    }
                    return act;
                }
                None
            }
            Policy::CostAware { budget_bytes, idle_windows } => {
                if burned {
                    self.reactive_action(compliance, growing, view, Some(budget_bytes))
                } else if view.metadata_bytes > budget_bytes {
                    // Over budget on a healthy window: reclaim bytes.
                    // Levers are checked before the bucket so a cluster
                    // with nothing to reclaim doesn't bleed tokens it
                    // will need when a window eventually burns.
                    if view.failed_replicas > 0 {
                        // Crashed capacity: the healthy window is being
                        // carried by fewer replicas than the footprint
                        // suggests — hold the reclaim until they return.
                        return None;
                    }
                    if !(view.can_downgrade || view.can_scale_down) {
                        return None;
                    }
                    if !self.bucket.try_take(self.completions) {
                        return None;
                    }
                    if view.can_downgrade {
                        Some(SloAction::Downgrade)
                    } else {
                        Some(SloAction::RemoveReplica)
                    }
                } else {
                    self.try_scale_down(idle_windows, 0.7, stats, view)
                }
            }
        }
    }

    /// Burned window: bandit-arbitrated scale-up, optionally constrained
    /// by a metadata budget (a lever that would bust it is off the
    /// table; if both would, the action is withheld entirely).
    fn reactive_action(
        &mut self,
        compliance: f64,
        growing: bool,
        view: &EngineView,
        budget: Option<u64>,
    ) -> Option<SloAction> {
        let mut can_up = view.can_upgrade;
        let mut can_scale = view.can_scale_up;
        if let Some(b) = budget {
            // A lever is admissible when it fits the budget — or adds no
            // bytes at all, so an already-over-budget cluster can still
            // take footprint-neutral (or shrinking) actions against burn.
            let fits =
                |delta: u64| delta == 0 || view.metadata_bytes.saturating_add(delta) <= b;
            can_up = can_up && fits(view.upgrade_meta_delta);
            can_scale = can_scale && fits(view.scale_up_meta_delta);
        }
        if !(can_up || can_scale) {
            return None;
        }
        if !self.bucket.try_take(self.completions) {
            return None;
        }
        let severe = compliance < self.cfg.target - 0.05;
        let ctx = Context::from_signals(severe, can_up || can_scale, growing);
        let (arm, slot) = self.bandit.choose_arm(ctx, 2);
        self.pending_slot = Some(slot);
        self.pending_base = Some(slot - arm);
        let act = if arm == 0 { SloAction::Upgrade } else { SloAction::AddReplica };
        // The bandit may pick a lever the budget forbids — steer to the
        // other; settle_applied re-points the reward to the executed arm.
        Some(match act {
            SloAction::Upgrade if !can_up => SloAction::AddReplica,
            SloAction::AddReplica if !can_scale => SloAction::Upgrade,
            a => a,
        })
    }

    /// Sustained-headroom scale-down with hysteresis: requires
    /// `idle_windows` consecutive windows whose P99 stays under
    /// `headroom × SLO`, then re-arms the streak so each release is
    /// separated by a full re-earned streak (no flapping). Suppressed —
    /// and the streak disarmed — while any replica is crashed or
    /// degraded: apparent headroom during a fault window says nothing
    /// about the healthy-capacity requirement.
    fn try_scale_down(
        &mut self,
        idle_windows: u32,
        headroom: f64,
        stats: &WindowStats,
        view: &EngineView,
    ) -> Option<SloAction> {
        if view.failed_replicas > 0 || view.degraded_replicas > 0 {
            self.healthy_streak = 0;
            return None;
        }
        if stats.p99_us > self.cfg.slo_us * headroom {
            // Healthy but not comfortably so: no scale-down credit.
            self.healthy_streak = 0;
            return None;
        }
        if self.healthy_streak < idle_windows || !view.can_scale_down {
            return None;
        }
        if !self.bucket.try_take(self.completions) {
            return None;
        }
        self.healthy_streak = 0;
        Some(SloAction::RemoveReplica)
    }

    /// Tell the controller what the engine actually did with the last
    /// proposed action. The engine may fall back to the other lever when
    /// the chosen one is exhausted for the bottleneck service — the next
    /// window's reward must then land on the arm that *executed*, and a
    /// dropped action must not be rewarded at all. Scale-downs carry no
    /// bandit reward.
    pub fn settle_applied(&mut self, applied: Option<SloAction>) {
        match (applied, self.pending_base) {
            (Some(act @ (SloAction::Upgrade | SloAction::AddReplica)), Some(base)) => {
                self.pending_slot = Some(base + arm_of(act));
            }
            _ => self.pending_slot = None,
        }
        self.pending_base = None;
    }

    /// Burn rate: fraction of evaluated windows below target compliance.
    pub fn burn_rate(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.violated as f64 / self.windows as f64
        }
    }

    /// Current action-budget token level (read-only; for observability
    /// snapshots).
    pub fn bucket_level(&self) -> f64 {
        self.bucket.level()
    }
}

// ---------- Multi-tenant burn tracking and lever arbitration ----------

/// Configuration of the multi-tenant control loop (DESIGN.md §10).
#[derive(Clone, Debug)]
pub struct TenantCtrlCfg {
    /// Completions per per-tenant evaluation window.
    pub window: u32,
    /// Compliance target: a tenant window below it burns.
    pub target: f64,
    /// Per-service replica cap for the add-replica lever.
    pub max_replicas: u32,
    /// Shared action budget: actions per 1000 completions *across all
    /// tenants* (one token bucket, so tenants contend for levers).
    pub action_rate_per_kreq: f64,
    pub action_burst: f64,
    /// Per-tenant action rate (actions per 1000 of *that tenant's*
    /// completions), enforced through the coordinator's
    /// [`TenantLimiter`] — one starving tenant cannot monopolize the
    /// shared budget.
    pub tenant_rate_per_kreq: f64,
}

impl Default for TenantCtrlCfg {
    fn default() -> Self {
        TenantCtrlCfg {
            window: 2_000,
            target: 0.99,
            max_replicas: 8,
            action_rate_per_kreq: 2.0,
            action_burst: 2.0,
            tenant_rate_per_kreq: 1.0,
        }
    }
}

/// What the multi-tenant loop asks the engine to do for a burning
/// tenant, in deterministic preference order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantAction {
    /// Move one L1-I way from the most-slack co-tenant to the burning
    /// tenant (the new lever: free — no capacity or metadata cost).
    Repartition,
    /// Switch the tenant's bottleneck service to its next faster config.
    Upgrade,
    /// Add one replica to the tenant's bottleneck service.
    AddReplica,
}

/// Engine-side lever availability for one tenant, snapshotted at the
/// completion that closes its window.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantView {
    /// The tenant is way-starved (demand > share) and a donor exists.
    pub can_repartition: bool,
    /// The tenant's bottleneck service has a faster candidate left.
    pub can_upgrade: bool,
    /// The tenant's bottleneck service is below the replica cap.
    pub can_scale_up: bool,
}

/// Per-tenant windowed SLO burn tracker plus lever arbitration: each
/// tenant's completions close their own windows; a burned window
/// proposes the first available lever (repartition → upgrade → add
/// replica — deterministic, no bandit, no RNG), admitted by the shared
/// action bucket *and* the tenant's own rate limiter.
pub struct TenantController {
    pub cfg: TenantCtrlCfg,
    adaptive: bool,
    slos: Vec<f64>,
    /// Completions in the current window, per tenant (compliance comes
    /// from `met` — no latency distribution is retained).
    counts: Vec<u32>,
    met: Vec<u32>,
    /// Windows evaluated / burned, per tenant.
    pub windows: Vec<u32>,
    pub violated: Vec<u32>,
    /// Shared budget over total completions (all tenants).
    bucket: TokenBucket,
    /// Per-tenant limiter over that tenant's completions
    /// (`coordinator/tenant.rs`, live at last).
    limiter: TenantLimiter,
    completions: u64,
    per_tenant: Vec<u64>,
}

impl TenantController {
    /// `slos[i]` is tenant i's latency target (µs). `adaptive = false`
    /// tracks burn but never proposes an action (static co-location).
    pub fn new(mut cfg: TenantCtrlCfg, slos: Vec<f64>, adaptive: bool) -> TenantController {
        // Same clamp as SloController: an empty window must never close.
        cfg.window = cfg.window.max(1);
        let n = slos.len();
        let bucket = TokenBucket::new(cfg.action_rate_per_kreq, cfg.action_burst);
        let limiter = TenantLimiter::new(cfg.tenant_rate_per_kreq);
        TenantController {
            counts: vec![0; n],
            met: vec![0; n],
            windows: vec![0; n],
            violated: vec![0; n],
            bucket,
            limiter,
            completions: 0,
            per_tenant: vec![0; n],
            slos,
            adaptive,
            cfg,
        }
    }

    /// Whether the next completion of `tenant` will close its window —
    /// the only moment [`Self::on_complete`] consults the lever view,
    /// so the engine can skip building one everywhere else.
    pub fn window_closing(&self, tenant: usize) -> bool {
        self.counts[tenant] + 1 >= self.cfg.window
    }

    /// Feed one completed request of `tenant`. At that tenant's window
    /// boundary, evaluates burn and may return a lever to pull.
    pub fn on_complete(
        &mut self,
        tenant: usize,
        latency_us: f64,
        view: &TenantView,
    ) -> Option<TenantAction> {
        self.completions += 1;
        self.per_tenant[tenant] += 1;
        self.counts[tenant] += 1;
        if latency_us <= self.slos[tenant] {
            self.met[tenant] += 1;
        }
        if self.counts[tenant] < self.cfg.window {
            return None;
        }
        let compliance = self.met[tenant] as f64 / self.cfg.window as f64;
        self.windows[tenant] += 1;
        let burned = compliance < self.cfg.target;
        if burned {
            self.violated[tenant] += 1;
        }
        self.counts[tenant] = 0;
        self.met[tenant] = 0;
        if !(self.adaptive && burned) {
            return None;
        }
        // Deterministic preference: the free lever first (way
        // repartition costs no capacity and no metadata), then the
        // scale-up levers.
        let act = if view.can_repartition {
            TenantAction::Repartition
        } else if view.can_upgrade {
            TenantAction::Upgrade
        } else if view.can_scale_up {
            TenantAction::AddReplica
        } else {
            return None;
        };
        // Shared budget first, then the tenant's own limiter: a tenant
        // whose limiter denies still debits the shared bucket (its burn
        // *did* contend for the budget), which keeps arbitration
        // conservative under pressure — and deterministic.
        if !self.bucket.try_take(self.completions) {
            return None;
        }
        if !self.limiter.allow(tenant as u8, self.per_tenant[tenant]) {
            return None;
        }
        Some(act)
    }

    /// Fraction of tenant `i`'s evaluated windows that burned.
    pub fn burn_rate(&self, tenant: usize) -> f64 {
        if self.windows[tenant] == 0 {
            0.0
        } else {
            self.violated[tenant] as f64 / self.windows[tenant] as f64
        }
    }

    /// Current shared action-budget token level (read-only; for
    /// observability snapshots).
    pub fn bucket_level(&self) -> f64 {
        self.bucket.level()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(window: u32) -> SloCfg {
        SloCfg { window, ..SloCfg::new(10.0, 42) }
    }

    /// A view with both scale-up levers (mirrors the old `headroom`
    /// boolean) and no cost pressure.
    fn up(headroom: bool) -> EngineView {
        EngineView {
            now_us: 0.0,
            can_upgrade: headroom,
            can_scale_up: headroom,
            can_scale_down: false,
            can_downgrade: false,
            metadata_bytes: 0,
            upgrade_meta_delta: 0,
            scale_up_meta_delta: 0,
            failed_replicas: 0,
            degraded_replicas: 0,
        }
    }

    #[test]
    fn zero_window_is_clamped_never_divides_by_zero() {
        // Regression companion to the Digest NaN change: window = 0 used
        // to evaluate compliance as met/0 (∞ or NaN), so no window could
        // ever burn — an empty window silently counted as compliant.
        let mut c = SloController::new(cfg(0));
        assert_eq!(c.cfg.window, 1, "window not clamped");
        for _ in 0..5 {
            c.on_complete(100.0, &up(false)); // every request misses SLO
        }
        assert_eq!(c.windows, 5);
        assert_eq!(c.violated, 5, "burned single-completion windows not counted");
        let w = c.last_window.unwrap();
        assert!(w.compliance == 0.0 && w.p99_us == 100.0);
    }

    #[test]
    fn no_action_before_a_full_window() {
        let mut c = SloController::new(cfg(100));
        for _ in 0..99 {
            assert_eq!(c.on_complete(50.0, &up(true)), None);
        }
        assert_eq!(c.windows, 0);
    }

    #[test]
    fn compliant_windows_do_not_act() {
        let mut c = SloController::new(cfg(100));
        for _ in 0..500 {
            assert_eq!(c.on_complete(1.0, &up(true)), None, "action on a healthy window");
        }
        assert_eq!(c.windows, 5);
        assert_eq!(c.violated, 0);
        assert_eq!(c.burn_rate(), 0.0);
    }

    #[test]
    fn burned_window_triggers_an_action() {
        let mut c = SloController::new(cfg(100));
        let mut acted = false;
        for _ in 0..100 {
            // Every request misses the 10 µs SLO.
            if c.on_complete(100.0, &up(true)).is_some() {
                acted = true;
            }
        }
        assert!(acted, "no action after a fully-burned window");
        assert_eq!(c.violated, 1);
        assert!((c.burn_rate() - 1.0).abs() < 1e-9);
        assert!(c.last_window.unwrap().compliance < 1e-9);
    }

    #[test]
    fn no_headroom_means_no_action() {
        let mut c = SloController::new(cfg(100));
        for _ in 0..300 {
            assert_eq!(c.on_complete(100.0, &up(false)), None);
        }
        assert_eq!(c.violated, 3, "burn is still tracked without headroom");
    }

    #[test]
    fn token_bucket_bounds_action_rate() {
        // Burst 2, refill 2/kreq: 10 consecutive burned 100-req windows
        // can fire at most burst + refilled ≈ 2 + 2 actions.
        let mut c = SloController::new(cfg(100));
        let mut actions = 0;
        for _ in 0..1000 {
            if c.on_complete(100.0, &up(true)).is_some() {
                actions += 1;
            }
        }
        assert!(actions >= 2, "bucket burst unused: {actions}");
        assert!(actions <= 4, "bucket failed to bound actions: {actions}");
    }

    #[test]
    fn settle_applied_repoints_or_clears_the_reward() {
        // Drive the controller to a proposal, then tell it the engine
        // fell back to the other lever: the pending reward must follow.
        let propose = |c: &mut SloController| -> SloAction {
            loop {
                if let Some(a) = c.on_complete(100.0, &up(true)) {
                    return a;
                }
            }
        };
        let mut c = SloController::new(cfg(100));
        let chosen = propose(&mut c);
        let other = match chosen {
            SloAction::Upgrade => SloAction::AddReplica,
            _ => SloAction::Upgrade,
        };
        c.settle_applied(Some(other));
        let base = c.pending_base; // cleared by settle
        assert_eq!(base, None);
        let slot = c.pending_slot.expect("reward slot lost");
        assert_eq!(slot % crate::ml::bandit::THRESHOLDS.len(), arm_of(other));

        // A dropped action must not be rewarded at all.
        let mut c = SloController::new(cfg(100));
        propose(&mut c);
        c.settle_applied(None);
        assert_eq!(c.pending_slot, None);

        // A scale-down execution must not claim the bandit reward either.
        let mut c = SloController::new(cfg(100));
        propose(&mut c);
        c.settle_applied(Some(SloAction::RemoveReplica));
        assert_eq!(c.pending_slot, None);
    }

    #[test]
    fn deterministic_for_seed() {
        let run = || {
            let mut c = SloController::new(cfg(50));
            let mut log = Vec::new();
            for i in 0..2000u64 {
                let lat = if i % 3 == 0 { 100.0 } else { 1.0 };
                log.push(c.on_complete(lat, &up(true)));
            }
            log
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn policy_specs_parse_and_roundtrip() {
        assert_eq!(Policy::parse("reactive").unwrap(), Policy::Reactive);
        assert_eq!(
            Policy::parse("hysteresis").unwrap(),
            Policy::Hysteresis { idle_windows: 4, headroom: 0.7 }
        );
        assert_eq!(
            Policy::parse("hysteresis:6:0.5").unwrap(),
            Policy::Hysteresis { idle_windows: 6, headroom: 0.5 }
        );
        assert_eq!(
            Policy::parse("predictive:20000").unwrap(),
            Policy::Predictive { lead_us: 20_000.0, idle_windows: 4 }
        );
        assert_eq!(
            Policy::parse("cost-aware:262144:3").unwrap(),
            Policy::CostAware { budget_bytes: 262_144, idle_windows: 3 }
        );
        // Case-insensitive like prefetcher/traffic specs.
        assert_eq!(Policy::parse("REACTIVE").unwrap(), Policy::Reactive);
        for spec in ["reactive", "hysteresis:6:0.5", "predictive:20000:4", "cost-aware:262144:3"] {
            let p = Policy::parse(spec).unwrap();
            assert_eq!(Policy::parse(&p.label()).unwrap(), p, "label roundtrip for {spec}");
        }
    }

    #[test]
    fn bad_policy_specs_are_rejected() {
        assert!(Policy::parse("chaos-monkey").is_err());
        assert!(Policy::parse("reactive:1").is_err(), "surplus fields must error");
        assert!(Policy::parse("hysteresis:0").is_err(), "idle_windows 0");
        assert!(Policy::parse("hysteresis:4:1.5").is_err(), "headroom > 1");
        assert!(Policy::parse("predictive:-5").is_err());
        assert!(Policy::parse("cost-aware:0").is_err());
        assert!(Policy::parse("cost-aware:abc").is_err());
    }

    #[test]
    fn integer_policy_fields_reject_fractional_and_negative_input() {
        // These used to coerce through `as u32`/`as u64`: 2.7 → 2 and
        // -1 → 0, silently running a different policy than specified.
        assert!(Policy::parse("hysteresis:2.7").is_err(), "fractional idle_windows");
        assert!(Policy::parse("hysteresis:-1").is_err(), "negative idle_windows");
        assert!(Policy::parse("predictive:30000:2.5").is_err(), "fractional idle_windows");
        assert!(Policy::parse("predictive:30000:-4").is_err(), "negative idle_windows");
        assert!(Policy::parse("cost-aware:-1:4").is_err(), "negative budget_bytes");
        assert!(Policy::parse("cost-aware:0.5").is_err(), "fractional budget_bytes");
        assert!(Policy::parse("cost-aware:1024:4.5").is_err(), "fractional idle_windows");
        // Fractional input remains fine for genuinely real-valued fields.
        assert!(Policy::parse("hysteresis:4:0.55").is_ok());
        assert!(Policy::parse("predictive:12500.5:4").is_ok());
    }

    #[test]
    fn faulted_views_suppress_scale_down_and_reclaim() {
        // Hysteresis with deep sustained headroom would normally release
        // a replica — but not while the view reports crashed or degraded
        // capacity, and the streak must re-arm from zero afterwards.
        let mk = || {
            SloController::new(SloCfg {
                window: 100,
                policy: Policy::Hysteresis { idle_windows: 2, headroom: 0.7 },
                ..SloCfg::new(100.0, 9)
            })
        };
        for faulted in [
            EngineView { failed_replicas: 1, can_scale_down: true, ..up(true) },
            EngineView { degraded_replicas: 2, can_scale_down: true, ..up(true) },
        ] {
            let mut c = mk();
            for _ in 0..800 {
                assert_eq!(c.on_complete(5.0, &faulted), None, "scaled down mid-fault");
            }
            // Fault clears: the streak starts over, so the release needs
            // a full re-earned idle_windows run, not one healthy window.
            let healthy = EngineView { can_scale_down: true, ..up(true) };
            let mut first_down = None;
            for w in 0..6 {
                for _ in 0..100 {
                    if let Some(SloAction::RemoveReplica) = c.on_complete(5.0, &healthy) {
                        first_down.get_or_insert(w);
                    }
                }
            }
            let w = first_down.expect("never scaled down after the fault cleared");
            assert!(w >= 1, "streak was not disarmed by the faulted window");
        }
        // Cost-aware over-budget reclaim holds while replicas are down.
        let mut c = SloController::new(SloCfg {
            window: 100,
            policy: Policy::CostAware { budget_bytes: 1_000, idle_windows: 4 },
            ..SloCfg::new(100.0, 7)
        });
        let v = EngineView {
            metadata_bytes: 1_500,
            can_downgrade: true,
            can_scale_down: true,
            failed_replicas: 1,
            ..up(true)
        };
        for _ in 0..500 {
            assert_eq!(c.on_complete(1.0, &v), None, "reclaimed bytes mid-crash");
        }
    }

    #[test]
    fn hysteresis_scales_down_after_sustained_headroom() {
        let cfg = SloCfg {
            window: 100,
            policy: Policy::Hysteresis { idle_windows: 4, headroom: 0.7 },
            ..SloCfg::new(100.0, 9)
        };
        let mut c = SloController::new(cfg);
        let v = EngineView { can_scale_down: true, ..up(true) };
        // Deeply healthy windows (P99 = 5 µs ≪ 70 µs headroom line).
        let mut downs_at = Vec::new();
        for w in 0..12 {
            for _ in 0..100 {
                if let Some(SloAction::RemoveReplica) = c.on_complete(5.0, &v) {
                    downs_at.push(w);
                }
            }
        }
        assert!(!downs_at.is_empty(), "sustained headroom never scaled down");
        assert!(downs_at[0] >= 3, "scaled down before the hysteresis streak: {downs_at:?}");
        if downs_at.len() >= 2 {
            assert!(
                downs_at[1] - downs_at[0] >= 4,
                "releases not separated by a re-earned streak: {downs_at:?}"
            );
        }
        assert_eq!(c.violated, 0);
    }

    #[test]
    fn hysteresis_never_flaps_under_burst_traffic() {
        // Alternating burned/healthy windows (a burst every other
        // window): the healthy streak never reaches idle_windows, so the
        // policy must not scale down — and therefore cannot flap.
        let cfg = SloCfg {
            window: 100,
            policy: Policy::Hysteresis { idle_windows: 4, headroom: 0.7 },
            ..SloCfg::new(100.0, 5)
        };
        let mut c = SloController::new(cfg);
        let v = EngineView { can_scale_down: true, ..up(true) };
        let (mut downs, mut ups) = (0, 0);
        for w in 0..400 {
            let lat = if w % 2 == 0 { 500.0 } else { 10.0 };
            for _ in 0..100 {
                match c.on_complete(lat, &v) {
                    Some(SloAction::RemoveReplica) => downs += 1,
                    Some(SloAction::Upgrade) | Some(SloAction::AddReplica) => ups += 1,
                    _ => {}
                }
            }
        }
        assert!(ups > 0, "burned windows never drew a scale-up");
        assert_eq!(downs, 0, "hysteresis flapped: {downs} scale-downs under bursts");
    }

    #[test]
    fn predictive_preprovisions_before_the_diurnal_peak() {
        // Peak offered load at t = 25 000 µs; every window is healthy, so
        // a purely reactive policy would never act. The predictive policy
        // must add capacity before the peak arrives.
        let shape = TrafficShape::Diurnal { util: 0.6, amplitude: 0.5, period_us: 100_000.0 };
        let cfg = SloCfg {
            window: 100,
            policy: Policy::Predictive { lead_us: 20_000.0, idle_windows: 4 },
            shape: Some(shape),
            ..SloCfg::new(100.0, 11)
        };
        let mut c = SloController::new(cfg);
        let mut first_add: Option<f64> = None;
        let mut t = 0.0;
        for _ in 0..3_000 {
            t += 5.0;
            let v = EngineView { now_us: t, can_scale_down: true, ..up(true) };
            if let Some(SloAction::AddReplica) = c.on_complete(10.0, &v) {
                first_add.get_or_insert(t);
            }
        }
        let t_add = first_add.expect("predictive policy never pre-provisioned");
        assert!(t_add < 25_000.0, "pre-provision at {t_add} µs is after the peak");
        assert_eq!(c.violated, 0, "windows were healthy by construction");
    }

    #[test]
    fn tenant_controller_tracks_burn_per_tenant() {
        let cfg = TenantCtrlCfg { window: 100, ..TenantCtrlCfg::default() };
        // Tenant 0 misses its 10 µs SLO, tenant 1 meets its 100 µs one.
        let mut c = TenantController::new(cfg, vec![10.0, 100.0], false);
        let v = TenantView::default();
        for _ in 0..300 {
            assert_eq!(c.on_complete(0, 50.0, &v), None, "static run must not act");
            assert_eq!(c.on_complete(1, 50.0, &v), None);
        }
        assert_eq!(c.windows, vec![3, 3]);
        assert_eq!(c.violated, vec![3, 0], "burn leaked across tenants");
        assert_eq!(c.burn_rate(0), 1.0);
        assert_eq!(c.burn_rate(1), 0.0);
    }

    #[test]
    fn tenant_controller_prefers_the_free_lever_in_order() {
        let mk = |view: TenantView| {
            let cfg = TenantCtrlCfg { window: 50, ..TenantCtrlCfg::default() };
            let mut c = TenantController::new(cfg, vec![10.0, 10.0], true);
            let mut first = None;
            for _ in 0..50 {
                if let Some(a) = c.on_complete(0, 99.0, &view) {
                    first.get_or_insert(a);
                }
            }
            first
        };
        let all = TenantView { can_repartition: true, can_upgrade: true, can_scale_up: true };
        assert_eq!(mk(all), Some(TenantAction::Repartition));
        let no_ways = TenantView { can_repartition: false, ..all };
        assert_eq!(mk(no_ways), Some(TenantAction::Upgrade));
        let only_scale =
            TenantView { can_repartition: false, can_upgrade: false, can_scale_up: true };
        assert_eq!(mk(only_scale), Some(TenantAction::AddReplica));
        let none = TenantView::default();
        assert_eq!(mk(none), None, "no lever available must propose nothing");
    }

    #[test]
    fn tenant_controller_is_bounded_by_shared_and_per_tenant_budgets() {
        // Shared bucket: burst 2, 2/kreq. Per-tenant limiter: 1/kreq
        // (burst 4). 20 consecutive burned windows of tenant 0 must be
        // clipped by both meters.
        let cfg = TenantCtrlCfg { window: 100, ..TenantCtrlCfg::default() };
        let mut c = TenantController::new(cfg, vec![10.0], true);
        let v = TenantView { can_repartition: true, can_upgrade: true, can_scale_up: true };
        let mut actions = 0;
        for _ in 0..2_000 {
            if c.on_complete(0, 99.0, &v).is_some() {
                actions += 1;
            }
        }
        assert!(actions >= 2, "budget burst unused: {actions}");
        assert!(actions <= 6, "budgets failed to bound actions: {actions}");
        assert_eq!(c.violated, vec![20]);
    }

    #[test]
    fn cost_aware_respects_the_metadata_budget_cap() {
        let mk = || {
            SloController::new(SloCfg {
                window: 100,
                policy: Policy::CostAware { budget_bytes: 1_000, idle_windows: 4 },
                ..SloCfg::new(10.0, 7)
            })
        };
        // Upgrading fits the budget, adding a replica would bust it: the
        // policy must always steer to the fitting lever.
        let mut c = mk();
        let v = EngineView {
            metadata_bytes: 600,
            upgrade_meta_delta: 300,
            scale_up_meta_delta: 600,
            ..up(true)
        };
        let mut acts = Vec::new();
        for _ in 0..2_000 {
            if let Some(a) = c.on_complete(100.0, &v) {
                acts.push(a);
            }
        }
        assert!(!acts.is_empty(), "budget-fitting lever never used");
        assert!(
            acts.iter().all(|a| *a == SloAction::Upgrade),
            "chose a lever that busts the budget: {acts:?}"
        );
        // Both levers over budget: the policy must hold back entirely.
        let mut c = mk();
        let v = EngineView {
            metadata_bytes: 900,
            upgrade_meta_delta: 200,
            scale_up_meta_delta: 600,
            ..up(true)
        };
        for _ in 0..2_000 {
            assert_eq!(c.on_complete(100.0, &v), None, "acted over budget");
        }
        // Over budget on healthy windows: reclaims via downgrade.
        let mut c = mk();
        let v = EngineView {
            metadata_bytes: 1_500,
            can_downgrade: true,
            can_scale_down: true,
            ..up(true)
        };
        let mut reclaimed = false;
        for _ in 0..500 {
            if c.on_complete(1.0, &v) == Some(SloAction::Downgrade) {
                reclaimed = true;
            }
        }
        assert!(reclaimed, "over-budget footprint never reclaimed");
    }
}
