//! Deterministic discrete-event cluster simulator: a pluggable-scheduler
//! event loop over request DAGs with replicated services — FCFS per
//! replica, least-outstanding-requests load balancing, open-loop
//! arrivals from [`super::workload`], and an optional SLO control loop
//! ([`super::slo`]) that reconfigures services mid-run.
//!
//! Determinism contract (DESIGN.md §8/§13): the loop is single-threaded,
//! the scheduler orders events by the contractual
//! [`super::sched::event_key`] `(time bits, sequence number)` so ties
//! break identically on every run *and on every scheduler backend*
//! (calendar queue by default, the original binary heap as a cross-check
//! oracle — byte-identical stdout either way), and all randomness flows
//! through one seeded [`Rng`] whose draw order is a pure function of the
//! event order. Request state lives in a reusable slab and per-replica
//! load lives in struct-of-arrays vectors on each service — after
//! warm-up the completion hot path performs no per-request allocation
//! and the balancer scan touches two flat arrays, not replica structs.

use super::faults::{EdgePolicy, FaultEv, FaultPlan, FaultsSpec};
use super::sched::{CalendarQueue, HeapQueue, SchedKind, Scheduler};
use super::servicetime::ServiceTimeModel;
use super::slo::{
    EngineView, SloAction, SloCfg, SloController, TenantAction, TenantController, TenantCtrlCfg,
    TenantView,
};
use super::topology::{Candidate, ResolvedTopology};
use super::workload::{ArrivalGen, TrafficShape};
use crate::coordinator::tenant::WayPartition;
use crate::obs::{ObsCfg, ObsData, Recorder};
use crate::util::percentile::Digest;
use crate::util::rng::{mix64, Rng};
use anyhow::{bail, Result};
use std::collections::VecDeque;

/// Per-scenario run knobs.
#[derive(Clone, Debug)]
pub struct RunParams {
    pub requests: u64,
    pub seed: u64,
    /// Latency SLO (µs) for compliance/burn accounting.
    pub slo_us: f64,
    /// Absolute reference rate (req/µs) that shape utilization 1.0 maps
    /// to — typically the baseline config's bottleneck rate, so faster
    /// configs see the same offered load at lower utilization.
    pub base_rate_per_us: f64,
}

/// Fault/self-healing bookkeeping for one run (all zero on a healthy
/// run — the counters are only bumped on the fault-aware paths).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Replica crash events processed.
    pub crashes: u64,
    /// Attempts re-dispatched (timeout retries + crash requeues).
    pub retries: u64,
    /// Hedged duplicate dispatches issued.
    pub hedges: u64,
    /// Client timeouts that fired on a live attempt.
    pub timeouts: u64,
    /// Stages abandoned after exhausting the retry budget (the request
    /// still completes — as an SLO miss, never a hang).
    pub failed: u64,
    /// Events discarded as stale (lazily cancelled timers, losing
    /// hedge twins, crash-orphaned completions).
    pub stale_events: u64,
}

impl FaultStats {
    pub fn is_zero(&self) -> bool {
        *self == FaultStats::default()
    }
}

/// One control action taken during a run.
#[derive(Clone, Debug, PartialEq)]
pub struct ActionLog {
    pub t_us: f64,
    pub service: String,
    pub action: String,
}

/// One tenant's runtime binding for a multi-tenant run (DESIGN.md §10).
#[derive(Clone, Debug)]
pub struct TenantRun {
    pub name: String,
    /// This tenant's open-loop arrival shape.
    pub shape: TrafficShape,
    /// Arrivals this tenant offers (the run completes Σ over tenants).
    pub requests: u64,
    /// Arrival-stream seed. A tenant's solo and co-located runs share
    /// it, so the comparison is paired: identical offered-load
    /// realization, like the `~emp` twins.
    pub arrival_seed: u64,
    /// Per-tenant latency SLO (µs); 0 = the run's `RunParams::slo_us`.
    pub slo_us: f64,
    /// L1-I ways locked to this tenant ([`WayPartition`] share).
    pub ways: u32,
    /// Ways the tenant's working set wants; overflow beyond the locked
    /// share is what dilates co-runners.
    pub demand_ways: u32,
    /// Member service indexes — a dep-closed sub-DAG of the topology
    /// (`ClusterSpec::tenant_services`).
    pub services: Vec<u32>,
}

/// Multi-tenant run knobs shared by every tenant.
#[derive(Clone, Debug)]
pub struct TenancyParams {
    pub total_ways: u32,
    /// Interference dilation coefficient α.
    pub alpha: f64,
    /// Enable the per-tenant control loop (repartition / upgrade /
    /// add-replica arbitration); `false` tracks per-tenant burn only.
    pub adaptive: bool,
    pub ctrl: TenantCtrlCfg,
}

/// Per-tenant outcome of a multi-tenant (or solo) run.
#[derive(Clone, Debug)]
pub struct TenantStat {
    pub name: String,
    /// The tenant's traffic-shape label.
    pub traffic: String,
    pub requests: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub slo_us: f64,
    pub compliance: f64,
    pub windows: u32,
    pub violated_windows: u32,
    /// L1-I way share at end of run (the repartition lever moves it).
    pub final_ways: u32,
}

/// Scenario outcome: the latency distribution plus SLO burn accounting
/// and the control loop's trace.
#[derive(Clone, Debug)]
pub struct ClusterResult {
    /// Config or policy label (filled by the caller, e.g. `ceip256` or
    /// `reactive`).
    pub label: String,
    /// Traffic-shape label (filled by the caller).
    pub traffic: String,
    pub requests: u64,
    /// Events processed (arrivals + completions).
    pub events: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub max_us: f64,
    pub slo_us: f64,
    /// Fraction of requests within the SLO.
    pub compliance: f64,
    /// Evaluation windows seen / windows that burned.
    pub windows: u32,
    pub violated_windows: u32,
    pub actions: Vec<ActionLog>,
    /// Final *active* replica count per service (spec order): retired
    /// replicas are excluded.
    pub final_replicas: Vec<u32>,
    /// Final config label per service (spec order).
    pub final_configs: Vec<String>,
    /// ∫ provisioned replicas dt over the run (replica-µs) — the
    /// capacity cost an autoscaler policy is judged on.
    pub replica_us: f64,
    /// ∫ prefetcher-metadata footprint dt (byte-µs).
    pub meta_byte_us: f64,
    /// Metadata footprint at the end of the run (bytes).
    pub final_metadata_bytes: u64,
    /// Simulated duration (µs, time of the last processed event).
    pub duration_us: f64,
    /// Peak pending-event depth over the run, whichever scheduler
    /// backend is active (self-profiling for the bench scoreboard;
    /// tracked on every run). The field keeps its pre-§13 name so BENCH
    /// JSON and downstream consumers are unchanged; both backends report
    /// the identical value — they hold the same pending set.
    pub peak_heap: u64,
    /// Fault-axis counters (all zero unless the run carried a fault
    /// plan or client policies).
    pub fault_stats: FaultStats,
    /// Per-tenant outcomes (multi-tenant runs only; empty otherwise).
    pub tenants: Vec<TenantStat>,
    /// Observability payload (`None` unless the run was launched with
    /// [`ObsCfg::enabled`] via [`run_obs`]/[`run_tenants_obs`]).
    pub obs: Option<ObsData>,
}

/// Event payloads. `Complete`/`Timeout`/`Hedge`/`Retry` carry the
/// attempt generation they were scheduled against: the pop-side gen
/// check is the lazy-cancellation mechanism (sched.rs "Stale events") —
/// a bumped slab gen turns every older event for that (slot, service)
/// into a no-op discard instead of requiring a queue cancel operation.
#[derive(Clone, Copy, Debug)]
enum EvKind {
    Arrival { tenant: u8 },
    Complete { svc: u32, rep: u32, slot: u32, gen: u32 },
    /// Pre-materialized fault-plan events (never scheduled mid-run).
    ReplicaDown { svc: u32, rep: u32 },
    ReplicaUp { svc: u32, rep: u32 },
    GrayStart { svc: u32, rep: u32, factor: f64 },
    GrayEnd { svc: u32, rep: u32 },
    /// Client-policy timers for one attempt of (slot, service).
    Timeout { svc: u32, slot: u32, gen: u32 },
    Hedge { svc: u32, slot: u32, gen: u32 },
    Retry { svc: u32, slot: u32, gen: u32 },
}

#[derive(Default)]
struct Replica {
    /// Waiting attempts as (slot, gen); stale entries (gen no longer
    /// current) are skipped — and uncounted — when they reach the head.
    queue: VecDeque<(u32, u32)>,
    in_service: Option<(u32, u32)>,
    /// Outstanding requests per tenant (queued + in service) — the
    /// interference model's per-replica mix. Empty on the single-tenant
    /// path, which never touches it.
    out_t: Vec<u32>,
}

struct Svc {
    replicas: Vec<Replica>,
    /// Outstanding requests (queued + in service) per replica —
    /// struct-of-arrays mirror of the replica state, so the
    /// least-outstanding balancer scan walks one flat `u32` array
    /// instead of chasing `VecDeque` headers.
    out: Vec<u32>,
    /// Retired-by-scale-down flag per replica: the load balancer skips
    /// it and it drains its residual work, but the slot stays in place —
    /// pending completion events keep valid indexes. A later scale-up
    /// revives it.
    retired: Vec<bool>,
    /// Current candidate index (the SLO loop advances this).
    current: usize,
    /// Cached `candidates[current].model(cv)` — analytic jitter or the
    /// candidate's trace-replayed quantile table (DESIGN.md §8).
    model: ServiceTimeModel,
    /// The spec's analytic jitter knob (rebuilding `model` on
    /// upgrade/downgrade needs it even when the table rides along).
    cv: f64,
    children: Vec<u32>,
    /// Crashed-by-fault flag per replica: the balancer skips it, its
    /// work was requeued at the crash, and `ReplicaUp` clears it.
    down: Vec<bool>,
    /// Gray-failure service-time dilation per replica (1.0 = healthy).
    gray: Vec<f64>,
    /// Attempts waiting for *any* live replica (every replica of the
    /// service is down or retired); flushed FIFO at `ReplicaUp`.
    parked: Vec<(u32, u32)>,
}

impl Svc {
    fn fresh(
        replicas: u32,
        ntenants: usize,
        model: ServiceTimeModel,
        cv: f64,
        children: Vec<u32>,
    ) -> Svc {
        Svc {
            replicas: (0..replicas)
                .map(|_| Replica { out_t: vec![0; ntenants], ..Replica::default() })
                .collect(),
            out: vec![0; replicas as usize],
            retired: vec![false; replicas as usize],
            current: 0,
            model,
            cv,
            children,
            down: vec![false; replicas as usize],
            gray: vec![1.0; replicas as usize],
            parked: Vec::new(),
        }
    }

    /// Non-retired replicas (the provisioned capacity).
    fn active_replicas(&self) -> u32 {
        self.retired.iter().filter(|r| !**r).count() as u32
    }
}

/// Reusable request slab: slots are recycled through a free list, so
/// steady-state throughput allocates nothing per request.
struct Slab {
    nsvc: usize,
    arrive: Vec<f64>,
    /// Unfinished upstream count per (slot, service), flattened.
    pending: Vec<u32>,
    /// Services not yet completed for this slot.
    remaining: Vec<u32>,
    /// Owning tenant per slot (always 0 on the single-tenant path).
    tenant: Vec<u8>,
    /// Attempt generation per (slot, service), flattened like `pending`.
    /// Bumped whenever an attempt is invalidated (timeout, winning
    /// completion, crash requeue) — and NEVER reset when a slot is
    /// recycled, so an in-flight event from a previous occupant of the
    /// slot can never alias a fresh attempt.
    gen: Vec<u32>,
    /// Retries consumed per (slot, service); reset at each stage's
    /// first dispatch.
    tries: Vec<u32>,
    free: Vec<u32>,
}

impl Slab {
    fn new(nsvc: usize) -> Slab {
        Slab {
            nsvc,
            arrive: Vec::new(),
            pending: Vec::new(),
            remaining: Vec::new(),
            tenant: Vec::new(),
            gen: Vec::new(),
            tries: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Allocate a slot: `remaining` is how many services must complete
    /// for the request (the owning tenant's member count — the full
    /// service count on the single-tenant path), `indegrees` the
    /// per-service fan-in it waits on (always `nsvc` entries).
    fn alloc(&mut self, t: f64, indegrees: &[u32], remaining: u32, tenant: u8) -> u32 {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                let s = self.arrive.len() as u32;
                self.arrive.push(0.0);
                self.remaining.push(0);
                self.tenant.push(0);
                self.pending.resize(self.pending.len() + self.nsvc, 0);
                self.gen.resize(self.gen.len() + self.nsvc, 0);
                self.tries.resize(self.tries.len() + self.nsvc, 0);
                s
            }
        };
        let i = slot as usize;
        self.arrive[i] = t;
        self.remaining[i] = remaining;
        self.tenant[i] = tenant;
        self.pending[i * self.nsvc..(i + 1) * self.nsvc].copy_from_slice(indegrees);
        slot
    }
}

/// Live multi-tenant state (DESIGN.md §10): per-tenant arrival streams
/// and sub-DAG views over the shared services, the L1-I way partition,
/// and the per-tenant burn/arbitration controller. `None` = the
/// single-tenant path, byte-identical to pre-tenancy builds (no extra
/// RNG draws, no event reordering).
struct Tenancy {
    tenants: Vec<TenantState>,
    partition: WayPartition,
    total_ways: u32,
    /// Interference dilation coefficient α.
    alpha: f64,
    ctrl: TenantController,
    adaptive: bool,
}

struct TenantState {
    name: String,
    gen: ArrivalGen,
    requests: u64,
    arrived: u64,
    completed: u64,
    met: u64,
    slo_us: f64,
    demand_ways: u32,
    /// Membership over the shared services.
    member: Vec<bool>,
    /// Member count (the slab `remaining` for this tenant's requests).
    nsvc: u32,
    /// Entry points of the tenant's sub-DAG.
    roots: Vec<u32>,
    /// Fan-in per service, restricted to the sub-DAG (0 for
    /// non-members — never consulted).
    indegrees: Vec<u32>,
    /// Children per service, restricted to the sub-DAG.
    children: Vec<Vec<u32>>,
    digest: Digest,
    traffic: String,
}

struct Sim<S: Scheduler<EvKind>> {
    svc: Vec<Svc>,
    names: Vec<String>,
    cands: Vec<Vec<Candidate>>,
    indegrees: Vec<u32>,
    roots: Vec<u32>,
    /// Pending-event queue — statically dispatched, so the heap oracle
    /// and the calendar queue each compile to a monomorphized loop.
    sched: S,
    seq: u64,
    rng: Rng,
    gen: ArrivalGen,
    slab: Slab,
    digest: Digest,
    met: u64,
    arrived: u64,
    completed: u64,
    events: u64,
    requests: u64,
    slo_us: f64,
    ctrl: SloController,
    adaptive: bool,
    actions: Vec<ActionLog>,
    /// Current metadata footprint: Σ active replicas × config bytes.
    meta_now: u64,
    /// Current provisioned (non-retired) replicas across all services.
    live_replicas: u32,
    /// Time the capacity/metadata integrals were last advanced to.
    last_change_us: f64,
    replica_us: f64,
    meta_byte_us: f64,
    /// Time of the most recently processed event (integral upper bound).
    last_event_us: f64,
    /// Per-service client policy (timeout/retry/hedge); empty on runs
    /// without a fault plan.
    policies: Vec<Option<EdgePolicy>>,
    /// Fault plan active: gates every gen check/bump so a healthy run
    /// does zero extra bookkeeping and stays byte-identical.
    faulty: bool,
    /// Retry/hedge/timeout/stale counters (all zero when `!faulty`).
    fstats: FaultStats,
    /// Multi-tenant state; `None` = the single-tenant path.
    tenancy: Option<Tenancy>,
    /// Peak pending-event depth (self-profiling; an integer compare per
    /// schedule, tracked even with obs off). Scheduler-independent: both
    /// backends hold the identical pending set at every step.
    peak_pending: usize,
    /// Observability recorder; `None` = the byte-identical baseline
    /// path (every hook is behind an `if let`).
    obs: Option<Recorder>,
}

impl<S: Scheduler<EvKind>> Sim<S> {
    fn schedule(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.sched.push(t, self.seq, kind);
        if self.sched.len() > self.peak_pending {
            self.peak_pending = self.sched.len();
        }
    }

    fn sample_service(&mut self, svc: usize) -> f64 {
        // Analytic: the same lognormal-flavored jitter as the rpc tandem
        // model, bit-for-bit. Empirical: one inverse-CDF draw through the
        // candidate's quantile table (the §8 one-draw rule).
        self.svc[svc].model.sample(&mut self.rng)
    }

    #[inline]
    fn gen_at(&self, slot: u32, svc: usize) -> u32 {
        self.slab.gen[slot as usize * self.slab.nsvc + svc]
    }

    /// Invalidate every pending event (timeout, hedge, retry, losing
    /// completion) of the current attempt of (slot, svc): O(1) lazy
    /// cancellation — the events stay queued and discard at pop.
    #[inline]
    fn bump_gen(&mut self, slot: u32, svc: usize) {
        let i = slot as usize * self.slab.nsvc + svc;
        self.slab.gen[i] = self.slab.gen[i].wrapping_add(1);
    }

    #[inline]
    fn policy(&self, svc: usize) -> Option<EdgePolicy> {
        self.policies.get(svc).copied().flatten()
    }

    /// First dispatch of a stage: reset its retry budget, then attempt.
    fn dispatch(&mut self, svc: usize, slot: u32, now: f64) {
        if self.faulty {
            self.slab.tries[slot as usize * self.slab.nsvc + svc] = 0;
        }
        self.dispatch_attempt(svc, slot, now);
    }

    /// One attempt: arm the edge's client timers against the current
    /// generation, then place the work on a replica.
    fn dispatch_attempt(&mut self, svc: usize, slot: u32, now: f64) {
        let gen = if self.faulty { self.gen_at(slot, svc) } else { 0 };
        if let Some(p) = self.policy(svc) {
            let (s, sl) = (svc as u32, slot);
            if let Some(to) = p.timeout_us {
                self.schedule(now + to, EvKind::Timeout { svc: s, slot: sl, gen });
            }
            if let Some(h) = p.hedge_after_us {
                self.schedule(now + h, EvKind::Hedge { svc: s, slot: sl, gen });
            }
        }
        self.place(svc, slot, gen, now);
    }

    /// Place one attempt of (slot, gen) on a replica of `svc`:
    /// least-outstanding-requests balancing over *live* replicas
    /// (neither retired nor crashed), lowest index on ties. On the
    /// healthy path at least one is always live (retire is gated on ≥ 2
    /// active); under faults a fully-crashed service parks the attempt
    /// until a `ReplicaUp` flushes it. The scan reads the flat SoA
    /// vectors — no replica structs, no VecDeque headers.
    fn place(&mut self, svc: usize, slot: u32, gen: u32, now: f64) {
        let mut best = usize::MAX;
        let mut best_out = u32::MAX;
        {
            let s = &self.svc[svc];
            for (i, (&out, &retired)) in s.out.iter().zip(&s.retired).enumerate() {
                if !retired && !s.down[i] && out < best_out {
                    best_out = out;
                    best = i;
                }
            }
        }
        if let Some(o) = self.obs.as_mut() {
            o.spans.on_enqueue(slot, svc as u32, now);
        }
        if best == usize::MAX {
            debug_assert!(self.faulty, "service with no active replica on a healthy run");
            self.svc[svc].parked.push((slot, gen));
            return;
        }
        self.svc[svc].out[best] += 1;
        if self.tenancy.is_some() {
            let t = self.slab.tenant[slot as usize] as usize;
            self.svc[svc].replicas[best].out_t[t] += 1;
        }
        if self.svc[svc].replicas[best].in_service.is_none() {
            self.svc[svc].replicas[best].in_service = Some((slot, gen));
            let base = self.sample_service(svc);
            // `base * dilation` is the baseline's `dt *= dilation`
            // bit-for-bit; the split exposes the interference component.
            let mut dt =
                if self.tenancy.is_some() { base * self.dilation(svc, best, slot) } else { base };
            if self.faulty {
                dt *= self.svc[svc].gray[best];
            }
            if let Some(o) = self.obs.as_mut() {
                o.spans.on_start(slot, svc as u32, best as u32, now, dt - base);
            }
            let kind =
                EvKind::Complete { svc: svc as u32, rep: best as u32, slot, gen };
            self.schedule(now + dt, kind);
        } else {
            self.svc[svc].replicas[best].queue.push_back((slot, gen));
        }
    }

    /// Deterministic interference dilation for the request in `slot`
    /// starting service on `(svc, rep)` (DESIGN.md §10): co-runners
    /// whose way demand exceeds their locked share spill into the
    /// victim's ways —
    /// `1 + α × mix × min(1, excess/W) × (1 − share/W)`, where `mix` is
    /// the co-runners' fraction of the replica's outstanding requests,
    /// `excess` their summed demand overflow, and `share` the victim's
    /// own locked ways (way locking is protection). Pure arithmetic on
    /// engine state — no RNG draws, so the draw sequence stays a pure
    /// function of the event order.
    fn dilation(&self, svc: usize, rep: usize, slot: u32) -> f64 {
        let tn = match &self.tenancy {
            Some(tn) => tn,
            None => return 1.0,
        };
        let tenant = self.slab.tenant[slot as usize];
        let out = &self.svc[svc].replicas[rep].out_t;
        let mut total = 0u32;
        let mut others = 0u32;
        let mut excess = 0u32;
        for (u, &o) in out.iter().enumerate() {
            total += o;
            if u as u8 != tenant && o > 0 {
                others += o;
                excess += tn.tenants[u].demand_ways.saturating_sub(tn.partition.share(u as u8));
            }
        }
        if others == 0 || excess == 0 {
            return 1.0;
        }
        let mix = others as f64 / total as f64;
        let pressure = (excess as f64 / tn.total_ways as f64).min(1.0);
        let shield = (tn.partition.share(tenant) as f64 / tn.total_ways as f64).min(1.0);
        1.0 + tn.alpha * mix * pressure * (1.0 - shield)
    }

    /// Bottleneck service: lowest aggregate active service rate.
    fn bottleneck(&self) -> usize {
        let mut best = 0usize;
        let mut worst_rate = f64::INFINITY;
        for (i, s) in self.svc.iter().enumerate() {
            let rate = s.active_replicas() as f64 / s.model.mean_us();
            if rate < worst_rate {
                worst_rate = rate;
                best = i;
            }
        }
        best
    }

    /// Advance the capacity/metadata integrals to `now` (call before any
    /// change to `live_replicas` or `meta_now`, and once at end of run).
    fn account(&mut self, now: f64) {
        let dt = now - self.last_change_us;
        self.replica_us += dt * self.live_replicas as f64;
        self.meta_byte_us += dt * self.meta_now as f64;
        self.last_change_us = now;
    }

    /// Service to release a replica from: the non-bottleneck service
    /// with the most aggregate headroom (highest active rate) and ≥ 2
    /// active replicas; ties break to the lowest index. Falls back to
    /// the bottleneck itself so single-service topologies still scale
    /// down.
    fn scale_down_target(&self) -> Option<usize> {
        let b = self.bottleneck();
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in self.svc.iter().enumerate() {
            if i == b || s.active_replicas() < 2 {
                continue;
            }
            let rate = s.active_replicas() as f64 / s.model.mean_us();
            if best.map(|(_, r)| rate > r).unwrap_or(true) {
                best = Some((i, rate));
            }
        }
        best.map(|(i, _)| i)
            .or_else(|| (self.svc[b].active_replicas() >= 2).then_some(b))
    }

    /// Service to move to a cheaper config: the non-bottleneck service
    /// whose downgrade reclaims the most metadata bytes (None when no
    /// downgrade would reclaim anything).
    fn downgrade_target(&self) -> Option<usize> {
        let b = self.bottleneck();
        let mut best: Option<(usize, u64)> = None;
        for (i, s) in self.svc.iter().enumerate() {
            if i == b || s.current == 0 {
                continue;
            }
            let cand = &self.cands[i];
            let per = cand[s.current]
                .metadata_bytes
                .saturating_sub(cand[s.current - 1].metadata_bytes);
            if per == 0 {
                continue;
            }
            let reclaim = per.saturating_mul(s.active_replicas() as u64);
            if best.map(|(_, r)| reclaim > r).unwrap_or(true) {
                best = Some((i, reclaim));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Engine-side facts for the policy, snapshotted at `now`.
    fn view(&self, now: f64) -> EngineView {
        let b = self.bottleneck();
        let cur = self.svc[b].current;
        let can_upgrade = cur + 1 < self.cands[b].len();
        let active_b = self.svc[b].active_replicas();
        let upgrade_meta_delta = if can_upgrade {
            self.cands[b][cur + 1]
                .metadata_bytes
                .saturating_sub(self.cands[b][cur].metadata_bytes)
                .saturating_mul(active_b as u64)
        } else {
            0
        };
        let (mut failed, mut degraded) = (0u32, 0u32);
        if self.faulty {
            for s in &self.svc {
                failed += s.down.iter().filter(|d| **d).count() as u32;
                degraded += s.gray.iter().filter(|g| **g > 1.0).count() as u32;
            }
        }
        EngineView {
            now_us: now,
            can_upgrade,
            can_scale_up: active_b < self.ctrl.cfg.max_replicas,
            can_scale_down: self.scale_down_target().is_some(),
            can_downgrade: self.downgrade_target().is_some(),
            metadata_bytes: self.meta_now,
            upgrade_meta_delta,
            scale_up_meta_delta: self.cands[b][cur].metadata_bytes,
            failed_replicas: failed,
            degraded_replicas: degraded,
        }
    }

    /// Apply a control action, falling back to the other scale-up lever
    /// when the chosen one is exhausted. Returns the action actually
    /// executed (None = dropped) so the controller can credit its bandit
    /// reward to the right arm.
    fn apply_action(&mut self, act: SloAction, now: f64) -> Option<SloAction> {
        match act {
            SloAction::Upgrade | SloAction::AddReplica => self.apply_scale_up(act, now),
            SloAction::RemoveReplica => self.apply_remove(now),
            SloAction::Downgrade => self.apply_downgrade(now),
        }
    }

    fn apply_scale_up(&mut self, act: SloAction, now: f64) -> Option<SloAction> {
        let b = self.bottleneck();
        let can_upgrade = self.svc[b].current + 1 < self.cands[b].len();
        let can_scale = self.svc[b].active_replicas() < self.ctrl.cfg.max_replicas;
        let act = match act {
            SloAction::Upgrade if can_upgrade => SloAction::Upgrade,
            SloAction::AddReplica if can_scale => SloAction::AddReplica,
            _ if can_upgrade => SloAction::Upgrade,
            _ if can_scale => SloAction::AddReplica,
            _ => return None,
        };
        match act {
            SloAction::Upgrade => self.upgrade_service(b, now),
            SloAction::AddReplica => self.add_replica(b, 0, now),
            _ => unreachable!(),
        }
        Some(act)
    }

    /// Switch service `b` to its next faster candidate, with metadata
    /// accounting and action logging — the Upgrade lever shared by the
    /// single-tenant control loop and the tenant arbitration. The caller
    /// has already verified a faster candidate exists.
    fn upgrade_service(&mut self, b: usize, now: f64) {
        self.account(now);
        let cur = self.svc[b].current;
        let delta = self.cands[b][cur + 1].metadata_bytes as i64
            - self.cands[b][cur].metadata_bytes as i64;
        let n = self.svc[b].active_replicas() as i64;
        self.meta_now = (self.meta_now as i64 + delta * n).max(0) as u64;
        let cv = self.svc[b].cv;
        self.svc[b].current = cur + 1;
        self.svc[b].model = self.cands[b][cur + 1].model(cv);
        self.actions.push(ActionLog {
            t_us: now,
            service: self.names[b].clone(),
            action: format!("upgrade→{}", self.cands[b][cur + 1].label),
        });
    }

    /// Add one replica to service `b`: revive a retired slot when one
    /// exists (index-stable), otherwise grow the pool — a fresh replica
    /// gets an `ntenants`-sized outstanding vector (0 on the
    /// single-tenant path, where `out_t` stays empty). Shared by both
    /// control loops; the caller has already checked the replica cap.
    fn add_replica(&mut self, b: usize, ntenants: usize, now: f64) {
        self.account(now);
        let s = &mut self.svc[b];
        if let Some(i) = s.retired.iter().position(|&r| r) {
            s.retired[i] = false;
        } else {
            s.replicas.push(Replica {
                out_t: vec![0; ntenants],
                ..Replica::default()
            });
            s.out.push(0);
            s.retired.push(false);
            s.down.push(false);
            s.gray.push(1.0);
        }
        self.live_replicas += 1;
        self.meta_now += self.cands[b][self.svc[b].current].metadata_bytes;
        self.actions.push(ActionLog {
            t_us: now,
            service: self.names[b].clone(),
            action: format!("replicas→{}", self.svc[b].active_replicas()),
        });
    }

    fn apply_remove(&mut self, now: f64) -> Option<SloAction> {
        let t = self.scale_down_target()?;
        // Retire the emptiest active replica: capacity is handed back at
        // the action; residual queued work drains in place (the slot —
        // and any pending completion event pointing at it — stays put).
        let mut pick = usize::MAX;
        let mut least = u32::MAX;
        {
            let s = &self.svc[t];
            for (i, (&out, &retired)) in s.out.iter().zip(&s.retired).enumerate() {
                if !retired && out < least {
                    least = out;
                    pick = i;
                }
            }
        }
        debug_assert!(pick != usize::MAX, "scale-down target had no active replica");
        self.account(now);
        self.svc[t].retired[pick] = true;
        self.live_replicas -= 1;
        self.meta_now = self
            .meta_now
            .saturating_sub(self.cands[t][self.svc[t].current].metadata_bytes);
        self.actions.push(ActionLog {
            t_us: now,
            service: self.names[t].clone(),
            action: format!("replicas→{}", self.svc[t].active_replicas()),
        });
        Some(SloAction::RemoveReplica)
    }

    fn apply_downgrade(&mut self, now: f64) -> Option<SloAction> {
        let t = self.downgrade_target()?;
        self.account(now);
        let cur = self.svc[t].current;
        let delta = self.cands[t][cur - 1].metadata_bytes as i64
            - self.cands[t][cur].metadata_bytes as i64;
        let n = self.svc[t].active_replicas() as i64;
        self.meta_now = (self.meta_now as i64 + delta * n).max(0) as u64;
        let cv = self.svc[t].cv;
        self.svc[t].current = cur - 1;
        self.svc[t].model = self.cands[t][cur - 1].model(cv);
        self.actions.push(ActionLog {
            t_us: now,
            service: self.names[t].clone(),
            action: format!("downgrade→{}", self.cands[t][cur - 1].label),
        });
        Some(SloAction::Downgrade)
    }

    fn finish(&mut self, slot: u32, now: f64) {
        let latency = now - self.slab.arrive[slot as usize];
        self.digest.add(latency);
        if latency <= self.slo_us {
            self.met += 1;
        }
        self.completed += 1;
        if let Some(o) = self.obs.as_mut() {
            o.spans.on_finish(slot);
            o.metrics.observe("latency_us", latency);
        }
        self.slab.free.push(slot);
        // Static scenarios feed a lever-less view: the controller tracks
        // windows/burn but its policy can never propose anything.
        let view = if self.adaptive { self.view(now) } else { EngineView::frozen(now) };
        let windows_before = self.ctrl.windows;
        if let Some(act) = self.ctrl.on_complete(latency, &view) {
            let applied = self.apply_action(act, now);
            self.ctrl.settle_applied(applied);
        }
        // Snapshot after the boundary's lever (if any) applied, so the
        // timeseries reflects the controller's post-decision state.
        if self.obs.is_some() && self.ctrl.windows > windows_before {
            self.snapshot_metrics(now);
        }
    }

    fn step(&mut self) -> bool {
        let (t, _seq, kind) = match self.sched.pop() {
            Some(ev) => ev,
            None => return false,
        };
        self.events += 1;
        self.last_event_us = t;
        match kind {
            EvKind::Arrival { tenant } => {
                if self.tenancy.is_some() {
                    self.arrive_tenant(tenant, t);
                } else {
                    let n = self.slab.nsvc as u32;
                    let slot = self.slab.alloc(t, &self.indegrees, n, 0);
                    if let Some(o) = self.obs.as_mut() {
                        // Request id = arrival index (incremented below).
                        o.spans.on_arrival(slot, self.arrived, 0);
                    }
                    let roots = std::mem::take(&mut self.roots);
                    for &r in &roots {
                        self.dispatch(r as usize, slot, t);
                    }
                    self.roots = roots;
                    self.arrived += 1;
                    if self.arrived < self.requests {
                        let t_next = self.gen.next_arrival();
                        self.schedule(t_next, EvKind::Arrival { tenant: 0 });
                    }
                }
            }
            EvKind::Complete { svc, rep, slot, gen } => {
                let (svc, rep) = (svc as usize, rep as usize);
                // Attempt liveness: under faults, a completion whose
                // generation is no longer current lost to a timeout, a
                // hedge twin, or a crash requeue — it may still free the
                // replica it ran on, but never advances the request.
                let live = !self.faulty || self.gen_at(slot, svc) == gen;
                let occupied =
                    self.svc[svc].replicas[rep].in_service == Some((slot, gen));
                if !occupied {
                    // The occupancy was already torn down (crash drain) —
                    // or, on a healthy run, the invariant that used to be
                    // `expect("completion on an idle replica")` broke.
                    // Either way: discard, don't abort the shard.
                    debug_assert!(!live, "completion on an idle replica");
                    self.fstats.stale_events += 1;
                    return true;
                }
                self.svc[svc].replicas[rep].in_service = None;
                self.svc[svc].out[rep] = self.svc[svc].out[rep].saturating_sub(1);
                if self.tenancy.is_some() {
                    let done = self.slab.tenant[slot as usize] as usize;
                    let o = &mut self.svc[svc].replicas[rep].out_t[done];
                    *o = o.saturating_sub(1);
                }
                if live {
                    if let Some(o) = self.obs.as_mut() {
                        o.spans.on_end(slot, svc as u32, t);
                    }
                    if self.faulty {
                        // First completion wins: cancel this attempt's
                        // timeout and any still-running hedge twin.
                        self.bump_gen(slot, svc);
                    }
                }
                self.start_next(svc, rep, t);
                if live {
                    self.complete_stage(svc, slot, t);
                } else {
                    self.fstats.stale_events += 1;
                }
            }
            EvKind::ReplicaDown { svc, rep } => {
                self.fstats.crashes += 1;
                self.crash_replica(svc as usize, rep as usize, t);
            }
            EvKind::ReplicaUp { svc, rep } => {
                let (svc, rep) = (svc as usize, rep as usize);
                self.svc[svc].down[rep] = false;
                // Flush attempts parked while the whole service was
                // down, FIFO, skipping any that timed out or failed in
                // the meantime.
                let parked = std::mem::take(&mut self.svc[svc].parked);
                for (slot, gen) in parked {
                    if self.gen_at(slot, svc) == gen {
                        self.place(svc, slot, gen, t);
                    } else {
                        self.fstats.stale_events += 1;
                    }
                }
            }
            EvKind::GrayStart { svc, rep, factor } => {
                // In-flight work keeps its sampled service time; the
                // dilation applies to starts inside the gray interval.
                self.svc[svc as usize].gray[rep as usize] = factor;
            }
            EvKind::GrayEnd { svc, rep } => {
                self.svc[svc as usize].gray[rep as usize] = 1.0;
            }
            EvKind::Timeout { svc, slot, gen } => {
                let svc = svc as usize;
                if self.gen_at(slot, svc) != gen {
                    self.fstats.stale_events += 1;
                } else {
                    self.fstats.timeouts += 1;
                    self.bump_gen(slot, svc);
                    let idx = slot as usize * self.slab.nsvc + svc;
                    // Timers only exist on policy edges.
                    let p = self.policy(svc).unwrap_or_default();
                    if self.slab.tries[idx] < p.retries {
                        self.slab.tries[idx] += 1;
                        self.fstats.retries += 1;
                        // Deterministic exponential backoff: attempt n
                        // waits backoff_us × 2^(n−1) before redispatch.
                        let shift = (self.slab.tries[idx] - 1).min(62);
                        let backoff = p.backoff_us * (1u64 << shift) as f64;
                        if backoff > 0.0 {
                            let g = self.gen_at(slot, svc);
                            let kind =
                                EvKind::Retry { svc: svc as u32, slot, gen: g };
                            self.schedule(t + backoff, kind);
                        } else {
                            self.dispatch_attempt(svc, slot, t);
                        }
                    } else {
                        self.fail_stage(svc, slot, t);
                    }
                }
            }
            EvKind::Hedge { svc, slot, gen } => {
                let svc = svc as usize;
                if self.gen_at(slot, svc) != gen {
                    self.fstats.stale_events += 1;
                } else {
                    // Duplicate dispatch against the SAME generation:
                    // the first completion wins and bumps the gen,
                    // turning the loser into a stale discard.
                    self.fstats.hedges += 1;
                    self.place(svc, slot, gen, t);
                }
            }
            EvKind::Retry { svc, slot, gen } => {
                let svc = svc as usize;
                if self.gen_at(slot, svc) != gen {
                    self.fstats.stale_events += 1;
                } else {
                    self.dispatch_attempt(svc, slot, t);
                }
            }
        }
        true
    }

    /// Start the replica's next waiting attempt, skipping — and
    /// un-counting — entries whose generation went stale while queued.
    fn start_next(&mut self, svc: usize, rep: usize, now: f64) {
        loop {
            let (slot, gen) = match self.svc[svc].replicas[rep].queue.pop_front() {
                Some(x) => x,
                None => return,
            };
            if self.faulty && self.gen_at(slot, svc) != gen {
                self.svc[svc].out[rep] = self.svc[svc].out[rep].saturating_sub(1);
                if self.tenancy.is_some() {
                    let done = self.slab.tenant[slot as usize] as usize;
                    let o = &mut self.svc[svc].replicas[rep].out_t[done];
                    *o = o.saturating_sub(1);
                }
                self.fstats.stale_events += 1;
                continue;
            }
            self.svc[svc].replicas[rep].in_service = Some((slot, gen));
            let base = self.sample_service(svc);
            let mut dt = if self.tenancy.is_some() {
                base * self.dilation(svc, rep, slot)
            } else {
                base
            };
            if self.faulty {
                dt *= self.svc[svc].gray[rep];
            }
            if let Some(o) = self.obs.as_mut() {
                o.spans.on_start(slot, svc as u32, rep as u32, now, dt - base);
            }
            let kind = EvKind::Complete { svc: svc as u32, rep: rep as u32, slot, gen };
            self.schedule(now + dt, kind);
            return;
        }
    }

    /// The stage of (slot, svc) resolved (successfully or via
    /// [`Self::fail_stage`]): clear downstream edges and finish the
    /// request when it was the last one.
    fn complete_stage(&mut self, svc: usize, slot: u32, now: f64) {
        // Fan out: along the owning tenant's sub-DAG in tenant mode,
        // along the full topology otherwise — one shared loop, with the
        // edge list detached around dispatch.
        let tenant = self.slab.tenant[slot as usize] as usize;
        let children = match self.tenancy.as_mut() {
            Some(tn) => std::mem::take(&mut tn.tenants[tenant].children[svc]),
            None => std::mem::take(&mut self.svc[svc].children),
        };
        for &c in &children {
            let ci = c as usize;
            let idx = slot as usize * self.slab.nsvc + ci;
            if let Some(o) = self.obs.as_mut() {
                o.spans.on_first_dep(slot, c, now);
            }
            self.slab.pending[idx] -= 1;
            if self.slab.pending[idx] == 0 {
                self.dispatch(ci, slot, now);
            }
        }
        match self.tenancy.as_mut() {
            Some(tn) => tn.tenants[tenant].children[svc] = children,
            None => self.svc[svc].children = children,
        }
        self.slab.remaining[slot as usize] -= 1;
        if self.slab.remaining[slot as usize] == 0 {
            if self.tenancy.is_some() {
                self.finish_tenant(slot, now);
            } else {
                self.finish(slot, now);
            }
        }
    }

    /// A replica crashed: mark it down, then requeue its in-flight and
    /// queued work. Each live attempt is invalidated (its timers and
    /// any hedge twin die with it) and re-dispatched immediately while
    /// retry budget remains — edges without a client policy requeue for
    /// free, so plain specs are crash-safe by default — otherwise the
    /// stage fails as an SLO miss.
    fn crash_replica(&mut self, svc: usize, rep: usize, now: f64) {
        self.svc[svc].down[rep] = true;
        let r = &mut self.svc[svc].replicas[rep];
        let mut work: Vec<(u32, u32)> = Vec::with_capacity(r.queue.len() + 1);
        if let Some(x) = r.in_service.take() {
            work.push(x);
        }
        work.extend(r.queue.drain(..));
        r.out_t.iter_mut().for_each(|o| *o = 0);
        self.svc[svc].out[rep] = 0;
        for (slot, gen) in work {
            if self.gen_at(slot, svc) != gen {
                self.fstats.stale_events += 1;
                continue;
            }
            self.bump_gen(slot, svc);
            let idx = slot as usize * self.slab.nsvc + svc;
            match self.policy(svc) {
                Some(p) if self.slab.tries[idx] >= p.retries => {
                    self.fail_stage(svc, slot, now)
                }
                pol => {
                    if pol.is_some() {
                        self.slab.tries[idx] += 1;
                    }
                    self.fstats.retries += 1;
                    self.dispatch_attempt(svc, slot, now);
                }
            }
        }
    }

    /// Abandon the stage — its retry budget is exhausted (timeout chain
    /// or crash; the caller has already bumped the generation). The
    /// request still completes downstream, carrying the elapsed time as
    /// latency — an SLO miss, never a hang, so `completed == requests`
    /// holds under every fault schedule.
    fn fail_stage(&mut self, svc: usize, slot: u32, now: f64) {
        self.fstats.failed += 1;
        self.complete_stage(svc, slot, now);
    }

    /// One tenant's arrival: allocate a slot over its sub-DAG, dispatch
    /// its entry points, and schedule that tenant's next arrival from
    /// its own stream. Field-disjoint borrows (`self.tenancy` vs
    /// `self.slab`) keep the whole tenancy struct in place — no
    /// per-arrival move of it.
    fn arrive_tenant(&mut self, tenant: u8, now: f64) {
        let t = tenant as usize;
        let (slot, next, roots) = {
            let tn = self.tenancy.as_mut().expect("tenant arrival without tenancy");
            let ts = &mut tn.tenants[t];
            let slot = self.slab.alloc(now, &ts.indegrees, ts.nsvc, tenant);
            ts.arrived += 1;
            let next =
                if ts.arrived < ts.requests { Some(ts.gen.next_arrival()) } else { None };
            // Detach the root list: dispatch needs the whole Sim (and
            // reads the tenancy state for dilation).
            (slot, next, std::mem::take(&mut ts.roots))
        };
        if let Some(o) = self.obs.as_mut() {
            // Request id = global arrival index (incremented below), so
            // sampling stays decorrelated across tenants.
            o.spans.on_arrival(slot, self.arrived, tenant);
        }
        for &r in &roots {
            self.dispatch(r as usize, slot, now);
        }
        self.tenancy.as_mut().unwrap().tenants[t].roots = roots;
        self.arrived += 1;
        if let Some(t_next) = next {
            self.schedule(t_next, EvKind::Arrival { tenant });
        }
    }

    /// Multi-tenant request completion: per-tenant latency/burn
    /// tracking, then (adaptive runs) the lever arbitration.
    fn finish_tenant(&mut self, slot: u32, now: f64) {
        let latency = now - self.slab.arrive[slot as usize];
        let tenant = self.slab.tenant[slot as usize] as usize;
        self.digest.add(latency);
        self.completed += 1;
        if let Some(o) = self.obs.as_mut() {
            o.spans.on_finish(slot);
            o.metrics.observe("latency_us", latency);
        }
        self.slab.free.push(slot);
        // Lever availability first (immutable reads). The view is only
        // consulted at the tenant's window boundary, so the
        // bottleneck/donor scans stay off the completion hot path.
        let view = {
            let tn = self.tenancy.as_ref().expect("tenant completion without tenancy");
            if tn.adaptive && tn.ctrl.window_closing(tenant) {
                let b = Self::tenant_bottleneck(&self.svc, tn, tenant);
                TenantView {
                    can_repartition: tn.tenants[tenant].demand_ways
                        > tn.partition.share(tenant as u8)
                        && Self::repartition_donor(tn, tenant).is_some(),
                    can_upgrade: self.svc[b].current + 1 < self.cands[b].len(),
                    can_scale_up: self.svc[b].active_replicas() < tn.ctrl.cfg.max_replicas,
                }
            } else {
                TenantView::default()
            }
        };
        let (act, window_closed) = {
            let tn = self.tenancy.as_mut().expect("tenant completion without tenancy");
            let ts = &mut tn.tenants[tenant];
            ts.digest.add(latency);
            ts.completed += 1;
            if latency <= ts.slo_us {
                ts.met += 1;
                self.met += 1;
            }
            let windows_before = tn.ctrl.windows[tenant];
            let act = tn.ctrl.on_complete(tenant, latency, &view);
            (act, tn.ctrl.windows[tenant] > windows_before)
        };
        if let Some(act) = act {
            self.apply_tenant_action(tenant, act, now);
        }
        // Snapshot after the boundary's lever (if any) applied.
        if window_closed && self.obs.is_some() {
            self.snapshot_metrics(now);
        }
    }

    /// Push one metrics-registry snapshot at an SLO-window boundary:
    /// engine state, controller internals, and (tenant runs) per-tenant
    /// way shares and burn rates. Every value is a pure function of the
    /// simulated event order — nothing wall-clock. Called only with obs
    /// enabled.
    fn snapshot_metrics(&mut self, now: f64) {
        // Gauge name predates the pluggable scheduler: "heap_len" is the
        // pending-event depth whichever backend is active (§13).
        let heap_len = self.sched.len();
        let live_replicas = self.live_replicas;
        let meta_now = self.meta_now;
        let nactions = self.actions.len() as u64;
        let depths: Vec<(String, f64)> = self
            .svc
            .iter()
            .enumerate()
            .map(|(i, s)| {
                // Sum over all replicas (retired ones drain residuals).
                let d: u32 = s.out.iter().sum();
                (format!("depth.{}", self.names[i]), f64::from(d))
            })
            .collect();
        let (windows, violated, burn, bucket, tenant_gauges) = match &self.tenancy {
            None => (
                self.ctrl.windows as u64,
                self.ctrl.violated as u64,
                self.ctrl.burn_rate(),
                self.ctrl.bucket_level(),
                Vec::new(),
            ),
            Some(tn) => {
                let windows: u32 = tn.ctrl.windows.iter().sum();
                let violated: u32 = tn.ctrl.violated.iter().sum();
                let burn =
                    if windows > 0 { violated as f64 / windows as f64 } else { 0.0 };
                let mut gauges = Vec::with_capacity(tn.tenants.len() * 2);
                for (i, ts) in tn.tenants.iter().enumerate() {
                    gauges.push((
                        format!("ways.{}", ts.name),
                        tn.partition.share(i as u8) as f64,
                    ));
                    gauges.push((format!("burn.{}", ts.name), tn.ctrl.burn_rate(i)));
                }
                (windows as u64, violated as u64, burn, tn.ctrl.bucket_level(), gauges)
            }
        };
        let (arrived, completed, events) = (self.arrived, self.completed, self.events);
        let (faulty, fstats) = (self.faulty, self.fstats);
        let o = self.obs.as_mut().expect("snapshot_metrics without obs");
        o.metrics.counter("arrived", arrived);
        o.metrics.counter("completed", completed);
        o.metrics.counter("events", events);
        o.metrics.counter("actions", nactions);
        o.metrics.counter("violated_windows", violated);
        if faulty {
            // Fault-axis counters exist only on fault-plan runs, so a
            // healthy run's metric snapshots stay byte-identical.
            o.metrics.counter("crashes", fstats.crashes);
            o.metrics.counter("retries", fstats.retries);
            o.metrics.counter("hedges", fstats.hedges);
            o.metrics.counter("timeouts", fstats.timeouts);
            o.metrics.counter("failed_stages", fstats.failed);
            o.metrics.counter("stale_events", fstats.stale_events);
        }
        o.metrics.gauge("heap_len", heap_len as f64);
        o.metrics.gauge("live_replicas", live_replicas as f64);
        o.metrics.gauge("metadata_bytes", meta_now as f64);
        o.metrics.gauge("burn_rate", burn);
        o.metrics.gauge("token_bucket_level", bucket);
        for (k, v) in &depths {
            o.metrics.gauge(k, *v);
        }
        for (k, v) in &tenant_gauges {
            o.metrics.gauge(k, *v);
        }
        o.snapshot(now, windows);
    }

    /// Bottleneck service within one tenant's sub-DAG (lowest aggregate
    /// active rate; ties to the lowest index). Associated function over
    /// the service slice so callers can hold `&self.tenancy` and
    /// `&self.svc` as disjoint field borrows.
    fn tenant_bottleneck(svc: &[Svc], tn: &Tenancy, tenant: usize) -> usize {
        let mut best = 0usize;
        let mut worst = f64::INFINITY;
        for (i, s) in svc.iter().enumerate() {
            if !tn.tenants[tenant].member[i] {
                continue;
            }
            let rate = s.active_replicas() as f64 / s.model.mean_us();
            if rate < worst {
                worst = rate;
                best = i;
            }
        }
        best
    }

    /// Way-repartition donor for `to`: prefer the co-tenant with the
    /// most slack (share > demand — giving a way up costs it nothing),
    /// else the largest share that can spare a way (≥ 2). Lowest index
    /// breaks ties; never the beneficiary.
    fn repartition_donor(tn: &Tenancy, to: usize) -> Option<usize> {
        let share = |u: usize| tn.partition.share(u as u8);
        let mut slack_best: Option<(usize, u32)> = None;
        for (u, t) in tn.tenants.iter().enumerate() {
            if u == to || share(u) == 0 {
                continue;
            }
            let slack = share(u).saturating_sub(t.demand_ways);
            if slack > 0 && slack_best.map(|(_, b)| slack > b).unwrap_or(true) {
                slack_best = Some((u, slack));
            }
        }
        if let Some((u, _)) = slack_best {
            return Some(u);
        }
        let mut big: Option<(usize, u32)> = None;
        for u in 0..tn.tenants.len() {
            if u == to || share(u) < 2 {
                continue;
            }
            if big.map(|(_, b)| share(u) > b).unwrap_or(true) {
                big = Some((u, share(u)));
            }
        }
        big.map(|(u, _)| u)
    }

    /// Apply a tenant lever. Availability was checked when the view was
    /// built (same completion — no intervening events), but each arm
    /// re-checks cheaply and degrades to a no-op rather than panicking.
    fn apply_tenant_action(&mut self, tenant: usize, act: TenantAction, now: f64) {
        match act {
            TenantAction::Repartition => {
                let moved = {
                    let tn = self.tenancy.as_mut().expect("repartition without tenancy");
                    Self::repartition_donor(tn, tenant).map(|donor| {
                        let freed = tn.partition.share(donor as u8) - 1;
                        let grown = tn.partition.share(tenant as u8) + 1;
                        // Shrink first so the grow can never oversubscribe.
                        tn.partition.assign(donor as u8, freed).expect("shrink always fits");
                        tn.partition.assign(tenant as u8, grown).expect("freed way fits");
                        format!("{}→{}:{grown}", tn.tenants[donor].name, tn.tenants[tenant].name)
                    })
                };
                if let Some(action) = moved {
                    self.actions.push(ActionLog { t_us: now, service: "ways".into(), action });
                }
            }
            TenantAction::Upgrade => {
                let b = {
                    let tn = self.tenancy.as_ref().expect("upgrade without tenancy");
                    Self::tenant_bottleneck(&self.svc, tn, tenant)
                };
                if self.svc[b].current + 1 < self.cands[b].len() {
                    self.upgrade_service(b, now);
                }
            }
            TenantAction::AddReplica => {
                let (b, nt, cap) = {
                    let tn = self.tenancy.as_ref().expect("scale-up without tenancy");
                    let b = Self::tenant_bottleneck(&self.svc, tn, tenant);
                    (b, tn.tenants.len(), tn.ctrl.cfg.max_replicas)
                };
                if self.svc[b].active_replicas() < cap {
                    self.add_replica(b, nt, now);
                }
            }
        }
    }
}

/// Run one scenario to completion. `ctrl = None` tracks SLO burn but
/// never acts (static config); `Some(cfg)` enables the control loop.
/// Equal inputs produce bit-equal results on every run. Unrunnable
/// parameters (0 requests, a non-positive reference or peak arrival
/// rate) are errors, not hangs: a release build used to spin forever in
/// `ArrivalGen::next_arrival` on a zero rate.
pub fn run(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    ctrl: Option<SloCfg>,
) -> Result<ClusterResult> {
    run_obs(topo, shape, params, ctrl, &ObsCfg::off())
}

/// [`run`] on an explicit scheduler backend (DESIGN.md §13). Both
/// backends produce bit-equal results; `SchedKind::Heap` is the
/// cross-check oracle.
pub fn run_sched(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    ctrl: Option<SloCfg>,
    sched: SchedKind,
) -> Result<ClusterResult> {
    run_obs_sched(topo, shape, params, ctrl, &ObsCfg::off(), sched)
}

/// [`run`] with an observability configuration (DESIGN.md §11).
/// `obs.enabled = false` is exactly [`run`]: the recorder is never
/// constructed, every hook is skipped, and the result is bit-equal to
/// the baseline. Enabled, the hooks read engine state the loop already
/// computes — no RNG draws, no event reordering — so the recorded data
/// is a pure function of the (unchanged) event order.
pub fn run_obs(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    ctrl: Option<SloCfg>,
    obs: &ObsCfg,
) -> Result<ClusterResult> {
    run_obs_sched(topo, shape, params, ctrl, obs, SchedKind::default())
}

/// [`run_obs`] on an explicit scheduler backend. Monomorphizes the
/// event loop per backend — no dynamic dispatch on the hot path.
pub fn run_obs_sched(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    ctrl: Option<SloCfg>,
    obs: &ObsCfg,
    sched: SchedKind,
) -> Result<ClusterResult> {
    run_obs_sched_faults(topo, shape, params, ctrl, obs, sched, None)
}

/// Pre-materialization horizon for rate-driven fault schedules: a pure
/// function of the run parameters (8× the mean span the offered load
/// needs for `requests` arrivals), so the expansion is identical on
/// every thread count and scheduler backend.
pub fn fault_horizon_us(params: &RunParams) -> f64 {
    8.0 * params.requests as f64 / params.base_rate_per_us
}

/// [`run`] under a fault plan (DESIGN.md §14). `faults = None` or an
/// empty spec is exactly [`run`]: no fault events are scheduled, no
/// generation bookkeeping runs, and the result is byte-identical to the
/// pre-fault build. Otherwise the spec's schedules are expanded into
/// pre-materialized events from their own seeded RNG stream — the
/// arrival stream is untouched — and the client policies arm per-edge
/// timeout/retry/hedge timers.
pub fn run_faults(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    ctrl: Option<SloCfg>,
    faults: Option<&FaultsSpec>,
) -> Result<ClusterResult> {
    run_obs_sched_faults(
        topo,
        shape,
        params,
        ctrl,
        &ObsCfg::off(),
        SchedKind::default(),
        faults,
    )
}

/// The fully-general entry point: observability × scheduler backend ×
/// fault plan.
pub fn run_obs_sched_faults(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    ctrl: Option<SloCfg>,
    obs: &ObsCfg,
    sched: SchedKind,
    faults: Option<&FaultsSpec>,
) -> Result<ClusterResult> {
    let plan = match faults {
        Some(f) if !f.is_empty() => {
            if !(params.base_rate_per_us > 0.0) {
                bail!("fault plan needs a positive base rate");
            }
            let names: Vec<String> =
                topo.services.iter().map(|s| s.name.clone()).collect();
            let replicas: Vec<u32> = topo.services.iter().map(|s| s.replicas).collect();
            Some(f.plan(&names, &replicas, params.seed, fault_horizon_us(params))?)
        }
        _ => None,
    };
    let plan = plan.as_ref();
    match sched {
        SchedKind::Heap => {
            run_obs_core::<HeapQueue<EvKind>>(topo, shape, params, ctrl, obs, plan)
        }
        SchedKind::Calendar => {
            run_obs_core::<CalendarQueue<EvKind>>(topo, shape, params, ctrl, obs, plan)
        }
    }
}

fn run_obs_core<S: Scheduler<EvKind>>(
    topo: &ResolvedTopology,
    shape: &TrafficShape,
    params: &RunParams,
    ctrl: Option<SloCfg>,
    obs: &ObsCfg,
    plan: Option<&FaultPlan>,
) -> Result<ClusterResult> {
    if params.requests == 0 {
        bail!("cluster run with 0 requests");
    }
    let gen = ArrivalGen::new(
        shape.clone(),
        params.base_rate_per_us,
        mix64(params.seed ^ 0xA441_1A7E),
    )?;
    let adaptive = ctrl.is_some();
    let mut ctrl_cfg =
        ctrl.unwrap_or_else(|| SloCfg::new(params.slo_us, mix64(params.seed ^ 0xC1A5_7E55)));
    ctrl_cfg.slo_us = params.slo_us; // single source of truth for the SLO
    let n = topo.services.len();
    let live_replicas: u32 = topo.services.iter().map(|s| s.replicas).sum();
    let meta_now: u64 = topo
        .services
        .iter()
        .map(|s| s.candidates[0].metadata_bytes * s.replicas as u64)
        .sum();
    let mut sim = Sim {
        svc: topo
            .services
            .iter()
            .map(|s| {
                Svc::fresh(s.replicas, 0, s.candidates[0].model(s.cv), s.cv, s.children.clone())
            })
            .collect(),
        names: topo.services.iter().map(|s| s.name.clone()).collect(),
        cands: topo.services.iter().map(|s| s.candidates.clone()).collect(),
        indegrees: topo.services.iter().map(|s| s.indegree).collect(),
        roots: topo.roots(),
        sched: S::with_capacity(1024),
        seq: 0,
        rng: Rng::new(mix64(params.seed ^ 0x5E41_71CE)),
        gen,
        slab: Slab::new(n),
        digest: Digest::with_capacity(params.requests as usize),
        met: 0,
        arrived: 0,
        completed: 0,
        events: 0,
        requests: params.requests,
        slo_us: params.slo_us,
        ctrl: SloController::new(ctrl_cfg),
        adaptive,
        actions: Vec::new(),
        meta_now,
        live_replicas,
        last_change_us: 0.0,
        replica_us: 0.0,
        meta_byte_us: 0.0,
        last_event_us: 0.0,
        policies: plan.map(|p| p.policies.clone()).unwrap_or_default(),
        faulty: plan.map(|p| !p.is_empty()).unwrap_or(false),
        fstats: FaultStats::default(),
        tenancy: None,
        peak_pending: 0,
        obs: obs.enabled.then(|| Recorder::new(obs.clone(), n)),
    };
    // Pre-materialized fault events first, in plan (time) order, so
    // their sequence numbers — and thus all tie-breaks — are a pure
    // function of the spec. A faults-off run schedules nothing here and
    // stays byte-identical to the pre-fault build.
    if let Some(p) = plan {
        for &(ft, fe) in &p.events {
            let kind = match fe {
                FaultEv::Down { svc, rep } => EvKind::ReplicaDown { svc, rep },
                FaultEv::Up { svc, rep } => EvKind::ReplicaUp { svc, rep },
                FaultEv::GrayStart { svc, rep, factor } => {
                    EvKind::GrayStart { svc, rep, factor }
                }
                FaultEv::GrayEnd { svc, rep } => EvKind::GrayEnd { svc, rep },
            };
            sim.schedule(ft, kind);
        }
    }
    let t0 = sim.gen.next_arrival();
    sim.schedule(t0, EvKind::Arrival { tenant: 0 });
    // Stop at the last completion: leftover pre-materialized fault
    // events beyond it would otherwise inflate `events`/`duration_us`
    // (and on a faults-off run the final completion already empties the
    // queue, so the break changes nothing).
    while sim.step() {
        if sim.completed == sim.requests {
            break;
        }
    }
    debug_assert_eq!(sim.completed, params.requests);
    // Close the capacity/metadata integrals at the last event.
    let end = sim.last_event_us;
    sim.account(end);
    let obs_data = sim.obs.take().map(|rec| rec.into_data(&sim.names));
    let mut digest = sim.digest;
    Ok(ClusterResult {
        label: String::new(),
        traffic: shape.label(),
        requests: sim.completed,
        events: sim.events,
        p50_us: digest.percentile(50.0),
        p95_us: digest.percentile(95.0),
        p99_us: digest.percentile(99.0),
        mean_us: digest.mean(),
        max_us: digest.max(),
        slo_us: params.slo_us,
        compliance: sim.met as f64 / sim.completed.max(1) as f64,
        windows: sim.ctrl.windows,
        violated_windows: sim.ctrl.violated,
        actions: sim.actions,
        final_replicas: sim.svc.iter().map(Svc::active_replicas).collect(),
        final_configs: sim
            .svc
            .iter()
            .enumerate()
            .map(|(i, s)| sim.cands[i][s.current].label.clone())
            .collect(),
        replica_us: sim.replica_us,
        meta_byte_us: sim.meta_byte_us,
        final_metadata_bytes: sim.meta_now,
        duration_us: sim.last_event_us,
        peak_heap: sim.peak_pending as u64,
        fault_stats: sim.fstats,
        tenants: Vec::new(),
        obs: obs_data,
    })
}

/// Run a multi-tenant scenario to completion (DESIGN.md §10): every
/// tenant offers its own open-loop arrival stream over its dep-closed
/// sub-DAG, all streams share the same replica pool, and the way
/// partition drives a deterministic interference dilation. With
/// `tp.adaptive`, per-tenant SLO burn arbitrates the repartition /
/// upgrade / add-replica levers under a shared action budget. Equal
/// inputs produce bit-equal results on every run.
///
/// Aggregate semantics: the result's `compliance` is the fraction of
/// requests meeting *their own tenant's* SLO (tenants may carry
/// distinct targets), while `slo_us` records the scenario default —
/// per-tenant compliance against a single target lives in
/// [`ClusterResult::tenants`].
pub fn run_tenants(
    topo: &ResolvedTopology,
    tenants: &[TenantRun],
    params: &RunParams,
    tp: &TenancyParams,
) -> Result<ClusterResult> {
    run_tenants_obs(topo, tenants, params, tp, &ObsCfg::off())
}

/// [`run_tenants`] with an observability configuration (DESIGN.md §11);
/// `obs.enabled = false` is exactly [`run_tenants`].
pub fn run_tenants_obs(
    topo: &ResolvedTopology,
    tenants: &[TenantRun],
    params: &RunParams,
    tp: &TenancyParams,
    obs: &ObsCfg,
) -> Result<ClusterResult> {
    run_tenants_obs_sched(topo, tenants, params, tp, obs, SchedKind::default())
}

/// [`run_tenants_obs`] on an explicit scheduler backend (DESIGN.md §13).
pub fn run_tenants_obs_sched(
    topo: &ResolvedTopology,
    tenants: &[TenantRun],
    params: &RunParams,
    tp: &TenancyParams,
    obs: &ObsCfg,
    sched: SchedKind,
) -> Result<ClusterResult> {
    match sched {
        SchedKind::Heap => {
            run_tenants_core::<HeapQueue<EvKind>>(topo, tenants, params, tp, obs)
        }
        SchedKind::Calendar => {
            run_tenants_core::<CalendarQueue<EvKind>>(topo, tenants, params, tp, obs)
        }
    }
}

fn run_tenants_core<S: Scheduler<EvKind>>(
    topo: &ResolvedTopology,
    tenants: &[TenantRun],
    params: &RunParams,
    tp: &TenancyParams,
    obs: &ObsCfg,
) -> Result<ClusterResult> {
    if tenants.is_empty() {
        bail!("multi-tenant run with no tenants");
    }
    if tenants.len() > u8::MAX as usize {
        // Tenant ids travel as u8 (event payloads, slab tags, way
        // partition keys); wrapping would silently merge tenants.
        bail!("multi-tenant run with {} tenants (max {})", tenants.len(), u8::MAX);
    }
    if tp.total_ways == 0 {
        bail!("multi-tenant run with 0 ways to partition");
    }
    let n = topo.services.len();
    let nt = tenants.len();
    let mut partition = WayPartition::new(tp.total_ways);
    let mut states = Vec::with_capacity(nt);
    for (ti, t) in tenants.iter().enumerate() {
        if t.requests == 0 {
            bail!("tenant '{}' offers 0 requests", t.name);
        }
        partition
            .assign(ti as u8, t.ways)
            .map_err(|e| anyhow::anyhow!("tenant '{}': way partition {e}", t.name))?;
        let sub = topo
            .sub_dag(&t.services)
            .map_err(|e| anyhow::anyhow!("tenant '{}': {e}", t.name))?;
        let gen = ArrivalGen::new(
            t.shape.clone(),
            params.base_rate_per_us,
            mix64(t.arrival_seed ^ 0xA441_1A7E),
        )?;
        states.push(TenantState {
            name: t.name.clone(),
            gen,
            requests: t.requests,
            arrived: 0,
            completed: 0,
            met: 0,
            slo_us: if t.slo_us > 0.0 { t.slo_us } else { params.slo_us },
            demand_ways: t.demand_ways,
            nsvc: sub.nsvc,
            member: sub.member,
            roots: sub.roots,
            indegrees: sub.indegrees,
            children: sub.children,
            digest: Digest::with_capacity(t.requests as usize),
            traffic: t.shape.label(),
        });
    }
    let total_requests: u64 = tenants.iter().map(|t| t.requests).sum();
    let slos: Vec<f64> = states.iter().map(|s| s.slo_us).collect();
    let ctrl = TenantController::new(tp.ctrl.clone(), slos, tp.adaptive);
    let live_replicas: u32 = topo.services.iter().map(|s| s.replicas).sum();
    let meta_now: u64 = topo
        .services
        .iter()
        .map(|s| s.candidates[0].metadata_bytes * s.replicas as u64)
        .sum();
    // `Sim.gen` only drives the single-tenant path; tenant arrivals come
    // from the per-tenant streams, so this placeholder never draws.
    let idle_gen =
        ArrivalGen::new(tenants[0].shape.clone(), params.base_rate_per_us, 0)?;
    let mut sim = Sim {
        svc: topo
            .services
            .iter()
            .map(|s| {
                Svc::fresh(s.replicas, nt, s.candidates[0].model(s.cv), s.cv, s.children.clone())
            })
            .collect(),
        names: topo.services.iter().map(|s| s.name.clone()).collect(),
        cands: topo.services.iter().map(|s| s.candidates.clone()).collect(),
        indegrees: topo.services.iter().map(|s| s.indegree).collect(),
        roots: topo.roots(),
        sched: S::with_capacity(1024),
        seq: 0,
        rng: Rng::new(mix64(params.seed ^ 0x5E41_71CE)),
        gen: idle_gen,
        slab: Slab::new(n),
        digest: Digest::with_capacity(total_requests as usize),
        met: 0,
        arrived: 0,
        completed: 0,
        events: 0,
        requests: total_requests,
        slo_us: params.slo_us,
        // Inert on the tenant path (finish_tenant never feeds it); the
        // per-tenant controller owns all burn accounting.
        ctrl: SloController::new(SloCfg::new(params.slo_us, mix64(params.seed ^ 0xC1A5_7E55))),
        adaptive: false,
        actions: Vec::new(),
        meta_now,
        live_replicas,
        last_change_us: 0.0,
        replica_us: 0.0,
        meta_byte_us: 0.0,
        last_event_us: 0.0,
        policies: Vec::new(),
        faulty: false,
        fstats: FaultStats::default(),
        tenancy: Some(Tenancy {
            tenants: states,
            partition,
            total_ways: tp.total_ways,
            alpha: tp.alpha,
            ctrl,
            adaptive: tp.adaptive,
        }),
        peak_pending: 0,
        obs: obs.enabled.then(|| Recorder::new(obs.clone(), n)),
    };
    // First arrival per tenant, declaration order (the scheduler's
    // sequence number breaks simultaneous arrivals deterministically).
    for ti in 0..nt {
        let t0 = sim.tenancy.as_mut().unwrap().tenants[ti].gen.next_arrival();
        sim.schedule(t0, EvKind::Arrival { tenant: ti as u8 });
    }
    while sim.step() {}
    debug_assert_eq!(sim.completed, total_requests);
    let end = sim.last_event_us;
    sim.account(end);
    let obs_data = sim.obs.take().map(|rec| rec.into_data(&sim.names));
    let mut tn = sim.tenancy.take().expect("tenancy state lost");
    let tenant_stats: Vec<TenantStat> = tn
        .tenants
        .iter_mut()
        .enumerate()
        .map(|(i, ts)| TenantStat {
            name: ts.name.clone(),
            traffic: ts.traffic.clone(),
            requests: ts.completed,
            p50_us: ts.digest.percentile(50.0),
            p95_us: ts.digest.percentile(95.0),
            p99_us: ts.digest.percentile(99.0),
            mean_us: ts.digest.mean(),
            slo_us: ts.slo_us,
            compliance: ts.met as f64 / ts.completed.max(1) as f64,
            windows: tn.ctrl.windows[i],
            violated_windows: tn.ctrl.violated[i],
            final_ways: tn.partition.share(i as u8),
        })
        .collect();
    let mut digest = sim.digest;
    Ok(ClusterResult {
        label: String::new(),
        traffic: tenant_stats
            .iter()
            .map(|t| t.traffic.as_str())
            .collect::<Vec<_>>()
            .join("+"),
        requests: sim.completed,
        events: sim.events,
        p50_us: digest.percentile(50.0),
        p95_us: digest.percentile(95.0),
        p99_us: digest.percentile(99.0),
        mean_us: digest.mean(),
        max_us: digest.max(),
        slo_us: params.slo_us,
        compliance: sim.met as f64 / sim.completed.max(1) as f64,
        windows: tn.ctrl.windows.iter().sum(),
        violated_windows: tn.ctrl.violated.iter().sum(),
        actions: sim.actions,
        final_replicas: sim.svc.iter().map(Svc::active_replicas).collect(),
        final_configs: sim
            .svc
            .iter()
            .enumerate()
            .map(|(i, s)| sim.cands[i][s.current].label.clone())
            .collect(),
        replica_us: sim.replica_us,
        meta_byte_us: sim.meta_byte_us,
        final_metadata_bytes: sim.meta_now,
        duration_us: sim.last_event_us,
        peak_heap: sim.peak_pending as u64,
        fault_stats: sim.fstats,
        tenants: tenant_stats,
        obs: obs_data,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::slo::Policy;
    use crate::cluster::topology::ResolvedService;

    fn chain(ipcs: &[f64]) -> ResolvedTopology {
        let named: Vec<(String, f64)> =
            ipcs.iter().enumerate().map(|(i, &x)| (format!("s{i}"), x)).collect();
        ResolvedTopology::chain_from_ipcs(&named, 25_000.0, 0.35, 2.5)
    }

    fn params(topo: &ResolvedTopology, util: f64, requests: u64, slo_us: f64) -> RunParams {
        RunParams {
            requests,
            seed: 17,
            slo_us,
            base_rate_per_us: topo.bottleneck_rate() * util,
        }
    }

    #[test]
    fn completes_every_request_and_orders_percentiles() {
        let topo = chain(&[2.0, 1.5, 2.5]);
        let p = params(&topo, 0.6, 20_000, 1e9);
        let r = run(&topo, &TrafficShape::Poisson { util: 1.0 }, &p, None).unwrap();
        assert_eq!(r.requests, 20_000);
        assert!(r.events >= 20_000 * 4, "arrival + 3 completions per request");
        assert!(r.p50_us <= r.p95_us && r.p95_us <= r.p99_us && r.p99_us <= r.max_us);
        assert!(r.p50_us >= topo.zero_load_us() * 0.5);
        assert!(r.p99_us > topo.zero_load_us(), "no queueing tail at 60% load");
        assert_eq!(r.compliance, 1.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let topo = chain(&[2.0, 1.8]);
        let p = params(&topo, 0.7, 15_000, 50.0);
        let shape = TrafficShape::Burst { util: 1.0, mult: 2.0, period_us: 5_000.0, duty: 0.3 };
        let a = run(&topo, &shape, &p, None).unwrap();
        let b = run(&topo, &shape, &p, None).unwrap();
        assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!(a.compliance.to_bits(), b.compliance.to_bits());
        // Policy-driven runs are bit-equal too (scale-downs included).
        let cfg = || {
            SloCfg::new(50.0, 7)
                .with_policy(Policy::Hysteresis { idle_windows: 2, headroom: 0.8 })
        };
        let c = run(&topo, &shape, &p, Some(cfg())).unwrap();
        let d = run(&topo, &shape, &p, Some(cfg())).unwrap();
        assert_eq!(c.p99_us.to_bits(), d.p99_us.to_bits());
        assert_eq!(c.actions, d.actions);
        assert_eq!(c.replica_us.to_bits(), d.replica_us.to_bits());
        assert_eq!(c.meta_byte_us.to_bits(), d.meta_byte_us.to_bits());
    }

    #[test]
    fn schedulers_agree_bit_for_bit() {
        // The §13 contract: the calendar queue and the heap oracle pop
        // the identical (time, seq) order, so every simulation output —
        // tails, event counts, control actions, integrals, peak depth —
        // is bit-equal across backends, static and policy-driven alike.
        let topo = chain(&[2.0, 1.8]);
        let p = params(&topo, 0.7, 15_000, 50.0);
        let shape = TrafficShape::Burst { util: 1.0, mult: 2.0, period_us: 5_000.0, duty: 0.3 };
        let heap = run_sched(&topo, &shape, &p, None, SchedKind::Heap).unwrap();
        let cal = run_sched(&topo, &shape, &p, None, SchedKind::Calendar).unwrap();
        assert_eq!(heap.p99_us.to_bits(), cal.p99_us.to_bits());
        assert_eq!(heap.mean_us.to_bits(), cal.mean_us.to_bits());
        assert_eq!(heap.events, cal.events);
        assert_eq!(heap.compliance.to_bits(), cal.compliance.to_bits());
        assert_eq!(heap.peak_heap, cal.peak_heap, "pending sets diverged");
        let cfg = || {
            SloCfg::new(50.0, 7)
                .with_policy(Policy::Hysteresis { idle_windows: 2, headroom: 0.8 })
        };
        let hp = run_sched(&topo, &shape, &p, Some(cfg()), SchedKind::Heap).unwrap();
        let cp = run_sched(&topo, &shape, &p, Some(cfg()), SchedKind::Calendar).unwrap();
        assert_eq!(hp.p99_us.to_bits(), cp.p99_us.to_bits());
        assert_eq!(hp.actions, cp.actions, "control traces diverged");
        assert_eq!(hp.replica_us.to_bits(), cp.replica_us.to_bits());
        assert_eq!(hp.meta_byte_us.to_bits(), cp.meta_byte_us.to_bits());
        assert_eq!(hp.final_replicas, cp.final_replicas);
        // And the default entry point is the calendar queue.
        let dflt = run(&topo, &shape, &p, Some(cfg())).unwrap();
        assert_eq!(dflt.p99_us.to_bits(), cp.p99_us.to_bits());
    }

    #[test]
    fn obs_never_perturbs_the_baseline() {
        // The §11 contract from both sides: obs-off is the baseline
        // (trivially — same code path), and obs-ON must still be
        // bit-equal on every simulation output, because the hooks read
        // state without scheduling events or drawing randomness.
        let topo = chain(&[2.0, 1.8]);
        let p = params(&topo, 0.7, 15_000, 50.0);
        let shape = TrafficShape::Burst { util: 1.0, mult: 2.0, period_us: 5_000.0, duty: 0.3 };
        let cfg = || {
            SloCfg::new(50.0, 7)
                .with_policy(Policy::Hysteresis { idle_windows: 2, headroom: 0.8 })
        };
        let base = run(&topo, &shape, &p, Some(cfg())).unwrap();
        let obs = run_obs(&topo, &shape, &p, Some(cfg()), &ObsCfg::on(4)).unwrap();
        assert_eq!(base.p99_us.to_bits(), obs.p99_us.to_bits());
        assert_eq!(base.events, obs.events);
        assert_eq!(base.actions, obs.actions);
        assert_eq!(base.replica_us.to_bits(), obs.replica_us.to_bits());
        assert_eq!(base.peak_heap, obs.peak_heap);
        assert!(base.obs.is_none());
        let data = obs.obs.expect("obs payload");
        assert!(data.sampled_requests > 0, "1/16 of 15k requests must sample");
        assert!(!data.trace_spans.is_empty() && !data.span_stats.is_empty());
        assert_eq!(data.snapshots.len() as u32, obs.windows, "one snapshot per window");
        // Spans decompose: queue + fan-in are non-negative, end ≥ start.
        for sp in &data.trace_spans {
            assert!(sp.queue_us >= 0.0 && sp.fanin_us >= 0.0 && sp.end_us >= sp.start_us);
        }
        // And the payload itself is bit-stable across reruns.
        let again = run_obs(&topo, &shape, &p, Some(cfg()), &ObsCfg::on(4)).unwrap();
        let d2 = again.obs.unwrap();
        assert_eq!(data.sampled_requests, d2.sampled_requests);
        assert_eq!(data.trace_spans.len(), d2.trace_spans.len());
        let ids: Vec<u64> = data.trace_spans.iter().map(|s| s.req).collect();
        let ids2: Vec<u64> = d2.trace_spans.iter().map(|s| s.req).collect();
        assert_eq!(ids, ids2);
    }

    #[test]
    fn static_run_tracks_capacity_integrals() {
        let topo = chain(&[2.0, 1.8]);
        let p = params(&topo, 0.6, 10_000, 1e9);
        let r = run(&topo, &TrafficShape::Poisson { util: 1.0 }, &p, None).unwrap();
        assert!(r.duration_us > 0.0);
        // 2 static replicas for the whole run: ∫ = 2 × duration exactly.
        assert!((r.replica_us - 2.0 * r.duration_us).abs() < 1e-6 * r.duration_us);
        // chain_from_ipcs carries no metadata.
        assert_eq!(r.final_metadata_bytes, 0);
        assert_eq!(r.meta_byte_us, 0.0);
    }

    #[test]
    fn faster_services_tighten_the_tail() {
        // Fixed absolute arrival rate, 10% faster services → lower P99
        // (the paper's §XI compounding claim, now through the DAG engine).
        let slow = chain(&[1.8, 1.62, 1.98]);
        let fast = chain(&[1.98, 1.782, 2.178]);
        let lambda = slow.bottleneck_rate() * 0.7;
        let p = |_topo: &ResolvedTopology| RunParams {
            requests: 30_000,
            seed: 3,
            slo_us: 1e9,
            base_rate_per_us: lambda,
        };
        let rs = run(&slow, &TrafficShape::Poisson { util: 1.0 }, &p(&slow), None).unwrap();
        let rf = run(&fast, &TrafficShape::Poisson { util: 1.0 }, &p(&fast), None).unwrap();
        assert!(rf.p95_us < rs.p95_us, "p95 {} !< {}", rf.p95_us, rs.p95_us);
        assert!(rf.p99_us < rs.p99_us, "p99 {} !< {}", rf.p99_us, rs.p99_us);
    }

    #[test]
    fn fan_out_latency_is_governed_by_slowest_branch() {
        // root → {fast branch, slow branch} → join: zero-load latency
        // must track the slow branch, and the engine must wait for both.
        let svc = |name: &str, mean: f64, children: Vec<u32>, indeg: u32| ResolvedService {
            name: name.into(),
            replicas: 1,
            cv: 0.0,
            candidates: vec![Candidate {
                label: "static".into(),
                mean_us: mean,
                metadata_bytes: 0,
                table: None,
            }],
            children,
            indegree: indeg,
        };
        let topo = ResolvedTopology {
            services: vec![
                svc("root", 1.0, vec![1, 2], 0),
                svc("fast", 2.0, vec![3], 1),
                svc("slow", 9.0, vec![3], 1),
                svc("join", 1.0, vec![], 2),
            ],
        };
        let p = params(&topo, 0.2, 5_000, 1e9);
        let r = run(&topo, &TrafficShape::Poisson { util: 1.0 }, &p, None).unwrap();
        // cv=0 ⇒ at light load latency ≈ 1 + max(2, 9) + 1 = 11 µs.
        assert!(r.p50_us >= 11.0 - 1e-6, "p50 {} ignores the slow branch", r.p50_us);
        assert!(r.p50_us < 13.0, "p50 {} queues too much at 20% load", r.p50_us);
    }

    #[test]
    fn replicas_raise_throughput_capacity() {
        // Same offered load: 1 replica at util 0.9 queues hard; 2 replicas
        // (half the per-replica utilization) cut the tail sharply.
        let one = chain(&[2.0]);
        let mut two = one.clone();
        two.services[0].replicas = 2;
        let lambda = one.bottleneck_rate() * 0.9;
        let p = RunParams { requests: 30_000, seed: 5, slo_us: 1e9, base_rate_per_us: lambda };
        let r1 = run(&one, &TrafficShape::Poisson { util: 1.0 }, &p, None).unwrap();
        let r2 = run(&two, &TrafficShape::Poisson { util: 1.0 }, &p, None).unwrap();
        assert!(
            r2.p99_us < r1.p99_us * 0.8,
            "2 replicas {} !<< 1 replica {}",
            r2.p99_us,
            r1.p99_us
        );
    }

    #[test]
    fn burst_overload_burns_windows() {
        let topo = chain(&[2.0, 1.8]);
        // Peak 1.8× capacity for 30% of each period.
        let shape = TrafficShape::Burst { util: 0.6, mult: 3.0, period_us: 20_000.0, duty: 0.3 };
        let slo = topo.zero_load_us() * 4.0;
        let p = params(&topo, 1.0, 60_000, slo);
        let r = run(&topo, &shape, &p, None).unwrap();
        assert!(r.windows > 0);
        assert!(r.violated_windows > 0, "overload bursts never burned the SLO");
        assert!(r.compliance < 1.0);
        assert!(r.actions.is_empty(), "static run must not act");
    }

    #[test]
    fn control_loop_reduces_burn_under_bursts() {
        // Candidates: slow nl-like config first, then a 25% faster one.
        let mk = |label: &str, ipc: f64| Candidate {
            label: label.into(),
            mean_us: 25_000.0 / ipc / 2500.0,
            metadata_bytes: 0,
            table: None,
        };
        let topo = ResolvedTopology {
            services: vec![ResolvedService {
                name: "frontend".into(),
                replicas: 1,
                cv: 0.35,
                candidates: vec![mk("nl", 1.6), mk("ceip", 2.0)],
                children: vec![],
                indegree: 0,
            }],
        };
        let shape = TrafficShape::Burst { util: 0.55, mult: 2.4, period_us: 30_000.0, duty: 0.35 };
        let slo = topo.zero_load_us() * 5.0;
        let p = RunParams {
            requests: 80_000,
            seed: 11,
            slo_us: slo,
            base_rate_per_us: topo.bottleneck_rate(),
        };
        let stat = run(&topo, &shape, &p, None).unwrap();
        // Same window size as the static run's tracker, so burn counts
        // are directly comparable.
        let adap = run(&topo, &shape, &p, Some(SloCfg::new(slo, 99))).unwrap();
        assert_eq!(adap.windows, stat.windows, "trackers diverged");
        assert!(!adap.actions.is_empty(), "control loop never acted");
        assert!(
            adap.violated_windows < stat.violated_windows,
            "burn not reduced: adaptive {}/{} vs static {}/{}",
            adap.violated_windows,
            adap.windows,
            stat.violated_windows,
            stat.windows
        );
        assert!(adap.p99_us < stat.p99_us, "p99 not reduced");
        // The loop actually reconfigured: faster config or more replicas.
        assert!(
            adap.final_configs[0] == "ceip" || adap.final_replicas[0] > 1,
            "final state unchanged: {:?} {:?}",
            adap.final_configs,
            adap.final_replicas
        );
    }

    #[test]
    fn hysteresis_policy_releases_replicas_under_light_load() {
        // Overprovisioned single service (4 replicas) at 35% offered
        // load: the hysteresis policy retires replicas, cutting
        // replica-seconds versus the static run, without losing a single
        // request or wrecking compliance.
        let mut topo = chain(&[2.0]);
        topo.services[0].replicas = 4;
        let slo = topo.zero_load_us() * 6.0;
        let shape = TrafficShape::Poisson { util: 1.0 };
        let p = RunParams {
            requests: 40_000,
            seed: 13,
            slo_us: slo,
            base_rate_per_us: topo.bottleneck_rate() * 0.35,
        };
        let stat = run(&topo, &shape, &p, None).unwrap();
        let cfg = SloCfg::new(slo, 21)
            .with_policy(Policy::Hysteresis { idle_windows: 3, headroom: 0.7 });
        let adap = run(&topo, &shape, &p, Some(cfg)).unwrap();
        assert_eq!(adap.requests, 40_000, "draining lost requests");
        assert!(!adap.actions.is_empty(), "sustained headroom never released capacity");
        assert!(adap.final_replicas[0] < 4, "still at {} replicas", adap.final_replicas[0]);
        assert!(
            adap.replica_us < stat.replica_us,
            "no replica-seconds saved: {} !< {}",
            adap.replica_us,
            stat.replica_us
        );
        assert!(adap.compliance > 0.9, "scale-down wrecked the SLO: {}", adap.compliance);
    }

    #[test]
    fn cost_aware_policy_keeps_metadata_under_budget() {
        // nl is cheap (1 KB), ceip fast but heavy (8 KB). Budget 8.5 KB
        // admits exactly one of {upgrade to ceip, a few nl replicas} at a
        // time — the run must never exceed it, which the time integral
        // certifies (mean footprint ≤ budget would fail if any interval
        // overshot while the rest sat at the cap).
        let mk = |label: &str, ipc: f64, meta: u64| Candidate {
            label: label.into(),
            mean_us: 25_000.0 / ipc / 2500.0,
            metadata_bytes: meta,
            table: None,
        };
        let topo = ResolvedTopology {
            services: vec![ResolvedService {
                name: "frontend".into(),
                replicas: 1,
                cv: 0.35,
                candidates: vec![mk("nl", 1.6, 1_000), mk("ceip", 2.0, 8_000)],
                children: vec![],
                indegree: 0,
            }],
        };
        let shape = TrafficShape::Burst { util: 0.55, mult: 2.4, period_us: 30_000.0, duty: 0.35 };
        let slo = topo.zero_load_us() * 5.0;
        let p = RunParams {
            requests: 80_000,
            seed: 11,
            slo_us: slo,
            base_rate_per_us: topo.bottleneck_rate(),
        };
        let budget = 8_500u64;
        let cfg = SloCfg::new(slo, 99)
            .with_policy(Policy::CostAware { budget_bytes: budget, idle_windows: 4 });
        let r = run(&topo, &shape, &p, Some(cfg)).unwrap();
        assert!(!r.actions.is_empty(), "cost-aware never acted under burst pressure");
        assert!(
            r.final_metadata_bytes <= budget,
            "budget busted: {} > {budget}",
            r.final_metadata_bytes
        );
        assert!(
            r.meta_byte_us <= budget as f64 * r.duration_us * (1.0 + 1e-9),
            "metadata footprint exceeded the budget at some point"
        );
    }

    #[test]
    fn zero_requests_and_zero_rate_are_errors_not_hangs() {
        // Regression companions to ArrivalGen::new: unrunnable scenario
        // parameters must fail fast in release builds too.
        let topo = chain(&[2.0]);
        let shape = TrafficShape::Poisson { util: 1.0 };
        let bad_requests =
            RunParams { requests: 0, seed: 1, slo_us: 1e9, base_rate_per_us: 0.1 };
        assert!(run(&topo, &shape, &bad_requests, None).is_err());
        let bad_rate =
            RunParams { requests: 100, seed: 1, slo_us: 1e9, base_rate_per_us: 0.0 };
        assert!(run(&topo, &shape, &bad_rate, None).is_err());
    }

    fn shared_service(replicas: u32, mean_us: f64) -> ResolvedTopology {
        ResolvedTopology {
            services: vec![ResolvedService {
                name: "gw".into(),
                replicas,
                cv: 0.35,
                candidates: vec![Candidate {
                    label: "static".into(),
                    mean_us,
                    metadata_bytes: 0,
                    table: None,
                }],
                children: vec![],
                indegree: 0,
            }],
        }
    }

    fn tenant(name: &str, util: f64, seed: u64, slo: f64, ways: u32, demand: u32) -> TenantRun {
        TenantRun {
            name: name.into(),
            shape: TrafficShape::Poisson { util },
            requests: 15_000,
            arrival_seed: seed,
            slo_us: slo,
            ways,
            demand_ways: demand,
            services: vec![0],
        }
    }

    fn tp(alpha: f64, adaptive: bool) -> TenancyParams {
        TenancyParams { total_ways: 8, alpha, adaptive, ctrl: TenantCtrlCfg::default() }
    }

    #[test]
    fn coloc_is_deterministic_and_conserves_per_tenant_requests() {
        let topo = shared_service(2, 10.0);
        let tenants = vec![tenant("a", 0.45, 1, 1e9, 4, 4), tenant("b", 0.4, 2, 1e9, 4, 4)];
        let p = RunParams { requests: 30_000, seed: 9, slo_us: 1e9, base_rate_per_us: 0.2 };
        let r = run_tenants(&topo, &tenants, &p, &tp(0.8, false)).unwrap();
        assert_eq!(r.requests, 30_000, "a tenant lost requests");
        assert_eq!(r.tenants.len(), 2);
        for ts in &r.tenants {
            assert_eq!(ts.requests, 15_000, "{} lost requests", ts.name);
            assert!(ts.p50_us <= ts.p95_us && ts.p95_us <= ts.p99_us, "{}", ts.name);
            assert_eq!(ts.final_ways, 4, "static run moved ways");
        }
        assert!(r.actions.is_empty(), "static co-location must not act");
        let again = run_tenants(&topo, &tenants, &p, &tp(0.8, false)).unwrap();
        assert_eq!(r.p99_us.to_bits(), again.p99_us.to_bits());
        assert_eq!(r.events, again.events);
        for (x, y) in r.tenants.iter().zip(&again.tenants) {
            assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}", x.name);
        }
    }

    #[test]
    fn schedulers_agree_on_tenant_runs() {
        // Simultaneous per-tenant arrivals at t0 are the hardest tie-break
        // case: both backends must serve them in schedule (seq) order.
        let topo = shared_service(2, 10.0);
        let tenants = vec![tenant("a", 0.45, 1, 1e9, 4, 6), tenant("b", 0.4, 2, 1e9, 4, 6)];
        let p = RunParams { requests: 30_000, seed: 9, slo_us: 1e9, base_rate_per_us: 0.2 };
        let obs = ObsCfg::off();
        let h =
            run_tenants_obs_sched(&topo, &tenants, &p, &tp(0.8, true), &obs, SchedKind::Heap)
                .unwrap();
        let c = run_tenants_obs_sched(
            &topo,
            &tenants,
            &p,
            &tp(0.8, true),
            &obs,
            SchedKind::Calendar,
        )
        .unwrap();
        assert_eq!(h.p99_us.to_bits(), c.p99_us.to_bits());
        assert_eq!(h.events, c.events);
        assert_eq!(h.actions, c.actions);
        assert_eq!(h.peak_heap, c.peak_heap);
        for (x, y) in h.tenants.iter().zip(&c.tenants) {
            assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}", x.name);
            assert_eq!(x.final_ways, y.final_ways, "{}", x.name);
        }
    }

    #[test]
    fn way_overflow_dilates_co_runner_tails() {
        let topo = shared_service(2, 10.0);
        // Both tenants want 6 ways but hold 2: overflow 4 each way.
        let starved = vec![tenant("a", 0.35, 1, 1e9, 2, 6), tenant("b", 0.35, 2, 1e9, 2, 6)];
        let p = RunParams { requests: 30_000, seed: 5, slo_us: 1e9, base_rate_per_us: 0.2 };
        let calm = run_tenants(&topo, &starved, &p, &tp(0.0, false)).unwrap();
        let noisy = run_tenants(&topo, &starved, &p, &tp(1.0, false)).unwrap();
        assert!(
            noisy.p99_us > calm.p99_us,
            "overflowing co-runners did not widen the tail: {} !> {}",
            noisy.p99_us,
            calm.p99_us
        );
        assert!(noisy.mean_us > calm.mean_us, "dilation left the mean untouched");
        // Working sets that fit their shares feel no interference at
        // all: α is inert, bit for bit.
        let fitting = vec![tenant("a", 0.35, 1, 1e9, 4, 4), tenant("b", 0.35, 2, 1e9, 4, 4)];
        let off = run_tenants(&topo, &fitting, &p, &tp(0.0, false)).unwrap();
        let on = run_tenants(&topo, &fitting, &p, &tp(1.0, false)).unwrap();
        assert_eq!(off.p99_us.to_bits(), on.p99_us.to_bits(), "fitting tenants dilated");
        assert_eq!(off.events, on.events);
    }

    #[test]
    fn adaptive_loop_pulls_the_repartition_lever_first() {
        let topo = shared_service(3, 10.0);
        // "hot" is way-starved under a tight SLO; "cold" holds slack
        // ways (share 6, demand 1) it can donate for free.
        let tenants =
            vec![tenant("hot", 0.5, 1, 22.0, 2, 6), tenant("cold", 0.3, 2, 1e9, 6, 1)];
        let p = RunParams { requests: 30_000, seed: 3, slo_us: 1e9, base_rate_per_us: 0.3 };
        let mut cfg = tp(1.0, true);
        cfg.ctrl.window = 500;
        let r = run_tenants(&topo, &tenants, &p, &cfg).unwrap();
        let hot = &r.tenants[0];
        assert!(hot.violated_windows > 0, "scenario never burned — not a stress test");
        assert!(
            r.actions.iter().any(|a| a.service == "ways"),
            "repartition lever never pulled: {:?}",
            r.actions
        );
        assert!(hot.final_ways > 2, "ways not moved to the starved tenant");
        assert_eq!(hot.final_ways + r.tenants[1].final_ways, 8, "ways leaked");
        // Bit-equal rerun, control actions included.
        let again = run_tenants(&topo, &tenants, &p, &cfg).unwrap();
        assert_eq!(r.actions, again.actions);
        assert_eq!(r.p99_us.to_bits(), again.p99_us.to_bits());
    }

    #[test]
    fn empirical_tables_shape_the_tail_and_stay_deterministic() {
        use crate::cluster::servicetime::QuantileTable;
        use crate::util::rng::Rng;
        // Two unit-mean distributions: near-constant vs heavy-tailed.
        let flat = QuantileTable::normalized(&[1.0; 64]).unwrap();
        let mut r = Rng::new(3);
        let heavy: Vec<f64> = (0..20_000).map(|_| (1.2 * r.normal()).exp()).collect();
        let heavy = QuantileTable::normalized(&heavy).unwrap();
        let topo_with = |table: Option<QuantileTable>| ResolvedTopology {
            services: vec![ResolvedService {
                name: "svc".into(),
                replicas: 1,
                cv: 0.35,
                candidates: vec![Candidate {
                    label: "emp".into(),
                    mean_us: 10.0,
                    metadata_bytes: 0,
                    table,
                }],
                children: vec![],
                indegree: 0,
            }],
        };
        let shape = TrafficShape::Poisson { util: 1.0 };
        let p = RunParams {
            requests: 30_000,
            seed: 9,
            slo_us: 1e9,
            base_rate_per_us: 0.05, // util 0.5 of the 0.1/µs capacity
        };
        let flat_r = run(&topo_with(Some(flat)), &shape, &p, None).unwrap();
        let heavy_r = run(&topo_with(Some(heavy)), &shape, &p, None).unwrap();
        // Same mean service time, very different per-request shape: the
        // heavy-tailed replay must widen the tail.
        assert!(
            heavy_r.p99_us > flat_r.p99_us * 1.3,
            "heavy tail {} !> flat tail {}",
            heavy_r.p99_us,
            flat_r.p99_us
        );
        // Deterministic rerun, bit for bit.
        let again = run(&topo_with(Some(heavy)), &shape, &p, None).unwrap();
        assert_eq!(again.p99_us.to_bits(), heavy_r.p99_us.to_bits());
        assert_eq!(again.events, heavy_r.events);
        // And distinct from the analytic model at the same mean/seed.
        let analytic = run(&topo_with(None), &shape, &p, None).unwrap();
        assert_ne!(analytic.p99_us.to_bits(), flat_r.p99_us.to_bits());
    }
}
