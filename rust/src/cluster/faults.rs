//! Deterministic fault injection for the cluster engine (DESIGN.md §14):
//! seeded fault schedules — replica crash/restart, rate-driven crashes,
//! correlated slow replicas (gray failure), and service brownouts —
//! expanded into a pre-materialized event plan, plus the client-side
//! per-edge response policies (timeouts, bounded retries with
//! deterministic backoff, hedged requests) that define real microservice
//! tails.
//!
//! ## Schedule grammar (`FaultsSpec::events`)
//!
//! - `down:SVC:REP:T:DUR` — replica `REP` of service `SVC` crashes at
//!   `T` µs and restarts at `T + DUR` µs.
//! - `downrate:SVC:PERIOD:DUR` — crashes arrive on `SVC` as a Poisson
//!   process with mean inter-crash gap `PERIOD` µs; each crash picks a
//!   replica uniformly and lasts `DUR` µs. Materialized up to the run
//!   horizon from the schedule's own RNG sub-stream.
//! - `gray:SVC:K:FACTOR:T:DUR` — gray failure: the first `K` replicas of
//!   `SVC` serve `FACTOR`× slower during `[T, T + DUR)`.
//! - `brownout:SVC:FACTOR:T:DUR` — every replica of `SVC` serves
//!   `FACTOR`× slower during the interval (a transient service-wide
//!   brownout; shorthand for `gray` over the full replica set).
//!
//! Overlapping windows compose last-write-wins at each boundary event —
//! schedules are applied exactly as written.
//!
//! ## Determinism
//!
//! Fault schedules draw from their own RNG stream
//! (`mix64(seed ^ 0xFAE1_7000)`, one sub-stream per schedule entry), so
//! the arrival and service-time streams are byte-identical with faults
//! on or off, and the expanded plan is a pure function of
//! (spec, seed, horizon) — independent of thread count and scheduler
//! backend.

use crate::util::json::Json;
use crate::util::rng::{mix64, Rng};
use anyhow::{bail, Result};

/// Seed-domain separator for the fault-schedule RNG stream: faults never
/// share draws with arrivals (`0xA441_1A7E`) or service times
/// (`0x5E41_71CE`).
pub const FAULT_SEED_SALT: u64 = 0xFAE1_7000;

/// Retry budgets above this are a spec typo, not a policy (the
/// exponential backoff ladder would dwarf any run horizon).
pub const MAX_RETRIES: u32 = 16;

/// Client-side response policy for one DAG edge — every dispatch *to*
/// the selected service, whatever the caller. All-default is a raw RPC:
/// no timeout, no retry budget, no hedging.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct EdgePolicy {
    /// Cancel an attempt that has not completed after this long and
    /// consume a retry (or fail the stage once the budget is spent).
    pub timeout_us: Option<f64>,
    /// Re-dispatch budget per stage, shared by timeouts and crash
    /// requeues. 0 = fail on the first loss.
    pub retries: u32,
    /// Base backoff before retry `n` waits `backoff_us × 2^(n-1)` µs
    /// (deterministic exponential ladder; 0 = immediate re-dispatch).
    pub backoff_us: f64,
    /// Dispatch a duplicate attempt if the first has not completed after
    /// this long; first completion wins, the loser is lazily cancelled.
    pub hedge_after_us: Option<f64>,
}

impl EdgePolicy {
    /// True when the policy changes nothing about a dispatch (no
    /// timeout, no hedge, no budget for crash requeues).
    pub fn is_noop(&self) -> bool {
        self.timeout_us.is_none() && self.hedge_after_us.is_none() && self.retries == 0
    }

    fn validate(&self, ctx: &str) -> Result<()> {
        if let Some(t) = self.timeout_us {
            if !t.is_finite() || t <= 0.0 {
                bail!("{ctx}: timeout_us must be > 0, got {t}");
            }
        }
        if let Some(h) = self.hedge_after_us {
            if !h.is_finite() || h <= 0.0 {
                bail!("{ctx}: hedge_after_us must be > 0, got {h}");
            }
            if let Some(t) = self.timeout_us {
                if h >= t {
                    bail!(
                        "{ctx}: hedge_after_us ({h}) must be < timeout_us ({t}) — \
                         a hedge launched after the timeout is already cancelled"
                    );
                }
            }
        }
        if self.retries > MAX_RETRIES {
            bail!("{ctx}: retries must be ≤ {MAX_RETRIES}, got {}", self.retries);
        }
        if !self.backoff_us.is_finite() || self.backoff_us < 0.0 {
            bail!("{ctx}: backoff_us must be ≥ 0, got {}", self.backoff_us);
        }
        Ok(())
    }

    fn to_json(&self) -> Vec<(&'static str, Json)> {
        let mut fields = Vec::new();
        if let Some(t) = self.timeout_us {
            fields.push(("timeout_us", Json::num(t)));
        }
        if self.retries > 0 {
            fields.push(("retries", Json::num(self.retries as f64)));
        }
        if self.backoff_us > 0.0 {
            fields.push(("backoff_us", Json::num(self.backoff_us)));
        }
        if let Some(h) = self.hedge_after_us {
            fields.push(("hedge_after_us", Json::num(h)));
        }
        fields
    }
}

/// One `client` entry: an [`EdgePolicy`] plus the service selector it
/// applies to (`"*"` = every service). Entries apply in order, so a
/// named entry after a `"*"` entry overrides the wildcard for that
/// service.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientPolicySpec {
    pub service: String,
    pub policy: EdgePolicy,
}

/// The `faults` section of a `ClusterSpec`: a seeded fault schedule plus
/// the client-side response policies. Default (both empty) means the
/// section never serializes and the engine takes the exact pre-fault
/// code path.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct FaultsSpec {
    /// Fault-schedule specs (grammar in the module docs).
    pub events: Vec<String>,
    /// Per-edge client policies, applied in order.
    pub client: Vec<ClientPolicySpec>,
}

/// A parsed schedule entry, validated against the topology.
#[derive(Clone, Debug, PartialEq)]
enum Schedule {
    Down { svc: u32, rep: u32, t_us: f64, dur_us: f64 },
    DownRate { svc: u32, period_us: f64, dur_us: f64 },
    Gray { svc: u32, k: u32, factor: f64, t_us: f64, dur_us: f64 },
    Brownout { svc: u32, factor: f64, t_us: f64, dur_us: f64 },
}

/// One expanded fault boundary the engine schedules as an event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultEv {
    Down { svc: u32, rep: u32 },
    Up { svc: u32, rep: u32 },
    GrayStart { svc: u32, rep: u32, factor: f64 },
    GrayEnd { svc: u32, rep: u32 },
}

/// The pre-materialized plan one engine run injects: boundary events in
/// ascending time (stable on ties: schedule order), plus the resolved
/// per-service client policies.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// `(t_us, event)`, ascending `t_us.to_bits()`.
    pub events: Vec<(f64, FaultEv)>,
    /// Client policy per service index (`None` = raw RPC).
    pub policies: Vec<Option<EdgePolicy>>,
}

impl FaultPlan {
    /// True when the plan changes nothing: no boundary events and no
    /// policy on any edge (the engine takes the pre-fault path).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.policies.iter().all(|p| p.is_none())
    }
}

fn parse_fields(spec: &str, parts: &[&str]) -> Result<Vec<f64>> {
    let mut nums = Vec::with_capacity(parts.len());
    for p in parts {
        match p.parse::<f64>() {
            Ok(v) if v.is_finite() => nums.push(v),
            _ => bail!("fault '{spec}': '{p}' is not a finite number"),
        }
    }
    Ok(nums)
}

fn as_count(spec: &str, v: f64, what: &str) -> Result<u32> {
    if v < 0.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
        bail!("fault '{spec}': {what} must be a non-negative integer, got {v}");
    }
    Ok(v as u32)
}

fn positive(spec: &str, v: f64, what: &str) -> Result<f64> {
    if v <= 0.0 {
        bail!("fault '{spec}': {what} must be > 0, got {v}");
    }
    Ok(v)
}

fn parse_schedule(spec: &str, names: &[String], replicas: &[u32]) -> Result<Schedule> {
    let parts: Vec<&str> = spec.split(':').collect();
    let kind = parts.first().copied().unwrap_or("").to_lowercase();
    let svc_of = |name: &str| -> Result<u32> {
        match names.iter().position(|n| n == name) {
            Some(i) => Ok(i as u32),
            None => bail!("fault '{spec}': unknown service '{name}'"),
        }
    };
    let arity = |want: usize, shape: &str| -> Result<()> {
        if parts.len() != want + 2 {
            bail!("fault '{spec}': {kind} takes {kind}:{shape}");
        }
        Ok(())
    };
    if parts.len() < 2 {
        bail!(
            "fault '{spec}': expected kind:svc:… \
             (try down:svc:rep:t:dur | downrate:svc:period:dur | \
             gray:svc:k:factor:t:dur | brownout:svc:factor:t:dur)"
        );
    }
    let svc = svc_of(parts[1])?;
    let nums = parse_fields(spec, &parts[2..])?;
    match kind.as_str() {
        "down" => {
            arity(3, "svc:rep:t_us:dur_us")?;
            let rep = as_count(spec, nums[0], "replica index")?;
            if rep >= replicas[svc as usize] {
                bail!(
                    "fault '{spec}': replica index {rep} out of range \
                     (service '{}' has {} replicas)",
                    parts[1],
                    replicas[svc as usize]
                );
            }
            Ok(Schedule::Down {
                svc,
                rep,
                t_us: positive(spec, nums[1], "t_us")?,
                dur_us: positive(spec, nums[2], "dur_us")?,
            })
        }
        "downrate" => {
            arity(2, "svc:period_us:dur_us")?;
            Ok(Schedule::DownRate {
                svc,
                period_us: positive(spec, nums[0], "period_us")?,
                dur_us: positive(spec, nums[1], "dur_us")?,
            })
        }
        "gray" => {
            arity(4, "svc:k:factor:t_us:dur_us")?;
            let k = as_count(spec, nums[0], "replica count k")?;
            if k == 0 || k > replicas[svc as usize] {
                bail!(
                    "fault '{spec}': k must be in 1..={} (service '{}' replicas), got {k}",
                    replicas[svc as usize],
                    parts[1]
                );
            }
            let factor = nums[1];
            if factor < 1.0 {
                bail!("fault '{spec}': dilation factor must be ≥ 1, got {factor}");
            }
            Ok(Schedule::Gray {
                svc,
                k,
                factor,
                t_us: positive(spec, nums[2], "t_us")?,
                dur_us: positive(spec, nums[3], "dur_us")?,
            })
        }
        "brownout" => {
            arity(3, "svc:factor:t_us:dur_us")?;
            let factor = nums[0];
            if factor < 1.0 {
                bail!("fault '{spec}': dilation factor must be ≥ 1, got {factor}");
            }
            Ok(Schedule::Brownout {
                svc,
                factor,
                t_us: positive(spec, nums[1], "t_us")?,
                dur_us: positive(spec, nums[2], "dur_us")?,
            })
        }
        other => bail!(
            "fault '{spec}': unknown fault kind '{other}' \
             (try down:svc:rep:t:dur | downrate:svc:period:dur | \
             gray:svc:k:factor:t:dur | brownout:svc:factor:t:dur)"
        ),
    }
}

impl FaultsSpec {
    /// True when the section changes nothing and must not serialize.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.client.is_empty()
    }

    /// Validate every schedule entry and client policy against a
    /// topology given as parallel `(service name, replica count)` slices.
    pub fn validate(&self, names: &[String], replicas: &[u32]) -> Result<()> {
        for ev in &self.events {
            parse_schedule(ev, names, replicas)?;
        }
        for c in &self.client {
            if c.service != "*" && !names.iter().any(|n| n == &c.service) {
                bail!("faults client policy: unknown service '{}'", c.service);
            }
            c.policy.validate(&format!("faults client policy '{}'", c.service))?;
            if c.policy.is_noop() {
                bail!(
                    "faults client policy '{}' is a no-op \
                     (set timeout_us, hedge_after_us, or retries)",
                    c.service
                );
            }
        }
        Ok(())
    }

    /// Expand into the event plan one engine run injects. Rate-driven
    /// schedules are materialized up to `horizon_us` from the schedule's
    /// own sub-stream of `mix64(seed ^ FAULT_SEED_SALT)`; fixed
    /// schedules expand without touching any RNG. The result is sorted
    /// by time (stable: schedule order on ties) and is a pure function
    /// of the arguments.
    pub fn plan(
        &self,
        names: &[String],
        replicas: &[u32],
        seed: u64,
        horizon_us: f64,
    ) -> Result<FaultPlan> {
        self.validate(names, replicas)?;
        let mut events: Vec<(f64, FaultEv)> = Vec::new();
        let base = mix64(seed ^ FAULT_SEED_SALT);
        for (i, ev) in self.events.iter().enumerate() {
            match parse_schedule(ev, names, replicas)? {
                Schedule::Down { svc, rep, t_us, dur_us } => {
                    events.push((t_us, FaultEv::Down { svc, rep }));
                    events.push((t_us + dur_us, FaultEv::Up { svc, rep }));
                }
                Schedule::DownRate { svc, period_us, dur_us } => {
                    let mut rng = Rng::new(mix64(base ^ i as u64));
                    let nrep = replicas[svc as usize] as u64;
                    let mut t = 0.0;
                    loop {
                        t += rng.exp(period_us);
                        if t >= horizon_us {
                            break;
                        }
                        let rep = rng.below(nrep) as u32;
                        events.push((t, FaultEv::Down { svc, rep }));
                        events.push((t + dur_us, FaultEv::Up { svc, rep }));
                    }
                }
                Schedule::Gray { svc, k, factor, t_us, dur_us } => {
                    for rep in 0..k {
                        events.push((t_us, FaultEv::GrayStart { svc, rep, factor }));
                        events.push((t_us + dur_us, FaultEv::GrayEnd { svc, rep }));
                    }
                }
                Schedule::Brownout { svc, factor, t_us, dur_us } => {
                    for rep in 0..replicas[svc as usize] {
                        events.push((t_us, FaultEv::GrayStart { svc, rep, factor }));
                        events.push((t_us + dur_us, FaultEv::GrayEnd { svc, rep }));
                    }
                }
            }
        }
        // Stable sort: simultaneous boundaries keep schedule order, so
        // overlapping windows compose exactly as written.
        events.sort_by(|a, b| a.0.to_bits().cmp(&b.0.to_bits()));
        let mut policies = vec![None; names.len()];
        for c in &self.client {
            if c.service == "*" {
                policies.iter_mut().for_each(|p| *p = Some(c.policy));
            } else if let Some(i) = names.iter().position(|n| n == &c.service) {
                policies[i] = Some(c.policy);
            }
        }
        Ok(FaultPlan { events, policies })
    }

    /// Serialize the section (omitting empty subsections; callers omit
    /// the whole section when [`FaultsSpec::is_empty`]).
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if !self.events.is_empty() {
            fields.push((
                "events",
                Json::Arr(self.events.iter().map(|e| Json::str(e)).collect()),
            ));
        }
        if !self.client.is_empty() {
            fields.push((
                "client",
                Json::Arr(
                    self.client
                        .iter()
                        .map(|c| {
                            let mut cf = vec![("service", Json::str(&c.service))];
                            cf.extend(c.policy.to_json());
                            Json::obj(cf)
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    /// Parse the `faults` section. Structural errors are typed; semantic
    /// validation against the topology happens in `ClusterSpec::validate`.
    pub fn from_json(j: &Json) -> Result<FaultsSpec> {
        let obj = match j.as_obj() {
            Some(o) => o,
            None => bail!("faults must be an object"),
        };
        let mut spec = FaultsSpec::default();
        if let Some(events) = obj.get("events") {
            let arr = events
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("faults.events must be an array of strings"))?;
            for e in arr {
                match e.as_str() {
                    Some(s) => spec.events.push(s.to_string()),
                    None => bail!("faults.events entries must be strings"),
                }
            }
        }
        if let Some(client) = obj.get("client") {
            let arr = client
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("faults.client must be an array of objects"))?;
            for c in arr {
                let service = c
                    .get("service")
                    .and_then(Json::as_str)
                    .ok_or_else(|| {
                        anyhow::anyhow!("faults.client entries need a 'service' string")
                    })?
                    .to_string();
                let num_field = |key: &str| -> Result<Option<f64>> {
                    match c.get(key) {
                        None => Ok(None),
                        Some(v) => match v.as_f64() {
                            Some(n) if n.is_finite() => Ok(Some(n)),
                            _ => bail!("faults.client '{service}': {key} must be a finite number"),
                        },
                    }
                };
                let retries = match c.get("retries") {
                    None => 0,
                    Some(v) => match v.as_u64() {
                        Some(n) if n <= MAX_RETRIES as u64 => n as u32,
                        _ => bail!(
                            "faults.client '{service}': retries must be an integer in \
                             0..={MAX_RETRIES}"
                        ),
                    },
                };
                spec.client.push(ClientPolicySpec {
                    service,
                    policy: EdgePolicy {
                        timeout_us: num_field("timeout_us")?,
                        retries,
                        backoff_us: num_field("backoff_us")?.unwrap_or(0.0),
                        hedge_after_us: num_field("hedge_after_us")?,
                    },
                });
            }
        }
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> (Vec<String>, Vec<u32>) {
        (vec!["gw".to_string(), "be".to_string()], vec![2, 3])
    }

    fn spec_with(events: &[&str]) -> FaultsSpec {
        FaultsSpec {
            events: events.iter().map(|s| s.to_string()).collect(),
            client: Vec::new(),
        }
    }

    #[test]
    fn schedule_grammar_parses_and_validates() {
        let (names, reps) = topo();
        for ok in [
            "down:gw:0:1000:500",
            "down:be:2:1:1",
            "downrate:be:20000:5000",
            "gray:be:2:3.5:1000:2000",
            "brownout:gw:2:500:1000",
        ] {
            spec_with(&[ok]).validate(&names, &reps).unwrap_or_else(|e| {
                panic!("'{ok}' rejected: {e}");
            });
        }
    }

    #[test]
    fn schedule_grammar_rejects_bad_specs() {
        let (names, reps) = topo();
        for bad in [
            "meteor:gw:0:1:1",          // unknown kind
            "down:nope:0:1:1",          // unknown service
            "down:gw:2:1:1",            // replica out of range
            "down:gw:0.5:1:1",          // fractional replica index
            "down:gw:-1:1:1",           // negative replica index
            "down:gw:0:0:1",            // t_us not > 0
            "down:gw:0:1:0",            // dur_us not > 0
            "down:gw:0:1",              // missing field
            "down:gw:0:1:1:7",          // surplus field
            "down:gw:0:abc:1",          // non-numeric
            "downrate:be:0:100",        // period not > 0
            "gray:be:0:2:1:1",          // k = 0
            "gray:be:4:2:1:1",          // k > replicas
            "gray:be:1:0.5:1:1",        // factor < 1
            "brownout:gw:0.9:1:1",      // factor < 1
            "down",                     // no service at all
        ] {
            assert!(
                spec_with(&[bad]).validate(&names, &reps).is_err(),
                "'{bad}' accepted"
            );
        }
    }

    #[test]
    fn client_policies_validate_and_resolve_in_order() {
        let (names, reps) = topo();
        let spec = FaultsSpec {
            events: Vec::new(),
            client: vec![
                ClientPolicySpec {
                    service: "*".into(),
                    policy: EdgePolicy {
                        timeout_us: Some(400.0),
                        retries: 2,
                        backoff_us: 50.0,
                        hedge_after_us: None,
                    },
                },
                ClientPolicySpec {
                    service: "be".into(),
                    policy: EdgePolicy {
                        timeout_us: Some(200.0),
                        retries: 1,
                        backoff_us: 0.0,
                        hedge_after_us: Some(80.0),
                    },
                },
            ],
        };
        spec.validate(&names, &reps).unwrap();
        let plan = spec.plan(&names, &reps, 7, 1e6).unwrap();
        assert_eq!(plan.policies.len(), 2);
        // The wildcard set both, the named entry overrode "be".
        assert_eq!(plan.policies[0].unwrap().timeout_us, Some(400.0));
        assert_eq!(plan.policies[1].unwrap().timeout_us, Some(200.0));
        assert_eq!(plan.policies[1].unwrap().hedge_after_us, Some(80.0));
        assert!(!plan.is_empty(), "policies alone make the plan non-empty");
    }

    #[test]
    fn client_policies_reject_bad_entries() {
        let (names, reps) = topo();
        let mk = |service: &str, policy: EdgePolicy| FaultsSpec {
            events: Vec::new(),
            client: vec![ClientPolicySpec { service: service.into(), policy }],
        };
        let timeout = EdgePolicy { timeout_us: Some(100.0), ..Default::default() };
        assert!(mk("nope", timeout).validate(&names, &reps).is_err(), "unknown service");
        assert!(
            mk("gw", EdgePolicy { timeout_us: Some(0.0), ..Default::default() })
                .validate(&names, &reps)
                .is_err(),
            "zero timeout"
        );
        assert!(
            mk("gw", EdgePolicy { hedge_after_us: Some(-1.0), ..Default::default() })
                .validate(&names, &reps)
                .is_err(),
            "negative hedge"
        );
        assert!(
            mk(
                "gw",
                EdgePolicy {
                    timeout_us: Some(100.0),
                    hedge_after_us: Some(100.0),
                    ..Default::default()
                }
            )
            .validate(&names, &reps)
            .is_err(),
            "hedge at/after timeout never fires"
        );
        assert!(
            mk("gw", EdgePolicy { retries: MAX_RETRIES + 1, timeout_us: Some(1.0), ..Default::default() })
                .validate(&names, &reps)
                .is_err(),
            "retry budget cap"
        );
        assert!(
            mk("gw", EdgePolicy::default()).validate(&names, &reps).is_err(),
            "no-op policy"
        );
        assert!(
            mk("gw", EdgePolicy { timeout_us: Some(100.0), backoff_us: -1.0, ..Default::default() })
                .validate(&names, &reps)
                .is_err(),
            "negative backoff"
        );
    }

    #[test]
    fn fixed_schedules_expand_sorted_without_rng() {
        let (names, reps) = topo();
        let spec = spec_with(&["down:gw:1:5000:1000", "gray:be:2:2:1000:500"]);
        let plan = spec.plan(&names, &reps, 42, 1e9).unwrap();
        // gray opens first (t=1000), then closes (1500), then the crash.
        let ts: Vec<f64> = plan.events.iter().map(|(t, _)| *t).collect();
        assert_eq!(ts, vec![1000.0, 1000.0, 1500.0, 1500.0, 5000.0, 6000.0]);
        assert_eq!(plan.events[4].1, FaultEv::Down { svc: 0, rep: 1 });
        assert_eq!(plan.events[5].1, FaultEv::Up { svc: 0, rep: 1 });
        assert!(matches!(plan.events[0].1, FaultEv::GrayStart { svc: 1, rep: 0, .. }));
        // Fixed schedules are seed-independent.
        let other = spec.plan(&names, &reps, 43, 1e9).unwrap();
        assert_eq!(plan.events, other.events);
    }

    #[test]
    fn rate_driven_schedules_are_seeded_and_horizon_bounded() {
        let (names, reps) = topo();
        let spec = spec_with(&["downrate:be:5000:1000"]);
        let a = spec.plan(&names, &reps, 7, 200_000.0).unwrap();
        let b = spec.plan(&names, &reps, 7, 200_000.0).unwrap();
        assert_eq!(a.events, b.events, "same seed must rematerialize identically");
        let c = spec.plan(&names, &reps, 8, 200_000.0).unwrap();
        assert_ne!(a.events, c.events, "different seed must move the crash times");
        assert!(!a.events.is_empty(), "40 mean periods must yield crashes");
        // Every Down lands inside the horizon and pairs with an Up.
        let downs = a.events.iter().filter(|(_, e)| matches!(e, FaultEv::Down { .. }));
        let ups = a.events.iter().filter(|(_, e)| matches!(e, FaultEv::Up { .. }));
        assert_eq!(downs.count(), ups.count());
        for (t, e) in &a.events {
            if matches!(e, FaultEv::Down { .. }) {
                assert!(*t < 200_000.0);
            }
            if let FaultEv::Down { svc, rep } | FaultEv::Up { svc, rep } = e {
                assert_eq!(*svc, 1);
                assert!(*rep < 3);
            }
        }
        // The plan is time-sorted.
        assert!(a.events.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn json_roundtrip_preserves_the_section() {
        let spec = FaultsSpec {
            events: vec!["down:gw:0:1000:500".into(), "downrate:be:20000:5000".into()],
            client: vec![ClientPolicySpec {
                service: "*".into(),
                policy: EdgePolicy {
                    timeout_us: Some(400.0),
                    retries: 2,
                    backoff_us: 100.0,
                    hedge_after_us: Some(250.0),
                },
            }],
        };
        let back = FaultsSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // Empty sections round-trip to empty.
        let empty = FaultsSpec::default();
        assert!(empty.is_empty());
        assert_eq!(empty.to_json().dump(), "{}");
        assert!(FaultsSpec::from_json(&empty.to_json()).unwrap().is_empty());
    }

    #[test]
    fn json_rejects_malformed_sections() {
        for bad in [
            r#"[]"#,
            r#"{"events": "down:gw:0:1:1"}"#,
            r#"{"events": [7]}"#,
            r#"{"client": {}}"#,
            r#"{"client": [{"timeout_us": 10}]}"#,
            r#"{"client": [{"service": "gw", "timeout_us": "fast"}]}"#,
            r#"{"client": [{"service": "gw", "retries": 2.5}]}"#,
            r#"{"client": [{"service": "gw", "retries": -1}]}"#,
            r#"{"client": [{"service": "gw", "retries": 99}]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(FaultsSpec::from_json(&j).is_err(), "'{bad}' accepted");
        }
    }
}
