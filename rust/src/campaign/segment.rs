//! Immutable, sorted segment files for the tiered campaign store
//! (DESIGN.md §6).
//!
//! A segment is one flushed memtable: a header line, a block of
//! key-sorted record lines, and a self-describing footer (bloom filter,
//! sparse key index, CRC) plus a fixed-shape trailer that points at the
//! footer. Opening a segment reads **only** the trailer and footer —
//! never the record block — so a resume probe against an N-record
//! segment costs one bloom check and (on a bloom hit) one short block
//! read, not an N-line replay.
//!
//! On-disk layout (all text, one construct per line):
//!
//! ```text
//! {"format":"slofetch-seg","version":1}          <- header
//! ["<key>",<seq>,{<record JSON>}]                <- data block, sorted
//! ...                                               by raw key bytes
//! {"bloom_bits":...,"crc":...,"index":...}       <- footer (one line)
//! #slfseg:<footer offset>:<footer crc32 hex>     <- trailer
//! ```
//!
//! The filename is `seg-<content_hash(block)>.seg`, so a segment's name
//! commits to its contents and re-flushing identical records is
//! idempotent. Any footer/trailer damage (torn write, truncation) makes
//! [`Segment::open`] fail, which the store surfaces as a *quarantine* —
//! never a silent drop.

use crate::campaign::spec::content_hash;
use crate::util::json::Json;
use crate::util::rng::mix64;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Format version stamped into header and footer.
const VERSION: u64 = 1;
/// Header line (also doubles as a magic number for `file`-style sniffs).
const HEADER: &str = "{\"format\":\"slofetch-seg\",\"version\":1}\n";
/// Trailer prefix; the final line is `#slfseg:<offset>:<crc32 hex>`.
const TRAILER_TAG: &str = "#slfseg:";
/// Every STRIDE-th record (including the first) lands in the sparse
/// index; a bloom hit reads at most STRIDE lines from disk.
const INDEX_STRIDE: usize = 16;
/// Bloom sizing: bits per stored key (k=7 gives ~1% false positives at
/// 10 bits/key; false positives cost one wasted block read, never a
/// wrong answer — `contains` always confirms against the block).
const BLOOM_BITS_PER_KEY: usize = 10;
const BLOOM_K: u32 = 7;

/// CRC-32/IEEE (poly 0xEDB88320), table built at compile time.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32/IEEE of `bytes` (the zlib/gzip polynomial).
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Salted 64-bit key hash (chained [`mix64`], same shape as
/// `spec::cell_seed`); two salts give the bloom filter's double-hash
/// pair.
fn hash_key(key: &str, salt: u64) -> u64 {
    let mut h = mix64(salt ^ 0xB100_F117_E25E_6AA1);
    for b in key.bytes() {
        h = mix64(h ^ b as u64);
    }
    h
}

/// Classic bloom filter over the segment's key set (double hashing,
/// k probes). Membership misses answer resume probes without touching
/// the record block at all.
pub(crate) struct Bloom {
    k: u32,
    words: Vec<u64>,
}

impl Bloom {
    /// An empty filter sized for `n` keys.
    fn with_capacity(n: usize) -> Bloom {
        let bits = (n.max(1) * BLOOM_BITS_PER_KEY).max(64);
        Bloom { k: BLOOM_K, words: vec![0u64; bits.div_ceil(64)] }
    }

    fn bit_positions(&self, key: &str) -> impl Iterator<Item = u64> + '_ {
        let nbits = (self.words.len() * 64) as u64;
        let h1 = hash_key(key, 0x9E37_79B9_7F4A_7C15);
        let h2 = hash_key(key, 0xC2B2_AE3D_27D4_EB4F) | 1;
        (0..self.k as u64).map(move |i| h1.wrapping_add(i.wrapping_mul(h2)) % nbits)
    }

    fn insert(&mut self, key: &str) {
        for bit in self.bit_positions(key).collect::<Vec<_>>() {
            self.words[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
    }

    /// `false` means definitely absent; `true` means "probe the block".
    pub(crate) fn maybe_contains(&self, key: &str) -> bool {
        self.bit_positions(key)
            .all(|bit| self.words[(bit / 64) as usize] & (1u64 << (bit % 64)) != 0)
    }

    /// Hex dump of the filter words (16 chars per word, in order).
    fn to_hex(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::with_capacity(self.words.len() * 16);
        for w in &self.words {
            let _ = write!(s, "{w:016x}");
        }
        s
    }

    fn from_hex(k: u32, hex: &str) -> Result<Bloom> {
        if hex.is_empty() || hex.len() % 16 != 0 {
            bail!("segment bloom: bad hex length {}", hex.len());
        }
        let mut words = Vec::with_capacity(hex.len() / 16);
        let bytes = hex.as_bytes();
        for chunk in bytes.chunks(16) {
            let s = std::str::from_utf8(chunk).context("segment bloom: non-utf8 hex")?;
            words.push(u64::from_str_radix(s, 16).context("segment bloom: bad hex word")?);
        }
        Ok(Bloom { k, words })
    }
}

/// One record bound for a segment: its dedup key, global store sequence
/// number (reports re-sort by it to recover append order), kind slot
/// (0 = sim, 1 = cluster, 2 = sketch), and the record's own JSON line.
pub(crate) struct SegEntry {
    pub key: String,
    pub seq: u64,
    pub kind: usize,
    pub json: String,
}

/// An open (footer-loaded) immutable segment. The record block stays on
/// disk; `contains` reads at most one index stride of it, `load_entries`
/// reads and CRC-checks all of it.
pub(crate) struct Segment {
    path: PathBuf,
    /// Records in the block.
    n: usize,
    /// Records per kind slot (sim/cluster/sketch) — lets report scans
    /// skip segments that hold none of the kind they aggregate.
    kinds: [usize; 3],
    pub min_seq: u64,
    pub max_seq: u64,
    bloom: Bloom,
    /// `(first key, absolute file offset)` of every INDEX_STRIDE-th
    /// record, starting with the first.
    index: Vec<(String, u64)>,
    data_start: u64,
    data_len: u64,
    /// CRC-32 of the record block (verified on full loads).
    crc: u32,
    /// Lazily opened read handle for block probes (`contains` takes
    /// `&self`; the store is single-threaded on the writer side).
    file: RefCell<Option<File>>,
}

impl Segment {
    /// Write `entries` as a new immutable segment in `dir` and return it
    /// opened. Entries are sorted by raw key bytes; keys must be unique
    /// (the store's push-side dedup guarantees it). The file is written
    /// to a `.seg.tmp` sibling and renamed into place, so a crash leaves
    /// either no segment or a complete one — never a half-written file
    /// under the final name.
    pub(crate) fn write(dir: &Path, mut entries: Vec<SegEntry>) -> Result<Segment> {
        if entries.is_empty() {
            bail!("segment write: empty entry list");
        }
        entries.sort_by(|a, b| a.key.cmp(&b.key));
        let mut bloom = Bloom::with_capacity(entries.len());
        let mut kinds = [0usize; 3];
        let mut min_seq = u64::MAX;
        let mut max_seq = 0u64;
        let mut block = String::new();
        let mut index: Vec<(String, u64)> = Vec::new();
        let data_start = HEADER.len() as u64;
        use std::fmt::Write as _;
        for (i, e) in entries.iter().enumerate() {
            if i % INDEX_STRIDE == 0 {
                index.push((e.key.clone(), data_start + block.len() as u64));
            }
            bloom.insert(&e.key);
            kinds[e.kind] += 1;
            min_seq = min_seq.min(e.seq);
            max_seq = max_seq.max(e.seq);
            // Key, seq, and record JSON are all already canonical (the
            // key via dump()'s escaping, seq a plain integer, the
            // record a sorted-key dump()), so the line is deterministic.
            let _ = writeln!(block, "[{},{},{}]", Json::str(&e.key).dump(), e.seq, e.json);
        }
        let data_len = block.len() as u64;
        let crc = crc32(block.as_bytes());
        let footer = Json::obj(vec![
            ("bloom_bits", Json::str(&bloom.to_hex())),
            ("bloom_k", Json::num(bloom.k as f64)),
            ("crc", Json::num(crc as f64)),
            ("data_len", Json::num(data_len as f64)),
            ("data_start", Json::num(data_start as f64)),
            (
                "index",
                Json::Arr(
                    index
                        .iter()
                        .map(|(k, off)| {
                            Json::Arr(vec![Json::str(k), Json::num(*off as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "kinds",
                Json::obj(vec![
                    ("cluster", Json::num(kinds[1] as f64)),
                    ("sim", Json::num(kinds[0] as f64)),
                    ("sketch", Json::num(kinds[2] as f64)),
                ]),
            ),
            ("max_seq", Json::num(max_seq as f64)),
            ("min_seq", Json::num(min_seq as f64)),
            ("n", Json::num(entries.len() as f64)),
            ("version", Json::num(VERSION as f64)),
        ])
        .dump();
        let footer_offset = data_start + data_len;
        let trailer =
            format!("{TRAILER_TAG}{footer_offset}:{:08x}\n", crc32(footer.as_bytes()));
        let name = format!("seg-{:016x}.seg", content_hash(block.as_bytes()));
        let path = dir.join(&name);
        let tmp = dir.join(format!("{name}.tmp"));
        {
            let mut f = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
            f.write_all(HEADER.as_bytes())
                .and_then(|_| f.write_all(block.as_bytes()))
                .and_then(|_| f.write_all(footer.as_bytes()))
                .and_then(|_| f.write_all(b"\n"))
                .and_then(|_| f.write_all(trailer.as_bytes()))
                .with_context(|| format!("write {tmp:?}"))?;
            f.sync_all().with_context(|| format!("sync {tmp:?}"))?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
        Ok(Segment {
            path,
            n: entries.len(),
            kinds,
            min_seq,
            max_seq,
            bloom,
            index,
            data_start,
            data_len,
            crc,
            file: RefCell::new(None),
        })
    }

    /// Open a segment by reading only its trailer and footer (the record
    /// block stays untouched until a probe needs it). Any inconsistency
    /// — missing trailer, footer CRC mismatch, malformed footer — is an
    /// error; the store quarantines such files rather than guessing.
    pub(crate) fn open(path: &Path) -> Result<Segment> {
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let len = file.metadata().with_context(|| format!("stat {path:?}"))?.len();
        let tail_len = len.min(96);
        file.seek(SeekFrom::Start(len - tail_len))
            .with_context(|| format!("seek {path:?}"))?;
        let mut tail = vec![0u8; tail_len as usize];
        file.read_exact(&mut tail).with_context(|| format!("read tail of {path:?}"))?;
        let tail = String::from_utf8_lossy(&tail).into_owned();
        let pos = tail
            .rfind(TRAILER_TAG)
            .with_context(|| format!("{path:?}: no segment trailer (torn write?)"))?;
        let trailer_len = (tail.len() - pos) as u64;
        let body = tail[pos + TRAILER_TAG.len()..].trim_end();
        let (off_s, crc_s) = body
            .split_once(':')
            .with_context(|| format!("{path:?}: malformed trailer '{body}'"))?;
        let footer_offset: u64 =
            off_s.parse().with_context(|| format!("{path:?}: bad footer offset"))?;
        let footer_crc = u32::from_str_radix(crc_s, 16)
            .with_context(|| format!("{path:?}: bad footer crc"))?;
        let footer_end = len - trailer_len;
        if footer_offset >= footer_end {
            bail!("{path:?}: footer offset {footer_offset} past end {footer_end}");
        }
        file.seek(SeekFrom::Start(footer_offset))
            .with_context(|| format!("seek {path:?}"))?;
        let mut footer = vec![0u8; (footer_end - footer_offset) as usize];
        file.read_exact(&mut footer).with_context(|| format!("read footer {path:?}"))?;
        while footer.last() == Some(&b'\n') {
            footer.pop();
        }
        if crc32(&footer) != footer_crc {
            bail!("{path:?}: footer crc mismatch (torn or corrupted write)");
        }
        let footer = std::str::from_utf8(&footer)
            .with_context(|| format!("{path:?}: non-utf8 footer"))?;
        let j = Json::parse(footer)
            .map_err(anyhow::Error::from)
            .with_context(|| format!("{path:?}: unparseable footer"))?;
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("{path:?}: footer missing '{k}'"))
        };
        if u("version")? != VERSION {
            bail!("{path:?}: unsupported segment version");
        }
        let kinds_j = j.get("kinds").with_context(|| format!("{path:?}: no kinds"))?;
        let kind = |k: &str| kinds_j.get(k).and_then(Json::as_u64).unwrap_or(0) as usize;
        let bloom = Bloom::from_hex(
            u("bloom_k")? as u32,
            j.get("bloom_bits")
                .and_then(Json::as_str)
                .with_context(|| format!("{path:?}: no bloom"))?,
        )?;
        let mut index = Vec::new();
        if let Some(Json::Arr(items)) = j.get("index") {
            for it in items {
                let pair = it.as_arr().with_context(|| format!("{path:?}: bad index"))?;
                let key = pair
                    .first()
                    .and_then(Json::as_str)
                    .with_context(|| format!("{path:?}: bad index key"))?;
                let off = pair
                    .get(1)
                    .and_then(Json::as_u64)
                    .with_context(|| format!("{path:?}: bad index offset"))?;
                index.push((key.to_string(), off));
            }
        }
        let (data_start, data_len) = (u("data_start")?, u("data_len")?);
        if data_start + data_len > footer_offset {
            bail!("{path:?}: data block overruns footer");
        }
        Ok(Segment {
            path: path.to_path_buf(),
            n: u("n")? as usize,
            kinds: [kind("sim"), kind("cluster"), kind("sketch")],
            min_seq: u("min_seq")?,
            max_seq: u("max_seq")?,
            bloom,
            index,
            data_start,
            data_len,
            crc: u("crc")? as u32,
            file: RefCell::new(Some(file)),
        })
    }

    pub(crate) fn path(&self) -> &Path {
        &self.path
    }

    pub(crate) fn record_count(&self) -> usize {
        self.n
    }

    /// Records of one kind slot (0 = sim, 1 = cluster, 2 = sketch).
    pub(crate) fn kind_count(&self, kind: usize) -> usize {
        self.kinds[kind]
    }

    /// Read `[start, start+len)` of the segment file.
    fn read_range(&self, start: u64, len: usize) -> Result<Vec<u8>> {
        let mut slot = self.file.borrow_mut();
        if slot.is_none() {
            *slot = Some(
                File::open(&self.path).with_context(|| format!("open {:?}", self.path))?,
            );
        }
        let file = slot.as_mut().expect("file handle just ensured");
        file.seek(SeekFrom::Start(start))
            .with_context(|| format!("seek {:?}", self.path))?;
        let mut buf = vec![0u8; len];
        file.read_exact(&mut buf)
            .with_context(|| format!("read {len}B @{start} of {:?}", self.path))?;
        Ok(buf)
    }

    /// Exact membership probe: bloom filter, then sparse-index binary
    /// search, then a byte-prefix match over one index stride of the
    /// block. A bloom false positive costs one short read, never a wrong
    /// answer.
    pub(crate) fn contains(&self, key: &str) -> Result<bool> {
        if !self.bloom.maybe_contains(key) {
            return Ok(false);
        }
        let idx = self.index.partition_point(|(k, _)| k.as_str() <= key);
        if idx == 0 {
            // Probe key sorts before the segment's first record.
            return Ok(false);
        }
        let start = self.index[idx - 1].1;
        let end = self
            .index
            .get(idx)
            .map(|(_, off)| *off)
            .unwrap_or(self.data_start + self.data_len);
        let buf = self.read_range(start, (end - start) as usize)?;
        let needle = format!("[{},", Json::str(key).dump());
        Ok(buf
            .split(|&b| b == b'\n')
            .any(|line| line.starts_with(needle.as_bytes())))
    }

    /// Load and CRC-verify the whole record block, returning
    /// `(key, seq, record JSON)` triples in key order.
    pub(crate) fn load_entries(&self) -> Result<Vec<(String, u64, Json)>> {
        let buf = self.read_range(self.data_start, self.data_len as usize)?;
        if crc32(&buf) != self.crc {
            bail!("{:?}: record block crc mismatch", self.path);
        }
        let text = std::str::from_utf8(&buf)
            .with_context(|| format!("{:?}: non-utf8 block", self.path))?;
        let mut out = Vec::with_capacity(self.n);
        for (no, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(anyhow::Error::from)
                .with_context(|| format!("{:?} record {}", self.path, no + 1))?;
            let arr = j
                .as_arr()
                .with_context(|| format!("{:?} record {}: not a triple", self.path, no + 1))?;
            let key = arr
                .first()
                .and_then(Json::as_str)
                .with_context(|| format!("{:?} record {}: no key", self.path, no + 1))?
                .to_string();
            let seq = arr
                .get(1)
                .and_then(Json::as_u64)
                .with_context(|| format!("{:?} record {}: no seq", self.path, no + 1))?;
            let rec = arr
                .get(2)
                .with_context(|| format!("{:?} record {}: no record", self.path, no + 1))?
                .clone();
            out.push((key, seq, rec));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(key: &str, seq: u64, kind: usize) -> SegEntry {
        SegEntry {
            key: key.to_string(),
            seq,
            kind,
            json: format!("{{\"key\":{},\"v\":{}}}", Json::str(key).dump(), seq),
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("slofetch_seg_{name}"));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_matches_reference_vector() {
        // The CRC-32/IEEE check value (RFC 1952 / zlib family).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn bloom_has_no_false_negatives() {
        let keys: Vec<String> = (0..500).map(|i| format!("cell|{i}|nl")).collect();
        let mut b = Bloom::with_capacity(keys.len());
        for k in &keys {
            b.insert(k);
        }
        for k in &keys {
            assert!(b.maybe_contains(k), "false negative on {k}");
        }
        // False positives exist but must be rare at 10 bits/key.
        let fp = (0..2000)
            .filter(|i| b.maybe_contains(&format!("absent|{i}")))
            .count();
        assert!(fp < 100, "bloom false-positive rate too high: {fp}/2000");
    }

    #[test]
    fn write_open_probe_roundtrip() {
        let dir = tmpdir("roundtrip");
        let entries: Vec<SegEntry> =
            (0..100).map(|i| entry(&format!("key{i:03}"), 1000 + i, (i % 3) as usize)).collect();
        let seg = Segment::write(&dir, entries).unwrap();
        assert_eq!(seg.record_count(), 100);
        assert_eq!(seg.min_seq, 1000);
        assert_eq!(seg.max_seq, 1099);
        // Reopen cold and probe.
        let seg = Segment::open(seg.path()).unwrap();
        assert_eq!(seg.record_count(), 100);
        assert_eq!(seg.kind_count(0) + seg.kind_count(1) + seg.kind_count(2), 100);
        for i in [0u64, 1, 15, 16, 17, 63, 99] {
            assert!(seg.contains(&format!("key{i:03}")).unwrap(), "missing key{i:03}");
        }
        assert!(!seg.contains("key100").unwrap());
        assert!(!seg.contains("aaa-before-first").unwrap());
        assert!(!seg.contains("zzz-after-last").unwrap());
        let loaded = seg.load_entries().unwrap();
        assert_eq!(loaded.len(), 100);
        // Block is key-sorted; seqs survive for append-order recovery.
        assert!(loaded.windows(2).all(|w| w[0].0 < w[1].0), "block not key-sorted");
        assert_eq!(loaded[0].1, 1000);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_footer_fails_open() {
        let dir = tmpdir("torn");
        let entries: Vec<SegEntry> = (0..40).map(|i| entry(&format!("k{i:02}"), i, 0)).collect();
        let seg = Segment::write(&dir, entries).unwrap();
        let path = seg.path().to_path_buf();
        let len = std::fs::metadata(&path).unwrap().len();
        // Tear off the trailer and half the footer, as a crash mid-flush
        // (or a truncated copy) would.
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 60).unwrap();
        drop(f);
        assert!(Segment::open(&path).is_err(), "torn segment opened cleanly");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_block_fails_full_load_but_not_open() {
        let dir = tmpdir("bitrot");
        let entries: Vec<SegEntry> = (0..40).map(|i| entry(&format!("k{i:02}"), i, 0)).collect();
        let seg = Segment::write(&dir, entries).unwrap();
        let path = seg.path().to_path_buf();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one byte inside the record block (past the header line).
        let i = HEADER.len() + 5;
        bytes[i] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let seg = Segment::open(&path).expect("footer is intact; open must succeed");
        assert!(seg.load_entries().is_err(), "block crc failed to catch bit rot");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn filename_commits_to_contents() {
        let dir = tmpdir("name");
        let mk = || (0..10).map(|i| entry(&format!("k{i}"), i, 0)).collect::<Vec<_>>();
        let a = Segment::write(&dir, mk()).unwrap();
        let b = Segment::write(&dir, mk()).unwrap();
        assert_eq!(a.path(), b.path(), "identical contents must reuse the name");
        let mut other = mk();
        other.push(entry("extra", 99, 0));
        let c = Segment::write(&dir, other).unwrap();
        assert_ne!(a.path(), c.path());
        std::fs::remove_dir_all(&dir).ok();
    }
}
