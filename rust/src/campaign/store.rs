//! Tiered, compacting campaign result store (DESIGN.md §6).
//!
//! The store is the campaign's memory — reloading it before a run lets
//! repeated campaigns *resume* (cells whose key is already present are
//! skipped, not recomputed), and `merge` folds stores from different
//! machines or shards into one. Records are emitted in spec-expansion
//! order with sorted object keys, so a given (spec, seed set) always
//! produces byte-identical record streams.
//!
//! Two on-disk layouts share that contract (see [`StoreFormat`]): the
//! legacy single-file append-only JSONL log, and the tiered layout — a
//! directory with a write-ahead `wal.jsonl` tail mirroring an in-memory
//! memtable, flushed at a size threshold into immutable, key-sorted,
//! bloom-filtered segment files (built in segment.rs) that make cold
//! opens footer-only and resume probes O(1), plus explicit foreground
//! compaction merging segments and dropping superseded duplicates.
//! Legacy files import transparently: the old log becomes the new
//! store's WAL, so every record resumes with its key and bytes intact.

use crate::campaign::segment::{SegEntry, Segment};
use crate::cluster::{ClusterResult, TenantStat};
use crate::obs::telemetry::Telemetry;
use crate::sim::engine::SimResult;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashSet};
use std::io::Write;
use std::path::{Path, PathBuf};

/// One JSONL line: the scenario coordinates plus every scalar the report
/// layer aggregates.
#[derive(Clone, Debug, PartialEq)]
pub struct CellRecord {
    pub key: String,
    pub app: String,
    pub label: String,
    pub records: u64,
    pub trace_seed: u64,
    pub sim_seed: u64,
    pub ml: bool,
    pub churn_scale: f64,
    pub ipc: f64,
    /// Speedup over the same-scenario `nl` baseline (absent when the
    /// campaign has no such baseline cell).
    pub speedup: Option<f64>,
    pub mpki: f64,
    pub l1d_mpki: f64,
    pub accuracy: f64,
    pub coverage: f64,
    pub timeliness: f64,
    pub metadata_bytes: u64,
    pub pf_issued: u64,
    pub pf_timely: u64,
    pub pf_late: u64,
    pub pf_useless: u64,
    pub pf_skipped: u64,
    pub instrs: u64,
    pub cycles: f64,
    pub controller: Option<ControllerRecord>,
    /// Tail-latency evaluation, present on cells with a traffic shape
    /// (the campaign `traffic` axis; see `cluster::evaluate_tail`).
    pub tail: Option<TailRecord>,
}

/// Controller counters, present on `+ml` cells.
#[derive(Clone, Debug, PartialEq)]
pub struct ControllerRecord {
    pub decisions: u64,
    pub issued: u64,
    pub skipped: u64,
    pub trains: u64,
    pub last_loss: f64,
}

/// Queueing-tail summary of a cell under one traffic shape.
#[derive(Clone, Debug, PartialEq)]
pub struct TailRecord {
    /// Normalized shape label (e.g. `poisson:0.65`).
    pub traffic: String,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Fraction of requests within the evaluation SLO.
    pub compliance: f64,
    pub slo_us: f64,
}

impl CellRecord {
    /// Build from a finished simulation (speedup filled in later, once
    /// the baseline's IPC is known).
    pub fn from_result(
        key: &str,
        ml: bool,
        churn_scale: f64,
        records: u64,
        trace_seed: u64,
        sim_seed: u64,
        r: &SimResult,
    ) -> CellRecord {
        CellRecord {
            key: key.to_string(),
            app: r.app.clone(),
            label: r.label.clone(),
            records,
            trace_seed,
            sim_seed,
            ml,
            churn_scale,
            ipc: r.ipc(),
            speedup: None,
            mpki: r.stats.mpki(),
            l1d_mpki: r.stats.l1d_mpki(),
            accuracy: r.stats.accuracy(),
            coverage: r.stats.coverage(),
            timeliness: r.stats.timeliness(),
            metadata_bytes: r.metadata_bytes,
            pf_issued: r.stats.pf_issued,
            pf_timely: r.stats.pf_timely,
            pf_late: r.stats.pf_late,
            pf_useless: r.stats.pf_useless,
            pf_skipped: r.stats.pf_skipped,
            instrs: r.stats.instrs,
            cycles: r.stats.cycles,
            controller: r.controller.as_ref().map(|c| ControllerRecord {
                decisions: c.decisions,
                issued: c.issued,
                skipped: c.skipped,
                trains: c.trains,
                last_loss: c.last_loss as f64,
            }),
            tail: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let controller = match &self.controller {
            None => Json::Null,
            Some(c) => Json::obj(vec![
                ("decisions", Json::num(c.decisions as f64)),
                ("issued", Json::num(c.issued as f64)),
                ("skipped", Json::num(c.skipped as f64)),
                ("trains", Json::num(c.trains as f64)),
                ("last_loss", Json::num(c.last_loss)),
            ]),
        };
        let tail = match &self.tail {
            None => Json::Null,
            Some(t) => Json::obj(vec![
                ("traffic", Json::str(&t.traffic)),
                ("p50_us", Json::num(t.p50_us)),
                ("p95_us", Json::num(t.p95_us)),
                ("p99_us", Json::num(t.p99_us)),
                ("compliance", Json::num(t.compliance)),
                ("slo_us", Json::num(t.slo_us)),
            ]),
        };
        Json::obj(vec![
            ("key", Json::str(&self.key)),
            ("app", Json::str(&self.app)),
            ("label", Json::str(&self.label)),
            ("records", Json::num(self.records as f64)),
            ("trace_seed", Json::num(self.trace_seed as f64)),
            // As a string: full-range 64-bit hashes do not survive the
            // f64 JSON number path (2^53 mantissa).
            ("sim_seed", Json::str(&self.sim_seed.to_string())),
            ("ml", Json::Bool(self.ml)),
            ("churn_scale", Json::num(self.churn_scale)),
            ("ipc", Json::num(self.ipc)),
            (
                "speedup",
                self.speedup.map(Json::num).unwrap_or(Json::Null),
            ),
            ("mpki", Json::num(self.mpki)),
            ("l1d_mpki", Json::num(self.l1d_mpki)),
            ("accuracy", Json::num(self.accuracy)),
            ("coverage", Json::num(self.coverage)),
            ("timeliness", Json::num(self.timeliness)),
            ("metadata_bytes", Json::num(self.metadata_bytes as f64)),
            ("pf_issued", Json::num(self.pf_issued as f64)),
            ("pf_timely", Json::num(self.pf_timely as f64)),
            ("pf_late", Json::num(self.pf_late as f64)),
            ("pf_useless", Json::num(self.pf_useless as f64)),
            ("pf_skipped", Json::num(self.pf_skipped as f64)),
            ("instrs", Json::num(self.instrs as f64)),
            ("cycles", Json::num(self.cycles)),
            ("controller", controller),
            ("tail", tail),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CellRecord> {
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("cell record: missing string '{k}'"))
        };
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("cell record: missing integer '{k}'"))
        };
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("cell record: missing number '{k}'"))
        };
        let controller = match j.get("controller") {
            None | Some(Json::Null) => None,
            Some(c) => Some(ControllerRecord {
                decisions: c.get("decisions").and_then(Json::as_u64).unwrap_or(0),
                issued: c.get("issued").and_then(Json::as_u64).unwrap_or(0),
                skipped: c.get("skipped").and_then(Json::as_u64).unwrap_or(0),
                trains: c.get("trains").and_then(Json::as_u64).unwrap_or(0),
                last_loss: c.get("last_loss").and_then(Json::as_f64).unwrap_or(0.0),
            }),
        };
        // Absent on pre-traffic-axis lines: they reload as tail-less.
        let tail = match j.get("tail") {
            None | Some(Json::Null) => None,
            Some(t) => Some(TailRecord {
                traffic: t.get("traffic").and_then(Json::as_str).unwrap_or("").to_string(),
                p50_us: t.get("p50_us").and_then(Json::as_f64).unwrap_or(0.0),
                p95_us: t.get("p95_us").and_then(Json::as_f64).unwrap_or(0.0),
                p99_us: t.get("p99_us").and_then(Json::as_f64).unwrap_or(0.0),
                compliance: t.get("compliance").and_then(Json::as_f64).unwrap_or(0.0),
                slo_us: t.get("slo_us").and_then(Json::as_f64).unwrap_or(0.0),
            }),
        };
        Ok(CellRecord {
            key: s("key")?,
            app: s("app")?,
            label: s("label")?,
            records: u("records")?,
            trace_seed: u("trace_seed")?,
            sim_seed: j
                .get("sim_seed")
                .and_then(Json::as_str)
                .and_then(|v| v.parse().ok())
                .context("cell record: missing u64 string 'sim_seed'")?,
            ml: j.get("ml").and_then(Json::as_bool).unwrap_or(false),
            churn_scale: j.get("churn_scale").and_then(Json::as_f64).unwrap_or(1.0),
            ipc: f("ipc")?,
            speedup: j.get("speedup").and_then(Json::as_f64),
            mpki: f("mpki")?,
            l1d_mpki: f("l1d_mpki")?,
            accuracy: f("accuracy")?,
            coverage: f("coverage")?,
            timeliness: f("timeliness")?,
            metadata_bytes: u("metadata_bytes")?,
            pf_issued: u("pf_issued")?,
            pf_timely: u("pf_timely")?,
            pf_late: u("pf_late")?,
            pf_useless: u("pf_useless")?,
            pf_skipped: u("pf_skipped")?,
            instrs: u("instrs")?,
            cycles: f("cycles")?,
            controller,
            tail,
        })
    }

    /// The single JSONL line (sorted keys, no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().dump()
    }
}

/// One JSONL line for a campaign cluster-scenario cell (tagged
/// `"kind": "cluster"`; untagged lines stay [`CellRecord`]s, so
/// pre-cluster stores load unchanged): per-scenario SLO burn,
/// replica-seconds, and metadata cost of one (cluster, policy, traffic)
/// coordinate.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterCellRecord {
    pub key: String,
    /// Cluster scenario name (from the campaign spec).
    pub cluster: String,
    /// Autoscaler policy label ([`crate::cluster::Policy::label`]) —
    /// or, on tenant cells, the run mode (`"solo"` / `"coloc"`).
    pub policy: String,
    /// Tenant name on multi-tenant cells; empty on policy cells (and on
    /// every line written before tenancy existed — the key is only
    /// serialized when non-empty, so old stores reload byte-compatibly
    /// and new single-tenant lines stay byte-identical).
    pub tenant: String,
    /// Fault regime the cell ran under (`;`-joined schedule specs from
    /// the campaign `faults` axis); empty on healthy-regime cells — and
    /// on every line written before the fault axis existed. Serialized
    /// only when non-empty, so old stores reload byte-compatibly.
    pub faults: String,
    /// Normalized traffic-shape label.
    pub traffic: String,
    /// Service-time model the scenario ran under (`"analytic"` or
    /// `"empirical"`); lines written before the model existed reload as
    /// `"analytic"`.
    pub service_times: String,
    pub requests: u64,
    pub slo_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub compliance: f64,
    pub windows: u32,
    pub violated_windows: u32,
    /// Control actions the policy executed.
    pub actions: u64,
    /// Final active replicas across all services.
    pub final_replicas: u32,
    /// ∫ provisioned replicas dt (replica-µs).
    pub replica_us: f64,
    /// ∫ metadata footprint dt (byte-µs).
    pub meta_byte_us: f64,
    pub final_metadata_bytes: u64,
    /// Simulated duration (µs).
    pub duration_us: f64,
    pub events: u64,
}

impl ClusterCellRecord {
    pub fn from_result(
        key: &str,
        cluster: &str,
        policy: &str,
        service_times: &str,
        r: &ClusterResult,
    ) -> Self {
        ClusterCellRecord {
            key: key.to_string(),
            cluster: cluster.to_string(),
            policy: policy.to_string(),
            tenant: String::new(),
            faults: String::new(),
            service_times: service_times.to_string(),
            traffic: r.traffic.clone(),
            requests: r.requests,
            slo_us: r.slo_us,
            p50_us: r.p50_us,
            p95_us: r.p95_us,
            p99_us: r.p99_us,
            compliance: r.compliance,
            windows: r.windows,
            violated_windows: r.violated_windows,
            actions: r.actions.len() as u64,
            final_replicas: r.final_replicas.iter().sum(),
            replica_us: r.replica_us,
            meta_byte_us: r.meta_byte_us,
            final_metadata_bytes: r.final_metadata_bytes,
            duration_us: r.duration_us,
            events: r.events,
        }
    }

    /// Build a per-tenant line from a multi-tenant run (solo or
    /// co-located): latency/burn fields come from the tenant's own
    /// stats, capacity and event accounting from the run all its
    /// tenants shared.
    pub fn from_tenant(
        key: &str,
        cluster: &str,
        mode: &str,
        service_times: &str,
        r: &ClusterResult,
        ts: &TenantStat,
    ) -> Self {
        ClusterCellRecord {
            key: key.to_string(),
            cluster: cluster.to_string(),
            policy: mode.to_string(),
            tenant: ts.name.clone(),
            faults: String::new(),
            service_times: service_times.to_string(),
            traffic: ts.traffic.clone(),
            requests: ts.requests,
            slo_us: ts.slo_us,
            p50_us: ts.p50_us,
            p95_us: ts.p95_us,
            p99_us: ts.p99_us,
            compliance: ts.compliance,
            windows: ts.windows,
            violated_windows: ts.violated_windows,
            actions: r.actions.len() as u64,
            final_replicas: r.final_replicas.iter().sum(),
            replica_us: r.replica_us,
            meta_byte_us: r.meta_byte_us,
            final_metadata_bytes: r.final_metadata_bytes,
            duration_us: r.duration_us,
            events: r.events,
        }
    }

    /// Fraction of evaluated windows that burned.
    pub fn burn_rate(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            self.violated_windows as f64 / self.windows as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("kind", Json::str("cluster")),
            ("key", Json::str(&self.key)),
            ("cluster", Json::str(&self.cluster)),
            ("policy", Json::str(&self.policy)),
        ];
        // Only tenant cells carry the key: non-tenant lines serialize
        // byte-identically to pre-tenancy builds.
        if !self.tenant.is_empty() {
            fields.push(("tenant", Json::str(&self.tenant)));
        }
        // Same discipline for the fault regime: healthy-regime lines
        // serialize byte-identically to pre-fault builds.
        if !self.faults.is_empty() {
            fields.push(("faults", Json::str(&self.faults)));
        }
        fields.extend(vec![
            ("service_times", Json::str(&self.service_times)),
            ("traffic", Json::str(&self.traffic)),
            ("requests", Json::num(self.requests as f64)),
            ("slo_us", Json::num(self.slo_us)),
            ("p50_us", Json::num(self.p50_us)),
            ("p95_us", Json::num(self.p95_us)),
            ("p99_us", Json::num(self.p99_us)),
            ("compliance", Json::num(self.compliance)),
            ("windows", Json::num(self.windows as f64)),
            ("violated_windows", Json::num(self.violated_windows as f64)),
            ("actions", Json::num(self.actions as f64)),
            ("final_replicas", Json::num(self.final_replicas as f64)),
            ("replica_us", Json::num(self.replica_us)),
            ("meta_byte_us", Json::num(self.meta_byte_us)),
            (
                "final_metadata_bytes",
                Json::num(self.final_metadata_bytes as f64),
            ),
            ("duration_us", Json::num(self.duration_us)),
            ("events", Json::num(self.events as f64)),
        ]);
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<ClusterCellRecord> {
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("cluster record: missing string '{k}'"))
        };
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("cluster record: missing integer '{k}'"))
        };
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("cluster record: missing number '{k}'"))
        };
        Ok(ClusterCellRecord {
            key: s("key")?,
            cluster: s("cluster")?,
            policy: s("policy")?,
            // Absent on pre-tenancy lines (and on policy cells).
            tenant: j.get("tenant").and_then(Json::as_str).unwrap_or("").to_string(),
            // Absent on pre-fault lines (and on healthy-regime cells).
            faults: j.get("faults").and_then(Json::as_str).unwrap_or("").to_string(),
            // Absent on pre-empirical lines: those ran the analytic model.
            service_times: j
                .get("service_times")
                .and_then(Json::as_str)
                .unwrap_or("analytic")
                .to_string(),
            traffic: s("traffic")?,
            requests: u("requests")?,
            slo_us: f("slo_us")?,
            p50_us: f("p50_us")?,
            p95_us: f("p95_us")?,
            p99_us: f("p99_us")?,
            compliance: f("compliance")?,
            windows: u("windows")? as u32,
            violated_windows: u("violated_windows")? as u32,
            actions: u("actions")?,
            final_replicas: u("final_replicas")? as u32,
            replica_us: f("replica_us")?,
            meta_byte_us: f("meta_byte_us")?,
            final_metadata_bytes: u("final_metadata_bytes")?,
            duration_us: f("duration_us")?,
            events: u("events")?,
        })
    }

    /// The single JSONL line (sorted keys, no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().dump()
    }
}

/// One JSONL line for a campaign sketch-accuracy cell (tagged
/// `"kind": "sketch"`; DESIGN.md §12): the exact-vs-sketch comparison
/// tallies of one compare-mode run — decision agreement and feature
/// error against the sketch's byte budget and the exact counters it
/// replaces.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchCellRecord {
    pub key: String,
    pub app: String,
    /// Prefetcher label the compare run used (always ML-gated).
    pub label: String,
    pub records: u64,
    pub trace_seed: u64,
    pub sim_seed: u64,
    /// Canonical geometry label (`w{width}d{depth}p{hll_p}k{topk}`).
    pub geom: String,
    /// Sketch footprint in bytes (count-mins + HLL + top-K).
    pub sketch_bytes: u64,
    /// What exact per-context counters would cost (3 × u64 per distinct
    /// context actually seen).
    pub exact_bytes: u64,
    /// Exact distinct source contexts.
    pub distinct_exact: u64,
    /// HLL estimate of the same cardinality (rounded).
    pub distinct_est: u64,
    /// Prefetches the run issued (count-min total — exact by design).
    pub issued: u64,
    /// Decisions where the exact and sketch-fed scores were compared.
    pub decisions: u64,
    /// Fraction of compared decisions where both sides agreed.
    pub agreement: f64,
    /// Mean absolute error over the substituted feature values.
    pub feature_mae: f64,
    /// Occupied fraction of the issue count-min.
    pub fill: f64,
}

impl SketchCellRecord {
    /// Build from a finished compare-mode run's telemetry.
    pub fn from_telemetry(
        key: &str,
        app: &str,
        label: &str,
        records: u64,
        trace_seed: u64,
        sim_seed: u64,
        geom: &str,
        t: &Telemetry,
    ) -> SketchCellRecord {
        SketchCellRecord {
            key: key.to_string(),
            app: app.to_string(),
            label: label.to_string(),
            records,
            trace_seed,
            sim_seed,
            geom: geom.to_string(),
            sketch_bytes: t.bytes(),
            exact_bytes: t.exact_counter_bytes().unwrap_or(0),
            distinct_exact: t.exact_srcs.len() as u64,
            distinct_est: t.contexts.estimate().round() as u64,
            issued: t.issued.total(),
            decisions: t.decisions_compared,
            agreement: t.agreement().unwrap_or(1.0),
            feature_mae: t.feature_mae().unwrap_or(0.0),
            fill: t.issued.fill_ratio(),
        }
    }

    /// Sketch-vs-exact byte ratio (< 1.0 means the sketch is smaller).
    pub fn byte_ratio(&self) -> f64 {
        if self.exact_bytes == 0 {
            0.0
        } else {
            self.sketch_bytes as f64 / self.exact_bytes as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("sketch")),
            ("key", Json::str(&self.key)),
            ("app", Json::str(&self.app)),
            ("label", Json::str(&self.label)),
            ("records", Json::num(self.records as f64)),
            ("trace_seed", Json::num(self.trace_seed as f64)),
            // As a string: full-range 64-bit hashes do not survive the
            // f64 JSON number path (2^53 mantissa).
            ("sim_seed", Json::str(&self.sim_seed.to_string())),
            ("geom", Json::str(&self.geom)),
            ("sketch_bytes", Json::num(self.sketch_bytes as f64)),
            ("exact_bytes", Json::num(self.exact_bytes as f64)),
            ("distinct_exact", Json::num(self.distinct_exact as f64)),
            ("distinct_est", Json::num(self.distinct_est as f64)),
            ("issued", Json::num(self.issued as f64)),
            ("decisions", Json::num(self.decisions as f64)),
            ("agreement", Json::num(self.agreement)),
            ("feature_mae", Json::num(self.feature_mae)),
            ("fill", Json::num(self.fill)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SketchCellRecord> {
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("sketch record: missing string '{k}'"))
        };
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("sketch record: missing integer '{k}'"))
        };
        let f = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("sketch record: missing number '{k}'"))
        };
        Ok(SketchCellRecord {
            key: s("key")?,
            app: s("app")?,
            label: s("label")?,
            records: u("records")?,
            trace_seed: u("trace_seed")?,
            sim_seed: j
                .get("sim_seed")
                .and_then(Json::as_str)
                .and_then(|v| v.parse().ok())
                .context("sketch record: missing u64 string 'sim_seed'")?,
            geom: s("geom")?,
            sketch_bytes: u("sketch_bytes")?,
            exact_bytes: u("exact_bytes")?,
            distinct_exact: u("distinct_exact")?,
            distinct_est: u("distinct_est")?,
            issued: u("issued")?,
            decisions: u("decisions")?,
            agreement: f("agreement")?,
            feature_mae: f("feature_mae")?,
            fill: f("fill")?,
        })
    }

    /// The single JSONL line (sorted keys, no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().dump()
    }
}

/// A parsed store line: untagged lines are simulation cells, lines
/// tagged `"kind": "cluster"` / `"kind": "sketch"` are cluster-scenario
/// / sketch-accuracy cells.
enum Record {
    Sim(CellRecord),
    Cluster(ClusterCellRecord),
    Sketch(SketchCellRecord),
}

impl Record {
    fn from_json(j: &Json) -> Result<Record> {
        match j.get("kind").and_then(Json::as_str) {
            None => Ok(Record::Sim(CellRecord::from_json(j)?)),
            Some("cluster") => Ok(Record::Cluster(ClusterCellRecord::from_json(j)?)),
            Some("sketch") => Ok(Record::Sketch(SketchCellRecord::from_json(j)?)),
            Some(other) => bail!("unknown record kind '{other}'"),
        }
    }
}

/// How a [`ResultStore`] persists records on disk.
///
/// * `Jsonl` — the original single-file append-only log: one JSON line
///   per record, replayed in full on open. Simple, diffable, fine up to
///   tens of thousands of cells.
/// * `Tiered` — a directory holding a write-ahead `wal.jsonl` tail plus
///   immutable, sorted, bloom-filtered segment files (DESIGN.md §6):
///   cold opens read only segment footers and resume probes are O(1)
///   index lookups, so campaigns can sweep millions of cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreFormat {
    Jsonl,
    Tiered,
}

impl StoreFormat {
    /// Parse a `--store-format` value.
    pub fn parse(s: &str) -> Result<StoreFormat> {
        match s {
            "jsonl" => Ok(StoreFormat::Jsonl),
            "tiered" => Ok(StoreFormat::Tiered),
            other => bail!("unknown store format '{other}' (expected 'jsonl' or 'tiered')"),
        }
    }
}

/// What [`ResultStore::compact`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactStats {
    pub segments_before: usize,
    pub segments_after: usize,
    /// Live records in the compacted store.
    pub records: usize,
    /// Superseded duplicates dropped during the merge.
    pub dropped: usize,
}

/// Name of the write-ahead tail inside a tiered store directory.
const WAL_NAME: &str = "wal.jsonl";
/// Default memtable size that triggers an automatic segment flush.
const DEFAULT_FLUSH_THRESHOLD: usize = 4096;

/// Memtable flush threshold (`SLOFETCH_STORE_FLUSH` overrides it, for
/// tests and benches).
fn flush_threshold() -> usize {
    std::env::var("SLOFETCH_STORE_FLUSH")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_FLUSH_THRESHOLD)
}

/// Kind slot of a record's JSON (0 = sim, 1 = cluster, 2 = sketch),
/// mirroring [`Record::from_json`]'s dispatch.
fn kind_of(j: &Json) -> Result<usize> {
    match j.get("kind").and_then(Json::as_str) {
        None => Ok(0),
        Some("cluster") => Ok(1),
        Some("sketch") => Ok(2),
        Some(other) => bail!("unknown record kind '{other}'"),
    }
}

impl Record {
    fn key(&self) -> &str {
        match self {
            Record::Sim(r) => &r.key,
            Record::Cluster(r) => &r.key,
            Record::Sketch(r) => &r.key,
        }
    }

    fn kind(&self) -> usize {
        match self {
            Record::Sim(_) => 0,
            Record::Cluster(_) => 1,
            Record::Sketch(_) => 2,
        }
    }

    fn to_line(&self) -> String {
        match self {
            Record::Sim(r) => r.to_line(),
            Record::Cluster(r) => r.to_line(),
            Record::Sketch(r) => r.to_line(),
        }
    }
}

/// Storage backend behind a [`ResultStore`].
enum Backend {
    /// Single-file JSONL log (or pure in-memory when `file` is `None`).
    Jsonl { file: Option<std::fs::File> },
    Tiered(Tiered),
}

/// Tiered backend state: the open segment set plus the write-ahead
/// tail the memtable mirrors.
struct Tiered {
    dir: PathBuf,
    /// `None` on read-only ([`ResultStore::load`]) handles; pushes then
    /// stay in memory, like a file-less JSONL store.
    wal: Option<std::fs::File>,
    threshold: usize,
    /// Open segments, sorted by `min_seq` (flush order).
    segments: Vec<Segment>,
    /// Segment files that failed to open (torn footer, CRC mismatch):
    /// renamed to `*.seg.quarantined` and preserved for inspection,
    /// never silently dropped.
    quarantined: Vec<PathBuf>,
}

impl Tiered {
    /// Exact membership probe across all segments. Probe errors degrade
    /// to "absent" (the cell is recomputed; push-side dedup absorbs any
    /// duplicate) rather than aborting a campaign.
    fn segments_contain(&self, key: &str) -> bool {
        for seg in &self.segments {
            match seg.contains(key) {
                Ok(true) => return true,
                Ok(false) => {}
                Err(e) => {
                    crate::obs_warn!(
                        "store: probe of {:?} failed ({e:#}); treating '{key}' as absent",
                        seg.path()
                    );
                }
            }
        }
        false
    }
}

/// The campaign's memory: resume probes (`contains`), append-with-dedup
/// (`push*`), and the record scans reports aggregate. Two backends
/// implement one contract — records are immutable once written, the
/// first writer wins a key, and emission order is recoverable — the
/// legacy single-file JSONL log and the tiered memtable → WAL → segment
/// layout (DESIGN.md §6).
pub struct ResultStore {
    /// Recent records: everything for JSONL stores, the unflushed
    /// memtable for tiered ones. Each record carries its global
    /// sequence number (append order over the store's lifetime), which
    /// scans use to recover emission order from key-sorted segments.
    mem: Vec<(u64, Record)>,
    /// Keys of `mem` records (segment membership is probed separately).
    mem_keys: HashSet<String>,
    next_seq: u64,
    backend: Backend,
}

impl ResultStore {
    /// A store with no backing file (tests, ad-hoc aggregation).
    pub fn in_memory() -> ResultStore {
        ResultStore {
            mem: Vec::new(),
            mem_keys: HashSet::new(),
            next_seq: 0,
            backend: Backend::Jsonl { file: None },
        }
    }

    /// Parse a JSONL file (a legacy store or a tiered store's WAL) into
    /// records, in file order with first-record-wins dedup. A final
    /// line with no trailing newline is the signature of a killed
    /// mid-write campaign and is tolerated; a malformed *complete* line
    /// is an error. Also returns the byte length to truncate to
    /// (partial unparseable tail) and whether the tail lacked its
    /// newline, for the writable opens' repair.
    fn parse_jsonl(path: &Path) -> Result<(Vec<Record>, Option<u64>, bool)> {
        let mut out = Vec::new();
        let mut keys: HashSet<String> = HashSet::new();
        let mut keep_bytes: Option<u64> = None;
        let mut truncated_tail = false;
        if path.exists() {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
            truncated_tail = !text.is_empty() && !text.ends_with('\n');
            let mut offset = 0usize;
            for (no, line) in text.split_inclusive('\n').enumerate() {
                let complete = line.ends_with('\n');
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    let parsed = Json::parse(trimmed)
                        .map_err(anyhow::Error::from)
                        .and_then(|j| Record::from_json(&j));
                    match parsed {
                        // Mirror push(): first record wins on key
                        // conflicts (e.g. concatenated shard files).
                        Ok(rec) => {
                            if keys.insert(rec.key().to_string()) {
                                out.push(rec);
                            }
                        }
                        Err(_) if !complete && truncated_tail => {
                            // Partial final write: drop it from the file.
                            keep_bytes = Some(offset as u64);
                            break;
                        }
                        Err(e) => {
                            return Err(e.context(format!("{path:?} line {}", no + 1)))
                        }
                    }
                }
                offset += line.len();
            }
        }
        Ok((out, keep_bytes, truncated_tail))
    }

    /// Build a store over `backend`, assigning sequence numbers from 0.
    fn from_records(records: Vec<Record>, backend: Backend) -> ResultStore {
        let mut store = ResultStore {
            mem: Vec::new(),
            mem_keys: HashSet::new(),
            next_seq: 0,
            backend,
        };
        for rec in records {
            store.mem_keys.insert(rec.key().to_string());
            let seq = store.next_seq;
            store.next_seq += 1;
            store.mem.push((seq, rec));
        }
        store
    }

    /// Open a legacy single-file JSONL store for writing: load existing
    /// lines, then repair any killed-mid-write tail (truncate a partial
    /// line, or newline-terminate a complete one) so appends land on a
    /// clean line boundary (crash-resume contract, DESIGN.md §6).
    fn open_jsonl(path: &Path) -> Result<ResultStore> {
        let (records, keep_bytes, truncated_tail) = Self::parse_jsonl(path)?;
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open {path:?}"))?;
        if let Some(len) = keep_bytes {
            file.set_len(len).with_context(|| format!("truncate {path:?}"))?;
        } else if truncated_tail {
            file.write_all(b"\n").with_context(|| format!("repair {path:?}"))?;
        }
        Ok(Self::from_records(records, Backend::Jsonl { file: Some(file) }))
    }

    /// Scratch sibling used while migrating a legacy file to a tiered
    /// directory (`<store>.migrate-tmp`).
    fn migrate_tmp_path(path: &Path) -> PathBuf {
        let mut os = path.as_os_str().to_os_string();
        os.push(".migrate-tmp");
        PathBuf::from(os)
    }

    /// Import a legacy single-file JSONL store in place: the file
    /// becomes the new tiered store's WAL, so every old record resumes
    /// with its key (and report bytes) intact. The dance is
    /// crash-recoverable: mkdir tmp → move file into tmp as `wal.jsonl`
    /// → rename tmp over the original path; any prefix of it left by a
    /// crash is finished on the next open.
    fn migrate_legacy(path: &Path) -> Result<()> {
        let tmp = Self::migrate_tmp_path(path);
        if tmp.exists() {
            if path.is_dir() {
                // A previous migration completed; the tmp dir is stale.
                std::fs::remove_dir_all(&tmp)
                    .with_context(|| format!("remove stale {tmp:?}"))?;
                return Ok(());
            }
            if path.is_file() {
                std::fs::rename(path, tmp.join(WAL_NAME))
                    .with_context(|| format!("resume migration of {path:?}"))?;
            }
            std::fs::rename(&tmp, path)
                .with_context(|| format!("finish migration of {path:?}"))?;
            crate::obs_info!("store: completed interrupted migration of {path:?}");
            return Ok(());
        }
        if path.is_file() {
            std::fs::create_dir_all(&tmp).with_context(|| format!("mkdir {tmp:?}"))?;
            std::fs::rename(path, tmp.join(WAL_NAME))
                .with_context(|| format!("stage legacy store {path:?}"))?;
            std::fs::rename(&tmp, path)
                .with_context(|| format!("finish migration of {path:?}"))?;
            crate::obs_info!(
                "store: imported legacy JSONL store {path:?} into the tiered layout"
            );
        }
        Ok(())
    }

    /// Open a tiered store directory. `writable` handles repair crash
    /// damage (quarantine unreadable segments, delete stale flush
    /// temps, truncate a torn WAL tail) and hold the WAL open for
    /// appends; read-only handles just skip what they cannot parse.
    fn open_tiered(path: &Path, writable: bool) -> Result<ResultStore> {
        let mut segments = Vec::new();
        let mut quarantined = Vec::new();
        if path.exists() {
            let mut paths: Vec<PathBuf> = std::fs::read_dir(path)
                .with_context(|| format!("read store dir {path:?}"))?
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .collect();
            paths.sort();
            for p in paths {
                let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
                if name.ends_with(".seg.tmp") {
                    // A flush died before its rename; the WAL still
                    // holds every record, so the partial file is junk.
                    if writable {
                        std::fs::remove_file(&p).ok();
                    }
                } else if name.ends_with(".seg.quarantined") {
                    quarantined.push(p);
                } else if name.ends_with(".seg") {
                    match Segment::open(&p) {
                        Ok(seg) => segments.push(seg),
                        Err(e) if writable => {
                            let q = p.with_extension("seg.quarantined");
                            match std::fs::rename(&p, &q) {
                                Ok(()) => {
                                    crate::obs_warn!(
                                        "store: quarantined unreadable segment {p:?} ({e:#})"
                                    );
                                    quarantined.push(q);
                                }
                                Err(re) => {
                                    crate::obs_warn!(
                                        "store: cannot quarantine {p:?} ({re}); unreadable: {e:#}"
                                    );
                                    quarantined.push(p);
                                }
                            }
                        }
                        Err(e) => {
                            crate::obs_warn!("store: skipping unreadable segment {p:?} ({e:#})");
                            quarantined.push(p);
                        }
                    }
                }
            }
        } else if writable {
            std::fs::create_dir_all(path).with_context(|| format!("mkdir {path:?}"))?;
        }
        segments.sort_by_key(|s| s.min_seq);
        let mut next_seq = segments.iter().map(|s| s.max_seq + 1).max().unwrap_or(0);
        let wal_path = path.join(WAL_NAME);
        let (wal_records, keep_bytes, truncated_tail) = Self::parse_jsonl(&wal_path)?;
        let wal = if writable {
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&wal_path)
                .with_context(|| format!("open {wal_path:?}"))?;
            if let Some(len) = keep_bytes {
                f.set_len(len).with_context(|| format!("truncate {wal_path:?}"))?;
            } else if truncated_tail {
                f.write_all(b"\n").with_context(|| format!("repair {wal_path:?}"))?;
            }
            Some(f)
        } else {
            None
        };
        let threshold = flush_threshold();
        let tiered = Tiered { dir: path.to_path_buf(), wal, threshold, segments, quarantined };
        let mut mem = Vec::new();
        let mut mem_keys = HashSet::new();
        for rec in wal_records {
            // Crash window: a flush renamed its segment but died before
            // the WAL truncate — those records are already durable.
            if tiered.segments_contain(rec.key()) {
                continue;
            }
            mem_keys.insert(rec.key().to_string());
            mem.push((next_seq, rec));
            next_seq += 1;
        }
        let mut store =
            ResultStore { mem, mem_keys, next_seq, backend: Backend::Tiered(tiered) };
        if writable && store.mem.len() >= threshold {
            // E.g. a freshly imported legacy store: fold the whole WAL
            // into a segment now so the next open is footer-only.
            store.flush()?;
        }
        Ok(store)
    }

    /// Read a result store without touching it — no write access, no
    /// crash repair, no quarantining. For aggregating shard stores
    /// (feed into [`ResultStore::merge`]) and read-only reporting.
    /// Accepts both layouts (a file is a JSONL log, a directory a
    /// tiered store).
    pub fn load(path: &Path) -> Result<ResultStore> {
        if path.is_dir() {
            Self::open_tiered(path, false)
        } else {
            let (records, _, _) = Self::parse_jsonl(path)?;
            Ok(Self::from_records(records, Backend::Jsonl { file: None }))
        }
    }

    /// Open a store for a campaign run, auto-detecting the layout: an
    /// existing directory opens as tiered, anything else (including a
    /// missing path) as a legacy JSONL file. Use
    /// [`ResultStore::open_format`] to force a layout — notably to
    /// import a legacy file into the tiered layout.
    pub fn open(path: &Path) -> Result<ResultStore> {
        if path.is_dir() {
            Self::open_format(path, StoreFormat::Tiered)
        } else {
            Self::open_format(path, StoreFormat::Jsonl)
        }
    }

    /// Open a store in an explicit format. `Tiered` on a legacy JSONL
    /// file transparently imports it (see [`ResultStore::load`] for
    /// read-only access); `Jsonl` on a tiered directory is an error.
    pub fn open_format(path: &Path, format: StoreFormat) -> Result<ResultStore> {
        match format {
            StoreFormat::Jsonl => {
                if path.is_dir() {
                    bail!(
                        "{path:?} is a tiered store directory; open it with --store-format tiered"
                    );
                }
                Self::open_jsonl(path)
            }
            StoreFormat::Tiered => {
                Self::migrate_legacy(path)?;
                Self::open_tiered(path, true)
            }
        }
    }

    /// Total stored records (simulation + cluster + sketch cells).
    pub fn len(&self) -> usize {
        let flushed: usize = match &self.backend {
            Backend::Tiered(t) => t.segments.iter().map(|s| s.record_count()).sum(),
            Backend::Jsonl { .. } => 0,
        };
        self.mem.len() + flushed
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Exact membership probe: the memtable key set, then each
    /// segment's bloom filter + sparse index (an O(1) probe per
    /// segment, not a log replay).
    pub fn contains(&self, key: &str) -> bool {
        if self.mem_keys.contains(key) {
            return true;
        }
        match &self.backend {
            Backend::Tiered(t) => t.segments_contain(key),
            Backend::Jsonl { .. } => false,
        }
    }

    /// Stream records of one kind slot in emission order: segments in
    /// flush order (each re-sorted by sequence number — segment seq
    /// ranges are disjoint, so per-segment order is global order), then
    /// the memtable. Loads one segment at a time; segments holding no
    /// record of the kind are skipped entirely (the report range-scan
    /// path).
    fn for_each_record(
        &self,
        kind: usize,
        mut f: impl FnMut(&Record) -> Result<()>,
    ) -> Result<()> {
        if let Backend::Tiered(t) = &self.backend {
            for seg in &t.segments {
                if seg.kind_count(kind) == 0 {
                    continue;
                }
                let mut entries = seg.load_entries()?;
                entries.sort_by_key(|&(_, seq, _)| seq);
                for (_, _, j) in &entries {
                    if kind_of(j)? != kind {
                        continue;
                    }
                    f(&Record::from_json(j)?)?;
                }
            }
        }
        for (_, rec) in &self.mem {
            if rec.kind() == kind {
                f(rec)?;
            }
        }
        Ok(())
    }

    /// Stream every simulation record in emission order, one segment in
    /// memory at a time — the bounded-memory path behind
    /// [`ResultStore::records`] and large merges.
    pub fn for_each_sim(&self, mut f: impl FnMut(&CellRecord) -> Result<()>) -> Result<()> {
        self.for_each_record(0, |r| match r {
            Record::Sim(c) => f(c),
            _ => Ok(()),
        })
    }

    /// Stream every cluster-scenario record in emission order (see
    /// [`ResultStore::for_each_sim`]).
    pub fn for_each_cluster(
        &self,
        mut f: impl FnMut(&ClusterCellRecord) -> Result<()>,
    ) -> Result<()> {
        self.for_each_record(1, |r| match r {
            Record::Cluster(c) => f(c),
            _ => Ok(()),
        })
    }

    /// Stream every sketch-accuracy record in emission order (see
    /// [`ResultStore::for_each_sim`]).
    pub fn for_each_sketch(
        &self,
        mut f: impl FnMut(&SketchCellRecord) -> Result<()>,
    ) -> Result<()> {
        self.for_each_record(2, |r| match r {
            Record::Sketch(c) => f(c),
            _ => Ok(()),
        })
    }

    /// All simulation records in emission order, materialized. Prefer
    /// [`ResultStore::for_each_sim`] when a streaming pass suffices. A
    /// segment read failure degrades to the readable prefix (with an
    /// error-level diagnostic) so reporting stays best-effort.
    pub fn records(&self) -> Vec<CellRecord> {
        let mut out = Vec::new();
        if let Err(e) = self.for_each_sim(|r| {
            out.push(r.clone());
            Ok(())
        }) {
            crate::obs_error!("store: sim record scan failed: {e:#}");
        }
        out
    }

    /// All cluster-scenario records in emission order, materialized
    /// (see [`ResultStore::records`]).
    pub fn cluster_records(&self) -> Vec<ClusterCellRecord> {
        let mut out = Vec::new();
        if let Err(e) = self.for_each_cluster(|r| {
            out.push(r.clone());
            Ok(())
        }) {
            crate::obs_error!("store: cluster record scan failed: {e:#}");
        }
        out
    }

    /// All sketch-accuracy records in emission order, materialized (see
    /// [`ResultStore::records`]).
    pub fn sketch_records(&self) -> Vec<SketchCellRecord> {
        let mut out = Vec::new();
        if let Err(e) = self.for_each_sketch(|r| {
            out.push(r.clone());
            Ok(())
        }) {
            crate::obs_error!("store: sketch record scan failed: {e:#}");
        }
        out
    }

    /// Append one record (no-op returning `false` if the key is already
    /// present). Writes through to the backing file — the JSONL log, or
    /// the tiered store's WAL, flushing the memtable into a segment at
    /// the size threshold.
    pub fn push(&mut self, rec: CellRecord) -> Result<bool> {
        self.push_record(Record::Sim(rec))
    }

    /// Append one cluster-scenario record (same dedup/write-through
    /// semantics as [`ResultStore::push`]; the key space is shared).
    pub fn push_cluster(&mut self, rec: ClusterCellRecord) -> Result<bool> {
        self.push_record(Record::Cluster(rec))
    }

    /// Append one sketch-accuracy record (same dedup/write-through
    /// semantics as [`ResultStore::push`]; the key space is shared).
    pub fn push_sketch(&mut self, rec: SketchCellRecord) -> Result<bool> {
        self.push_record(Record::Sketch(rec))
    }

    fn push_record(&mut self, rec: Record) -> Result<bool> {
        if self.contains(rec.key()) {
            return Ok(false);
        }
        match &mut self.backend {
            Backend::Jsonl { file } => {
                if let Some(f) = file {
                    writeln!(f, "{}", rec.to_line()).context("append to result store")?;
                }
            }
            Backend::Tiered(t) => {
                if let Some(w) = &mut t.wal {
                    writeln!(w, "{}", rec.to_line()).context("append to store wal")?;
                }
            }
        }
        self.mem_keys.insert(rec.key().to_string());
        let seq = self.next_seq;
        self.next_seq += 1;
        self.mem.push((seq, rec));
        let full = matches!(&self.backend,
            Backend::Tiered(t) if t.wal.is_some() && self.mem.len() >= t.threshold);
        if full {
            self.flush()?;
        }
        Ok(true)
    }

    /// Fold another store's records into this one (first writer wins on
    /// key conflicts). Returns how many records were new. Streams the
    /// other store kind by kind — one segment in memory at a time, one
    /// record cloned per append — so merging fleet-scale shards keeps
    /// memory bounded.
    pub fn merge(&mut self, other: &ResultStore) -> Result<usize> {
        let mut added = 0;
        other.for_each_sim(|r| {
            if self.push(r.clone())? {
                added += 1;
            }
            Ok(())
        })?;
        other.for_each_cluster(|r| {
            if self.push_cluster(r.clone())? {
                added += 1;
            }
            Ok(())
        })?;
        other.for_each_sketch(|r| {
            if self.push_sketch(r.clone())? {
                added += 1;
            }
            Ok(())
        })?;
        Ok(added)
    }

    /// Flush the memtable into a new immutable segment and truncate the
    /// WAL. No-op for JSONL stores, empty memtables, and read-only
    /// handles. The segment rename happens before the WAL truncate, so
    /// a crash between the two leaves records duplicated on disk but
    /// deduplicated on open — never lost.
    pub fn flush(&mut self) -> Result<()> {
        if self.mem.is_empty() || !matches!(&self.backend, Backend::Tiered(_)) {
            return Ok(());
        }
        let entries: Vec<SegEntry> = self
            .mem
            .iter()
            .map(|(seq, rec)| SegEntry {
                key: rec.key().to_string(),
                seq: *seq,
                kind: rec.kind(),
                json: rec.to_line(),
            })
            .collect();
        let Backend::Tiered(t) = &mut self.backend else {
            return Ok(());
        };
        let Some(wal) = &t.wal else {
            return Ok(());
        };
        let seg = Segment::write(&t.dir, entries)?;
        wal.set_len(0).context("truncate store wal after flush")?;
        // Re-flushing identical contents reuses the content-hashed
        // filename; drop any stale handle to the same path.
        t.segments.retain(|s| s.path() != seg.path());
        t.segments.push(seg);
        self.mem.clear();
        self.mem_keys.clear();
        Ok(())
    }

    /// Merge all segments into one, dropping superseded duplicates
    /// (lowest sequence number wins, matching push-side
    /// first-writer-wins). Explicit and foreground-only — campaigns
    /// never pay a surprise compaction; run `slofetch campaign compact`
    /// between sweeps. The memtable is flushed first so the result is a
    /// single segment and an empty WAL.
    pub fn compact(&mut self) -> Result<CompactStats> {
        if !matches!(&self.backend, Backend::Tiered(_)) {
            bail!("compact requires a tiered store (--store-format tiered)");
        }
        self.flush()?;
        let Backend::Tiered(t) = &mut self.backend else {
            unreachable!("checked above");
        };
        if t.wal.is_none() {
            bail!("compact requires a writable store handle");
        }
        let before = t.segments.len();
        let total: usize = t.segments.iter().map(|s| s.record_count()).sum();
        if before <= 1 {
            return Ok(CompactStats {
                segments_before: before,
                segments_after: before,
                records: total,
                dropped: 0,
            });
        }
        // Lowest seq wins per key; BTreeMap keeps the merge key-sorted
        // and deterministic.
        let mut keep: BTreeMap<String, (u64, usize, String)> = BTreeMap::new();
        for seg in &t.segments {
            for (key, seq, j) in seg.load_entries()? {
                let kind = kind_of(&j)?;
                match keep.get(&key) {
                    Some((have, _, _)) if *have <= seq => {}
                    // parse→dump is byte-stable (sorted keys, canonical
                    // number form), so rewriting preserves record bytes.
                    _ => {
                        keep.insert(key, (seq, kind, j.dump()));
                    }
                }
            }
        }
        let records = keep.len();
        let dropped = total - records;
        let entries: Vec<SegEntry> = keep
            .into_iter()
            .map(|(key, (seq, kind, json))| SegEntry { key, seq, kind, json })
            .collect();
        let merged = Segment::write(&t.dir, entries)?;
        let old_paths: Vec<PathBuf> =
            t.segments.iter().map(|s| s.path().to_path_buf()).collect();
        t.segments = vec![merged];
        for p in old_paths {
            if p != t.segments[0].path() {
                std::fs::remove_file(&p)
                    .with_context(|| format!("remove compacted segment {p:?}"))?;
            }
        }
        Ok(CompactStats { segments_before: before, segments_after: 1, records, dropped })
    }

    /// Open segment files (0 for JSONL stores).
    pub fn segment_count(&self) -> usize {
        match &self.backend {
            Backend::Tiered(t) => t.segments.len(),
            Backend::Jsonl { .. } => 0,
        }
    }

    /// Segment files that failed to open and were quarantined
    /// (`*.seg.quarantined`) instead of silently dropped. Their cells
    /// read as absent and are recomputed on the next run.
    pub fn quarantined(&self) -> &[PathBuf] {
        match &self.backend {
            Backend::Tiered(t) => &t.quarantined,
            Backend::Jsonl { .. } => &[],
        }
    }

    /// Override the memtable flush threshold (tests and benches; the
    /// `SLOFETCH_STORE_FLUSH` env var sets the process default).
    pub fn set_flush_threshold(&mut self, records: usize) {
        if let Backend::Tiered(t) = &mut self.backend {
            t.threshold = records.max(1);
        }
    }
}
#[cfg(test)]
mod tests {
    use super::*;

    fn rec(key: &str, app: &str, label: &str, ipc: f64) -> CellRecord {
        CellRecord {
            key: key.into(),
            app: app.into(),
            label: label.into(),
            records: 1000,
            trace_seed: 7,
            sim_seed: 42,
            ml: false,
            churn_scale: 1.0,
            ipc,
            speedup: Some(1.05),
            mpki: 12.0,
            l1d_mpki: 3.0,
            accuracy: 0.8,
            coverage: 0.6,
            timeliness: 0.9,
            metadata_bytes: 25_200,
            pf_issued: 100,
            pf_timely: 70,
            pf_late: 10,
            pf_useless: 20,
            pf_skipped: 0,
            instrs: 16_000,
            cycles: 9_000.0,
            controller: Some(ControllerRecord {
                decisions: 50,
                issued: 40,
                skipped: 10,
                trains: 3,
                last_loss: 0.25,
            }),
            tail: None,
        }
    }

    fn crec(key: &str, policy: &str) -> ClusterCellRecord {
        ClusterCellRecord {
            key: key.into(),
            cluster: "frontend".into(),
            policy: policy.into(),
            tenant: String::new(),
            faults: String::new(),
            service_times: "analytic".into(),
            traffic: "poisson:0.65".into(),
            requests: 50_000,
            slo_us: 120.0,
            p50_us: 22.0,
            p95_us: 61.0,
            p99_us: 98.5,
            compliance: 0.993,
            windows: 25,
            violated_windows: 2,
            actions: 5,
            final_replicas: 9,
            replica_us: 4.2e6,
            meta_byte_us: 9.1e9,
            final_metadata_bytes: 131_072,
            duration_us: 6.0e5,
            events: 550_000,
        }
    }

    fn srec(key: &str, geom: &str) -> SketchCellRecord {
        SketchCellRecord {
            key: key.into(),
            app: "websearch".into(),
            label: "nl+ml".into(),
            records: 10_000,
            trace_seed: 3,
            sim_seed: 0xFEED_FACE_DEAD_BEEF,
            geom: geom.into(),
            sketch_bytes: 13_824,
            exact_bytes: 72_000,
            distinct_exact: 3_000,
            distinct_est: 2_950,
            issued: 45_000,
            decisions: 20_000,
            agreement: 0.972,
            feature_mae: 0.031,
            fill: 0.42,
        }
    }

    #[test]
    fn sketch_record_json_roundtrip_and_store_integration() {
        let r = srec("sketch|websearch|nl|r10000|s3|w256d4p10k16", "w256d4p10k16");
        let line = r.to_line();
        assert!(line.contains("\"kind\":\"sketch\""), "missing kind tag: {line}");
        let back = SketchCellRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.sim_seed, 0xFEED_FACE_DEAD_BEEF, "sim_seed truncated");
        assert!((r.byte_ratio() - 13_824.0 / 72_000.0).abs() < 1e-12);
        // File round-trip alongside the other record kinds, with dedup.
        let dir = std::env::temp_dir().join("slofetch_store_sketch");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sketch.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut s = ResultStore::open(&path).unwrap();
            assert!(s.push(rec("a", "crypto", "nl", 1.0)).unwrap());
            assert!(s.push_sketch(r.clone()).unwrap());
            assert!(!s.push_sketch(srec(&r.key, "w1d1p4k1")).unwrap(), "dedup failed");
            assert_eq!(s.len(), 2);
        }
        let reloaded = ResultStore::open(&path).unwrap();
        assert_eq!(reloaded.sketch_records().len(), 1);
        assert_eq!(reloaded.sketch_records()[0], r);
        assert!(reloaded.contains(&r.key));
        // Merge folds sketch records too, first writer winning.
        let mut main = ResultStore::in_memory();
        main.push_sketch(srec(&r.key, "stale")).unwrap();
        assert_eq!(main.merge(&reloaded).unwrap(), 1, "only the sim line is new");
        assert_eq!(main.sketch_records()[0].geom, "stale");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cluster_record_json_roundtrip_and_kind_tag() {
        let mut r = crec("cluster|frontend#abc|reactive|tpoisson:0.65", "reactive");
        r.service_times = "empirical".into();
        let line = r.to_line();
        assert!(line.contains("\"kind\":\"cluster\""), "missing kind tag: {line}");
        assert!(line.contains("\"service_times\":\"empirical\""), "model missing: {line}");
        let back =
            ClusterCellRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
        assert!((r.burn_rate() - 0.08).abs() < 1e-9);
    }

    #[test]
    fn tenant_cells_roundtrip_and_tenantless_lines_stay_byte_identical() {
        // Tenant cells serialize and reload their coordinate...
        let mut r = crec("cluster|shared#1|coloc|web|tpoisson:0.5", "coloc");
        r.tenant = "web".into();
        let line = r.to_line();
        assert!(line.contains("\"tenant\":\"web\""), "tenant missing: {line}");
        let back = ClusterCellRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
        // ...while policy cells carry no tenant key at all, so lines
        // written by pre-tenancy builds and by this build are identical.
        let plain = crec("cluster|frontend#1|reactive|tpoisson:0.65", "reactive");
        assert!(!plain.to_line().contains("tenant"), "tenant leaked: {}", plain.to_line());
        // A literal pre-tenancy line (no "tenant" key) reloads with the
        // empty default.
        let back =
            ClusterCellRecord::from_json(&Json::parse(&plain.to_line()).unwrap()).unwrap();
        assert_eq!(back, plain);
        assert_eq!(back.tenant, "");
    }

    #[test]
    fn fault_cells_roundtrip_and_healthy_lines_stay_byte_identical() {
        // Fault-regime cells serialize and reload their coordinate...
        let mut r = crec("cluster|frontend#1|reactive|tpoisson:0.65|fdown:be:0:1:2", "reactive");
        r.faults = "down:be:0:1:2".into();
        let line = r.to_line();
        assert!(line.contains("\"faults\":\"down:be:0:1:2\""), "faults missing: {line}");
        let back = ClusterCellRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
        // ...while healthy-regime cells carry no faults key at all, so
        // lines written by pre-fault builds and by this build are
        // identical — and pre-fault lines reload with the empty default.
        let plain = crec("cluster|frontend#1|reactive|tpoisson:0.65", "reactive");
        assert!(!plain.to_line().contains("faults"), "faults leaked: {}", plain.to_line());
        let back =
            ClusterCellRecord::from_json(&Json::parse(&plain.to_line()).unwrap()).unwrap();
        assert_eq!(back, plain);
        assert_eq!(back.faults, "");
    }

    #[test]
    fn pre_empirical_cluster_lines_reload_as_analytic() {
        // Lines written before the service-time models have no
        // "service_times" key; they ran the analytic model.
        let r = crec("old-cluster", "reactive");
        let line = r.to_line().replace(",\"service_times\":\"analytic\"", "");
        assert!(!line.contains("service_times"), "test setup failed to strip the key");
        let back = ClusterCellRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn store_holds_sim_and_cluster_records_side_by_side() {
        let dir = std::env::temp_dir().join("slofetch_store_mixed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mixed.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut s = ResultStore::open(&path).unwrap();
            assert!(s.push(rec("a", "crypto", "nl", 1.0)).unwrap());
            assert!(s.push_cluster(crec("cl1", "reactive")).unwrap());
            assert!(!s.push_cluster(crec("cl1", "hysteresis")).unwrap(), "dedup failed");
            assert_eq!(s.len(), 2);
        }
        let reloaded = ResultStore::open(&path).unwrap();
        assert_eq!(reloaded.records().len(), 1);
        assert_eq!(reloaded.cluster_records().len(), 1);
        assert_eq!(reloaded.cluster_records()[0].policy, "reactive");
        assert!(reloaded.contains("cl1"));
        // Merge folds both record kinds.
        let mut main = ResultStore::in_memory();
        main.push_cluster(crec("cl1", "stale")).unwrap();
        assert_eq!(main.merge(&reloaded).unwrap(), 1, "only the sim line is new");
        assert_eq!(main.cluster_records()[0].policy, "stale", "first writer must win");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_record_kind_is_an_error() {
        let dir = std::env::temp_dir().join("slofetch_store_kind");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kind.jsonl");
        std::fs::write(&path, "{\"kind\":\"martian\",\"key\":\"x\"}\n").unwrap();
        assert!(ResultStore::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn record_json_roundtrip() {
        let mut r = rec("k1", "crypto", "ceip256", 2.5);
        r.tail = Some(TailRecord {
            traffic: "burst:0.5:3:50000:0.2".into(),
            p50_us: 6.1,
            p95_us: 14.9,
            p99_us: 31.5,
            compliance: 0.97,
            slo_us: 25.0,
        });
        let back = CellRecord::from_json(&Json::parse(&r.to_line()).unwrap()).unwrap();
        assert_eq!(back, r);
        // Null speedup/controller/tail round-trip too.
        let mut r2 = r;
        r2.speedup = None;
        r2.controller = None;
        r2.tail = None;
        let back2 = CellRecord::from_json(&Json::parse(&r2.to_line()).unwrap()).unwrap();
        assert_eq!(back2, r2);
    }

    #[test]
    fn pre_traffic_lines_reload_without_tail() {
        // Lines written before the traffic axis have no "tail" key.
        let r = rec("old", "crypto", "nl", 1.0);
        let line = r.to_line().replace(",\"tail\":null", "");
        assert!(!line.contains("tail"), "test setup failed to strip the key");
        let back = CellRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn store_dedups_by_key() {
        let mut s = ResultStore::in_memory();
        assert!(s.push(rec("a", "crypto", "nl", 1.0)).unwrap());
        assert!(!s.push(rec("a", "crypto", "nl", 9.9)).unwrap());
        assert!(s.push(rec("b", "crypto", "eip256", 1.1)).unwrap());
        assert_eq!(s.len(), 2);
        assert!(s.contains("a"));
        assert!(!s.contains("c"));
        // First writer won.
        assert_eq!(s.records()[0].ipc, 1.0);
    }

    #[test]
    fn file_roundtrip_and_resume() {
        let dir = std::env::temp_dir().join("slofetch_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut s = ResultStore::open(&path).unwrap();
            s.push(rec("a", "crypto", "nl", 1.0)).unwrap();
            s.push(rec("b", "serde", "eip256", 1.2)).unwrap();
        }
        let reloaded = ResultStore::open(&path).unwrap();
        assert_eq!(reloaded.len(), 2);
        assert!(reloaded.contains("a") && reloaded.contains("b"));
        assert_eq!(reloaded.records()[1].ipc, 1.2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_counts_new_records_only() {
        let mut a = ResultStore::in_memory();
        a.push(rec("a", "crypto", "nl", 1.0)).unwrap();
        let mut b = ResultStore::in_memory();
        b.push(rec("a", "crypto", "nl", 2.0)).unwrap();
        b.push(rec("c", "crypto", "perfect", 3.0)).unwrap();
        assert_eq!(a.merge(&b).unwrap(), 1);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn full_range_sim_seed_roundtrips_exactly() {
        // cell_seed() yields full 64-bit hashes; the f64 JSON number
        // path would round anything above 2^53.
        let mut r = rec("k", "crypto", "nl", 1.0);
        r.sim_seed = 0xDEAD_BEEF_CAFE_F00D;
        let back = CellRecord::from_json(&Json::parse(&r.to_line()).unwrap()).unwrap();
        assert_eq!(back.sim_seed, 0xDEAD_BEEF_CAFE_F00D);
    }

    #[test]
    fn truncated_final_line_is_dropped_and_store_resumes() {
        let dir = std::env::temp_dir().join("slofetch_store_truncated");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("killed.jsonl");
        // Two complete lines, then a partial write (no trailing newline)
        // as left behind by a killed campaign.
        let mut content = String::new();
        content.push_str(&rec("a", "crypto", "nl", 1.0).to_line());
        content.push('\n');
        content.push_str(&rec("b", "crypto", "eip256", 1.1).to_line());
        content.push('\n');
        content.push_str("{\"key\":\"c\",\"app\":\"cry");
        std::fs::write(&path, &content).unwrap();
        let mut store = ResultStore::open(&path).unwrap();
        assert_eq!(store.len(), 2, "completed prefix must survive");
        // Appending after recovery lands on a clean line boundary.
        store.push(rec("c", "crypto", "perfect", 1.3)).unwrap();
        drop(store);
        let reloaded = ResultStore::open(&path).unwrap();
        assert_eq!(reloaded.len(), 3);
        assert!(reloaded.contains("c"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn complete_tail_missing_newline_is_repaired() {
        let dir = std::env::temp_dir().join("slofetch_store_nonewline");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("killed2.jsonl");
        // Killed between the JSON bytes and the '\n': the tail parses
        // but must be newline-terminated before the next append.
        let content = format!(
            "{}\n{}",
            rec("a", "crypto", "nl", 1.0).to_line(),
            rec("b", "crypto", "eip256", 1.1).to_line()
        );
        std::fs::write(&path, &content).unwrap();
        let mut store = ResultStore::open(&path).unwrap();
        assert_eq!(store.len(), 2, "parseable tail must be kept");
        store.push(rec("c", "crypto", "perfect", 1.3)).unwrap();
        drop(store);
        let reloaded = ResultStore::open(&path).unwrap();
        assert_eq!(reloaded.len(), 3, "append after repair corrupted the file");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_is_read_only_even_with_truncated_tail() {
        let dir = std::env::temp_dir().join("slofetch_store_load");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard.jsonl");
        // Shard with a killed tail: load must read it without repair.
        let content =
            format!("{}\n{{\"key\":\"partial", rec("a", "crypto", "nl", 1.0).to_line());
        std::fs::write(&path, &content).unwrap();
        let loaded = ResultStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(std::fs::read_to_string(&path).unwrap(), content, "load modified file");
        // And it feeds merge like any other store.
        let mut main = ResultStore::in_memory();
        assert_eq!(main.merge(&loaded).unwrap(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_dedups_concatenated_shards() {
        let dir = std::env::temp_dir().join("slofetch_store_dedup");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cat.jsonl");
        // `cat shard1 shard2` with one overlapping cell.
        let content = format!(
            "{}\n{}\n{}\n",
            rec("a", "crypto", "nl", 1.0).to_line(),
            rec("b", "crypto", "eip256", 1.1).to_line(),
            rec("a", "crypto", "nl", 9.9).to_line()
        );
        std::fs::write(&path, &content).unwrap();
        let store = ResultStore::open(&path).unwrap();
        assert_eq!(store.len(), 2, "duplicate key double-counted");
        assert_eq!(store.records()[0].ipc, 1.0, "first record must win");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_rejects_corrupt_lines() {
        let dir = std::env::temp_dir().join("slofetch_store_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{not json\n").unwrap();
        assert!(ResultStore::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// Fresh scratch directory for tiered-store tests.
    fn tdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(name);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn tiered_store_flushes_probes_and_resumes() {
        let dir = tdir("slofetch_store_tiered");
        let path = dir.join("r.store");
        {
            let mut s = ResultStore::open_format(&path, StoreFormat::Tiered).unwrap();
            s.set_flush_threshold(2);
            s.push(rec("a", "crypto", "nl", 1.0)).unwrap();
            s.push(rec("b", "serde", "eip256", 1.1)).unwrap(); // flush 1
            s.push_cluster(crec("cl", "reactive")).unwrap();
            s.push_sketch(srec("sk", "w1024d4")).unwrap(); // flush 2
            s.push(rec("c", "http", "perfect", 1.2)).unwrap(); // stays in WAL
            assert_eq!(s.segment_count(), 2);
        }
        // Auto-detect: a directory reopens as tiered.
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 5);
        assert_eq!(s.segment_count(), 2);
        for key in ["a", "b", "cl", "sk", "c"] {
            assert!(s.contains(key), "lost '{key}' across reopen");
        }
        assert!(!s.contains("nope"));
        // Emission order survives key-sorted segment files.
        let recs = s.records();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].ipc, 1.0);
        assert_eq!(recs[1].ipc, 1.1);
        assert_eq!(recs[2].ipc, 1.2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_jsonl_file_imports_into_tiered() {
        let dir = tdir("slofetch_store_import");
        let path = dir.join("legacy.jsonl");
        {
            let mut s = ResultStore::open(&path).unwrap();
            s.push(rec("a", "crypto", "nl", 1.0)).unwrap();
            s.push(rec("b", "serde", "eip256", 1.1)).unwrap();
        }
        let mut s = ResultStore::open_format(&path, StoreFormat::Tiered).unwrap();
        assert!(path.is_dir(), "legacy file should become a store directory");
        assert_eq!(s.len(), 2);
        assert_eq!(s.records()[0], rec("a", "crypto", "nl", 1.0));
        assert!(!s.push(rec("a", "crypto", "nl", 9.9)).unwrap(), "import lost resume dedup");
        assert!(s.push(rec("c", "http", "perfect", 1.2)).unwrap());
        drop(s);
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_segment_is_quarantined_not_silently_dropped() {
        let dir = tdir("slofetch_store_torn");
        let path = dir.join("r.store");
        {
            let mut s = ResultStore::open_format(&path, StoreFormat::Tiered).unwrap();
            s.set_flush_threshold(1);
            s.push(rec("a", "crypto", "nl", 1.0)).unwrap();
            assert_eq!(s.segment_count(), 1);
        }
        // Tear the segment's footer off, as a crashed disk flush would.
        let seg = std::fs::read_dir(&path)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| p.extension().is_some_and(|x| x == "seg"))
            .unwrap();
        let len = std::fs::metadata(&seg).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&seg)
            .unwrap()
            .set_len(len - 40)
            .unwrap();
        let mut s = ResultStore::open(&path).unwrap();
        assert_eq!(s.quarantined().len(), 1);
        assert!(
            s.quarantined()[0].to_string_lossy().ends_with(".seg.quarantined"),
            "torn segment should be renamed, got {:?}",
            s.quarantined()[0]
        );
        assert_eq!(s.segment_count(), 0);
        // Its cells read as absent and recompute cleanly.
        assert!(!s.contains("a"));
        assert!(s.push(rec("a", "crypto", "nl", 1.0)).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compact_merges_segments_and_preserves_order() {
        let dir = tdir("slofetch_store_compact");
        let path = dir.join("r.store");
        let mut s = ResultStore::open_format(&path, StoreFormat::Tiered).unwrap();
        s.set_flush_threshold(1);
        for (i, key) in ["f", "e", "d", "c", "b", "a"].iter().enumerate() {
            s.push(rec(key, "crypto", "nl", 1.0 + i as f64)).unwrap();
        }
        assert_eq!(s.segment_count(), 6);
        let before = s.records();
        let stats = s.compact().unwrap();
        assert_eq!(stats.segments_before, 6);
        assert_eq!(stats.segments_after, 1);
        assert_eq!(stats.records, 6);
        assert_eq!(stats.dropped, 0);
        assert_eq!(s.segment_count(), 1);
        assert_eq!(s.records(), before, "compaction reordered the scan");
        drop(s);
        let s = ResultStore::open(&path).unwrap();
        assert_eq!(s.len(), 6);
        assert_eq!(s.records(), before, "compacted store reopened differently");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_format_refuses_a_store_directory_and_compact_refuses_jsonl() {
        let dir = tdir("slofetch_store_refuse");
        let path = dir.join("r.store");
        drop(ResultStore::open_format(&path, StoreFormat::Tiered).unwrap());
        assert!(ResultStore::open_format(&path, StoreFormat::Jsonl).is_err());
        let mut jsonl = ResultStore::in_memory();
        assert!(jsonl.compact().is_err());
        assert!(StoreFormat::parse("parquet").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_streams_from_a_tiered_store() {
        let dir = tdir("slofetch_store_merge");
        let path = dir.join("shard.store");
        {
            let mut shard = ResultStore::open_format(&path, StoreFormat::Tiered).unwrap();
            shard.set_flush_threshold(1);
            shard.push(rec("a", "crypto", "nl", 2.0)).unwrap();
            shard.push(rec("b", "serde", "eip256", 1.1)).unwrap();
            shard.push_sketch(srec("sk", "w1024d4")).unwrap();
        }
        let shard = ResultStore::load(&path).unwrap();
        let mut main = ResultStore::in_memory();
        main.push(rec("a", "crypto", "nl", 1.0)).unwrap();
        assert_eq!(main.merge(&shard).unwrap(), 2);
        assert_eq!(main.len(), 3);
        assert_eq!(main.records()[0].ipc, 1.0, "first writer must win the merge");
        assert_eq!(main.sketch_records().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
