//! Declarative campaign specs: the scenario matrix (apps × prefetchers ×
//! seeds × ML gate × churn regimes) as a small JSON document, expanded
//! into deterministic per-cell simulation configs.
//!
//! Expansion order is the nested cartesian product in field order
//! (apps ▸ prefetchers ▸ seeds ▸ ml ▸ churn_scale) and is part of the
//! determinism contract: the JSONL store is written in this order, so a
//! campaign's output is byte-identical at any `--threads` value.

use super::runner::Cell;
use crate::cli::parse_prefetcher;
use crate::cluster::faults::FaultsSpec;
use crate::cluster::slo::Policy;
use crate::cluster::workload::TrafficShape;
use crate::cluster::ClusterSpec;
use crate::config::{ControllerCfg, SimConfig};
use crate::trace::gen::apps::{self, AppSpec};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A declarative scenario matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSpec {
    pub name: String,
    /// App preset names (see `slofetch apps`).
    pub apps: Vec<String>,
    /// Prefetcher specs in CLI syntax (`nl`, `eip256`, `ceip256s`, ...).
    pub prefetchers: Vec<String>,
    /// Records per trace.
    pub records: u64,
    /// Trace seeds; one full sub-matrix per seed.
    pub seeds: Vec<u64>,
    /// ML issue-controller gate off/on. `true` cells get a `+ml` label
    /// suffix so they never collide with ungated cells.
    pub ml: Vec<bool>,
    /// Churn intensity multipliers: scale `s` divides each app's churn
    /// period by `s` and multiplies the redirect fraction by `s`
    /// (capped at 1.0); `0` disables churn entirely.
    pub churn_scale: Vec<f64>,
    /// Traffic shapes (see [`TrafficShape::parse`]): each non-`"none"`
    /// entry adds a per-cell tail-latency evaluation — the cell's
    /// measured IPC drives a single-service cluster under that arrival
    /// shape (`cluster::evaluate_tail`) and the resulting P50/P95/P99 and
    /// SLO compliance land on the stored record. `"none"` (the default)
    /// keeps the cell IPC-only and its key identical to pre-traffic
    /// campaigns, so existing stores resume cleanly.
    pub traffic: Vec<String>,
    /// Cluster-scenario axis: whole cluster specs (topology + prefetcher
    /// candidate set + traffic shapes), each swept under every
    /// autoscaler policy in `policies` through the discrete-event
    /// engine. Empty (the default) adds no cluster cells, so
    /// pre-cluster campaigns — and their stores — are untouched.
    pub clusters: Vec<ClusterSpec>,
    /// Autoscaler policies ([`Policy::parse`] syntax) applied to every
    /// cluster scenario. Only consulted when `clusters` is non-empty.
    pub policies: Vec<String>,
    /// Fault-regime axis (DESIGN.md §14): each non-`"none"` entry is a
    /// `;`-joined list of fault-schedule specs (the grammar of
    /// `ClusterSpec.faults.events`) swept over every policy-swept
    /// cluster cell, so one campaign ranks the policy suite under each
    /// fault regime. `"none"` (the default) runs the cluster's own
    /// (schedule-free) fault section, keeping cell keys — and store
    /// resume — identical to pre-fault campaigns. Clusters keep their
    /// `faults.client` policies under every regime; their `faults.events`
    /// must be empty (schedules are this axis). Only consulted when
    /// `clusters` is non-empty; tenant clusters are exempt from the
    /// sweep (the tenant engine path has no fault axis).
    pub faults: Vec<String>,
    /// Sketch-accuracy axis (DESIGN.md §12): telemetry geometries
    /// (`w{width}d{depth}p{hll_p}k{topk}`) to evaluate in compare mode.
    /// Each geometry adds one ML-gated run of the campaign's *first*
    /// prefetcher per (app, seed) — exact features drive the decisions
    /// while a sketch-fed shadow is scored per decision, so the stored
    /// record prices decision agreement against sketch bytes. Empty
    /// (the default) adds no cells.
    pub sketch: Vec<String>,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        CampaignSpec {
            name: "campaign".into(),
            apps: Vec::new(),
            prefetchers: Vec::new(),
            records: 200_000,
            seeds: vec![7],
            ml: vec![false],
            churn_scale: vec![1.0],
            traffic: vec!["none".into()],
            clusters: Vec::new(),
            policies: vec!["reactive".into()],
            faults: vec!["none".into()],
            sketch: Vec::new(),
        }
    }
}

/// One expanded cell: the runnable [`Cell`] plus the scenario coordinates
/// the result store records alongside it.
#[derive(Clone)]
pub struct ExpandedCell {
    /// Stable identity used for store dedup/resume.
    pub key: String,
    /// The traffic-free prefix of `key`: cells sharing it run the exact
    /// same core simulation (same trace, same sim seed), so the runner
    /// simulates each distinct `base_key` once and fans the result out.
    pub base_key: String,
    pub ml: bool,
    pub churn_scale: f64,
    /// Traffic shape for the tail-latency evaluation (`None` = the
    /// `"none"` axis value: IPC-only cell).
    pub traffic: Option<TrafficShape>,
    pub cell: Cell,
}

/// One expanded cluster-scenario cell: a (cluster, policy, traffic
/// shape) coordinate — or, for multi-tenant clusters, a (cluster,
/// tenant, solo|coloc) coordinate — plus its stable store key.
#[derive(Clone)]
pub struct ClusterCell {
    /// Stable identity used for store dedup/resume. Includes a content
    /// hash of the full cluster spec (tenant bindings included), so
    /// editing the scenario definition invalidates its old lines.
    pub key: String,
    /// Index into the campaign's `clusters` list.
    pub cluster: usize,
    /// Autoscaler policy (policy cells only; tenant clusters run their
    /// own control loop, and this holds the inert default).
    pub policy: Policy,
    /// The cell's traffic shape (for tenant cells: that tenant's own
    /// shape).
    pub shape: TrafficShape,
    /// Tenant coordinate: `(tenant index, solo?)`. `None` = policy cell.
    pub tenant: Option<(usize, bool)>,
    /// Fault regime (`;`-joined schedule specs); empty = the `"none"`
    /// axis value — the cluster's own schedule-free fault section.
    pub faults: String,
}

/// The fault section one cluster cell runs under `regime` (`""` = the
/// `"none"` axis value): the cluster's own client policies, with the
/// regime's schedule swapped in when one is given.
pub fn regime_faults(cluster: &ClusterSpec, regime: &str) -> FaultsSpec {
    let mut f = cluster.faults.clone();
    if !regime.is_empty() {
        f.events = regime.split(';').map(str::to_string).collect();
    }
    f
}

/// One expanded sketch-accuracy cell (DESIGN.md §12): a compare-mode
/// ML-gated run of the campaign's first prefetcher under one sketch
/// geometry, plus the coordinates the result store records.
#[derive(Clone)]
pub struct SketchCell {
    /// Stable identity used for store dedup/resume.
    pub key: String,
    pub app: String,
    pub trace_seed: u64,
    /// Canonical geometry label (`w{width}d{depth}p{hll_p}k{topk}`).
    pub geom: String,
    pub cell: Cell,
}

/// Deterministic per-cell simulation seed: a splitmix64 hash
/// ([`crate::util::rng::mix64`]) of the base seed and the cell key, so
/// controller/bandit RNG streams are independent across cells yet
/// reproducible across runs, hosts, and thread counts.
pub fn cell_seed(base: u64, key: &str) -> u64 {
    use crate::util::rng::mix64;
    let mut h = mix64(base ^ 0x510F_E7C4_0DE5_1A7E);
    for b in key.bytes() {
        h = mix64(h ^ b as u64);
    }
    h
}

/// Stable 64-bit content hash of raw bytes (splitmix64 over 8-byte
/// chunks) — folds `.slft` trace-file contents into cluster cell keys,
/// so the empirical quantile tables (a pure function of spec JSON +
/// trace bytes) invalidate stored lines whenever their inputs change.
/// Also names tiered-store segment files (`seg-<hash>.seg` over the
/// record block), making a segment's identity commit to its contents.
pub fn content_hash(bytes: &[u8]) -> u64 {
    use crate::util::rng::mix64;
    let mut h = mix64(bytes.len() as u64 ^ 0x7ACE_C0DE_5EED_F11E);
    for chunk in bytes.chunks(8) {
        let mut v = [0u8; 8];
        v[..chunk.len()].copy_from_slice(chunk);
        h = mix64(h ^ u64::from_le_bytes(v));
    }
    h
}

/// Apply a churn-intensity multiplier to an app preset.
fn scaled_app(app: &AppSpec, scale: f64) -> AppSpec {
    let mut a = app.clone();
    if scale <= 0.0 {
        a.churn_period = 0;
        a.churn_redirect = 0.0;
    } else if scale != 1.0 && a.churn_period > 0 {
        a.churn_period = ((a.churn_period as f64 / scale).round().max(1.0)) as u64;
        a.churn_redirect = (a.churn_redirect * scale).min(1.0);
    }
    a
}

impl CampaignSpec {
    /// Validate the matrix axes (unknown apps/prefetchers, empty axes).
    pub fn validate(&self) -> Result<()> {
        if self.apps.is_empty() {
            bail!("campaign '{}' lists no apps", self.name);
        }
        if self.prefetchers.is_empty() {
            bail!("campaign '{}' lists no prefetchers", self.name);
        }
        if self.records == 0 {
            bail!("campaign '{}' has records = 0", self.name);
        }
        if self.seeds.is_empty()
            || self.ml.is_empty()
            || self.churn_scale.is_empty()
            || self.traffic.is_empty()
            || self.faults.is_empty()
        {
            bail!("campaign '{}' has an empty axis", self.name);
        }
        for &cs in &self.churn_scale {
            if !(cs.is_finite() && cs >= 0.0) {
                bail!(
                    "campaign '{}': churn_scale must be finite and ≥ 0, got {cs}",
                    self.name
                );
            }
        }
        for t in &self.traffic {
            if t != "none" {
                TrafficShape::parse(t).with_context(|| format!("in campaign '{}'", self.name))?;
            }
        }
        let mut geoms = std::collections::HashSet::new();
        for g in &self.sketch {
            let parsed = crate::obs::telemetry::TelemetryCfg::parse_geom(g)
                .with_context(|| format!("in campaign '{}'", self.name))?;
            if !geoms.insert(parsed) {
                bail!("campaign '{}': duplicate sketch geometry '{g}'", self.name);
            }
        }
        for app in &self.apps {
            apps::app(app).with_context(|| {
                format!("unknown app '{app}' in campaign (see `slofetch apps`)")
            })?;
        }
        for pf in &self.prefetchers {
            parse_prefetcher(pf).with_context(|| format!("in campaign '{}'", self.name))?;
        }
        let mut seen = std::collections::HashSet::new();
        for c in &self.clusters {
            c.validate().with_context(|| format!("in campaign '{}'", self.name))?;
            if c.adaptive || !c.policies.is_empty() {
                bail!(
                    "campaign '{}': cluster '{}' sets its own control scenarios — \
                     autoscaler policies are a campaign axis (set campaign.policies)",
                    self.name,
                    c.name
                );
            }
            if !c.faults.events.is_empty() {
                bail!(
                    "campaign '{}': cluster '{}' declares its own fault schedule — \
                     fault regimes are a campaign axis (set campaign.faults; the \
                     cluster keeps only faults.client)",
                    self.name,
                    c.name
                );
            }
            if !seen.insert(c.name.as_str()) {
                bail!("campaign '{}': duplicate cluster name '{}'", self.name, c.name);
            }
        }
        if !self.clusters.is_empty() {
            // Multi-tenant clusters run their own control loop, so only
            // policy-swept (single-tenant) clusters *need* the axis —
            // but a listed policy is always parse-validated, so a typo
            // never hides behind a tenant-only campaign.
            if self.policies.is_empty() && self.clusters.iter().any(|c| !c.tenancy()) {
                bail!("campaign '{}': clusters need at least one policy", self.name);
            }
            let mut seen = std::collections::HashSet::new();
            for p in &self.policies {
                let policy =
                    Policy::parse(p).with_context(|| format!("in campaign '{}'", self.name))?;
                if !seen.insert(policy.label()) {
                    bail!("campaign '{}': duplicate policy '{p}'", self.name);
                }
            }
            // Every non-"none" regime must parse against every cluster
            // it sweeps (the policy-swept ones — tenant clusters are
            // exempt), and a regime-only campaign with nothing to sweep
            // is a misconfiguration, not a silent no-op.
            let swept: Vec<&ClusterSpec> =
                self.clusters.iter().filter(|c| !c.tenancy()).collect();
            let mut seen = std::collections::HashSet::new();
            for f in &self.faults {
                if !seen.insert(f.as_str()) {
                    bail!("campaign '{}': duplicate fault regime '{f}'", self.name);
                }
                if f == "none" {
                    continue;
                }
                if swept.is_empty() {
                    bail!(
                        "campaign '{}': fault regime '{f}' has no policy-swept \
                         cluster to apply to",
                        self.name
                    );
                }
                for c in &swept {
                    let names: Vec<String> =
                        c.topology.services.iter().map(|s| s.name.clone()).collect();
                    let replicas: Vec<u32> =
                        c.topology.services.iter().map(|s| s.replicas).collect();
                    regime_faults(c, f).validate(&names, &replicas).with_context(|| {
                        format!(
                            "campaign '{}': fault regime '{f}' on cluster '{}'",
                            self.name, c.name
                        )
                    })?;
                }
            }
        }
        Ok(())
    }

    /// Total simulation-cell count of the matrix (cluster cells are
    /// counted separately by [`Self::cluster_cell_count`]).
    pub fn cell_count(&self) -> usize {
        self.apps.len()
            * self.prefetchers.len()
            * self.seeds.len()
            * self.ml.len()
            * self.churn_scale.len()
            * self.traffic.len()
    }

    /// Cluster-scenario cell count: Σ over clusters of
    /// (fault regimes × policies × that cluster's traffic shapes) —
    /// except multi-tenant clusters, which contribute one solo and one
    /// co-located cell per tenant instead (their tenants carry the
    /// traffic bindings, and the fault axis does not apply).
    pub fn cluster_cell_count(&self) -> usize {
        self.clusters
            .iter()
            .map(|c| {
                if c.tenancy() {
                    2 * c.tenants.len()
                } else {
                    self.faults.len() * self.policies.len() * c.traffic.len()
                }
            })
            .sum()
    }

    /// Sketch-accuracy cell count: apps × seeds × sketch geometries
    /// (first prefetcher only — the axis measures telemetry, not
    /// prefetcher configs).
    pub fn sketch_cell_count(&self) -> usize {
        if self.sketch.is_empty() {
            0
        } else {
            self.apps.len() * self.seeds.len() * self.sketch.len()
        }
    }

    /// Expand the matrix into runnable cells (deterministic order).
    pub fn expand(&self) -> Result<Vec<ExpandedCell>> {
        self.validate()?;
        // Parse each shape once, not once per expanded cell.
        let mut shapes = Vec::with_capacity(self.traffic.len());
        for t in &self.traffic {
            shapes.push(if t == "none" { None } else { Some(TrafficShape::parse(t)?) });
        }
        let mut out = Vec::with_capacity(self.cell_count());
        for app_name in &self.apps {
            let base_app = apps::app(app_name).unwrap();
            for pf in &self.prefetchers {
                let kind = parse_prefetcher(pf)?;
                // Normalized label so baseline detection (`nl`) is
                // case-insensitive like the parser.
                let pf = pf.to_lowercase();
                for &seed in &self.seeds {
                    for &ml in &self.ml {
                        let label =
                            if ml { format!("{pf}+ml") } else { pf.clone() };
                        for &cs in &self.churn_scale {
                            for shape in &shapes {
                                // Shape labels are normalized so e.g.
                                // `poisson:0.65` and `POISSON:0.65` share
                                // a key (and get rejected as duplicates).
                                // `{cs}` is Rust's shortest round-trip
                                // float form: distinct scales never
                                // collide. The `|t...` suffix is omitted
                                // for `"none"` so pre-traffic stores
                                // keep resuming.
                                let base_key = format!(
                                    "{app_name}|{label}|r{}|s{seed}|c{cs}",
                                    self.records
                                );
                                let mut key = base_key.clone();
                                if let Some(shape) = shape {
                                    key.push_str("|t");
                                    key.push_str(&shape.label());
                                }
                                let controller = ml.then(|| ControllerCfg {
                                    train_interval_cycles: 200_000,
                                    ..Default::default()
                                });
                                // The sim seed hashes the *traffic-free*
                                // key: arrival shape is an evaluation
                                // axis, so the same scenario yields
                                // bit-identical IPC under every shape
                                // (and `nl` baselines stay exact).
                                let cfg = SimConfig {
                                    prefetcher: kind.clone(),
                                    controller,
                                    seed: cell_seed(seed, &base_key),
                                    ..Default::default()
                                };
                                out.push(ExpandedCell {
                                    key,
                                    base_key,
                                    ml,
                                    churn_scale: cs,
                                    traffic: shape.clone(),
                                    cell: Cell {
                                        app: scaled_app(&base_app, cs),
                                        label: label.clone(),
                                        cfg,
                                        records: self.records,
                                        trace_seed: seed,
                                        trace: None,
                                    },
                                });
                            }
                        }
                    }
                }
            }
        }
        let mut seen = std::collections::HashSet::new();
        for c in &out {
            if !seen.insert(c.key.as_str()) {
                bail!(
                    "campaign '{}': duplicate cell key '{}' (repeated axis value)",
                    self.name,
                    c.key
                );
            }
        }
        Ok(out)
    }

    /// Expand the cluster-scenario axis into runnable cells
    /// (deterministic order: clusters ▸ policies ▸ that cluster's
    /// traffic shapes).
    pub fn expand_clusters(&self) -> Result<Vec<ClusterCell>> {
        self.validate()?;
        let mut out = Vec::with_capacity(self.cluster_cell_count());
        // Each distinct trace file is read and hashed once per expansion,
        // however many services (or clusters) reference it.
        let mut file_hashes: std::collections::HashMap<&str, u64> =
            std::collections::HashMap::new();
        for (ci, cluster) in self.clusters.iter().enumerate() {
            // Content hash over the canonical spec JSON: editing any part
            // of the scenario definition (topology, prefetcher set,
            // requests, seed, ...) changes the key, so stale store lines
            // are never mistaken for this cell. Referenced `.slft` trace
            // files fold in by *content*, not path: the empirical
            // quantile tables are a pure function of (spec JSON, trace
            // bytes), so editing a trace in place invalidates its cells
            // the same way editing the spec does.
            let mut hash = cell_seed(0xC1A5_7E55, &cluster.to_json().dump());
            for s in &cluster.topology.services {
                if let Some(path) = &s.trace {
                    let fh = if let Some(h) = file_hashes.get(path.as_str()) {
                        *h
                    } else {
                        let bytes = std::fs::read(path).with_context(|| {
                            format!(
                                "campaign '{}': cluster '{}' service '{}': hashing trace '{path}'",
                                self.name, cluster.name, s.name
                            )
                        })?;
                        let h = content_hash(&bytes);
                        file_hashes.insert(path.as_str(), h);
                        h
                    };
                    hash = crate::util::rng::mix64(hash ^ fh);
                }
            }
            if cluster.tenancy() {
                // Tenant pairings: one solo cell per tenant (the paired
                // baseline) then one co-located cell per tenant — all
                // records of one coloc run, written per tenant so the
                // report can pair and rank without re-deriving anything.
                for solo in [true, false] {
                    let mode = if solo { "solo" } else { "coloc" };
                    for (ti, t) in cluster.tenants.iter().enumerate() {
                        let shape = TrafficShape::parse(&t.traffic)?;
                        out.push(ClusterCell {
                            key: format!(
                                "cluster|{}#{hash:016x}|{mode}|{}|t{}",
                                cluster.name,
                                t.name,
                                shape.label()
                            ),
                            cluster: ci,
                            policy: Policy::Reactive,
                            shape,
                            tenant: Some((ti, solo)),
                            faults: String::new(),
                        });
                    }
                }
                continue;
            }
            // Fault regimes are the outer loop so the `"none"` block —
            // whose keys are byte-identical to pre-fault campaigns —
            // stays a contiguous prefix and existing stores resume with
            // 0 recomputed cells.
            for regime in &self.faults {
                let regime = if regime == "none" { "" } else { regime.as_str() };
                for pol in &self.policies {
                    let policy = Policy::parse(pol)?;
                    for t in &cluster.traffic {
                        let shape = TrafficShape::parse(t)?;
                        // The `|f` suffix is omitted for `"none"` so
                        // pre-fault stores keep resuming.
                        let mut key = format!(
                            "cluster|{}#{hash:016x}|{}|t{}",
                            cluster.name,
                            policy.label(),
                            shape.label()
                        );
                        if !regime.is_empty() {
                            key.push_str("|f");
                            key.push_str(regime);
                        }
                        out.push(ClusterCell {
                            key,
                            cluster: ci,
                            policy: policy.clone(),
                            shape,
                            tenant: None,
                            faults: regime.to_string(),
                        });
                    }
                }
            }
        }
        let mut seen = std::collections::HashSet::new();
        for c in &out {
            if !seen.insert(c.key.as_str()) {
                bail!(
                    "campaign '{}': duplicate cluster cell key '{}'",
                    self.name,
                    c.key
                );
            }
        }
        Ok(out)
    }

    /// Expand the sketch-accuracy axis into runnable compare-mode cells
    /// (deterministic order: apps ▸ seeds ▸ geometries). The validated
    /// geometry strings are re-emitted in canonical form, so the keys —
    /// and therefore store resume — never depend on cosmetic spelling.
    pub fn expand_sketch(&self) -> Result<Vec<SketchCell>> {
        self.validate()?;
        if self.sketch.is_empty() {
            return Ok(Vec::new());
        }
        let pf = self.prefetchers[0].to_lowercase();
        let kind = parse_prefetcher(&pf)?;
        let mut out = Vec::with_capacity(self.sketch_cell_count());
        for app_name in &self.apps {
            let app = apps::app(app_name).unwrap();
            for &seed in &self.seeds {
                for g in &self.sketch {
                    let (w, d, p, k) = crate::obs::telemetry::TelemetryCfg::parse_geom(g)?;
                    let geom = format!("w{w}d{d}p{p}k{k}");
                    let key = format!(
                        "sketch|{app_name}|{pf}|r{}|s{seed}|{geom}",
                        self.records
                    );
                    let cfg = SimConfig {
                        prefetcher: kind.clone(),
                        controller: Some(ControllerCfg {
                            train_interval_cycles: 200_000,
                            ..Default::default()
                        }),
                        seed: cell_seed(seed, &key),
                        telemetry: format!("compare:{geom}"),
                        ..Default::default()
                    };
                    out.push(SketchCell {
                        key,
                        app: app_name.clone(),
                        trace_seed: seed,
                        geom,
                        cell: Cell {
                            app: app.clone(),
                            label: format!("{pf}+ml"),
                            cfg,
                            records: self.records,
                            trace_seed: seed,
                            trace: None,
                        },
                    });
                }
            }
        }
        Ok(out)
    }

    // ---------- JSON (de)serialization ----------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            (
                "apps",
                Json::Arr(self.apps.iter().map(|a| Json::str(a)).collect()),
            ),
            (
                "prefetchers",
                Json::Arr(self.prefetchers.iter().map(|p| Json::str(p)).collect()),
            ),
            ("records", Json::num(self.records as f64)),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|s| Json::num(*s as f64)).collect()),
            ),
            (
                "ml",
                Json::Arr(self.ml.iter().map(|m| Json::Bool(*m)).collect()),
            ),
            (
                "churn_scale",
                Json::Arr(self.churn_scale.iter().map(|c| Json::num(*c)).collect()),
            ),
            (
                "traffic",
                Json::Arr(self.traffic.iter().map(|t| Json::str(t)).collect()),
            ),
            (
                "clusters",
                Json::Arr(self.clusters.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "policies",
                Json::Arr(self.policies.iter().map(|p| Json::str(p)).collect()),
            ),
            (
                "faults",
                Json::Arr(self.faults.iter().map(|f| Json::str(f)).collect()),
            ),
            (
                "sketch",
                Json::Arr(self.sketch.iter().map(|g| Json::str(g)).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CampaignSpec> {
        let mut spec = CampaignSpec::default();
        if let Some(n) = j.get("name").and_then(Json::as_str) {
            spec.name = n.to_string();
        }
        let strings = |key: &str| -> Result<Vec<String>> {
            j.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("campaign spec: '{key}' must be an array"))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .with_context(|| format!("'{key}' entries must be strings"))
                })
                .collect()
        };
        spec.apps = strings("apps")?;
        spec.prefetchers = strings("prefetchers")?;
        if let Some(r) = j.get("records").and_then(Json::as_u64) {
            spec.records = r;
        }
        if let Some(arr) = j.get("seeds").and_then(Json::as_arr) {
            spec.seeds = arr
                .iter()
                .map(|v| v.as_u64().context("'seeds' entries must be integers"))
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("ml").and_then(Json::as_arr) {
            spec.ml = arr
                .iter()
                .map(|v| v.as_bool().context("'ml' entries must be booleans"))
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("churn_scale").and_then(Json::as_arr) {
            spec.churn_scale = arr
                .iter()
                .map(|v| v.as_f64().context("'churn_scale' entries must be numbers"))
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("traffic").and_then(Json::as_arr) {
            spec.traffic = arr
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .context("'traffic' entries must be strings")
                })
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("clusters").and_then(Json::as_arr) {
            spec.clusters = arr
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    ClusterSpec::from_json(v).with_context(|| format!("in cluster #{i}"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("policies").and_then(Json::as_arr) {
            spec.policies = arr
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .context("'policies' entries must be strings")
                })
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("faults").and_then(Json::as_arr) {
            spec.faults = arr
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .context("'faults' entries must be strings")
                })
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("sketch").and_then(Json::as_arr) {
            spec.sketch = arr
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .context("'sketch' entries must be strings")
                })
                .collect::<Result<_>>()?;
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn load(path: &Path) -> Result<CampaignSpec> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        Self::from_json(&j).with_context(|| format!("in {path:?}"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("write {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CampaignSpec {
        CampaignSpec {
            name: "t".into(),
            apps: vec!["crypto".into(), "serde".into()],
            prefetchers: vec!["nl".into(), "ceip256".into()],
            records: 10_000,
            seeds: vec![3, 4],
            ml: vec![false, true],
            churn_scale: vec![1.0],
            traffic: vec!["none".into()],
            clusters: Vec::new(),
            policies: vec!["reactive".into()],
            faults: vec!["none".into()],
            sketch: Vec::new(),
        }
    }

    fn tiny_cluster(name: &str) -> ClusterSpec {
        let j = Json::parse(&format!(
            r#"{{
                "name": "{name}",
                "services": [
                    {{"name": "gw", "app": "admission"}},
                    {{"name": "be", "app": "serde", "deps": ["gw"]}}
                ],
                "prefetchers": ["nl", "ceip256"],
                "traffic": ["poisson:0.6", "burst:0.5:3:40000:0.25"],
                "requests": 5000,
                "records": 4000
            }}"#
        ))
        .unwrap();
        ClusterSpec::from_json(&j).unwrap()
    }

    #[test]
    fn expansion_is_full_cartesian_product() {
        let spec = small();
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), spec.cell_count());
        assert_eq!(cells.len(), 2 * 2 * 2 * 2);
        // Keys are unique.
        let mut keys: Vec<&str> = cells.iter().map(|c| c.key.as_str()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), cells.len());
        // ML cells get the +ml label and a controller.
        let ml_cell = cells.iter().find(|c| c.ml).unwrap();
        assert!(ml_cell.cell.label.ends_with("+ml"));
        assert!(ml_cell.cell.cfg.controller.is_some());
        let plain = cells.iter().find(|c| !c.ml).unwrap();
        assert!(plain.cell.cfg.controller.is_none());
    }

    #[test]
    fn expansion_order_is_stable() {
        let a = small().expand().unwrap();
        let b = small().expand().unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.key, y.key);
            assert_eq!(x.cell.cfg.seed, y.cell.cfg.seed);
        }
        // apps ▸ prefetchers ▸ seeds ▸ ml ▸ churn order.
        assert!(a[0].key.starts_with("crypto|nl|"));
        assert!(a.last().unwrap().key.starts_with("serde|ceip256+ml|"));
    }

    #[test]
    fn cell_seeds_are_distinct_and_deterministic() {
        let cells = small().expand().unwrap();
        let mut seeds: Vec<u64> = cells.iter().map(|c| c.cell.cfg.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), cells.len(), "cell seeds collide");
        assert_eq!(cell_seed(7, "a|b"), cell_seed(7, "a|b"));
        assert_ne!(cell_seed(7, "a|b"), cell_seed(8, "a|b"));
    }

    #[test]
    fn churn_scaling() {
        let app = apps::app("websearch").unwrap();
        let faster = scaled_app(&app, 2.0);
        assert_eq!(faster.churn_period, app.churn_period / 2);
        assert!(faster.churn_redirect > app.churn_redirect);
        let off = scaled_app(&app, 0.0);
        assert_eq!(off.churn_period, 0);
        let same = scaled_app(&app, 1.0);
        assert_eq!(same.churn_period, app.churn_period);
        // Steady-state apps stay steady at any scale.
        let crypto = apps::app("crypto").unwrap();
        assert_eq!(scaled_app(&crypto, 4.0).churn_period, 0);
    }

    #[test]
    fn near_identical_churn_scales_get_distinct_keys() {
        let spec = CampaignSpec {
            churn_scale: vec![0.1001, 0.1002],
            ..small()
        };
        let cells = spec.expand().unwrap();
        let mut keys: Vec<&str> = cells.iter().map(|c| c.key.as_str()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), cells.len());
    }

    #[test]
    fn traffic_axis_expands_and_preserves_sim_seeds() {
        let spec = CampaignSpec {
            traffic: vec!["none".into(), "poisson:0.65".into(), "burst:0.5:3:50000:0.2".into()],
            ..small()
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), spec.cell_count());
        assert_eq!(cells.len(), 2 * 2 * 2 * 2 * 3);
        // `none` keys match the pre-traffic format exactly.
        let plain = cells.iter().find(|c| c.traffic.is_none()).unwrap();
        assert!(!plain.key.contains("|t"), "none cell key changed: {}", plain.key);
        // Shaped cells append a normalized |t suffix...
        let shaped = cells.iter().find(|c| c.traffic.is_some()).unwrap();
        assert!(shaped.key.contains("|tpoisson:0.65") || shaped.key.contains("|tburst"));
        // ...but share the traffic-free sim seed with their `none` twin,
        // so the core simulation (and the nl baseline) is identical.
        let twin = cells
            .iter()
            .find(|c| c.traffic.is_some() && c.key.starts_with(&plain.key))
            .unwrap();
        assert_eq!(plain.cell.cfg.seed, twin.cell.cfg.seed);
        // Keys are still globally unique.
        let mut keys: Vec<&str> = cells.iter().map(|c| c.key.as_str()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), cells.len());
    }

    #[test]
    fn negative_churn_scale_is_rejected_with_clear_error() {
        let spec = CampaignSpec { churn_scale: vec![-1.0], ..small() };
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("churn_scale"), "unhelpful error: {err}");
        assert!(CampaignSpec { churn_scale: vec![f64::NAN], ..small() }.validate().is_err());
    }

    #[test]
    fn bad_traffic_axis_is_rejected() {
        let spec = CampaignSpec { traffic: vec!["tsunami".into()], ..small() };
        assert!(spec.validate().is_err());
        let spec = CampaignSpec { traffic: vec![], ..small() };
        assert!(spec.validate().is_err());
        // Case-variant duplicates normalize to the same key.
        let spec = CampaignSpec {
            traffic: vec!["poisson:0.65".into(), "POISSON:0.65".into()],
            ..small()
        };
        assert!(spec.expand().is_err(), "normalized duplicate shape not caught");
    }

    #[test]
    fn duplicate_axis_values_are_rejected() {
        let spec = CampaignSpec {
            prefetchers: vec!["nl".into(), "NL".into()],
            ..small()
        };
        assert!(spec.expand().is_err(), "case-normalized duplicate not caught");
    }

    #[test]
    fn json_roundtrip() {
        let spec = small();
        let back = CampaignSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // With the cluster axis populated too.
        let spec = CampaignSpec {
            clusters: vec![tiny_cluster("edge")],
            policies: vec!["reactive".into(), "hysteresis:6:0.5".into()],
            ..small()
        };
        let back = CampaignSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn cluster_axis_expands_with_stable_hashed_keys() {
        let spec = CampaignSpec {
            clusters: vec![tiny_cluster("edge")],
            policies: vec!["reactive".into(), "hysteresis".into()],
            ..small()
        };
        let cells = spec.expand_clusters().unwrap();
        // 2 policies × 2 shapes.
        assert_eq!(cells.len(), spec.cluster_cell_count());
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert!(c.key.starts_with("cluster|edge#"), "key {}", c.key);
        }
        // Keys are unique and stable across expansions.
        let keys: Vec<String> = cells.iter().map(|c| c.key.clone()).collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len());
        let again: Vec<String> =
            spec.expand_clusters().unwrap().iter().map(|c| c.key.clone()).collect();
        assert_eq!(again, keys);
        // Editing the scenario definition invalidates every key.
        let mut edited = spec.clone();
        edited.clusters[0].requests = 6_000;
        let new_keys: Vec<String> =
            edited.expand_clusters().unwrap().iter().map(|c| c.key.clone()).collect();
        for (a, b) in keys.iter().zip(&new_keys) {
            assert_ne!(a, b, "content hash ignored the spec edit");
        }
        // The sim-cell matrix is untouched by the cluster axis.
        assert_eq!(spec.expand().unwrap().len(), small().expand().unwrap().len());
    }

    #[test]
    fn trace_file_content_feeds_the_cluster_cell_hash() {
        let dir = std::env::temp_dir().join("slofetch_campaign_spec_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hashme.slft");
        let meta = crate::trace::TraceMeta {
            app: "serde".into(),
            seed: 1,
            line_bytes: 64,
            records: 2,
        };
        let recs =
            vec![crate::trace::Record::fetch(10, 16, 1), crate::trace::Record::fetch(11, 16, 2)];
        crate::trace::codec::write_trace_file(&path, &meta, &recs).unwrap();

        let mut cluster = tiny_cluster("edge");
        cluster.service_times = "empirical".into();
        cluster.topology.services[1].trace = Some(path.to_string_lossy().into_owned());
        let spec = CampaignSpec { clusters: vec![cluster], ..small() };
        let keys: Vec<String> =
            spec.expand_clusters().unwrap().iter().map(|c| c.key.clone()).collect();
        // Same content → same keys (stores resume).
        let again: Vec<String> =
            spec.expand_clusters().unwrap().iter().map(|c| c.key.clone()).collect();
        assert_eq!(keys, again);
        // Rewriting the trace with different records changes every key,
        // even though the spec JSON (and the path) is unchanged.
        let recs2 = vec![
            crate::trace::Record::fetch(10, 16, 1),
            crate::trace::Record::fetch(99, 16, 2),
        ];
        crate::trace::codec::write_trace_file(&path, &meta, &recs2).unwrap();
        let rehashed: Vec<String> =
            spec.expand_clusters().unwrap().iter().map(|c| c.key.clone()).collect();
        for (a, b) in keys.iter().zip(&rehashed) {
            assert_ne!(a, b, "trace content edit did not invalidate the cell key");
        }
        // A missing trace file is a clear error, not a silent skip.
        std::fs::remove_file(&path).unwrap();
        assert!(spec.expand_clusters().is_err());

        // content_hash itself: deterministic, content-sensitive,
        // length-sensitive (chunk padding must not alias).
        assert_eq!(content_hash(b"abc"), content_hash(b"abc"));
        assert_ne!(content_hash(b"abc"), content_hash(b"abd"));
        assert_ne!(content_hash(b"abc\0"), content_hash(b"abc"));
        assert_ne!(content_hash(b""), content_hash(b"\0"));
    }

    fn tenant_cluster(name: &str) -> ClusterSpec {
        let j = Json::parse(&format!(
            r#"{{
                "name": "{name}",
                "services": [
                    {{"name": "gw", "app": "admission"}},
                    {{"name": "be", "app": "serde", "deps": ["gw"]}}
                ],
                "prefetchers": ["nl", "ceip256"],
                "traffic": ["poisson:0.6"],
                "requests": 4000,
                "records": 4000,
                "adaptive": false,
                "tenants": [
                    {{"name": "web", "services": ["gw"], "traffic": "poisson:0.4",
                      "ways": 4, "demand_ways": 6}},
                    {{"name": "batch", "traffic": "poisson:0.3", "ways": 4,
                      "demand_ways": 5}}
                ]
            }}"#
        ))
        .unwrap();
        ClusterSpec::from_json(&j).unwrap()
    }

    #[test]
    fn tenant_clusters_expand_solo_and_coloc_cells() {
        let spec = CampaignSpec {
            clusters: vec![tenant_cluster("shared")],
            policies: vec!["reactive".into()],
            ..small()
        };
        let cells = spec.expand_clusters().unwrap();
        // 2 tenants × {solo, coloc} — the policy axis does not apply.
        assert_eq!(cells.len(), spec.cluster_cell_count());
        assert_eq!(cells.len(), 4);
        assert!(cells[0].key.contains("|solo|web|"), "{}", cells[0].key);
        assert!(cells[1].key.contains("|solo|batch|"), "{}", cells[1].key);
        assert!(cells[2].key.contains("|coloc|web|"), "{}", cells[2].key);
        assert!(cells[3].key.contains("|coloc|batch|"), "{}", cells[3].key);
        assert_eq!(cells[0].tenant, Some((0, true)));
        assert_eq!(cells[3].tenant, Some((1, false)));
        // Stable across expansions (stores resume)...
        let keys: Vec<String> = cells.iter().map(|c| c.key.clone()).collect();
        let again: Vec<String> =
            spec.expand_clusters().unwrap().iter().map(|c| c.key.clone()).collect();
        assert_eq!(again, keys);
        // ...and every key moves when a tenant binding changes.
        let mut edited = spec.clone();
        edited.clusters[0].tenants[0].ways = 3;
        edited.clusters[0].tenants[1].ways = 5;
        let moved: Vec<String> =
            edited.expand_clusters().unwrap().iter().map(|c| c.key.clone()).collect();
        for (a, b) in keys.iter().zip(&moved) {
            assert_ne!(a, b, "tenant binding edit did not invalidate the cell key");
        }
        // A tenant-only campaign does not need the policies axis.
        let no_pol = CampaignSpec {
            clusters: vec![tenant_cluster("shared")],
            policies: Vec::new(),
            ..small()
        };
        assert!(no_pol.validate().is_ok(), "tenant-only clusters must not need policies");
        // Mixing in a policy-swept cluster re-arms the requirement.
        let mixed = CampaignSpec {
            clusters: vec![tenant_cluster("shared"), tiny_cluster("edge")],
            policies: Vec::new(),
            ..small()
        };
        assert!(mixed.validate().is_err(), "policy cluster without policies accepted");
    }

    #[test]
    fn fault_axis_expands_suffixed_cells_after_the_none_block() {
        let base = CampaignSpec {
            clusters: vec![tiny_cluster("edge")],
            policies: vec!["reactive".into(), "hysteresis".into()],
            ..small()
        };
        let spec = CampaignSpec {
            faults: vec!["none".into(), "down:be:0:20000:30000;gray:gw:1:3:1:50000".into()],
            ..base.clone()
        };
        let cells = spec.expand_clusters().unwrap();
        // 2 regimes × 2 policies × 2 shapes.
        assert_eq!(cells.len(), spec.cluster_cell_count());
        assert_eq!(cells.len(), 8);
        // The "none" block is a byte-identical contiguous prefix of the
        // pre-fault expansion, so existing stores resume cleanly.
        let plain = base.expand_clusters().unwrap();
        for (c, p) in cells.iter().zip(&plain) {
            assert_eq!(c.key, p.key);
            assert!(c.faults.is_empty());
        }
        // Regime cells carry the |f suffix and the regime string.
        for c in &cells[4..] {
            assert!(c.key.contains("|fdown:be:0:20000:30000;gray"), "key {}", c.key);
            assert_eq!(c.faults, "down:be:0:20000:30000;gray:gw:1:3:1:50000");
        }
        // Keys stay globally unique.
        let mut keys: Vec<&str> = cells.iter().map(|c| c.key.as_str()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), cells.len());
        // The axis round-trips through JSON.
        let back = CampaignSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // regime_faults swaps the schedule in and keeps client policies.
        let f = regime_faults(&spec.clusters[0], "down:be:0:1:2");
        assert_eq!(f.events, vec!["down:be:0:1:2".to_string()]);
        assert!(f.client.is_empty());

        // Bad regimes are rejected: unknown service, bad grammar,
        // regime without a sweepable cluster, duplicates.
        let bad = CampaignSpec {
            faults: vec!["down:nope:0:1:2".into()],
            ..base.clone()
        };
        assert!(bad.validate().is_err(), "unknown regime service accepted");
        let bad = CampaignSpec { faults: vec!["meteor".into()], ..base.clone() };
        assert!(bad.validate().is_err(), "bad regime grammar accepted");
        let bad = CampaignSpec {
            faults: vec!["none".into(), "none".into()],
            ..base.clone()
        };
        assert!(bad.validate().is_err(), "duplicate regime accepted");
        let bad = CampaignSpec { faults: vec![], ..base.clone() };
        assert!(bad.validate().is_err(), "empty fault axis accepted");
        let orphan = CampaignSpec {
            faults: vec!["down:be:0:1:2".into()],
            clusters: vec![tenant_cluster("shared")],
            ..small()
        };
        assert!(orphan.validate().is_err(), "regime with only tenant clusters accepted");
        // A cluster carrying its own schedule conflicts with the axis.
        let mut owns = tiny_cluster("edge");
        owns.faults.events = vec!["down:be:0:1:2".into()];
        let conflicted = CampaignSpec { clusters: vec![owns], ..base };
        assert!(conflicted.validate().is_err(), "cluster-owned schedule accepted");
    }

    #[test]
    fn cluster_axis_validation_rejects_misconfiguration() {
        // A cluster carrying its own control scenarios is ambiguous.
        let mut adaptive = tiny_cluster("a");
        adaptive.adaptive = true;
        let spec = CampaignSpec { clusters: vec![adaptive], ..small() };
        assert!(spec.validate().is_err(), "embedded adaptive flag not rejected");

        let spec = CampaignSpec {
            clusters: vec![tiny_cluster("a"), tiny_cluster("a")],
            ..small()
        };
        assert!(spec.validate().is_err(), "duplicate cluster name not rejected");

        let spec = CampaignSpec {
            clusters: vec![tiny_cluster("a")],
            policies: vec![],
            ..small()
        };
        assert!(spec.validate().is_err(), "clusters without policies not rejected");

        let spec = CampaignSpec {
            clusters: vec![tiny_cluster("a")],
            policies: vec!["chaos-monkey".into()],
            ..small()
        };
        assert!(spec.validate().is_err(), "unknown policy not rejected");

        // Without clusters, the policies axis is inert: bogus entries
        // don't break pre-cluster campaigns.
        let spec = CampaignSpec { policies: vec![], ..small() };
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn sketch_axis_expands_compare_cells_with_canonical_keys() {
        let spec = CampaignSpec {
            sketch: vec!["w128d4p10k8".into(), "w256d4p10k16".into()],
            ..small()
        };
        let cells = spec.expand_sketch().unwrap();
        // 2 apps × 2 seeds × 2 geometries, first prefetcher only.
        assert_eq!(cells.len(), spec.sketch_cell_count());
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].key, "sketch|crypto|nl|r10000|s3|w128d4p10k8");
        for c in &cells {
            assert!(c.cell.cfg.controller.is_some(), "sketch cells must gate through ML");
            assert_eq!(c.cell.cfg.telemetry, format!("compare:{}", c.geom));
            assert_eq!(c.cell.label, "nl+ml");
            assert_eq!(c.cell.cfg.seed, cell_seed(c.trace_seed, &c.key));
        }
        // Keys are unique and stable across expansions.
        let keys: Vec<String> = cells.iter().map(|c| c.key.clone()).collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len());
        let again: Vec<String> =
            spec.expand_sketch().unwrap().iter().map(|c| c.key.clone()).collect();
        assert_eq!(again, keys);
        // The sim-cell matrix is untouched by the sketch axis, and a
        // sketch-free spec expands to nothing.
        assert_eq!(spec.expand().unwrap().len(), small().expand().unwrap().len());
        assert!(small().expand_sketch().unwrap().is_empty());
        assert_eq!(small().sketch_cell_count(), 0);
        // JSON round-trips the axis.
        let back = CampaignSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // Bad or duplicate geometries are rejected.
        let bad = CampaignSpec { sketch: vec!["128x4".into()], ..small() };
        assert!(bad.validate().is_err());
        let dup = CampaignSpec {
            sketch: vec!["w128d4p10k8".into(), "w128d4p10k8".into()],
            ..small()
        };
        assert!(dup.validate().is_err(), "duplicate geometry not rejected");
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(CampaignSpec::from_json(
            &Json::parse(r#"{"apps": ["nope"], "prefetchers": ["nl"]}"#).unwrap()
        )
        .is_err());
        assert!(CampaignSpec::from_json(
            &Json::parse(r#"{"apps": ["crypto"], "prefetchers": ["bogus9"]}"#).unwrap()
        )
        .is_err());
        assert!(CampaignSpec::from_json(
            &Json::parse(r#"{"apps": [], "prefetchers": ["nl"]}"#).unwrap()
        )
        .is_err());
    }
}
