//! Aggregate reports over a campaign's result store: per-app speedup
//! matrix, geomean summary per config, and best-config-per-app — the
//! same [`crate::figures::report::Table`] markdown the figure harness
//! emits, so campaign output drops straight into EXPERIMENTS.md.
//!
//! Each builder works over a materialized slice of one record kind;
//! [`reports`] materializes each kind once. On tiered stores the
//! per-kind scans are range scans: segments are tagged with per-kind
//! record counts, so (say) the sketch table never reads sim-only
//! segments.

use super::store::{CellRecord, ClusterCellRecord, ResultStore, SketchCellRecord};
use super::{group_of, Group, BASELINE_LABELS};
use crate::figures::report::{f2, f3, kb, pct, Table};
use std::collections::{BTreeMap, HashMap};

/// Geometric mean (0 when empty).
fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let logs: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (logs / xs.len() as f64).exp()
}

/// One-pass index over the store, so table builders stay O(n): records
/// grouped by (app, label) plus the baseline IPC per scenario group.
struct Index<'a> {
    /// (app, label) → records, sorted by key (stable table order).
    cells: BTreeMap<(&'a str, &'a str), Vec<&'a CellRecord>>,
    /// Scenario group → baseline IPC ([`BASELINE_LABELS`] preference).
    baseline: HashMap<Group, f64>,
}

impl<'a> Index<'a> {
    fn build(records: &'a [CellRecord]) -> Index<'a> {
        let mut cells: BTreeMap<(&str, &str), Vec<&CellRecord>> = BTreeMap::new();
        let mut baseline = HashMap::new();
        // Lowest preference first, so preferred labels overwrite.
        for pass_label in BASELINE_LABELS.iter().rev() {
            for r in records.iter().filter(|r| &r.label == pass_label) {
                baseline.insert(
                    group_of(&r.app, r.records, r.trace_seed, r.churn_scale),
                    r.ipc,
                );
            }
        }
        for r in records {
            cells.entry((r.app.as_str(), r.label.as_str())).or_default().push(r);
        }
        Index { cells, baseline }
    }

    fn apps(&self) -> Vec<&'a str> {
        let mut out: Vec<&str> = self.cells.keys().map(|(a, _)| *a).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    fn labels(&self) -> Vec<&'a str> {
        let mut out: Vec<&str> = self.cells.keys().map(|(_, l)| *l).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// A record's speedup, recomputed from the indexed baselines when
    /// the line predates its baseline (incremental campaigns append the
    /// baseline later).
    fn speedup_of(&self, r: &'a CellRecord) -> Option<f64> {
        r.speedup.or_else(|| {
            self.baseline
                .get(&group_of(&r.app, r.records, r.trace_seed, r.churn_scale))
                .map(|base| r.ipc / base)
        })
    }

    /// Speedups for one (app, label) across the scenario axes.
    fn speedups(&self, app: &'a str, label: &'a str) -> Vec<f64> {
        self.cells
            .get(&(app, label))
            .map(|rs| rs.iter().filter_map(|r| self.speedup_of(r)).collect())
            .unwrap_or_default()
    }
}

/// Per-app speedup table: apps × configs, geomean across seeds/churn.
pub fn per_app_speedup(store: &ResultStore) -> Table {
    per_app_speedup_from(&store.records())
}

fn per_app_speedup_from(records: &[CellRecord]) -> Table {
    let idx = Index::build(records);
    let labels = idx.labels();
    let mut headers: Vec<&str> = vec!["app"];
    headers.extend(&labels);
    let mut t = Table::new(
        "campaign_speedup",
        "Campaign speedup over the nl baseline (geomean across seeds/churn)",
        &headers,
    );
    for app in idx.apps() {
        let mut row = vec![app.to_string()];
        for &label in &labels {
            let s = idx.speedups(app, label);
            row.push(if s.is_empty() { "-".into() } else { f3(geomean(&s)) });
        }
        t.row(row);
    }
    t.note("'-' = no nl baseline cell in this campaign for that scenario");
    t
}

/// Per-config summary: geomean speedup across apps, mean accuracy, mean
/// MPKI, metadata footprint, cell count.
pub fn geomean_summary(store: &ResultStore) -> Table {
    geomean_summary_from(&store.records())
}

fn geomean_summary_from(records: &[CellRecord]) -> Table {
    let idx = Index::build(records);
    let apps = idx.apps();
    let mut t = Table::new(
        "campaign_summary",
        "Campaign geomean summary per config",
        &["config", "geomean speedup", "mean accuracy", "mean I-MPKI", "metadata", "cells"],
    );
    for label in idx.labels() {
        let per_app: Vec<f64> = apps
            .iter()
            .map(|a| idx.speedups(a, label))
            .filter(|s| !s.is_empty())
            .map(|s| geomean(&s))
            .collect();
        let cells: Vec<&CellRecord> = apps
            .iter()
            .filter_map(|a| idx.cells.get(&(*a, label)))
            .flatten()
            .copied()
            .collect();
        let n = cells.len().max(1) as f64;
        let mean_acc = cells.iter().map(|r| r.accuracy).sum::<f64>() / n;
        let mean_mpki = cells.iter().map(|r| r.mpki).sum::<f64>() / n;
        let meta = cells.iter().map(|r| r.metadata_bytes).max().unwrap_or(0);
        t.row(vec![
            label.to_string(),
            if per_app.is_empty() { "-".into() } else { f3(geomean(&per_app)) },
            pct(mean_acc),
            f2(mean_mpki),
            kb(meta),
            cells.len().to_string(),
        ]);
    }
    t
}

/// Best non-baseline config per app, by geomean speedup.
pub fn best_config(store: &ResultStore) -> Table {
    best_config_from(&store.records())
}

fn best_config_from(records: &[CellRecord]) -> Table {
    let idx = Index::build(records);
    let labels = idx.labels();
    let mut t = Table::new(
        "campaign_best",
        "Best config per app (by speedup; nl/perfect excluded)",
        &["app", "best config", "speedup", "metadata"],
    );
    for app in idx.apps() {
        let mut best: Option<(&str, f64)> = None;
        for &label in &labels {
            // Baselines (nl, nl+ml) and the oracle are not candidates.
            if BASELINE_LABELS.contains(&label) || label.starts_with("perfect") {
                continue;
            }
            let s = idx.speedups(app, label);
            if s.is_empty() {
                continue;
            }
            let g = geomean(&s);
            if best.map(|(_, b)| g > b).unwrap_or(true) {
                best = Some((label, g));
            }
        }
        match best {
            Some((label, g)) => {
                let meta = idx
                    .cells
                    .get(&(app, label))
                    .into_iter()
                    .flatten()
                    .map(|r| r.metadata_bytes)
                    .max()
                    .unwrap_or(0);
                t.row(vec![app.to_string(), label.to_string(), f3(g), kb(meta)]);
            }
            None => {
                t.row(vec![app.to_string(), "-".into(), "-".into(), "-".into()]);
            }
        }
    }
    t
}

/// Tail-latency table over traffic-axis cells: one row per
/// (app, config, shape), geomean-free (tails don't average well —
/// show the scenario values directly). `None` when the campaign had no
/// traffic axis.
pub fn tail_table(store: &ResultStore) -> Option<Table> {
    tail_table_from(&store.records())
}

fn tail_table_from(records: &[CellRecord]) -> Option<Table> {
    let mut t = Table::new(
        "campaign_tails",
        "Queueing tails per traffic shape (single-service cluster at the cell's IPC)",
        &["app", "config", "traffic", "P50 µs", "P95 µs", "P99 µs", "compliance"],
    );
    // Store order is expansion order — already deterministic and grouped.
    for r in records {
        if let Some(tail) = &r.tail {
            t.row(vec![
                r.app.clone(),
                r.label.clone(),
                tail.traffic.clone(),
                f2(tail.p50_us),
                f2(tail.p95_us),
                f2(tail.p99_us),
                pct(tail.compliance),
            ]);
        }
    }
    if t.rows.is_empty() {
        None
    } else {
        t.note("SLO for compliance = 5× the cell's zero-load service time");
        Some(t)
    }
}

/// Cluster-scenario sweep table: one row per stored (cluster, policy,
/// traffic) cell with its SLO burn and cost metrics. Tenant cells have
/// their own paired table ([`tenant_pairings`]), fault-regime cells
/// their own ranking ([`fault_ranking`]); both are excluded here so the
/// healthy-regime sweep renders exactly as it did before the fault
/// axis. `None` when the campaign had no (policy-swept) cluster axis.
pub fn cluster_table(store: &ResultStore) -> Option<Table> {
    cluster_table_from(&store.cluster_records())
}

fn cluster_table_from(records: &[ClusterCellRecord]) -> Option<Table> {
    let recs: Vec<&ClusterCellRecord> =
        records.iter().filter(|r| r.tenant.is_empty() && r.faults.is_empty()).collect();
    if recs.is_empty() {
        return None;
    }
    let mut t = Table::new(
        "campaign_cluster",
        "Cluster-scenario sweep: SLO burn and cost per autoscaler policy",
        &[
            "cluster",
            "policy",
            "traffic",
            "model",
            "P99 µs",
            "compliance",
            "burn",
            "replica·s",
            "metadata",
            "actions",
        ],
    );
    // Store order is expansion order — already deterministic.
    for r in recs {
        let mean_meta = if r.duration_us > 0.0 { r.meta_byte_us / r.duration_us } else { 0.0 };
        t.row(vec![
            r.cluster.clone(),
            r.policy.clone(),
            r.traffic.clone(),
            r.service_times.clone(),
            f2(r.p99_us),
            pct(r.compliance),
            format!("{}/{}", r.violated_windows, r.windows),
            f2(r.replica_us / 1e6),
            kb(mean_meta as u64),
            r.actions.to_string(),
        ]);
    }
    t.note(
        "burn = windows below target compliance / windows evaluated; replica·s = \
         ∫ provisioned replicas dt; metadata = time-averaged footprint; model = \
         service-time source (analytic mean+cv vs trace-replayed empirical)",
    );
    Some(t)
}

/// Policy ranking per (cluster, traffic, service-time model) group:
/// fewest burned windows first, cheapest replica-seconds on ties, then
/// P99. Grouping by model keeps analytic and empirical rows of the same
/// scenario — both present after flipping `service_times` against an
/// existing store — from being ranked against each other. `None`
/// without a cluster axis.
pub fn cluster_ranking(store: &ResultStore) -> Option<Table> {
    cluster_ranking_from(&store.cluster_records())
}

fn cluster_ranking_from(records: &[ClusterCellRecord]) -> Option<Table> {
    let recs: Vec<&ClusterCellRecord> =
        records.iter().filter(|r| r.tenant.is_empty() && r.faults.is_empty()).collect();
    if recs.is_empty() {
        return None;
    }
    // Group in first-seen (expansion) order.
    type RankKey = (String, String, String);
    let mut groups: Vec<(RankKey, Vec<&ClusterCellRecord>)> = Vec::new();
    for r in recs {
        let k = (r.cluster.clone(), r.traffic.clone(), r.service_times.clone());
        match groups.iter_mut().find(|(g, _)| *g == k) {
            Some((_, v)) => v.push(r),
            None => groups.push((k, vec![r])),
        }
    }
    let mut t = Table::new(
        "campaign_cluster_rank",
        "Autoscaler policy ranking per (cluster, traffic, model)",
        &["cluster", "traffic", "model", "rank", "policy", "burn", "replica·s", "P99 µs"],
    );
    for ((cluster, traffic, model), mut v) in groups {
        v.sort_by(|a, b| {
            a.burn_rate()
                .partial_cmp(&b.burn_rate())
                .unwrap()
                .then(a.replica_us.partial_cmp(&b.replica_us).unwrap())
                .then(a.p99_us.partial_cmp(&b.p99_us).unwrap())
        });
        for (i, r) in v.iter().enumerate() {
            t.row(vec![
                cluster.clone(),
                traffic.clone(),
                model.clone(),
                (i + 1).to_string(),
                r.policy.clone(),
                format!("{}/{}", r.violated_windows, r.windows),
                f2(r.replica_us / 1e6),
                f2(r.p99_us),
            ]);
        }
    }
    t.note("rank 1 = fewest burned windows, cheapest replica-seconds on ties");
    Some(t)
}

/// Policy ranking under injected fault regimes: one group per
/// (cluster, traffic, model, regime), ranked like [`cluster_ranking`]
/// (burn first, replica-seconds on ties, then P99). A policy that tops
/// the healthy ranking can drop here — retries and hedges that are
/// free under a healthy cluster become load under a crashed or gray
/// replica — which is exactly what this table is for. `None` when the
/// campaign had no `faults` axis beyond "none".
pub fn fault_ranking(store: &ResultStore) -> Option<Table> {
    fault_ranking_from(&store.cluster_records())
}

fn fault_ranking_from(records: &[ClusterCellRecord]) -> Option<Table> {
    let recs: Vec<&ClusterCellRecord> =
        records.iter().filter(|r| r.tenant.is_empty() && !r.faults.is_empty()).collect();
    if recs.is_empty() {
        return None;
    }
    // Group in first-seen (expansion) order: regime is the outer sweep
    // loop, so each regime's policies land contiguously.
    type FaultKey = (String, String, String, String);
    let mut groups: Vec<(FaultKey, Vec<&ClusterCellRecord>)> = Vec::new();
    for r in recs {
        let k =
            (r.cluster.clone(), r.traffic.clone(), r.service_times.clone(), r.faults.clone());
        match groups.iter_mut().find(|(g, _)| *g == k) {
            Some((_, v)) => v.push(r),
            None => groups.push((k, vec![r])),
        }
    }
    let mut t = Table::new(
        "campaign_faults",
        "Autoscaler policy ranking under injected fault regimes",
        &["cluster", "traffic", "model", "faults", "rank", "policy", "burn", "replica·s", "P99 µs"],
    );
    for ((cluster, traffic, model, regime), mut v) in groups {
        v.sort_by(|a, b| {
            a.burn_rate()
                .partial_cmp(&b.burn_rate())
                .unwrap()
                .then(a.replica_us.partial_cmp(&b.replica_us).unwrap())
                .then(a.p99_us.partial_cmp(&b.p99_us).unwrap())
        });
        for (i, r) in v.iter().enumerate() {
            t.row(vec![
                cluster.clone(),
                traffic.clone(),
                model.clone(),
                regime.clone(),
                (i + 1).to_string(),
                r.policy.clone(),
                format!("{}/{}", r.violated_windows, r.windows),
                f2(r.replica_us / 1e6),
                f2(r.p99_us),
            ]);
        }
    }
    t.note(
        "one group per fault regime (';'-joined schedule from the campaign faults \
         axis); rank 1 = fewest burned windows under that regime — compare against \
         campaign_cluster_rank to see which policies are robust, not just cheap",
    );
    Some(t)
}

/// Tenant-pairing table over multi-tenant cluster cells: one row per
/// (cluster, tenant) pairing each co-located cell with its solo twin
/// (same arrival seed ⇒ the Δ P99 is pure co-location interference).
/// Pairings — clusters — are ranked best-first by worst-tenant
/// co-located burn, then by worst interference Δ P99. `None` when the
/// store holds no tenant cells.
pub fn tenant_pairings(store: &ResultStore) -> Option<Table> {
    tenant_pairings_from(&store.cluster_records())
}

fn tenant_pairings_from(records: &[ClusterCellRecord]) -> Option<Table> {
    let recs: Vec<&ClusterCellRecord> =
        records.iter().filter(|r| !r.tenant.is_empty()).collect();
    if recs.is_empty() {
        return None;
    }
    // A coloc cell's solo twin has the *same key* with the mode segment
    // swapped — `cluster|{name}#{hash}|coloc|{tenant}|t{shape}` — so
    // pairing (and grouping) goes through the content-hashed key, never
    // through display names: stale lines left behind by an edited spec
    // carry an old hash and can only pair (and group) with each other.
    let solo_of = |coloc_key: &str| {
        let solo_key = coloc_key.replacen("|coloc|", "|solo|", 1);
        recs.iter().find(|r| r.key == solo_key).copied()
    };
    // Group co-located rows per (cluster, hash) key prefix, first-seen
    // (expansion) order.
    let mut groups: Vec<(String, Vec<&ClusterCellRecord>)> = Vec::new();
    for &r in recs.iter().filter(|r| r.policy == "coloc") {
        let prefix = r.key.split("|coloc|").next().unwrap_or(&r.key).to_string();
        match groups.iter_mut().find(|(p, _)| *p == prefix) {
            Some((_, v)) => v.push(r),
            None => groups.push((prefix, vec![r])),
        }
    }
    // Rank pairings: lowest worst-tenant burn first, then the smallest
    // worst-tenant Δ P99. Scores are computed once per group (not per
    // comparison — solo_of is a linear scan); stable sort keeps ties in
    // expansion order.
    let score = |v: &[&ClusterCellRecord]| {
        let burn = v.iter().map(|r| r.burn_rate()).fold(0.0f64, f64::max);
        let delta = v
            .iter()
            .filter_map(|r| solo_of(&r.key).map(|s| (r.p99_us - s.p99_us) / s.p99_us))
            .fold(0.0f64, f64::max);
        (burn, delta)
    };
    let mut groups: Vec<((f64, f64), Vec<&ClusterCellRecord>)> =
        groups.into_iter().map(|(_, v)| (score(&v), v)).collect();
    groups.sort_by(|(a, _), (b, _)| {
        a.0.partial_cmp(&b.0).unwrap().then(a.1.partial_cmp(&b.1).unwrap())
    });
    let mut t = Table::new(
        "campaign_tenants",
        "Tenant pairings: co-location Δ P99 vs solo, ranked by worst-tenant burn",
        &[
            "rank",
            "cluster",
            "tenant",
            "traffic",
            "P99 µs (solo)",
            "P99 µs (coloc)",
            "Δ P99",
            "burn",
            "compliance",
        ],
    );
    for (rank, (_, v)) in groups.iter().enumerate() {
        for r in v {
            let (solo_p99, delta) = match solo_of(&r.key) {
                Some(s) => (
                    f2(s.p99_us),
                    format!("{:+.1}%", (r.p99_us - s.p99_us) / s.p99_us * 100.0),
                ),
                None => ("-".into(), "-".into()),
            };
            t.row(vec![
                (rank + 1).to_string(),
                r.cluster.clone(),
                r.tenant.clone(),
                r.traffic.clone(),
                solo_p99,
                f2(r.p99_us),
                delta,
                format!("{}/{}", r.violated_windows, r.windows),
                pct(r.compliance),
            ]);
        }
    }
    t.note(
        "paired cells: a tenant's solo and co-located runs share the arrival seed, \
         so Δ P99 is pure co-location (shared queues + way-overflow dilation); \
         rank 1 = the pairing with the lowest worst-tenant burn",
    );
    Some(t)
}

/// Sketch-accuracy table over the campaign `sketch` axis: one row per
/// compare-mode cell showing what the bounded-memory telemetry costs
/// (sketch bytes vs exact per-context counters) against what it gives
/// up (decision agreement, feature error, cardinality error). `None`
/// when the campaign had no sketch axis.
pub fn sketch_table(store: &ResultStore) -> Option<Table> {
    sketch_table_from(&store.sketch_records())
}

fn sketch_table_from(records: &[SketchCellRecord]) -> Option<Table> {
    if records.is_empty() {
        return None;
    }
    let mut t = Table::new(
        "campaign_sketch",
        "Sketch telemetry accuracy vs footprint (compare-mode cells)",
        &[
            "app",
            "geometry",
            "issued",
            "ctx exact",
            "ctx est",
            "decisions",
            "agreement",
            "feat MAE",
            "fill",
            "sketch",
            "exact",
            "ratio",
        ],
    );
    // Store order is expansion order — already deterministic.
    for r in records {
        t.row(vec![
            r.app.clone(),
            r.geom.clone(),
            r.issued.to_string(),
            r.distinct_exact.to_string(),
            r.distinct_est.to_string(),
            r.decisions.to_string(),
            pct(r.agreement),
            format!("{:.4}", r.feature_mae),
            pct(r.fill),
            kb(r.sketch_bytes),
            kb(r.exact_bytes),
            f2(r.byte_ratio()),
        ]);
    }
    t.note(
        "agreement = gate decisions unchanged when sketch estimates replace exact \
         counters; ratio = sketch bytes / exact per-context counter bytes (lower \
         is cheaper); ctx est = HLL cardinality vs the exact distinct-context count",
    );
    Some(t)
}

/// All campaign tables, in print order. Each record kind is
/// materialized once and shared across its builders (three kind-tagged
/// range scans, however many tables render).
pub fn reports(store: &ResultStore) -> Vec<Table> {
    let sims = store.records();
    let clusters = store.cluster_records();
    let sketches = store.sketch_records();
    let mut out =
        vec![per_app_speedup_from(&sims), geomean_summary_from(&sims), best_config_from(&sims)];
    if let Some(t) = tail_table_from(&sims) {
        out.push(t);
    }
    if let Some(t) = cluster_table_from(&clusters) {
        out.push(t);
    }
    if let Some(t) = cluster_ranking_from(&clusters) {
        out.push(t);
    }
    if let Some(t) = fault_ranking_from(&clusters) {
        out.push(t);
    }
    if let Some(t) = tenant_pairings_from(&clusters) {
        out.push(t);
    }
    if let Some(t) = sketch_table_from(&sketches) {
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::store::ResultStore;

    fn rec(app: &str, label: &str, speedup: Option<f64>) -> CellRecord {
        CellRecord {
            key: format!("{app}|{label}"),
            app: app.into(),
            label: label.into(),
            records: 1000,
            trace_seed: 7,
            sim_seed: 1,
            ml: false,
            churn_scale: 1.0,
            ipc: 2.0,
            speedup,
            mpki: 10.0,
            l1d_mpki: 2.0,
            accuracy: 0.75,
            coverage: 0.5,
            timeliness: 0.9,
            metadata_bytes: 4096,
            pf_issued: 10,
            pf_timely: 7,
            pf_late: 1,
            pf_useless: 2,
            pf_skipped: 0,
            instrs: 1000,
            cycles: 500.0,
            controller: None,
            tail: None,
        }
    }

    fn store() -> ResultStore {
        let mut s = ResultStore::in_memory();
        s.push(rec("crypto", "nl", Some(1.0))).unwrap();
        s.push(rec("crypto", "eip256", Some(1.08))).unwrap();
        s.push(rec("crypto", "ceip256", Some(1.06))).unwrap();
        s.push(rec("serde", "nl", Some(1.0))).unwrap();
        s.push(rec("serde", "eip256", Some(1.12))).unwrap();
        s.push(rec("serde", "ceip256", Some(1.11))).unwrap();
        s
    }

    #[test]
    fn geomean_math() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn speedup_table_has_app_rows_and_config_cols() {
        let t = per_app_speedup(&store());
        assert_eq!(t.headers, vec!["app", "ceip256", "eip256", "nl"]);
        assert_eq!(t.rows.len(), 2);
        let md = t.markdown();
        assert!(md.contains("crypto"));
        assert!(md.contains("1.080"));
    }

    #[test]
    fn summary_and_best() {
        let s = store();
        let sum = geomean_summary(&s);
        assert_eq!(sum.rows.len(), 3); // ceip256, eip256, nl
        let best = best_config(&s);
        // eip256 wins both apps.
        for row in &best.rows {
            assert_eq!(row[1], "eip256");
        }
    }

    #[test]
    fn tail_table_only_renders_shaped_cells() {
        let s = store();
        assert!(tail_table(&s).is_none(), "tail table without a traffic axis");
        assert_eq!(reports(&s).len(), 3);
        let mut s = ResultStore::in_memory();
        let mut r = rec("crypto", "ceip256", Some(1.1));
        r.tail = Some(crate::campaign::store::TailRecord {
            traffic: "poisson:0.65".into(),
            p50_us: 6.0,
            p95_us: 12.0,
            p99_us: 20.0,
            compliance: 0.98,
            slo_us: 25.0,
        });
        s.push(r).unwrap();
        s.push(rec("crypto", "nl", Some(1.0))).unwrap();
        let t = tail_table(&s).expect("shaped cell missing from tail table");
        assert_eq!(t.rows.len(), 1);
        assert!(t.markdown().contains("poisson:0.65"));
        assert_eq!(reports(&s).len(), 4);
    }

    fn crec(policy: &str, traffic: &str, violated: u32, replica_us: f64) -> ClusterCellRecord {
        ClusterCellRecord {
            key: format!("cluster|web#0|{policy}|t{traffic}"),
            cluster: "web".into(),
            policy: policy.into(),
            tenant: String::new(),
            faults: String::new(),
            service_times: "empirical".into(),
            traffic: traffic.into(),
            requests: 50_000,
            slo_us: 100.0,
            p50_us: 20.0,
            p95_us: 55.0,
            p99_us: 80.0,
            compliance: 0.99,
            windows: 25,
            violated_windows: violated,
            actions: 3,
            final_replicas: 8,
            replica_us,
            meta_byte_us: 5.0e9,
            final_metadata_bytes: 65_536,
            duration_us: 5.0e5,
            events: 400_000,
        }
    }

    #[test]
    fn cluster_tables_rank_policies_per_group() {
        let s = store();
        assert!(cluster_table(&s).is_none(), "cluster table without a cluster axis");
        assert!(cluster_ranking(&s).is_none());

        let mut s = ResultStore::in_memory();
        s.push_cluster(crec("reactive", "poisson:0.65", 5, 9.0e6)).unwrap();
        s.push_cluster(crec("hysteresis:4:0.7", "poisson:0.65", 5, 6.0e6)).unwrap();
        s.push_cluster(crec("predictive:30000:4", "poisson:0.65", 1, 8.0e6)).unwrap();
        let t = cluster_table(&s).expect("cluster rows missing");
        assert_eq!(t.rows.len(), 3);
        // Empirical cells are labelled as such.
        assert_eq!(t.rows[0][3], "empirical");
        assert!(t.markdown().contains("model"));
        let rank = cluster_ranking(&s).expect("ranking missing");
        assert_eq!(rank.rows.len(), 3);
        // Fewest burned windows wins; replica-seconds break the tie.
        assert_eq!(rank.rows[0][4], "predictive:30000:4");
        assert_eq!(rank.rows[1][4], "hysteresis:4:0.7");
        assert_eq!(rank.rows[2][4], "reactive");
        assert_eq!(rank.rows[0][3], "1");
        // Both cluster tables ride along in reports().
        assert_eq!(reports(&s).len(), 5);

        // A stale analytic row of the same (cluster, traffic) — the
        // store state after flipping service_times and resuming — ranks
        // in its own model group, never against the empirical rows.
        let mut stale = crec("reactive", "poisson:0.65", 0, 1.0e6);
        stale.key = "cluster|web#old|reactive|tpoisson:0.65".into();
        stale.service_times = "analytic".into();
        s.push_cluster(stale).unwrap();
        let rank = cluster_ranking(&s).expect("ranking missing");
        assert_eq!(rank.rows.len(), 4);
        // The empirical group is unchanged (the 0-burn analytic row
        // would otherwise have stolen rank 1)...
        assert_eq!(rank.rows[0][4], "predictive:30000:4");
        assert_eq!(rank.rows[0][2], "empirical");
        // ...and the analytic row ranks first in its own group.
        let ana = rank.rows.iter().find(|r| r[2] == "analytic").unwrap();
        assert_eq!(ana[3], "1");
    }

    #[test]
    fn fault_cells_rank_in_their_own_table_and_stay_out_of_healthy_ones() {
        let s = store();
        assert!(fault_ranking(&s).is_none(), "fault table without a fault axis");

        let mut s = ResultStore::in_memory();
        // Healthy regime: reactive is cheapest and burns nothing.
        s.push_cluster(crec("reactive", "poisson:0.65", 0, 6.0e6)).unwrap();
        s.push_cluster(crec("predictive:30000:4", "poisson:0.65", 0, 8.0e6)).unwrap();
        // Under the crash regime reactive burns hard; predictive holds.
        let regime = "down:be:0:20000:30000";
        let mut f1 = crec("reactive", "poisson:0.65", 7, 6.5e6);
        f1.key = format!("{}|f{regime}", f1.key);
        f1.faults = regime.into();
        let mut f2 = crec("predictive:30000:4", "poisson:0.65", 1, 8.5e6);
        f2.key = format!("{}|f{regime}", f2.key);
        f2.faults = regime.into();
        s.push_cluster(f1).unwrap();
        s.push_cluster(f2).unwrap();

        // Healthy tables see only the healthy cells — same rows as a
        // pre-fault store — and reactive tops the healthy ranking.
        let t = cluster_table(&s).expect("healthy rows missing");
        assert_eq!(t.rows.len(), 2, "fault cells leaked into cluster_table");
        let rank = cluster_ranking(&s).expect("healthy ranking missing");
        assert_eq!(rank.rows.len(), 2);
        assert_eq!(rank.rows[0][4], "reactive");

        // The fault ranking flips the order, labelled with the regime.
        let ft = fault_ranking(&s).expect("fault ranking missing");
        assert_eq!(ft.rows.len(), 2);
        assert_eq!(ft.rows[0][3], regime);
        assert_eq!(ft.rows[0][4], "1");
        assert_eq!(ft.rows[0][5], "predictive:30000:4");
        assert_eq!(ft.rows[1][5], "reactive");
        assert!(ft.markdown().contains("campaign_faults"));
        // All three cluster tables ride along in reports().
        assert_eq!(reports(&s).len(), 6);
    }

    fn trec(cluster: &str, mode: &str, tenant: &str, p99: f64, violated: u32) -> ClusterCellRecord {
        let mut r = crec(mode, "poisson:0.5", violated, 5.0e6);
        r.key = format!("cluster|{cluster}#0|{mode}|{tenant}|tpoisson:0.5");
        r.cluster = cluster.into();
        r.tenant = tenant.into();
        r.p99_us = p99;
        r
    }

    #[test]
    fn tenant_pairings_pair_solo_rows_and_rank_by_worst_burn() {
        let s = store();
        assert!(tenant_pairings(&s).is_none(), "tenant table without tenant cells");

        let mut s = ResultStore::in_memory();
        // Pairing "calm": both tenants burn nothing, mild deltas.
        s.push_cluster(trec("calm", "solo", "a", 50.0, 0)).unwrap();
        s.push_cluster(trec("calm", "solo", "b", 40.0, 0)).unwrap();
        s.push_cluster(trec("calm", "coloc", "a", 55.0, 0)).unwrap();
        s.push_cluster(trec("calm", "coloc", "b", 44.0, 0)).unwrap();
        // Pairing "noisy": tenant b burns hard and doubles its tail.
        s.push_cluster(trec("noisy", "solo", "a", 50.0, 0)).unwrap();
        s.push_cluster(trec("noisy", "solo", "b", 40.0, 0)).unwrap();
        s.push_cluster(trec("noisy", "coloc", "a", 60.0, 1)).unwrap();
        s.push_cluster(trec("noisy", "coloc", "b", 80.0, 9)).unwrap();
        let t = tenant_pairings(&s).expect("tenant pairings missing");
        assert_eq!(t.rows.len(), 4, "one row per co-located tenant");
        // calm ranks 1 (worst burn 0), noisy 2 (worst burn 9/25).
        assert_eq!(t.rows[0][0], "1");
        assert_eq!(t.rows[0][1], "calm");
        assert_eq!(t.rows[2][1], "noisy");
        assert_eq!(t.rows[2][0], "2");
        // The paired delta is computed against the matching solo cell.
        let b_row = t.rows.iter().find(|r| r[1] == "noisy" && r[2] == "b").unwrap();
        assert_eq!(b_row[4], "40.00");
        assert_eq!(b_row[5], "80.00");
        assert_eq!(b_row[6], "+100.0%");
        // Tenant cells stay out of the policy tables.
        assert!(cluster_table(&s).is_none(), "tenant cells leaked into cluster_table");
        assert!(cluster_ranking(&s).is_none(), "tenant cells leaked into ranking");
        assert_eq!(reports(&s).len(), 4);
    }

    #[test]
    fn sketch_table_renders_accuracy_rows() {
        let s = store();
        assert!(sketch_table(&s).is_none(), "sketch table without a sketch axis");

        let mut s = ResultStore::in_memory();
        s.push(rec("crypto", "nl", Some(1.0))).unwrap();
        s.push_sketch(crate::campaign::store::SketchCellRecord {
            key: "sketch|crypto|nl|r1000|s7|w256d4p10k16".into(),
            app: "crypto".into(),
            label: "nl+ml".into(),
            records: 1000,
            trace_seed: 7,
            sim_seed: 1,
            geom: "w256d4p10k16".into(),
            sketch_bytes: 13_568,
            exact_bytes: 72_000,
            distinct_exact: 3000,
            distinct_est: 2950,
            issued: 40_000,
            decisions: 5000,
            agreement: 0.978,
            feature_mae: 0.0123,
            fill: 0.4,
        })
        .unwrap();
        let t = sketch_table(&s).expect("sketch rows missing");
        assert_eq!(t.rows.len(), 1);
        let row = &t.rows[0];
        assert_eq!(row[1], "w256d4p10k16");
        assert_eq!(row[6], "97.8%");
        assert_eq!(row[11], "0.19");
        assert!(t.markdown().contains("campaign_sketch"));
        // The sketch table rides along in reports(); plain stores are
        // unchanged (3 core tables only).
        assert_eq!(reports(&s).len(), 4);
    }

    #[test]
    fn missing_baseline_renders_dash() {
        let mut s = ResultStore::in_memory();
        s.push(rec("crypto", "eip256", None)).unwrap();
        let t = per_app_speedup(&s);
        assert_eq!(t.rows[0][1], "-");
        let b = best_config(&s);
        assert_eq!(b.rows[0][1], "-");
    }

    #[test]
    fn null_speedup_recomputed_once_baseline_lands() {
        // Incremental campaign: eip line stored before its nl baseline.
        let mut s = ResultStore::in_memory();
        let mut eip = rec("crypto", "eip256", None);
        eip.ipc = 2.2;
        s.push(eip).unwrap();
        let mut nl = rec("crypto", "nl", Some(1.0));
        nl.ipc = 2.0;
        s.push(nl).unwrap();
        let t = per_app_speedup(&s);
        // headers: app, eip256, nl
        assert_eq!(t.rows[0][1], "1.100");
        assert_eq!(t.rows[0][2], "1.000");
    }

    #[test]
    fn gated_baseline_used_when_no_plain_nl() {
        let mut s = ResultStore::in_memory();
        let mut nlml = rec("crypto", "nl+ml", None);
        nlml.ipc = 2.0;
        s.push(nlml).unwrap();
        let mut c = rec("crypto", "ceip256+ml", None);
        c.ipc = 2.4;
        s.push(c).unwrap();
        let t = per_app_speedup(&s);
        // headers: app, ceip256+ml, nl+ml
        assert_eq!(t.rows[0][1], "1.200");
        // The gated baseline must not be crowned best config.
        let b = best_config(&s);
        assert_eq!(b.rows[0][1], "ceip256+ml");
    }
}
