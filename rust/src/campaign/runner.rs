//! Work-queue executor for campaign cells: shards cells across N worker
//! threads over [`std::thread::scope`]. Every cell is fully independent —
//! it generates its own trace and runs its own [`crate::sim::engine`]
//! instance — so the result vector is a pure function of the cell list
//! and byte-identical regardless of thread count or scheduling (the
//! determinism contract in DESIGN.md "Campaign subsystem").
//!
//! Cells are *core-simulation* units only: the campaign `traffic` axis
//! (queueing-tail evaluation per arrival shape) is layered on top by
//! `campaign::run_to_store` at write time, so a cell's identity — and
//! its result — never depends on how it will be evaluated downstream.
//!
//! Resume is decided before cells reach this executor:
//! `campaign::run_to_store` probes `ResultStore::contains` per expanded
//! key and only enqueues misses. On tiered stores those probes hit the
//! memtable key set and each segment's bloom filter + sparse index —
//! the store never preloads the full key set, so a million-cell resume
//! costs O(pending) probes, not a full log replay.

use crate::config::SimConfig;
use crate::sim::engine::{self, SimResult};
use crate::trace::gen::{apps::AppSpec, generate_records};
use crate::trace::Record;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

/// One runnable simulation cell. The app spec is fully resolved (churn
/// knobs already applied) so workers never consult shared state.
#[derive(Clone)]
pub struct Cell {
    pub app: AppSpec,
    /// Reporting label (the spec's prefetcher name, e.g. `ceip256+ml`).
    pub label: String,
    pub cfg: SimConfig,
    pub records: u64,
    pub trace_seed: u64,
    /// Pre-loaded trace records replacing generation (`.slft` replay in
    /// the cluster layer). Shared read-only across workers; `None` =
    /// generate from the app preset as usual.
    pub trace: Option<Arc<Vec<Record>>>,
}

impl Cell {
    fn run(&self) -> SimResult {
        let generated;
        let records: &[Record] = match &self.trace {
            Some(t) => t,
            None => {
                generated = generate_records(&self.app, self.trace_seed, self.records);
                &generated
            }
        };
        let mut result = engine::run(&self.cfg, records);
        result.app = self.app.name.to_string();
        result.label = self.label.clone();
        result
    }
}

/// Number of worker threads to use when the caller passes 0 ("auto").
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run all cells, `threads` at a time (0 = available parallelism),
/// invoking `each(index, result)` on the calling thread as results
/// arrive (completion order — callers that need cell order buffer by
/// index, as [`run_cells`] does). `each` returning `false` cancels the
/// sweep: no new cells are handed out (in-flight cells still finish and
/// are discarded).
pub fn run_cells_each<F>(cells: &[Cell], threads: usize, mut each: F)
where
    F: FnMut(usize, SimResult) -> bool,
{
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.clamp(1, cells.len().max(1));
    let next = AtomicUsize::new(0);
    let next = &next;
    let (tx, rx) = mpsc::channel::<(usize, SimResult)>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                // Receiver outlives every worker; send cannot fail.
                let _ = tx.send((i, cells[i].run()));
            });
        }
        drop(tx);
        let mut cancelled = false;
        for (i, result) in rx {
            if !cancelled && !each(i, result) {
                cancelled = true;
                // Park the cursor past the end so workers stop claiming.
                next.store(cells.len(), Ordering::Relaxed);
            }
        }
    });
}

/// Generic deterministic parallel map: evaluate `f(0..n)` across
/// `threads` scoped workers (0 = auto) and return results in index
/// order — equal inputs yield equal outputs at any thread count. The
/// cluster scenario runner shards through this; [`run_cells_each`]
/// keeps its own loop because it additionally streams results and
/// supports cancellation.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.clamp(1, n.max(1));
    let next = AtomicUsize::new(0);
    let next = &next;
    let f = &f;
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // Receiver outlives every worker; send cannot fail.
                let _ = tx.send((i, f(i)));
            });
        }
        drop(tx);
        for (i, r) in rx {
            slots[i] = Some(r);
        }
    });
    slots.into_iter().map(|s| s.expect("worker skipped an item")).collect()
}

/// Run all cells and return results in cell order: equal inputs yield
/// equal outputs at any thread count.
pub fn run_cells(cells: &[Cell], threads: usize) -> Vec<SimResult> {
    let mut slots: Vec<Option<SimResult>> = cells.iter().map(|_| None).collect();
    run_cells_each(cells, threads, |i, result| {
        slots[i] = Some(result);
        true
    });
    slots
        .into_iter()
        .map(|s| s.expect("worker skipped a cell"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefetcherKind;
    use crate::trace::gen::apps;

    fn cell(app: &str, kind: PrefetcherKind, label: &str) -> Cell {
        Cell {
            app: apps::app(app).unwrap(),
            label: label.to_string(),
            cfg: SimConfig { prefetcher: kind, ..Default::default() },
            records: 20_000,
            trace_seed: 5,
            trace: None,
        }
    }

    #[test]
    fn preloaded_trace_overrides_generation() {
        use crate::trace::gen::generate_records;
        let app = apps::app("crypto").unwrap();
        // Records from a *different* app: the override must win.
        let serde_records =
            generate_records(&apps::app("serde").unwrap(), 5, 20_000);
        let mut with_trace = cell("crypto", PrefetcherKind::NextLineOnly, "nl");
        with_trace.trace = Some(std::sync::Arc::new(serde_records.clone()));
        let plain = cell("crypto", PrefetcherKind::NextLineOnly, "nl");
        let out = run_cells(&[with_trace, plain], 2);
        // Reporting identity still comes from the app preset…
        assert_eq!(out[0].app, app.name);
        // …but the simulated stream is the preloaded one.
        let direct = engine::run(
            &SimConfig { prefetcher: PrefetcherKind::NextLineOnly, ..Default::default() },
            &serde_records,
        );
        assert_eq!(out[0].stats.cycles, direct.stats.cycles);
        assert_ne!(out[0].stats.cycles, out[1].stats.cycles);
    }

    #[test]
    fn results_in_cell_order_with_labels() {
        let cells = vec![
            cell("crypto", PrefetcherKind::NextLineOnly, "nl"),
            cell("serde", PrefetcherKind::Eip { entries: 1024 }, "eip64"),
            cell("logging", PrefetcherKind::Perfect, "perfect"),
        ];
        let out = run_cells(&cells, 3);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].app, "crypto");
        assert_eq!(out[0].label, "nl");
        assert_eq!(out[1].label, "eip64");
        assert_eq!(out[2].label, "perfect");
        for r in &out {
            assert!(r.stats.instrs > 0);
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let cells: Vec<Cell> = ["crypto", "serde", "logging", "admission"]
            .iter()
            .map(|a| cell(a, PrefetcherKind::Eip { entries: 1024 }, "eip64"))
            .collect();
        let serial = run_cells(&cells, 1);
        let parallel = run_cells(&cells, 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.stats.cycles, b.stats.cycles);
            assert_eq!(a.stats.pf_issued, b.stats.pf_issued);
            assert_eq!(a.metadata_bytes, b.metadata_bytes);
        }
    }

    #[test]
    fn empty_and_oversubscribed_are_fine() {
        assert!(run_cells(&[], 8).is_empty());
        let one = vec![cell("crypto", PrefetcherKind::NextLineOnly, "nl")];
        assert_eq!(run_cells(&one, 64).len(), 1);
    }

    #[test]
    fn parallel_map_returns_results_in_index_order() {
        let out = parallel_map(17, 4, |i| i * i);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(3, 0, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn cancellation_stops_handing_out_cells() {
        let cells: Vec<Cell> = (0..6)
            .map(|_| cell("crypto", PrefetcherKind::NextLineOnly, "nl"))
            .collect();
        let mut seen = 0usize;
        run_cells_each(&cells, 1, |_, _| {
            seen += 1;
            false // cancel after the first result
        });
        // The single worker may already have claimed one more cell when
        // the cancellation lands, but the queue must not fully drain.
        assert_eq!(seen, 1, "callback ran after cancellation");
    }
}
