//! Experiment-campaign subsystem: scenario matrix → sharded execution →
//! tiered result store → aggregate reports (DESIGN.md "Campaign
//! subsystem").
//!
//! A campaign is a declarative sweep over the paper's evaluation axes
//! ([`spec::CampaignSpec`]): apps × prefetchers × seeds × ML gate ×
//! churn regimes × traffic shapes. [`runner`] shards the expanded cells across worker
//! threads; [`store`] persists one record per cell (a JSONL log or a
//! tiered memtable → segment layout, see [`store::StoreFormat`]) and
//! lets repeated campaigns resume instead of recompute; [`report`]
//! aggregates the store back into the markdown tables the figure
//! harness uses.
//!
//! Determinism contract: cells are seeded per-key ([`spec::cell_seed`]),
//! executed independently, and written in spec-expansion order — the
//! record stream is byte-identical for any `--threads` value. Records
//! are flushed incrementally (as soon as a cell *and* its baseline
//! finish), so a killed campaign keeps its completed prefix and resumes
//! from there.

pub mod report;
pub mod runner;
mod segment;
pub mod spec;
pub mod store;

pub use spec::CampaignSpec;
pub use store::{CompactStats, ResultStore, StoreFormat};

use anyhow::Result;
use std::collections::HashMap;
use store::{CellRecord, ClusterCellRecord, SketchCellRecord, TailRecord};

/// What one `run_to_store` call did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CampaignOutcome {
    /// Cells in the expanded matrix.
    pub total: usize,
    /// Cells written to the store this run. Traffic-axis twins share
    /// one deduplicated core simulation, so this counts result lines,
    /// not simulations.
    pub computed: usize,
    /// Cells skipped because the store already had them.
    pub skipped: usize,
}

/// Baseline labels in preference order: the plain `nl` cell, with
/// `nl+ml` as the fallback for all-ML campaigns. Single source for both
/// the run-time speedup computation and the report layer.
pub(crate) const BASELINE_LABELS: [&str; 2] = ["nl", "nl+ml"];

/// Scenario coordinates that identify a baseline group: speedup compares
/// against the `nl` cell sharing the same app, scale, trace seed, and
/// churn regime.
pub(crate) type Group = (String, u64, u64, u64);

pub(crate) fn group_of(app: &str, records: u64, trace_seed: u64, churn_scale: f64) -> Group {
    (app.to_string(), records, trace_seed, churn_scale.to_bits())
}

/// Where a scenario's baseline IPC comes from.
#[derive(Clone, Copy)]
enum Baseline {
    /// Reloaded from a previous run's store line.
    Stored(f64),
    /// Computed by this run: index into the deduplicated sim list.
    Pending(usize),
}

/// Baseline lookup per group, preferring the plain `nl` cell and falling
/// back to `nl+ml` (so an all-ML campaign still gets speedups).
#[derive(Default)]
struct Baselines {
    plain: HashMap<Group, Baseline>,
    gated: HashMap<Group, Baseline>,
}

impl Baselines {
    fn insert(&mut self, label: &str, group: Group, src: Baseline) {
        if label == BASELINE_LABELS[0] {
            self.plain.insert(group, src);
        } else if label == BASELINE_LABELS[1] {
            self.gated.insert(group, src);
        }
    }

    fn get(&self, group: &Group) -> Option<Baseline> {
        self.plain.get(group).or_else(|| self.gated.get(group)).copied()
    }
}

/// Run a campaign against a store: expand the matrix, skip cells the
/// store already holds, shard the rest across `threads` workers
/// (0 = auto), compute speedups against each scenario's `nl` baseline,
/// and append results incrementally in expansion order. Cluster-scenario
/// cells (the `clusters` × `policies` axis) run after the simulation
/// matrix: each cluster's (app × config) measurement matrix is prepared
/// once — and only when that cluster still has pending cells — then the
/// policy scenarios shard across the same workers.
pub fn run_to_store(
    spec: &CampaignSpec,
    threads: usize,
    store: &mut ResultStore,
) -> Result<CampaignOutcome> {
    let cells = spec.expand()?;
    let ccells = spec.expand_clusters()?;
    let scells = spec.expand_sketch()?;
    let total = cells.len() + ccells.len() + scells.len();
    let pending: Vec<&spec::ExpandedCell> =
        cells.iter().filter(|c| !store.contains(&c.key)).collect();
    let n = pending.len();
    // Traffic-axis twins share their `base_key` and are bit-identical
    // core simulations — simulate each distinct base once and fan the
    // result out to every line that needs it.
    let mut sim_of: HashMap<&str, usize> = HashMap::new();
    let mut cell_list: Vec<runner::Cell> = Vec::new();
    let mut base_of: Vec<usize> = Vec::with_capacity(n);
    for c in &pending {
        let idx = *sim_of.entry(c.base_key.as_str()).or_insert_with(|| {
            cell_list.push(c.cell.clone());
            cell_list.len() - 1
        });
        base_of.push(idx);
    }

    let mut baselines = Baselines::default();
    store.for_each_sim(|r| {
        baselines.insert(
            &r.label,
            group_of(&r.app, r.records, r.trace_seed, r.churn_scale),
            Baseline::Stored(r.ipc),
        );
        Ok(())
    })?;
    for (i, meta) in pending.iter().enumerate() {
        baselines.insert(
            &meta.cell.label,
            group_of(
                meta.cell.app.name,
                meta.cell.records,
                meta.cell.trace_seed,
                meta.churn_scale,
            ),
            Baseline::Pending(base_of[i]),
        );
    }

    // Stream results into the store: the write frontier advances in
    // expansion order as soon as a cell's sim and its baseline have
    // finished, so a killed run keeps every flushed line.
    let mut results: Vec<Option<crate::sim::engine::SimResult>> =
        (0..cell_list.len()).map(|_| None).collect();
    let mut write_pos = 0usize;
    let mut computed = 0usize;
    let mut io_err: Option<anyhow::Error> = None;
    // The runner stops invoking the callback after the first `false`
    // (cancellation), so no io_err re-entry guard is needed here.
    runner::run_cells_each(&cell_list, threads, |i, result| {
        results[i] = Some(result);
        while write_pos < n {
            let result = match &results[base_of[write_pos]] {
                Some(r) => r,
                None => break,
            };
            let meta = pending[write_pos];
            let group = group_of(
                meta.cell.app.name,
                meta.cell.records,
                meta.cell.trace_seed,
                meta.churn_scale,
            );
            // A baseline still in flight stalls the frontier (never a
            // deadlock: every pending cell eventually completes).
            let base_ipc = match baselines.get(&group) {
                None => None,
                Some(Baseline::Stored(v)) => Some(v),
                Some(Baseline::Pending(j)) => match &results[j] {
                    Some(b) => Some(b.ipc()),
                    None => break,
                },
            };
            let mut rec = CellRecord::from_result(
                &meta.key,
                meta.ml,
                meta.churn_scale,
                meta.cell.records,
                meta.cell.trace_seed,
                meta.cell.cfg.seed,
                result,
            );
            rec.speedup = base_ipc.map(|base| rec.ipc / base);
            // Traffic-axis cells additionally get a queueing-tail
            // evaluation: the measured IPC drives a single-service
            // cluster under the cell's arrival shape. Seeded from the
            // full (traffic-suffixed) key, it is a pure function of the
            // cell — deterministic at any thread count. It runs on the
            // writer thread: a tail eval is ~100k heap events, noise
            // next to the core sims the workers are busy with (revisit
            // if traffic axes grow — see ROADMAP "cluster-scale
            // campaign axis").
            if let Some(shape) = &meta.traffic {
                let t = match crate::cluster::evaluate_tail(
                    rec.ipc,
                    shape,
                    spec::cell_seed(meta.cell.trace_seed, &meta.key),
                ) {
                    Ok(t) => t,
                    Err(e) => {
                        // Same cancellation path as a store I/O failure.
                        io_err = Some(e);
                        return false;
                    }
                };
                rec.tail = Some(TailRecord {
                    traffic: shape.label(),
                    p50_us: t.p50_us,
                    p95_us: t.p95_us,
                    p99_us: t.p99_us,
                    compliance: t.compliance,
                    slo_us: t.slo_us,
                });
            }
            match store.push(rec) {
                Ok(true) => computed += 1,
                Ok(false) => {}
                Err(e) => {
                    // Cancel the sweep: simulating cells whose results
                    // can no longer be persisted is wasted compute.
                    io_err = Some(e);
                    return false;
                }
            }
            write_pos += 1;
        }
        true
    });
    if let Some(e) = io_err {
        return Err(e);
    }

    // Cluster-scenario cells. Preparation (IPC matrix + topology
    // resolution) is itself sharded and deterministic; scenario runs are
    // self-seeded, so collecting by index keeps the append order equal
    // to expansion order at any thread count.
    let cpending: Vec<&spec::ClusterCell> =
        ccells.iter().filter(|c| !store.contains(&c.key)).collect();
    let mut prepared: HashMap<usize, crate::cluster::PreparedSpec> = HashMap::new();
    for c in &cpending {
        if !prepared.contains_key(&c.cluster) {
            prepared.insert(
                c.cluster,
                crate::cluster::prepare_spec(&spec.clusters[c.cluster], threads)?,
            );
        }
    }
    // Tenant cells run under the first (baseline) config label. One
    // co-located run serves every pending coloc cell of its cluster, so
    // it executes once per cluster — deterministically, by index.
    let mut coloc_needed: Vec<usize> = cpending
        .iter()
        .filter(|c| matches!(c.tenant, Some((_, false))))
        .map(|c| c.cluster)
        .collect();
    coloc_needed.sort_unstable();
    coloc_needed.dedup();
    let coloc_runs = runner::parallel_map(coloc_needed.len(), threads, |i| {
        let ci = coloc_needed[i];
        crate::cluster::run_tenant_coloc(&prepared[&ci], &spec.clusters[ci], 0)
    });
    let mut coloc_of: HashMap<usize, crate::cluster::ClusterResult> = HashMap::new();
    for (ci, r) in coloc_needed.iter().zip(coloc_runs.into_iter()) {
        coloc_of.insert(*ci, r?);
    }
    let results = runner::parallel_map(cpending.len(), threads, |i| {
        let c = cpending[i];
        match c.tenant {
            None => {
                // The cell's fault regime (campaign axis) overlays the
                // cluster's own `faults.client` policies; "none" cells
                // pass no fault plan and run the pre-fault code path.
                let fs = spec::regime_faults(&spec.clusters[c.cluster], &c.faults);
                crate::cluster::run_policy_scenario_faults(
                    &prepared[&c.cluster],
                    &spec.clusters[c.cluster],
                    &c.policy,
                    &c.shape,
                    (!fs.is_empty()).then_some(&fs),
                )
                .map(Some)
            }
            Some((ti, true)) => crate::cluster::run_tenant_solo(
                &prepared[&c.cluster],
                &spec.clusters[c.cluster],
                0,
                ti,
            )
            .map(Some),
            // Served from the shared co-located run above.
            Some((_, false)) => Ok(None),
        }
    });
    for (c, r) in cpending.iter().zip(results.into_iter()) {
        let cluster = &spec.clusters[c.cluster];
        let rec = match c.tenant {
            None => {
                let run = r?.expect("policy cell produced no result");
                let mut rec = ClusterCellRecord::from_result(
                    &c.key,
                    &cluster.name,
                    &c.policy.label(),
                    &cluster.service_times,
                    &run,
                );
                rec.faults = c.faults.clone();
                rec
            }
            Some((ti, solo)) => {
                let owned;
                let run = if solo {
                    owned = r?.expect("solo cell produced no result");
                    &owned
                } else {
                    // Surface a (cancelled) error; the value is unused.
                    let _ = r?;
                    &coloc_of[&c.cluster]
                };
                // A solo run holds exactly its own tenant's stats.
                let ts = if solo { &run.tenants[0] } else { &run.tenants[ti] };
                ClusterCellRecord::from_tenant(
                    &c.key,
                    &cluster.name,
                    if solo { "solo" } else { "coloc" },
                    &cluster.service_times,
                    run,
                    ts,
                )
            }
        };
        if store.push_cluster(rec)? {
            computed += 1;
        }
    }

    // Sketch-accuracy cells (DESIGN.md §12): independent compare-mode
    // sims, sharded like the matrix and appended in expansion order —
    // the stored line is the run's exact-vs-sketch tallies.
    let spending: Vec<&spec::SketchCell> =
        scells.iter().filter(|c| !store.contains(&c.key)).collect();
    let scell_list: Vec<runner::Cell> = spending.iter().map(|c| c.cell.clone()).collect();
    let sresults = runner::run_cells(&scell_list, threads);
    for (c, r) in spending.iter().zip(&sresults) {
        let t = r.telemetry.as_deref().expect("compare-mode cell must carry telemetry");
        let rec = SketchCellRecord::from_telemetry(
            &c.key,
            &c.app,
            &c.cell.label,
            c.cell.records,
            c.trace_seed,
            c.cell.cfg.seed,
            &c.geom,
            t,
        );
        if store.push_sketch(rec)? {
            computed += 1;
        }
    }
    Ok(CampaignOutcome { total, computed, skipped: total - computed })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec() -> CampaignSpec {
        CampaignSpec {
            name: "quick".into(),
            apps: vec!["crypto".into(), "serde".into()],
            prefetchers: vec!["nl".into(), "eip256".into()],
            records: 15_000,
            seeds: vec![3],
            ml: vec![false],
            churn_scale: vec![1.0],
            traffic: vec!["none".into()],
            clusters: Vec::new(),
            policies: vec!["reactive".into()],
            faults: vec!["none".into()],
            sketch: Vec::new(),
        }
    }

    fn tiny_cluster() -> crate::cluster::ClusterSpec {
        let j = crate::util::json::Json::parse(
            r#"{
                "name": "mini",
                "services": [
                    {"name": "gw", "app": "admission"},
                    {"name": "be", "app": "serde", "deps": ["gw"]}
                ],
                "prefetchers": ["nl", "ceip256"],
                "traffic": ["poisson:0.6"],
                "requests": 5000,
                "records": 5000
            }"#,
        )
        .unwrap();
        crate::cluster::ClusterSpec::from_json(&j).unwrap()
    }

    #[test]
    fn runs_full_matrix_and_fills_speedups() {
        let spec = quick_spec();
        let mut store = ResultStore::in_memory();
        let out = run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(out, CampaignOutcome { total: 4, computed: 4, skipped: 0 });
        assert_eq!(store.len(), 4);
        for rec in store.records() {
            let s = rec.speedup.expect("nl baseline present → speedup set");
            if rec.label == "nl" {
                assert_eq!(s, 1.0);
            } else {
                assert!(s > 0.5 && s < 3.0, "implausible speedup {s}");
            }
        }
    }

    #[test]
    fn second_run_skips_everything() {
        let spec = quick_spec();
        let mut store = ResultStore::in_memory();
        run_to_store(&spec, 2, &mut store).unwrap();
        let again = run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(again, CampaignOutcome { total: 4, computed: 0, skipped: 4 });
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn resume_uses_stored_baseline_for_new_cells() {
        let mut spec = quick_spec();
        spec.prefetchers = vec!["nl".into()];
        let mut store = ResultStore::in_memory();
        run_to_store(&spec, 1, &mut store).unwrap();
        // Extend the matrix: only the new prefetcher's cells run, and
        // their speedup comes from the stored nl baseline.
        spec.prefetchers = vec!["nl".into(), "ceip256".into()];
        let out = run_to_store(&spec, 1, &mut store).unwrap();
        assert_eq!(out.computed, 2);
        assert_eq!(out.skipped, 2);
        for rec in store.records().iter().filter(|r| r.label == "ceip256") {
            assert!(rec.speedup.is_some(), "baseline lookup across runs failed");
        }
    }

    #[test]
    fn baseline_listed_after_dependents_still_resolves() {
        // nl *last* in the prefetcher axis: the write frontier must
        // stall until the baseline lands, then flush with speedups.
        let spec = CampaignSpec {
            prefetchers: vec!["eip256".into(), "nl".into()],
            ..quick_spec()
        };
        let mut store = ResultStore::in_memory();
        run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(store.len(), 4);
        for rec in store.records() {
            assert!(rec.speedup.is_some(), "{}: speedup missing", rec.key);
        }
        // Emission stayed in expansion order.
        assert_eq!(store.records()[0].label, "eip256");
        assert_eq!(store.records()[1].label, "nl");
    }

    #[test]
    fn traffic_axis_fills_tail_records_and_keeps_baselines_exact() {
        let spec = CampaignSpec {
            traffic: vec!["none".into(), "poisson:0.65".into()],
            ..quick_spec()
        };
        let mut store = ResultStore::in_memory();
        let out = run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(out.total, 8);
        for rec in store.records() {
            let shaped = rec.key.contains("|t");
            assert_eq!(rec.tail.is_some(), shaped, "{}: tail presence wrong", rec.key);
            if rec.label == "nl" {
                // Traffic-free sim seeding keeps the baseline exact.
                assert_eq!(rec.speedup, Some(1.0), "{}", rec.key);
            }
            if let Some(t) = &rec.tail {
                assert_eq!(t.traffic, "poisson:0.65");
                assert!(t.p50_us <= t.p95_us && t.p95_us <= t.p99_us);
                assert!(t.compliance > 0.0 && t.compliance <= 1.0);
            }
        }
        // The IPC of a shaped cell equals its `none` twin bit-for-bit.
        let recs = store.records();
        let plain = recs.iter().find(|r| !r.key.contains("|t")).unwrap();
        let twin = recs
            .iter()
            .find(|r| r.key.starts_with(&plain.key) && r.key.contains("|t"))
            .unwrap();
        assert_eq!(plain.ipc.to_bits(), twin.ipc.to_bits());
    }

    #[test]
    fn cluster_axis_records_burn_and_costs_then_resumes() {
        let spec = CampaignSpec {
            clusters: vec![tiny_cluster()],
            policies: vec!["reactive".into(), "hysteresis".into()],
            ..quick_spec()
        };
        let mut store = ResultStore::in_memory();
        let out = run_to_store(&spec, 2, &mut store).unwrap();
        // 4 sim cells + (2 policies × 1 shape) cluster cells.
        assert_eq!(out, CampaignOutcome { total: 6, computed: 6, skipped: 0 });
        assert_eq!(store.cluster_records().len(), 2);
        for r in store.cluster_records() {
            assert_eq!(r.cluster, "mini");
            assert_eq!(r.traffic, "poisson:0.6");
            assert!(r.windows > 0, "no SLO windows evaluated");
            assert!(r.replica_us > 0.0, "replica-seconds not accounted");
            assert!(r.events >= r.requests * 2, "arrival + completions per request");
            assert!(r.p50_us <= r.p95_us && r.p95_us <= r.p99_us);
            assert!(r.compliance > 0.0 && r.compliance <= 1.0);
        }
        // Rerun against the same store: nothing recomputes.
        let again = run_to_store(&spec, 4, &mut store).unwrap();
        assert_eq!(again, CampaignOutcome { total: 6, computed: 0, skipped: 6 });
        assert_eq!(store.len(), 6);
    }

    #[test]
    fn empirical_cluster_cells_are_labelled_and_resume() {
        let mut cluster = tiny_cluster();
        cluster.service_times = "empirical".into();
        let spec = CampaignSpec {
            clusters: vec![cluster],
            policies: vec!["reactive".into()],
            ..quick_spec()
        };
        let mut store = ResultStore::in_memory();
        let out = run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(out.computed, 5); // 4 sim cells + 1 cluster cell
        let crecs = store.cluster_records();
        assert_eq!(crecs.len(), 1);
        let rec = &crecs[0];
        assert_eq!(rec.service_times, "empirical");
        assert!(rec.windows > 0 && rec.p99_us.is_finite());
        // The report labels the model.
        let table = report::cluster_table(&store).expect("cluster table missing");
        assert_eq!(table.rows[0][3], "empirical");
        // Resume: zero recomputed cells.
        let again = run_to_store(&spec, 4, &mut store).unwrap();
        assert_eq!(again.computed, 0, "empirical cluster cells recomputed on resume");
    }

    #[test]
    fn fault_axis_records_regimes_and_resumes_over_a_healthy_store() {
        // Run the healthy campaign first — the store a user has before
        // adding a fault axis.
        let healthy = CampaignSpec {
            clusters: vec![tiny_cluster()],
            policies: vec!["reactive".into(), "predictive".into()],
            ..quick_spec()
        };
        let mut store = ResultStore::in_memory();
        let out = run_to_store(&healthy, 2, &mut store).unwrap();
        assert_eq!(out, CampaignOutcome { total: 6, computed: 6, skipped: 0 });
        let healthy_recs = store.cluster_records();

        // Extending the spec with a fault regime only computes the new
        // faulted cells; the healthy lines are resumed untouched.
        let spec = CampaignSpec {
            faults: vec!["none".into(), "down:be:0:20000:40000".into()],
            ..healthy.clone()
        };
        let out = run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(out, CampaignOutcome { total: 8, computed: 2, skipped: 6 });
        let recs = store.cluster_records();
        assert_eq!(&recs[..2], &healthy_recs[..], "healthy lines changed under resume");
        for r in &recs[2..] {
            assert_eq!(r.faults, "down:be:0:20000:40000");
            assert!(r.key.ends_with("|fdown:be:0:20000:40000"), "{}", r.key);
            assert!(r.windows > 0 && r.p99_us.is_finite(), "{}", r.key);
        }
        // Both regimes rank in their own tables; the healthy ranking
        // sees only healthy cells.
        let rank = report::cluster_ranking(&store).expect("healthy ranking missing");
        assert_eq!(rank.rows.len(), 2);
        let ft = report::fault_ranking(&store).expect("campaign_faults missing");
        assert_eq!(ft.rows.len(), 2);
        // Rerun: nothing recomputes; thread count changes nothing.
        let again = run_to_store(&spec, 4, &mut store).unwrap();
        assert_eq!(again, CampaignOutcome { total: 8, computed: 0, skipped: 8 });
        let mut store2 = ResultStore::in_memory();
        run_to_store(&spec, 1, &mut store2).unwrap();
        for (a, b) in store.cluster_records().iter().zip(store2.cluster_records().iter()) {
            assert_eq!(a, b, "fault cell differs across thread counts");
        }
    }

    fn tenant_cluster() -> crate::cluster::ClusterSpec {
        let j = crate::util::json::Json::parse(
            r#"{
                "name": "shared",
                "services": [
                    {"name": "gw", "app": "admission"},
                    {"name": "be", "app": "serde", "deps": ["gw"]}
                ],
                "prefetchers": ["nl", "ceip256"],
                "traffic": ["poisson:0.6"],
                "requests": 3000,
                "records": 4000,
                "adaptive": false,
                "tenants": [
                    {"name": "web", "services": ["gw"], "traffic": "poisson:0.4",
                     "ways": 4, "demand_ways": 6},
                    {"name": "batch", "traffic": "poisson:0.3", "ways": 4,
                     "demand_ways": 5}
                ]
            }"#,
        )
        .unwrap();
        crate::cluster::ClusterSpec::from_json(&j).unwrap()
    }

    #[test]
    fn tenant_cells_record_paired_runs_and_resume() {
        let spec = CampaignSpec { clusters: vec![tenant_cluster()], ..quick_spec() };
        let mut store = ResultStore::in_memory();
        let out = run_to_store(&spec, 2, &mut store).unwrap();
        // 4 sim cells + (2 tenants × {solo, coloc}).
        assert_eq!(out, CampaignOutcome { total: 8, computed: 8, skipped: 0 });
        let recs = store.cluster_records();
        assert_eq!(recs.len(), 4);
        for r in &recs {
            assert!(!r.tenant.is_empty(), "{}: tenant label missing", r.key);
            assert!(matches!(r.policy.as_str(), "solo" | "coloc"), "{}", r.policy);
            assert!(r.windows > 0, "{}: no SLO windows", r.key);
            assert!(r.p50_us <= r.p99_us && r.p99_us.is_finite(), "{}", r.key);
        }
        // Co-located cells share one run: same event count, and each
        // tenant still completed its own full request count.
        let coloc: Vec<_> = recs.iter().filter(|r| r.policy == "coloc").collect();
        assert_eq!(coloc.len(), 2);
        assert_eq!(coloc[0].events, coloc[1].events, "coloc cells ran twice");
        assert_eq!(coloc[0].requests, 3000);
        // The pairing report renders and pairs every tenant.
        let t = report::tenant_pairings(&store).expect("campaign_tenants missing");
        assert_eq!(t.rows.len(), 2);
        assert!(t.markdown().contains("web") && t.markdown().contains("batch"));
        assert!(t.rows.iter().all(|r| r[4] != "-"), "a solo twin failed to pair: {:?}", t.rows);
        // Rerun: everything resumes, nothing recomputes.
        let again = run_to_store(&spec, 4, &mut store).unwrap();
        assert_eq!(again, CampaignOutcome { total: 8, computed: 0, skipped: 8 });
        // Thread counts do not change the stored records.
        let mut store2 = ResultStore::in_memory();
        run_to_store(&spec, 1, &mut store2).unwrap();
        for (a, b) in store.cluster_records().iter().zip(store2.cluster_records().iter()) {
            assert_eq!(a, b, "tenant cell differs across thread counts");
        }
    }

    #[test]
    fn sketch_axis_records_compare_tallies_and_resumes() {
        let spec = CampaignSpec {
            sketch: vec!["w64d2p8k4".into(), "w256d4p10k16".into()],
            ..quick_spec()
        };
        let mut store = ResultStore::in_memory();
        let out = run_to_store(&spec, 2, &mut store).unwrap();
        // 4 sim cells + (2 apps × 1 seed × 2 geometries).
        assert_eq!(out, CampaignOutcome { total: 8, computed: 8, skipped: 0 });
        let recs = store.sketch_records();
        assert_eq!(recs.len(), 4);
        for r in &recs {
            assert_eq!(r.label, "nl+ml", "sketch cells run the ML-gated baseline");
            assert!(r.decisions > 0, "{}: no decisions compared", r.key);
            assert!(r.agreement > 0.0 && r.agreement <= 1.0, "{}", r.key);
            assert!(r.issued > 0 && r.distinct_exact > 0, "{}", r.key);
            assert!(r.feature_mae >= 0.0 && r.feature_mae.is_finite(), "{}", r.key);
            assert_eq!(r.exact_bytes, r.distinct_exact * 24);
        }
        // Geometry sets the footprint: w64d2p8k4 = 3·(64·2·4) + 2^8 + 4·16.
        let small = recs.iter().find(|r| r.geom == "w64d2p8k4").unwrap();
        assert_eq!(small.sketch_bytes, 3 * 64 * 2 * 4 + 256 + 64);
        // Resume: nothing recomputes.
        let again = run_to_store(&spec, 4, &mut store).unwrap();
        assert_eq!(again, CampaignOutcome { total: 8, computed: 0, skipped: 8 });
        // Thread counts do not change the stored records.
        let mut store2 = ResultStore::in_memory();
        run_to_store(&spec, 1, &mut store2).unwrap();
        for (a, b) in store.sketch_records().iter().zip(store2.sketch_records().iter()) {
            assert_eq!(a, b, "sketch cell differs across thread counts");
        }
        // The accuracy report renders one row per record; sketch-free
        // stores emit no table.
        let t = report::sketch_table(&store).expect("campaign_sketch missing");
        assert_eq!(t.rows.len(), 4);
        assert!(t.markdown().contains("w64d2p8k4"));
        let mut plain = ResultStore::in_memory();
        run_to_store(&quick_spec(), 2, &mut plain).unwrap();
        assert!(report::sketch_table(&plain).is_none());
    }

    #[test]
    fn all_ml_campaign_falls_back_to_gated_baseline() {
        let spec = CampaignSpec {
            prefetchers: vec!["nl".into(), "ceip256".into()],
            ml: vec![true],
            ..quick_spec()
        };
        let mut store = ResultStore::in_memory();
        run_to_store(&spec, 2, &mut store).unwrap();
        for rec in store.records() {
            let s = rec.speedup.expect("nl+ml fallback baseline missing");
            if rec.label == "nl+ml" {
                assert_eq!(s, 1.0);
            }
        }
    }
}
