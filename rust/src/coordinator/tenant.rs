//! Per-tenant isolation (paper §VI-A: "isolate tenants via way
//! partitioning or rate limiters"; §VII: "pair with partitioning or way
//! locking in multitenant settings").
//!
//! We model isolation through per-tenant issue-rate limiters plus a
//! static way-partition bookkeeping check: each tenant owns a share of
//! the L1-I ways that prefetch fills may occupy. (The timing effect of
//! way partitioning is approximated by the rate limiter; the partition
//! object enforces/accounts the share.)

use super::budget::TokenBucket;
use std::collections::HashMap;

/// Static way partition over an 8-way L1-I.
#[derive(Clone, Debug)]
pub struct WayPartition {
    pub total_ways: u32,
    shares: HashMap<u8, u32>,
}

impl WayPartition {
    pub fn new(total_ways: u32) -> Self {
        WayPartition {
            total_ways,
            shares: HashMap::new(),
        }
    }

    /// Assign `ways` to a tenant; fails if oversubscribed. Re-assigning
    /// adjusts in place (growing *or* shrinking a live share), so the
    /// cluster simulator's way-repartition lever moves ways between
    /// tenants with two calls: shrink the donor, then grow the taker.
    pub fn assign(&mut self, tenant: u8, ways: u32) -> Result<(), String> {
        // Widened arithmetic: a near-u32::MAX request used to wrap the
        // `used - cur + ways` sum back into acceptance.
        let used: u64 = self.shares.values().map(|&w| w as u64).sum();
        let cur = self.shares.get(&tenant).copied().unwrap_or(0) as u64;
        if used - cur + ways as u64 > self.total_ways as u64 {
            return Err(format!(
                "oversubscribed: {} + {} > {}",
                used - cur,
                ways,
                self.total_ways
            ));
        }
        self.shares.insert(tenant, ways);
        Ok(())
    }

    pub fn share(&self, tenant: u8) -> u32 {
        self.shares.get(&tenant).copied().unwrap_or(0)
    }

    /// Max prefetch-resident lines tenant may hold in a `sets`-set cache
    /// (saturating: `share × sets` on a large cache must cap, not wrap).
    pub fn prefetch_line_cap(&self, tenant: u8, sets: u32) -> u32 {
        self.share(tenant).saturating_mul(sets)
    }
}

/// Per-tenant issue-rate limiter registry.
pub struct TenantLimiter {
    buckets: HashMap<u8, TokenBucket>,
    default_rate: f64,
}

impl TenantLimiter {
    pub fn new(default_rate_per_kcycle: f64) -> Self {
        TenantLimiter {
            buckets: HashMap::new(),
            default_rate: default_rate_per_kcycle,
        }
    }

    pub fn set_rate(&mut self, tenant: u8, rate_per_kcycle: f64) {
        self.buckets
            .insert(tenant, TokenBucket::new(rate_per_kcycle, rate_per_kcycle.max(1.0) * 4.0));
    }

    /// May `tenant` issue a prefetch at `cycle`?
    pub fn allow(&mut self, tenant: u8, cycle: u64) -> bool {
        let rate = self.default_rate;
        self.buckets
            .entry(tenant)
            .or_insert_with(|| TokenBucket::new(rate, rate.max(1.0) * 4.0))
            .try_take(cycle)
    }

    /// Backoff one tenant (regression observed in its cell).
    pub fn backoff(&mut self, tenant: u8) {
        if let Some(b) = self.buckets.get_mut(&tenant) {
            b.backoff();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_rejects_oversubscription() {
        let mut p = WayPartition::new(8);
        p.assign(0, 4).unwrap();
        p.assign(1, 3).unwrap();
        assert!(p.assign(2, 2).is_err());
        assert!(p.assign(1, 4).is_ok(), "re-assign adjusts in place");
        assert_eq!(p.share(1), 4);
        assert_eq!(p.prefetch_line_cap(0, 64), 256);
    }

    #[test]
    fn assign_shrinks_in_place_and_rejects_overflowing_requests() {
        let mut p = WayPartition::new(8);
        p.assign(0, 5).unwrap();
        // Shrinking a live share frees the difference for other tenants.
        p.assign(0, 2).unwrap();
        assert_eq!(p.share(0), 2);
        p.assign(1, 6).unwrap();
        // Oversubscription is rejected without corrupting live shares.
        assert!(p.assign(2, 1).is_err());
        assert_eq!(p.share(0), 2);
        assert_eq!(p.share(1), 6);
        // Near-u32::MAX requests used to wrap `used - cur + ways` back
        // into acceptance; both the fresh and re-assign paths must
        // reject them.
        assert!(p.assign(2, u32::MAX).is_err(), "u32 overflow admitted a tenant");
        assert!(p.assign(1, u32::MAX - 1).is_err(), "re-assign path overflowed");
        assert_eq!(p.share(1), 6, "failed assign must not clobber the share");
    }

    #[test]
    fn prefetch_line_cap_saturates_instead_of_wrapping() {
        let mut p = WayPartition::new(u32::MAX);
        p.assign(0, 1 << 20).unwrap();
        // share × sets used to wrap u32 into a tiny cap on large caches.
        assert_eq!(p.prefetch_line_cap(0, 1 << 20), u32::MAX);
        assert_eq!(p.prefetch_line_cap(0, 64), 64 << 20);
        assert_eq!(p.prefetch_line_cap(1, 64), 0, "unassigned tenant holds nothing");
    }

    #[test]
    fn limiter_zero_rate_spends_its_burst_then_starves_forever() {
        // Rate 0 buckets get a burst of max(0,1)·4 = 4 tokens and never
        // refill, however far the cycle counter advances.
        let mut l = TenantLimiter::new(0.0);
        let mut got = 0;
        for c in (0..10).map(|i| i * 1_000_000u64) {
            if l.allow(0, c) {
                got += 1;
            }
        }
        assert_eq!(got, 4, "zero-rate bucket refilled: {got}");
        // The explicit set_rate(0) path behaves identically.
        l.set_rate(1, 0.0);
        let mut got = 0;
        for c in (0..10).map(|i| i * 1_000_000u64) {
            if l.allow(1, c) {
                got += 1;
            }
        }
        assert_eq!(got, 4, "set_rate(0) bucket refilled: {got}");
    }

    #[test]
    fn limiter_burst_exhaustion_then_refills_on_schedule() {
        let mut l = TenantLimiter::new(1.0); // 1 token/kcycle, burst 4
        let mut burst = 0;
        for _ in 0..10 {
            if l.allow(2, 0) {
                burst += 1;
            }
        }
        assert_eq!(burst, 4, "burst capacity");
        assert!(!l.allow(2, 500), "half a token is not a token");
        assert!(l.allow(2, 1_600), "1.6 kcycles must refill one token");
    }

    #[test]
    fn limiter_survives_far_future_and_backward_cycle_jumps() {
        let mut l = TenantLimiter::new(2.0); // burst 8
        for _ in 0..8 {
            assert!(l.allow(5, 0));
        }
        // A far-future jump refills to burst exactly — no f64 blowup,
        // no unbounded credit.
        let mut got = 0;
        for _ in 0..100 {
            if l.allow(5, u64::MAX) {
                got += 1;
            }
        }
        assert_eq!(got, 8, "far-future refill must cap at burst");
        // Time going backwards must not mint tokens (saturating elapsed).
        let mut l = TenantLimiter::new(1.0); // burst 4
        for _ in 0..4 {
            assert!(l.allow(6, 1_000_000));
        }
        assert!(!l.allow(6, 0), "backward cycle jump minted tokens");
    }

    #[test]
    fn limiter_isolates_tenants() {
        let mut l = TenantLimiter::new(1000.0);
        l.set_rate(1, 0.5); // throttled tenant
        let mut t0 = 0;
        let mut t1 = 0;
        for c in 0..10_000u64 {
            if l.allow(0, c) {
                t0 += 1;
            }
            if l.allow(1, c) {
                t1 += 1;
            }
        }
        assert!(t0 > 5_000, "unthrottled tenant starved: {t0}");
        assert!(t1 < 20, "throttled tenant over budget: {t1}");
    }

    #[test]
    fn backoff_halves_future_rate() {
        let mut l = TenantLimiter::new(10.0);
        // Prime the bucket.
        assert!(l.allow(3, 0));
        l.backoff(3);
        let mut got = 0;
        for c in 0..100_000u64 {
            if l.allow(3, c) {
                got += 1;
            }
        }
        // 5/kcycle * 100k ≈ 500.
        assert!((450..=560).contains(&got), "got {got}");
    }
}
