//! Per-tenant isolation (paper §VI-A: "isolate tenants via way
//! partitioning or rate limiters"; §VII: "pair with partitioning or way
//! locking in multitenant settings").
//!
//! We model isolation through per-tenant issue-rate limiters plus a
//! static way-partition bookkeeping check: each tenant owns a share of
//! the L1-I ways that prefetch fills may occupy. (The timing effect of
//! way partitioning is approximated by the rate limiter; the partition
//! object enforces/accounts the share.)

use super::budget::TokenBucket;
use std::collections::HashMap;

/// Static way partition over an 8-way L1-I.
#[derive(Clone, Debug)]
pub struct WayPartition {
    pub total_ways: u32,
    shares: HashMap<u8, u32>,
}

impl WayPartition {
    pub fn new(total_ways: u32) -> Self {
        WayPartition {
            total_ways,
            shares: HashMap::new(),
        }
    }

    /// Assign `ways` to a tenant; fails if oversubscribed.
    pub fn assign(&mut self, tenant: u8, ways: u32) -> Result<(), String> {
        let used: u32 = self.shares.values().sum();
        let cur = self.shares.get(&tenant).copied().unwrap_or(0);
        if used - cur + ways > self.total_ways {
            return Err(format!(
                "oversubscribed: {} + {} > {}",
                used - cur,
                ways,
                self.total_ways
            ));
        }
        self.shares.insert(tenant, ways);
        Ok(())
    }

    pub fn share(&self, tenant: u8) -> u32 {
        self.shares.get(&tenant).copied().unwrap_or(0)
    }

    /// Max prefetch-resident lines tenant may hold in a `sets`-set cache.
    pub fn prefetch_line_cap(&self, tenant: u8, sets: u32) -> u32 {
        self.share(tenant) * sets
    }
}

/// Per-tenant issue-rate limiter registry.
pub struct TenantLimiter {
    buckets: HashMap<u8, TokenBucket>,
    default_rate: f64,
}

impl TenantLimiter {
    pub fn new(default_rate_per_kcycle: f64) -> Self {
        TenantLimiter {
            buckets: HashMap::new(),
            default_rate: default_rate_per_kcycle,
        }
    }

    pub fn set_rate(&mut self, tenant: u8, rate_per_kcycle: f64) {
        self.buckets
            .insert(tenant, TokenBucket::new(rate_per_kcycle, rate_per_kcycle.max(1.0) * 4.0));
    }

    /// May `tenant` issue a prefetch at `cycle`?
    pub fn allow(&mut self, tenant: u8, cycle: u64) -> bool {
        let rate = self.default_rate;
        self.buckets
            .entry(tenant)
            .or_insert_with(|| TokenBucket::new(rate, rate.max(1.0) * 4.0))
            .try_take(cycle)
    }

    /// Backoff one tenant (regression observed in its cell).
    pub fn backoff(&mut self, tenant: u8) {
        if let Some(b) = self.buckets.get_mut(&tenant) {
            b.backoff();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_rejects_oversubscription() {
        let mut p = WayPartition::new(8);
        p.assign(0, 4).unwrap();
        p.assign(1, 3).unwrap();
        assert!(p.assign(2, 2).is_err());
        assert!(p.assign(1, 4).is_ok(), "re-assign adjusts in place");
        assert_eq!(p.share(1), 4);
        assert_eq!(p.prefetch_line_cap(0, 64), 256);
    }

    #[test]
    fn limiter_isolates_tenants() {
        let mut l = TenantLimiter::new(1000.0);
        l.set_rate(1, 0.5); // throttled tenant
        let mut t0 = 0;
        let mut t1 = 0;
        for c in 0..10_000u64 {
            if l.allow(0, c) {
                t0 += 1;
            }
            if l.allow(1, c) {
                t1 += 1;
            }
        }
        assert!(t0 > 5_000, "unthrottled tenant starved: {t0}");
        assert!(t1 < 20, "throttled tenant over budget: {t1}");
    }

    #[test]
    fn backoff_halves_future_rate() {
        let mut l = TenantLimiter::new(10.0);
        // Prime the bucket.
        assert!(l.allow(3, 0));
        l.backoff(3);
        let mut got = 0;
        for c in 0..100_000u64 {
            if l.allow(3, c) {
                got += 1;
            }
        }
        // 5/kcycle * 100k ≈ 500.
        assert!((450..=560).contains(&got), "got {got}");
    }
}
