//! Fleet driver: runs (app × prefetcher-config) simulation cells across
//! OS threads and collects per-cell results — now a thin compatibility
//! wrapper over [`crate::campaign::runner`], which owns the work-queue
//! executor (one sharding implementation to keep deterministic).

use crate::campaign::runner::{run_cells, Cell};
use crate::config::SimConfig;
use crate::obs::telemetry::Telemetry;
use crate::sim::engine::SimResult;
use crate::trace::gen::apps::AppSpec;

/// One simulation cell.
#[derive(Clone)]
pub struct FleetJob {
    pub app: AppSpec,
    pub cfg: SimConfig,
    pub records: u64,
    pub trace_seed: u64,
}

#[derive(Clone, Debug)]
pub struct CellResult {
    pub app: String,
    pub label: String,
    pub result: SimResult,
}

/// Run all jobs, `parallelism` at a time. Results return in job order.
pub fn run_fleet(jobs: Vec<FleetJob>, parallelism: usize) -> Vec<CellResult> {
    let cells: Vec<Cell> = jobs
        .into_iter()
        .map(|j| Cell {
            label: j.cfg.prefetcher.label(),
            app: j.app,
            cfg: j.cfg,
            records: j.records,
            trace_seed: j.trace_seed,
            trace: None,
        })
        .collect();
    run_cells(&cells, parallelism.max(1))
        .into_iter()
        .map(|result| CellResult {
            app: result.app.clone(),
            label: result.label.clone(),
            result,
        })
        .collect()
}

/// Merge the per-cell sketch telemetries of a fleet into one summary
/// (DESIGN.md §12): count-min and HLL merges are associative, and the
/// heavy-hitter union is truncated once across all parts, so the result
/// depends only on the (deterministic) cell order — never on thread
/// scheduling. Returns `None` when no cell carried telemetry.
pub fn merge_telemetry<'a, I>(telemetries: I) -> Option<Telemetry>
where
    I: IntoIterator<Item = &'a Telemetry>,
{
    let parts: Vec<&Telemetry> = telemetries.into_iter().collect();
    Telemetry::merged(&parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefetcherKind;
    use crate::trace::gen::apps;

    fn job(app: &str, kind: PrefetcherKind) -> FleetJob {
        FleetJob {
            app: apps::app(app).unwrap(),
            cfg: SimConfig {
                prefetcher: kind,
                ..Default::default()
            },
            records: 20_000,
            trace_seed: 5,
        }
    }

    #[test]
    fn runs_jobs_in_order_with_parallelism() {
        let jobs = vec![
            job("crypto", PrefetcherKind::NextLineOnly),
            job("serde", PrefetcherKind::Eip { entries: 1024 }),
            job("logging", PrefetcherKind::NextLineOnly),
            job("crypto", PrefetcherKind::Perfect),
        ];
        let out = run_fleet(jobs, 3);
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].app, "crypto");
        assert_eq!(out[1].app, "serde");
        assert_eq!(out[1].label, "eip1024");
        assert_eq!(out[3].label, "perfect");
        for c in &out {
            assert!(c.result.stats.instrs > 0);
        }
    }

    #[test]
    fn fleet_telemetry_merges_across_cells_thread_invariantly() {
        let jobs = || {
            let mut js = vec![
                job("serde", PrefetcherKind::Eip { entries: 1024 }),
                job("logging", PrefetcherKind::Eip { entries: 1024 }),
                job("crypto", PrefetcherKind::NextLineOnly),
            ];
            for j in &mut js {
                j.cfg.telemetry = "sketch:w128d4p10k8".into();
            }
            js
        };
        let par = run_fleet(jobs(), 3);
        let ser = run_fleet(jobs(), 1);
        let merge = |cells: &[CellResult]| {
            merge_telemetry(cells.iter().filter_map(|c| c.result.telemetry.as_deref()))
                .expect("telemetry missing")
        };
        let fp = merge(&par);
        let fs = merge(&ser);
        assert_eq!(fp, fs, "fleet telemetry diverged across thread counts");
        assert_eq!(fp.summary_json().dump(), fs.summary_json().dump());
        let per_cell: u64 =
            par.iter().map(|c| c.result.telemetry.as_ref().unwrap().issued.total()).sum();
        assert_eq!(fp.issued.total(), per_cell);
        // Exact-mode cells contribute nothing to merge.
        assert!(merge_telemetry(std::iter::empty()).is_none());
    }

    #[test]
    fn parallel_equals_serial() {
        let jobs = || {
            vec![
                job("serde", PrefetcherKind::Eip { entries: 1024 }),
                job("logging", PrefetcherKind::Eip { entries: 1024 }),
            ]
        };
        let par = run_fleet(jobs(), 2);
        let ser = run_fleet(jobs(), 1);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.result.stats.cycles, b.result.stats.cycles);
            assert_eq!(a.result.stats.pf_issued, b.result.stats.pf_issued);
        }
    }
}
