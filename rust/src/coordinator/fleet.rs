//! Fleet driver: runs (app × prefetcher-config) simulation cells across
//! OS threads (no tokio offline — std::thread + channels) and collects
//! per-cell results. This is what the figure harness and the deployment
//! playbook drive.

use crate::config::SimConfig;
use crate::sim::engine::{self, SimResult};
use crate::trace::gen::{apps::AppSpec, generate_records};
use std::sync::mpsc;
use std::thread;

/// One simulation cell.
#[derive(Clone)]
pub struct FleetJob {
    pub app: AppSpec,
    pub cfg: SimConfig,
    pub records: u64,
    pub trace_seed: u64,
}

#[derive(Clone, Debug)]
pub struct CellResult {
    pub app: String,
    pub label: String,
    pub result: SimResult,
}

/// Run all jobs, `parallelism` at a time. Results return in job order.
pub fn run_fleet(jobs: Vec<FleetJob>, parallelism: usize) -> Vec<CellResult> {
    let parallelism = parallelism.max(1);
    let n = jobs.len();
    let (tx, rx) = mpsc::channel::<(usize, CellResult)>();
    let mut results: Vec<Option<CellResult>> = (0..n).map(|_| None).collect();
    let mut next = 0usize;
    let mut inflight = 0usize;
    let mut done = 0usize;
    let mut jobs_iter = jobs.into_iter().enumerate();

    thread::scope(|scope| {
        let spawn_one = |idx: usize, job: FleetJob| {
            let tx = tx.clone();
            scope.spawn(move || {
                let records = generate_records(&job.app, job.trace_seed, job.records);
                let mut result = engine::run(&job.cfg, &records);
                result.app = job.app.name.to_string();
                let cell = CellResult {
                    app: job.app.name.to_string(),
                    label: result.label.clone(),
                    result,
                };
                // Receiver never hangs up before all results arrive.
                let _ = tx.send((idx, cell));
            });
        };
        // Prime the pipeline.
        while inflight < parallelism {
            match jobs_iter.next() {
                Some((idx, job)) => {
                    spawn_one(idx, job);
                    inflight += 1;
                    next += 1;
                }
                None => break,
            }
        }
        let _ = next;
        while done < n {
            let (idx, cell) = rx.recv().expect("worker channel closed");
            results[idx] = Some(cell);
            done += 1;
            inflight -= 1;
            if let Some((idx, job)) = jobs_iter.next() {
                spawn_one(idx, job);
                inflight += 1;
            }
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefetcherKind;
    use crate::trace::gen::apps;

    fn job(app: &str, kind: PrefetcherKind) -> FleetJob {
        FleetJob {
            app: apps::app(app).unwrap(),
            cfg: SimConfig {
                prefetcher: kind,
                ..Default::default()
            },
            records: 20_000,
            trace_seed: 5,
        }
    }

    #[test]
    fn runs_jobs_in_order_with_parallelism() {
        let jobs = vec![
            job("crypto", PrefetcherKind::NextLineOnly),
            job("serde", PrefetcherKind::Eip { entries: 1024 }),
            job("logging", PrefetcherKind::NextLineOnly),
            job("crypto", PrefetcherKind::Perfect),
        ];
        let out = run_fleet(jobs, 3);
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].app, "crypto");
        assert_eq!(out[1].app, "serde");
        assert_eq!(out[1].label, "eip1024");
        assert_eq!(out[3].label, "perfect");
        for c in &out {
            assert!(c.result.stats.instrs > 0);
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let jobs = || {
            vec![
                job("serde", PrefetcherKind::Eip { entries: 1024 }),
                job("logging", PrefetcherKind::Eip { entries: 1024 }),
            ]
        };
        let par = run_fleet(jobs(), 2);
        let ser = run_fleet(jobs(), 1);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.result.stats.cycles, b.result.stats.cycles);
            assert_eq!(a.result.stats.pf_issued, b.result.stats.pf_issued);
        }
    }
}
