//! The deployment playbook (paper §VI-A), as an executable state machine:
//!
//! 1. **Shadow** — run the candidate prefetcher on a trace slice with the
//!    controller logging decisions but *issuing nothing* (modeled by
//!    comparing against the control cell without fills); validates
//!    calibration (predicted-useful rate) before any blast radius.
//! 2. **Guarded canary** — enable on one cell with budget caps; compare
//!    P95 and pollution against the control cell; automatic backoff +
//!    rollback on regression.
//! 3. **Ramp** — roll out cell by cell; parameters freeze on incident.

use crate::config::{ControllerCfg, SimConfig};
use crate::rpc::{self, QueueParams, ServiceChain};
use crate::sim::engine::{self, SimResult};
use crate::trace::Record;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeployStage {
    Shadow,
    Canary,
    Ramp,
    RolledBack,
    Steady,
}

#[derive(Clone, Debug)]
pub struct StageReport {
    pub stage: DeployStage,
    pub detail: String,
    /// Control/treatment P95 (µs) where applicable.
    pub control_p95: f64,
    pub treat_p95: f64,
    pub pollution_rate: f64,
    pub predicted_useful: f64,
}

#[derive(Clone, Debug)]
pub struct DeployOutcome {
    pub final_stage: DeployStage,
    pub reports: Vec<StageReport>,
}

/// Gates for promotion (the playbook's guardrails).
#[derive(Clone, Debug)]
pub struct Gates {
    /// Max allowed P95 regression (treatment / control).
    pub p95_ratio_max: f64,
    /// Max pollution misses per issued prefetch.
    pub pollution_max: f64,
    /// Min shadow-mode predicted-useful fraction to proceed at all.
    pub shadow_useful_min: f64,
}

impl Default for Gates {
    fn default() -> Self {
        Gates {
            p95_ratio_max: 1.05,
            pollution_max: 0.10,
            shadow_useful_min: 0.30,
        }
    }
}

pub struct DeploymentManager {
    pub control_cfg: SimConfig,
    pub candidate_cfg: SimConfig,
    pub gates: Gates,
    pub cells: usize,
}

fn p95_of(result: &SimResult, seed: u64) -> f64 {
    // Control-plane chain with three replicas of this service's IPC.
    let ipc = result.ipc();
    let chain = ServiceChain::control_plane(
        &[
            ("admission".into(), ipc),
            ("featurestore".into(), ipc * 0.95),
            ("mlserve".into(), ipc * 1.05),
        ],
        25_000.0,
        2.5,
    );
    rpc::simulate_chain(
        &chain,
        &QueueParams {
            utilization: 0.65,
            requests: 8_000,
            seed,
        },
    )
    .p95_us
}

impl DeploymentManager {
    pub fn new(control_cfg: SimConfig, candidate_cfg: SimConfig) -> Self {
        DeploymentManager {
            control_cfg,
            candidate_cfg,
            gates: Gates::default(),
            cells: 4,
        }
    }

    /// Execute the full playbook over per-cell trace slices.
    pub fn run(&self, records: &[Record]) -> DeployOutcome {
        let mut reports = Vec::new();
        let slice = records.len() / (self.cells + 1).max(1);
        if slice == 0 {
            return DeployOutcome {
                final_stage: DeployStage::RolledBack,
                reports: vec![StageReport {
                    stage: DeployStage::RolledBack,
                    detail: "trace too short".into(),
                    control_p95: 0.0,
                    treat_p95: 0.0,
                    pollution_rate: 0.0,
                    predicted_useful: 0.0,
                }],
            };
        }

        // --- Stage 1: shadow (§VI-A: "enable prefetch decisions but do
        // not issue fills; log predicted utility, candidate windows, and
        // hypothetical bandwidth"). Calibration is validated by a paired
        // issuing run on the same slice.
        let shadow_slice = &records[0..slice];
        let mut shadow_cfg = self.candidate_cfg.clone();
        let mut sc = shadow_cfg.controller.clone().unwrap_or_default();
        sc.shadow = true;
        shadow_cfg.controller = Some(sc);
        let shadow = engine::run(&shadow_cfg, shadow_slice);
        // Paired issuing run → realized utility for calibration check.
        let realized = engine::run(&self.candidate_cfg, shadow_slice);
        let predicted_useful = realized.stats.accuracy();
        reports.push(StageReport {
            stage: DeployStage::Shadow,
            detail: format!(
                "would_issue={} hypothetical_bw={:.0}B/kcyc realized_acc={:.3}",
                shadow.stats.shadow_would_issue,
                shadow.stats.shadow_bytes as f64 / (shadow.stats.cycles / 1000.0).max(1.0),
                predicted_useful
            ),
            control_p95: 0.0,
            treat_p95: 0.0,
            pollution_rate: 0.0,
            predicted_useful,
        });
        if predicted_useful < self.gates.shadow_useful_min {
            reports.push(StageReport {
                stage: DeployStage::RolledBack,
                detail: format!(
                    "shadow gate: predicted useful {predicted_useful:.3} < {}",
                    self.gates.shadow_useful_min
                ),
                control_p95: 0.0,
                treat_p95: 0.0,
                pollution_rate: 0.0,
                predicted_useful,
            });
            return DeployOutcome {
                final_stage: DeployStage::RolledBack,
                reports,
            };
        }

        // --- Stage 2: guarded canary on cell 1 with a budget cap.
        let canary_slice = &records[slice..2 * slice];
        let mut canary_cfg = self.candidate_cfg.clone();
        if let Some(c) = &mut canary_cfg.controller {
            if c.issue_budget_per_kcycle == 0 {
                c.issue_budget_per_kcycle = 64; // guarded default
            }
        } else {
            canary_cfg.controller = Some(ControllerCfg {
                issue_budget_per_kcycle: 64,
                ..Default::default()
            });
        }
        let control = engine::run(&self.control_cfg, canary_slice);
        let treat = engine::run(&canary_cfg, canary_slice);
        let control_p95 = p95_of(&control, 11);
        let treat_p95 = p95_of(&treat, 11);
        let pollution_rate = if treat.stats.pf_issued == 0 {
            0.0
        } else {
            treat.stats.pollution_misses as f64 / treat.stats.pf_issued as f64
        };
        reports.push(StageReport {
            stage: DeployStage::Canary,
            detail: format!(
                "p95 {:.1}→{:.1}µs pollution={:.4}",
                control_p95, treat_p95, pollution_rate
            ),
            control_p95,
            treat_p95,
            pollution_rate,
            predicted_useful,
        });
        if treat_p95 > control_p95 * self.gates.p95_ratio_max
            || pollution_rate > self.gates.pollution_max
        {
            reports.push(StageReport {
                stage: DeployStage::RolledBack,
                detail: "canary gate tripped: automatic backoff + rollback".into(),
                control_p95,
                treat_p95,
                pollution_rate,
                predicted_useful,
            });
            return DeployOutcome {
                final_stage: DeployStage::RolledBack,
                reports,
            };
        }

        // --- Stage 3: ramp across remaining cells, uncapped budget.
        let mut worst_ratio = 0.0f64;
        for cell in 2..=self.cells {
            let lo = cell * slice;
            let hi = ((cell + 1) * slice).min(records.len());
            if lo >= hi {
                break;
            }
            let s = &records[lo..hi];
            let control = engine::run(&self.control_cfg, s);
            let treat = engine::run(&self.candidate_cfg, s);
            let cp = p95_of(&control, cell as u64);
            let tp = p95_of(&treat, cell as u64);
            worst_ratio = worst_ratio.max(tp / cp);
            reports.push(StageReport {
                stage: DeployStage::Ramp,
                detail: format!("cell {cell}: p95 {cp:.1}→{tp:.1}µs"),
                control_p95: cp,
                treat_p95: tp,
                pollution_rate,
                predicted_useful,
            });
        }
        let final_stage = if worst_ratio <= self.gates.p95_ratio_max {
            DeployStage::Steady
        } else {
            DeployStage::RolledBack
        };
        DeployOutcome {
            final_stage,
            reports,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefetcherKind;
    use crate::trace::gen::{apps, generate_records};

    fn records() -> Vec<Record> {
        generate_records(&apps::app("admission").unwrap(), 3, 250_000)
    }

    fn nl() -> SimConfig {
        SimConfig::default()
    }

    fn cheip() -> SimConfig {
        SimConfig {
            prefetcher: PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
            controller: Some(ControllerCfg {
                train_interval_cycles: 200_000,
                ..Default::default()
            }),
            ..Default::default()
        }
    }

    #[test]
    fn good_candidate_reaches_steady_state() {
        let recs = records();
        let dm = DeploymentManager::new(nl(), cheip());
        let out = dm.run(&recs);
        assert_eq!(
            out.final_stage,
            DeployStage::Steady,
            "reports: {:#?}",
            out.reports
        );
        assert!(out.reports.iter().any(|r| r.stage == DeployStage::Shadow));
        assert!(out.reports.iter().any(|r| r.stage == DeployStage::Canary));
        assert!(out.reports.iter().filter(|r| r.stage == DeployStage::Ramp).count() >= 2);
    }

    #[test]
    fn hopeless_candidate_rolls_back_in_shadow() {
        let recs = records();
        let dm = DeploymentManager {
            gates: Gates {
                shadow_useful_min: 1.01, // impossible gate
                ..Default::default()
            },
            ..DeploymentManager::new(nl(), cheip())
        };
        let out = dm.run(&recs);
        assert_eq!(out.final_stage, DeployStage::RolledBack);
        assert_eq!(out.reports.len(), 2, "must stop after shadow");
    }

    #[test]
    fn canary_gate_trips_on_tight_p95() {
        let recs = records();
        let dm = DeploymentManager {
            gates: Gates {
                p95_ratio_max: 0.5, // require 2x improvement: impossible
                ..Default::default()
            },
            ..DeploymentManager::new(nl(), cheip())
        };
        let out = dm.run(&recs);
        assert_eq!(out.final_stage, DeployStage::RolledBack);
        assert!(out
            .reports
            .iter()
            .any(|r| r.detail.contains("canary gate tripped")));
    }

    #[test]
    fn empty_trace_is_graceful() {
        let dm = DeploymentManager::new(nl(), cheip());
        let out = dm.run(&[]);
        assert_eq!(out.final_stage, DeployStage::RolledBack);
    }
}
