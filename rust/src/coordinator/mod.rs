//! The SLO-driven coordination layer (Layer 3's system contribution
//! beyond the prefetcher itself): a multi-core fleet driver that runs
//! per-service simulations in parallel, the paper's three-stage deployment
//! playbook (§VI-A: shadow → guarded canary → ramp) with automatic backoff
//! on pollution/P95 regression, and the budget/tenant guardrails (§I
//! challenge (iv)).

pub mod budget;
pub mod deploy;
pub mod fleet;
pub mod tenant;

pub use deploy::{DeployOutcome, DeployStage, DeploymentManager, StageReport};
pub use fleet::{run_fleet, CellResult, FleetJob};
