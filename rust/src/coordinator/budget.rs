//! Token-bucket bandwidth/issuance budgets — the playbook's "single knob,
//! target issuance rate, which maps to a bandwidth SLO" (§VI-A).

/// A token bucket with per-kilocycle refill.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    /// Tokens per 1000 cycles.
    pub rate_per_kcycle: f64,
    /// Burst capacity.
    pub burst: f64,
    tokens: f64,
    last: u64,
}

impl TokenBucket {
    pub fn new(rate_per_kcycle: f64, burst: f64) -> Self {
        TokenBucket {
            rate_per_kcycle,
            burst,
            tokens: burst,
            last: 0,
        }
    }

    /// Try to spend one token at `cycle`.
    pub fn try_take(&mut self, cycle: u64) -> bool {
        let elapsed = cycle.saturating_sub(self.last) as f64;
        self.last = cycle;
        self.tokens = (self.tokens + elapsed * self.rate_per_kcycle / 1000.0).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Current fill fraction.
    pub fn level(&self) -> f64 {
        (self.tokens / self.burst).clamp(0.0, 1.0)
    }

    /// Halve the rate (automatic backoff on regression).
    pub fn backoff(&mut self) {
        self.rate_per_kcycle *= 0.5;
    }

    /// Recover the rate by 25% up to `cap`.
    pub fn recover(&mut self, cap: f64) {
        self.rate_per_kcycle = (self.rate_per_kcycle * 1.25).min(cap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_starve_then_refill() {
        let mut b = TokenBucket::new(1.0, 4.0); // 1 token/kcycle, burst 4
        let mut got = 0;
        for _ in 0..10 {
            if b.try_take(0) {
                got += 1;
            }
        }
        assert_eq!(got, 4, "burst capacity");
        assert!(!b.try_take(100), "0.1 tokens after 100 cycles");
        assert!(b.try_take(2_000), "refilled after 2k cycles");
    }

    #[test]
    fn rate_limits_long_run() {
        let mut b = TokenBucket::new(2.0, 2.0);
        let mut got = 0;
        for c in 0..100_000u64 {
            if b.try_take(c) {
                got += 1;
            }
        }
        // 2 per kcycle over 100k cycles ≈ 200 (+burst).
        assert!((195..=210).contains(&got), "got {got}");
    }

    #[test]
    fn backoff_and_recover() {
        let mut b = TokenBucket::new(8.0, 16.0);
        b.backoff();
        assert_eq!(b.rate_per_kcycle, 4.0);
        b.recover(8.0);
        assert_eq!(b.rate_per_kcycle, 5.0);
        for _ in 0..10 {
            b.recover(8.0);
        }
        assert_eq!(b.rate_per_kcycle, 8.0, "capped");
    }

    #[test]
    fn level_reflects_fill() {
        let mut b = TokenBucket::new(1.0, 10.0);
        assert_eq!(b.level(), 1.0);
        for _ in 0..5 {
            b.try_take(0);
        }
        assert!((b.level() - 0.5).abs() < 1e-9);
    }
}
