//! Bounded-memory streaming summaries for fleet telemetry (DESIGN.md
//! §12): a count-min sketch for per-context counters, a hyperloglog for
//! distinct-context cardinality, and a space-saving top-K table for hot
//! contexts. The fleet-scale analogue of the paper's compressed on-chip
//! metadata: the hot, frequently-queried state stays small and the cold
//! tail is approximated.
//!
//! Determinism contract: every hash derives from [`mix64`] under the
//! fixed salts below — zero RNG draws, so recording is a pure function
//! of the update stream. [`CountMin::merge`] (cell-wise add) and
//! [`Hll::merge`] (register max) are associative and commutative;
//! [`TopK::merged`] unions *all* shards and truncates once, so a fleet
//! summary is invariant to the order cells are folded in.

use crate::util::rng::mix64;

/// Per-row salts for the count-min hash family (splitmix64 of 1..=8 —
/// fixed constants, never drawn from a run's RNG streams).
pub const CMS_ROW_SALTS: [u64; 8] = [
    0x910A_2DEC_8902_5CC1,
    0x6C45_E439_30E6_4F9D,
    0xF04E_00A7_A5E4_5E67,
    0x9B0B_CE16_41B9_1A3E,
    0x1F67_5F99_1C44_53DB,
    0xF4BE_B951_B9DD_4B57,
    0x66D4_8AA0_E597_BE1B,
    0x00D9_9375_0AD2_F6D5,
];

/// Salt for the hyperloglog register hash.
pub const HLL_SALT: u64 = 0x5EED_CA2D_1A11_7E1E;

/// Count-min sketch: `depth` rows of `width` u32 counters; a key's
/// estimate is the minimum of its cells, so errors are one-sided
/// (over-estimates only, by at most `2·N/width` with probability
/// `1 − 2^-depth` for N total insertions).
#[derive(Clone, Debug, PartialEq)]
pub struct CountMin {
    width: usize,
    depth: usize,
    cells: Vec<u32>,
    /// Exact total weight inserted (each row sums to this; kept as a
    /// counter so callers don't pay a row scan).
    total: u64,
}

impl CountMin {
    /// `depth` is capped by the fixed salt family (8 rows).
    pub fn new(width: usize, depth: usize) -> CountMin {
        assert!(width >= 1, "count-min width must be ≥ 1");
        assert!(
            (1..=CMS_ROW_SALTS.len()).contains(&depth),
            "count-min depth must be in 1..={}",
            CMS_ROW_SALTS.len()
        );
        CountMin { width, depth, cells: vec![0; width * depth], total: 0 }
    }

    #[inline]
    fn cell(&self, row: usize, key: u64) -> usize {
        row * self.width + (mix64(key ^ CMS_ROW_SALTS[row]) % self.width as u64) as usize
    }

    /// Add `n` to `key`'s count (cells saturate at `u32::MAX`).
    pub fn add(&mut self, key: u64, n: u32) {
        for row in 0..self.depth {
            let c = self.cell(row, key);
            self.cells[c] = self.cells[c].saturating_add(n);
        }
        self.total += n as u64;
    }

    /// Point estimate for `key` (min over rows; never under-counts).
    pub fn estimate(&self, key: u64) -> u64 {
        (0..self.depth).map(|row| self.cells[self.cell(row, key)] as u64).min().unwrap_or(0)
    }

    /// Exact total weight inserted across all keys.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Cell-wise add — associative and commutative, so shard merges are
    /// fold-order invariant. Panics on geometry mismatch (shards share
    /// one config by construction).
    pub fn merge(&mut self, other: &CountMin) {
        assert_eq!(
            (self.width, self.depth),
            (other.width, other.depth),
            "count-min merge: geometry mismatch"
        );
        for (a, b) in self.cells.iter_mut().zip(&other.cells) {
            *a = a.saturating_add(*b);
        }
        self.total += other.total;
    }

    /// Fraction of non-zero cells (1.0 = saturated hash space).
    pub fn fill_ratio(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        self.cells.iter().filter(|&&c| c > 0).count() as f64 / self.cells.len() as f64
    }

    pub fn bytes(&self) -> u64 {
        (self.cells.len() * std::mem::size_of::<u32>()) as u64
    }
}

/// HyperLogLog distinct counter with `2^p` one-byte registers.
#[derive(Clone, Debug, PartialEq)]
pub struct Hll {
    p: u32,
    regs: Vec<u8>,
}

impl Hll {
    /// `p` in 4..=16 (16 B .. 64 KB of registers).
    pub fn new(p: u32) -> Hll {
        assert!((4..=16).contains(&p), "hyperloglog precision must be in 4..=16");
        Hll { p, regs: vec![0; 1 << p] }
    }

    pub fn add(&mut self, key: u64) {
        let h = mix64(key ^ HLL_SALT);
        let idx = (h >> (64 - self.p)) as usize;
        // Rank = position of the first set bit in the remaining 64−p
        // bits (1-based), capped so an all-zero suffix still counts.
        let rest = h << self.p;
        let rank = if rest == 0 { 64 - self.p + 1 } else { rest.leading_zeros() + 1 } as u8;
        if rank > self.regs[idx] {
            self.regs[idx] = rank;
        }
    }

    /// Standard HLL estimate with the small-range (linear counting)
    /// correction.
    pub fn estimate(&self) -> f64 {
        let m = self.regs.len() as f64;
        let alpha = match self.regs.len() {
            16 => 0.673,
            32 => 0.697,
            64 => 0.709,
            _ => 0.7213 / (1.0 + 1.079 / m),
        };
        let sum: f64 = self.regs.iter().map(|&r| 2f64.powi(-(r as i32))).sum();
        let raw = alpha * m * m / sum;
        let zeros = self.regs.iter().filter(|&&r| r == 0).count();
        if raw <= 2.5 * m && zeros > 0 {
            m * (m / zeros as f64).ln()
        } else {
            raw
        }
    }

    /// Register-wise max — associative, commutative, idempotent.
    pub fn merge(&mut self, other: &Hll) {
        assert_eq!(self.p, other.p, "hyperloglog merge: precision mismatch");
        for (a, &b) in self.regs.iter_mut().zip(&other.regs) {
            *a = (*a).max(b);
        }
    }

    pub fn bytes(&self) -> u64 {
        self.regs.len() as u64
    }
}

/// Space-saving top-K heavy hitters: at most `k` (key, count) entries;
/// an overflowing new key evicts the current minimum and inherits its
/// count + 1 (the classic over-estimate bound). All tie-breaks are on
/// the key value, so the table is a pure function of the update stream.
#[derive(Clone, Debug, PartialEq)]
pub struct TopK {
    k: usize,
    entries: Vec<(u64, u64)>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        assert!(k >= 1, "top-k capacity must be ≥ 1");
        TopK { k, entries: Vec::new() }
    }

    /// Record one occurrence of `key`.
    pub fn offer(&mut self, key: u64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == key) {
            e.1 += 1;
            return;
        }
        if self.entries.len() < self.k {
            self.entries.push((key, 1));
            return;
        }
        // Evict the minimum count; ties break to the largest key so the
        // victim is unique and deterministic.
        let (i, &(_, min)) = self
            .entries
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
            .expect("k ≥ 1");
        self.entries[i] = (key, min + 1);
    }

    /// Entries sorted hottest-first (count desc, key asc).
    pub fn top(&self) -> Vec<(u64, u64)> {
        let mut out = self.entries.clone();
        out.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out
    }

    /// Merge any number of shards: union-sum every entry across *all*
    /// inputs, then truncate once to the capacity of the first. One
    /// union + one truncation — a permutation of `parts` cannot change
    /// the result, which pairwise fold-with-truncate could not
    /// guarantee.
    pub fn merged(parts: &[&TopK]) -> TopK {
        let k = parts.first().map_or(1, |t| t.k);
        let mut union: Vec<(u64, u64)> = Vec::new();
        for part in parts {
            for &(key, count) in &part.entries {
                match union.iter_mut().find(|e| e.0 == key) {
                    Some(e) => e.1 += count,
                    None => union.push((key, count)),
                }
            }
        }
        union.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        union.truncate(k);
        TopK { k, entries: union }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Capacity bytes: k × (key + count).
    pub fn bytes(&self) -> u64 {
        (self.k * 16) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_min_never_undercounts_and_is_exact_when_sparse() {
        let mut cm = CountMin::new(512, 4);
        for key in 0..64u64 {
            cm.add(key, (key + 1) as u32);
        }
        for key in 0..64u64 {
            let est = cm.estimate(key);
            assert!(est >= key + 1, "under-count for {key}: {est}");
            // 64 keys into 512×4 cells: collisions are essentially
            // impossible per-row across 4 rows' min.
            assert_eq!(est, key + 1, "sparse sketch must be exact");
        }
        assert_eq!(cm.estimate(999), 0);
        assert_eq!(cm.total(), (1..=64).sum::<u64>());
        assert!(cm.fill_ratio() > 0.0 && cm.fill_ratio() < 0.2);
    }

    #[test]
    fn count_min_merge_equals_single_stream_and_is_order_invariant() {
        let stream: Vec<u64> = (0..3000u64).map(|i| mix64(i) % 200).collect();
        let mut whole = CountMin::new(128, 4);
        let mut shards: Vec<CountMin> = (0..3).map(|_| CountMin::new(128, 4)).collect();
        for (i, &key) in stream.iter().enumerate() {
            whole.add(key, 1);
            shards[i % 3].add(key, 1);
        }
        // Merge is cell-wise add: any fold order gives the whole-stream
        // sketch exactly.
        let mut abc = shards[0].clone();
        abc.merge(&shards[1]);
        abc.merge(&shards[2]);
        let mut cab = shards[2].clone();
        cab.merge(&shards[0]);
        cab.merge(&shards[1]);
        assert_eq!(abc, whole);
        assert_eq!(cab, whole);
    }

    #[test]
    fn count_min_saturates_instead_of_wrapping() {
        let mut cm = CountMin::new(4, 1);
        cm.add(7, u32::MAX);
        cm.add(7, 10);
        assert_eq!(cm.estimate(7), u32::MAX as u64);
    }

    #[test]
    fn hll_estimates_within_a_few_percent() {
        let mut h = Hll::new(12);
        let n = 20_000u64;
        for i in 0..n {
            h.add(mix64(i));
            h.add(mix64(i)); // duplicates must not inflate
        }
        let est = h.estimate();
        let err = (est - n as f64).abs() / n as f64;
        assert!(err < 0.05, "hll err {err:.3} (est {est:.0} vs {n})");
    }

    #[test]
    fn hll_small_range_is_near_exact() {
        let mut h = Hll::new(10);
        for i in 0..50u64 {
            h.add(i);
        }
        let est = h.estimate();
        assert!((est - 50.0).abs() < 3.0, "linear-counting range est {est}");
    }

    #[test]
    fn hll_merge_is_order_invariant_and_matches_union() {
        let mut whole = Hll::new(10);
        let mut a = Hll::new(10);
        let mut b = Hll::new(10);
        let mut c = Hll::new(10);
        for i in 0..9_000u64 {
            whole.add(i);
            match i % 3 {
                0 => a.add(i),
                1 => b.add(i),
                _ => c.add(i),
            }
        }
        let mut abc = a.clone();
        abc.merge(&b);
        abc.merge(&c);
        let mut bca = b.clone();
        bca.merge(&c);
        bca.merge(&a);
        assert_eq!(abc, whole, "register-max union must equal the whole stream");
        assert_eq!(bca, whole);
    }

    #[test]
    fn topk_finds_heavy_hitters() {
        let mut t = TopK::new(4);
        // Heavy: 100, 200, 300 with descending weight; noise keys once.
        for i in 0..300u64 {
            t.offer(100);
            if i < 200 {
                t.offer(200);
            }
            if i < 100 {
                t.offer(300);
            }
            t.offer(1_000 + i);
        }
        let top = t.top();
        assert_eq!(top[0].0, 100);
        assert_eq!(top[1].0, 200);
        assert_eq!(top[2].0, 300);
        // Space-saving over-estimates, never under-estimates.
        assert!(top[0].1 >= 300);
        assert!(t.len() <= 4);
    }

    #[test]
    fn topk_merged_is_permutation_invariant() {
        let mut shards: Vec<TopK> = (0..4).map(|_| TopK::new(8)).collect();
        for i in 0..2_000u64 {
            shards[(i % 4) as usize].offer(mix64(i) % 50);
        }
        let refs: Vec<&TopK> = shards.iter().collect();
        let base = TopK::merged(&refs);
        let perm: Vec<&TopK> = vec![&shards[2], &shards[0], &shards[3], &shards[1]];
        assert_eq!(TopK::merged(&perm), base);
        assert_eq!(base.len(), 8);
        // Sorted hottest-first with deterministic tie-break.
        let top = base.top();
        for w in top.windows(2) {
            assert!(w[0].1 > w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0));
        }
    }

    #[test]
    fn bytes_accounting_matches_geometry() {
        assert_eq!(CountMin::new(256, 4).bytes(), 256 * 4 * 4);
        assert_eq!(Hll::new(10).bytes(), 1024);
        assert_eq!(TopK::new(16).bytes(), 256);
    }
}
