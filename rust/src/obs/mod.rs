//! Deterministic observability layer for the cluster simulator
//! (DESIGN.md §11): request spans over a hash-sampled subset of
//! requests, a counters/gauges/histograms metrics registry snapshotted
//! at SLO-window boundaries, Chrome-trace/Perfetto export, and a
//! leveled stderr log sink.
//!
//! The layer is opt-in per run and honors the §8 determinism contract
//! from both sides: disabled, the engine takes the exact baseline path
//! (no extra RNG draws, no event reordering, byte-identical outputs);
//! enabled, every recorded value is a pure function of the simulated
//! event order — simulated µs only, never wall-clock — so the emitted
//! trace and metrics artifacts are byte-identical across `--threads`
//! values and reruns.

pub mod log;
pub mod metrics;
pub mod sketch;
pub mod span;
pub mod telemetry;
pub mod trace;

use crate::util::json::Json;
use crate::util::rng::mix64;
use metrics::Registry;
use span::{SpanRecorder, SpanStat, TraceSpan};

/// Default sampling shift: 1 in 2^6 = 64 requests carry a span.
pub const DEFAULT_SAMPLE_SHIFT: u32 = 6;

/// Salt mixed into the request id before the sampling hash, so span
/// sampling is decorrelated from every other use of the id.
const SAMPLE_SALT: u64 = 0x0B5E_5A3F_1E57_C0DE;

/// Per-run observability configuration.
#[derive(Clone, Debug)]
pub struct ObsCfg {
    /// Master switch; `false` is the byte-identical baseline path.
    pub enabled: bool,
    /// Span sampling rate: 1 in 2^`sample_shift` requests (0 = every
    /// request). The decision is a stateless hash of the request's
    /// arrival index — no RNG draws, stable across reruns and threads.
    pub sample_shift: u32,
}

impl ObsCfg {
    /// Observability disabled (the DESIGN.md §8 baseline).
    pub fn off() -> ObsCfg {
        ObsCfg { enabled: false, sample_shift: DEFAULT_SAMPLE_SHIFT }
    }

    /// Observability enabled at a 1-in-2^`sample_shift` span sampling
    /// rate (clamped to 63 so the mask math stays defined).
    pub fn on(sample_shift: u32) -> ObsCfg {
        ObsCfg { enabled: true, sample_shift: sample_shift.min(63) }
    }

    /// Whether the request with arrival index `req` carries a span:
    /// `mix64(req ^ salt)` masked to the low `sample_shift` bits.
    #[inline]
    pub fn sampled(&self, req: u64) -> bool {
        mix64(req ^ SAMPLE_SALT) & ((1u64 << self.sample_shift) - 1) == 0
    }
}

/// Live recorder the engine threads through a run: span timings for
/// sampled requests plus the metrics registry and its window-boundary
/// snapshots.
pub struct Recorder {
    pub cfg: ObsCfg,
    pub spans: SpanRecorder,
    pub metrics: Registry,
    /// One [`Registry::snapshot`] object per closed SLO window,
    /// boundary order.
    pub snapshots: Vec<Json>,
}

impl Recorder {
    pub fn new(cfg: ObsCfg, nsvc: usize) -> Recorder {
        Recorder {
            spans: SpanRecorder::new(cfg.clone(), nsvc),
            metrics: Registry::default(),
            snapshots: Vec::new(),
            cfg,
        }
    }

    /// Snapshot the registry at an SLO-window boundary (`t_us` is
    /// simulated time, `window` the total windows closed so far).
    pub fn snapshot(&mut self, t_us: f64, window: u64) {
        self.snapshots.push(self.metrics.snapshot(t_us, window));
    }

    /// Freeze the recorder into the result payload (`services` are the
    /// run's service names, spec order).
    pub fn into_data(mut self, services: &[String]) -> ObsData {
        ObsData {
            sample_shift: self.cfg.sample_shift,
            sampled_requests: self.spans.sampled,
            services: services.to_vec(),
            span_stats: self.spans.stats(services),
            trace_spans: std::mem::take(&mut self.spans.finished),
            snapshots: self.snapshots,
        }
    }
}

/// Observability payload of one run, carried on
/// [`crate::cluster::engine::ClusterResult::obs`] (`None` when
/// disabled). Everything here is deterministic: request-completion
/// order for spans, window-boundary order for snapshots.
#[derive(Clone, Debug)]
pub struct ObsData {
    pub sample_shift: u32,
    /// Requests that carried a span.
    pub sampled_requests: u64,
    /// Service names, spec order (`TraceSpan::svc` indexes this).
    pub services: Vec<String>,
    /// Per-service critical-path attribution over the sampled spans.
    pub span_stats: Vec<SpanStat>,
    /// Per-(request, service) slices, request-completion order.
    pub trace_spans: Vec<TraceSpan>,
    /// Metrics-registry snapshots, one per closed SLO window.
    pub snapshots: Vec<Json>,
}
