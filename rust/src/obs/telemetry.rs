//! Sketch telemetry aggregator (DESIGN.md §12): bundles the streaming
//! summaries of [`super::sketch`] into the per-run recorder the engine
//! threads through a simulation — per-context prefetch issue / useful /
//! useless counts in three count-min sketches, distinct-context
//! cardinality in a hyperloglog, hot contexts in a space-saving top-K —
//! plus the exact-vs-sketch comparison tallies behind the
//! `campaign_sketch` accuracy report.
//!
//! The `telemetry` knob (`SimConfig` / `ClusterSpec`) selects the mode:
//! `"exact"` (the default) allocates nothing and is byte-identical to
//! pre-sketch builds; `"sketch[:GEOM]"` derives the controller's
//! decision context from sketch estimates instead of the exact EWMAs;
//! `"compare[:GEOM]"` keeps exact decisions while scoring a sketch-fed
//! shadow per decision, measuring feature error and decision agreement
//! on one trajectory. GEOM is `w{width}d{depth}p{hll_p}k{topk}`, e.g.
//! `w256d4p10k16`.

use super::sketch::{CountMin, Hll, TopK};
use crate::util::hashfx::FxHashSet;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// How telemetry participates in a run (the `"exact"` mode is the
/// *absence* of a [`Telemetry`] — nothing is allocated or recorded).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TelemetryMode {
    /// Sketch estimates replace the exact EWMAs in the controller's
    /// decision context.
    Sketch,
    /// Exact values drive decisions; a sketch-fed shadow score is
    /// compared per decision (agreement + feature error, zero extra RNG
    /// draws, zero perturbation of the run).
    Compare,
}

/// Sketch geometry + mode, parsed from the `telemetry` knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TelemetryCfg {
    pub mode: TelemetryMode,
    /// Count-min width (columns per row), shared by all three sketches.
    pub width: usize,
    /// Count-min depth (rows).
    pub depth: usize,
    /// HyperLogLog precision (2^p registers).
    pub hll_p: u32,
    /// Heavy-hitter table capacity.
    pub topk: usize,
}

/// Default geometry: 3 × (256×4 u32) + 2^10 B + 16×16 B ≈ 13.5 KB.
pub const DEFAULT_GEOM: (usize, usize, u32, usize) = (256, 4, 10, 16);

impl TelemetryCfg {
    /// Parse the full knob: `"exact"` → `None`, `"sketch[:GEOM]"` /
    /// `"compare[:GEOM]"` → `Some(cfg)`.
    pub fn parse(s: &str) -> Result<Option<TelemetryCfg>> {
        let (mode_str, geom) = match s.split_once(':') {
            Some((m, g)) => (m, Some(g)),
            None => (s, None),
        };
        let mode = match mode_str {
            "exact" => {
                if geom.is_some() {
                    bail!("telemetry 'exact' takes no sketch geometry (got '{s}')");
                }
                return Ok(None);
            }
            "sketch" => TelemetryMode::Sketch,
            "compare" => TelemetryMode::Compare,
            other => bail!(
                "unknown telemetry mode '{other}' (expected 'exact', \
                 'sketch[:GEOM]', or 'compare[:GEOM]')"
            ),
        };
        let (width, depth, hll_p, topk) = match geom {
            Some(g) => Self::parse_geom(g)?,
            None => DEFAULT_GEOM,
        };
        Ok(Some(TelemetryCfg { mode, width, depth, hll_p, topk }))
    }

    /// Parse a geometry string `w{width}d{depth}p{hll_p}k{topk}`.
    pub fn parse_geom(g: &str) -> Result<(usize, usize, u32, usize)> {
        let err = || format!("telemetry geometry '{g}' (expected w<width>d<depth>p<p>k<k>)");
        let rest = g.strip_prefix('w').with_context(err)?;
        let (w, rest) = rest.split_once('d').with_context(err)?;
        let (d, rest) = rest.split_once('p').with_context(err)?;
        let (p, k) = rest.split_once('k').with_context(err)?;
        let width: usize = w.parse().with_context(err)?;
        let depth: usize = d.parse().with_context(err)?;
        let hll_p: u32 = p.parse().with_context(err)?;
        let topk: usize = k.parse().with_context(err)?;
        if width == 0 || !(1..=8).contains(&depth) || !(4..=16).contains(&hll_p) || topk == 0 {
            bail!(
                "telemetry geometry '{g}' out of range (width ≥ 1, depth 1..=8, \
                 p 4..=16, k ≥ 1)"
            );
        }
        Ok((width, depth, hll_p, topk))
    }

    /// Canonical geometry label (also valid `parse_geom` input).
    pub fn geom_label(&self) -> String {
        format!("w{}d{}p{}k{}", self.width, self.depth, self.hll_p, self.topk)
    }

    /// Canonical knob string (`"sketch:GEOM"` / `"compare:GEOM"`).
    pub fn label(&self) -> String {
        let mode = match self.mode {
            TelemetryMode::Sketch => "sketch",
            TelemetryMode::Compare => "compare",
        };
        format!("{mode}:{}", self.geom_label())
    }
}

/// Sketch-derived substitutes for the exact decision-context EWMAs
/// ([`crate::ml::features::sketch_ctx`] splices them into a
/// `DecisionCtx`).
#[derive(Clone, Copy, Debug)]
pub struct CtxEstimates {
    pub hit: f32,
    pub pollution: f32,
    pub accuracy: f32,
}

/// Per-run sketch telemetry: the three per-context counters, the
/// cardinality and heavy-hitter summaries, and (compare mode) the
/// exact-vs-sketch tallies. Carried on
/// [`crate::sim::engine::SimResult::telemetry`] after the run.
#[derive(Clone, Debug, PartialEq)]
pub struct Telemetry {
    pub cfg: TelemetryCfg,
    /// Prefetches issued, by source context.
    pub issued: CountMin,
    /// Useful outcomes (timely + late), by source context.
    pub useful: CountMin,
    /// Useless outcomes (evicted unused), by source context.
    pub useless: CountMin,
    /// Distinct source contexts seen.
    pub contexts: Hll,
    /// Hottest source contexts by issue count.
    pub hot: TopK,
    /// Exact distinct contexts (compare-mode diagnostic only — this is
    /// the unbounded state the sketches replace, kept to price it).
    pub exact_srcs: FxHashSet<u64>,
    /// Decisions where exact and sketch scores were compared.
    pub decisions_compared: u64,
    /// ... of which both sides agreed on issue-vs-skip.
    pub decisions_agreed: u64,
    /// Σ |exact − sketch| over substituted feature values.
    pub feature_err_sum: f64,
    /// Substituted feature values compared.
    pub feature_err_n: u64,
}

impl Telemetry {
    pub fn new(cfg: TelemetryCfg) -> Telemetry {
        Telemetry {
            issued: CountMin::new(cfg.width, cfg.depth),
            useful: CountMin::new(cfg.width, cfg.depth),
            useless: CountMin::new(cfg.width, cfg.depth),
            contexts: Hll::new(cfg.hll_p),
            hot: TopK::new(cfg.topk),
            exact_srcs: FxHashSet::default(),
            decisions_compared: 0,
            decisions_agreed: 0,
            feature_err_sum: 0.0,
            feature_err_n: 0,
            cfg,
        }
    }

    /// Build from a `telemetry` knob string (`None` for `"exact"`).
    pub fn from_knob(s: &str) -> Result<Option<Telemetry>> {
        Ok(TelemetryCfg::parse(s)?.map(Telemetry::new))
    }

    /// One prefetch issued from source context `src`.
    pub fn record_issue(&mut self, src: u64) {
        self.issued.add(src, 1);
        self.contexts.add(src);
        self.hot.offer(src);
        if self.cfg.mode == TelemetryMode::Compare {
            self.exact_srcs.insert(src);
        }
    }

    /// One resolved prefetch outcome for source context `src`.
    pub fn record_outcome(&mut self, src: u64, useful: bool) {
        if useful {
            self.useful.add(src, 1);
        } else {
            self.useless.add(src, 1);
        }
    }

    /// Sketch-backed decision-context estimates for `src`. Mirrors what
    /// the exact path tracks: hit and accuracy EWMAs share one update
    /// rule there, so both map to the useful-outcome rate; pollution is
    /// the useless-fill rate per issue.
    pub fn estimates(&self, src: u64) -> CtxEstimates {
        let useful = self.useful.estimate(src);
        let useless = self.useless.estimate(src);
        let issued = self.issued.estimate(src);
        let outcomes = useful + useless;
        // Priors match the exact EWMAs' initial values (0.5 / 0.0) so a
        // cold context scores identically under both sources.
        let rate = if outcomes == 0 { 0.5 } else { useful as f32 / outcomes as f32 };
        let pollution = if issued == 0 { 0.0 } else { (useless as f32 / issued as f32).min(1.0) };
        CtxEstimates { hit: rate, pollution, accuracy: rate }
    }

    /// Compare-mode tally: whether exact and sketch sides agreed, plus
    /// the absolute error of each substituted feature value.
    pub fn tally_shadow(&mut self, agree: bool, exact: &[f32], sketch: &[f32]) {
        self.decisions_compared += 1;
        self.decisions_agreed += agree as u64;
        for (a, b) in exact.iter().zip(sketch) {
            self.feature_err_sum += (a - b).abs() as f64;
            self.feature_err_n += 1;
        }
    }

    /// Fraction of compared decisions where both sides agreed.
    pub fn agreement(&self) -> Option<f64> {
        (self.decisions_compared > 0)
            .then(|| self.decisions_agreed as f64 / self.decisions_compared as f64)
    }

    /// Mean absolute error over substituted feature values.
    pub fn feature_mae(&self) -> Option<f64> {
        (self.feature_err_n > 0).then(|| self.feature_err_sum / self.feature_err_n as f64)
    }

    /// Sketch footprint: the three count-min sketches + HLL registers +
    /// heavy-hitter table (the bounded state a deployment would ship).
    pub fn bytes(&self) -> u64 {
        self.issued.bytes()
            + self.useful.bytes()
            + self.useless.bytes()
            + self.contexts.bytes()
            + self.hot.bytes()
    }

    /// What exact per-context counters would cost: one u64 each for
    /// issued / useful / useless per distinct context. Compare mode only
    /// (it is the only mode that still tracks the exact context set).
    pub fn exact_counter_bytes(&self) -> Option<u64> {
        (self.cfg.mode == TelemetryMode::Compare)
            .then(|| self.exact_srcs.len() as u64 * 3 * 8)
    }

    /// Merge any number of per-cell telemetries into a fleet summary.
    /// Count-min and HLL merges are associative; the heavy-hitter union
    /// is done across all parts with a single truncation — so the
    /// result is invariant to the order cells are listed... provided the
    /// caller passes a deterministically-ordered slice (cells are in
    /// expansion order everywhere in this codebase).
    pub fn merged(parts: &[&Telemetry]) -> Option<Telemetry> {
        let (first, rest) = parts.split_first()?;
        let mut out = (*first).clone();
        for t in rest {
            assert_eq!(out.cfg.geom_label(), t.cfg.geom_label(), "telemetry merge geometry");
            out.issued.merge(&t.issued);
            out.useful.merge(&t.useful);
            out.useless.merge(&t.useless);
            out.contexts.merge(&t.contexts);
            out.exact_srcs.extend(t.exact_srcs.iter().copied());
            out.decisions_compared += t.decisions_compared;
            out.decisions_agreed += t.decisions_agreed;
            out.feature_err_sum += t.feature_err_sum;
            out.feature_err_n += t.feature_err_n;
        }
        out.hot = TopK::merged(&parts.iter().map(|t| &t.hot).collect::<Vec<_>>());
        Some(out)
    }

    /// Sorted-shape summary object for the metrics JSONL stream and the
    /// campaign store (keys emitted in one fixed order; contexts as hex
    /// strings so u64 values survive the f64 JSON number range).
    pub fn summary_json(&self) -> Json {
        let topk = self
            .hot
            .top()
            .into_iter()
            .map(|(ctx, n)| {
                Json::obj(vec![
                    ("ctx", Json::str(&format!("{ctx:#x}"))),
                    ("est", Json::num(n as f64)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("mode", Json::str(&self.cfg.label())),
            ("bytes", Json::num(self.bytes() as f64)),
            ("cms_fill", Json::num(self.issued.fill_ratio())),
            ("contexts_est", Json::num(self.contexts.estimate().round())),
            ("issued", Json::num(self.issued.total() as f64)),
            ("useful", Json::num(self.useful.total() as f64)),
            ("useless", Json::num(self.useless.total() as f64)),
            ("topk", Json::Arr(topk)),
        ];
        if self.cfg.mode == TelemetryMode::Compare {
            fields.push(("contexts_exact", Json::num(self.exact_srcs.len() as f64)));
            fields.push((
                "exact_bytes",
                Json::num(self.exact_counter_bytes().unwrap_or(0) as f64),
            ));
            fields.push(("decisions", Json::num(self.decisions_compared as f64)));
            fields.push(("agreement", Json::num(self.agreement().unwrap_or(1.0))));
            fields.push(("feature_mae", Json::num(self.feature_mae().unwrap_or(0.0))));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_parsing_covers_modes_and_rejects_garbage() {
        assert!(TelemetryCfg::parse("exact").unwrap().is_none());
        let s = TelemetryCfg::parse("sketch").unwrap().unwrap();
        assert_eq!(s.mode, TelemetryMode::Sketch);
        assert_eq!((s.width, s.depth, s.hll_p, s.topk), DEFAULT_GEOM);
        let c = TelemetryCfg::parse("compare:w128d3p8k9").unwrap().unwrap();
        assert_eq!(c.mode, TelemetryMode::Compare);
        assert_eq!((c.width, c.depth, c.hll_p, c.topk), (128, 3, 8, 9));
        // label round-trips through parse.
        assert_eq!(TelemetryCfg::parse(&c.label()).unwrap().unwrap(), c);
        assert_eq!(c.geom_label(), "w128d3p8k9");
        for bad in [
            "psychic",
            "sketch:128x4",
            "sketch:w0d4p10k16",
            "sketch:w64d9p10k16",
            "sketch:w64d4p3k16",
            "sketch:w64d4p17k16",
            "sketch:w64d4p10k0",
            "exact:w64d4p10k16",
        ] {
            assert!(TelemetryCfg::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    fn recorded(mode: &str) -> Telemetry {
        let mut t = Telemetry::from_knob(mode).unwrap().unwrap();
        for i in 0..200u64 {
            let src = i % 10;
            t.record_issue(src);
            t.record_outcome(src, src < 7);
        }
        t
    }

    #[test]
    fn estimates_track_the_recorded_ratios() {
        let t = recorded("sketch:w256d4p10k16");
        // src 3: always useful; src 9: never.
        let good = t.estimates(3);
        let bad = t.estimates(9);
        assert!(good.hit > 0.99 && good.accuracy > 0.99);
        assert!(bad.hit < 0.01 && bad.pollution > 0.99);
        // Cold context falls back to the exact-EWMA priors.
        let cold = t.estimates(0xDEAD_BEEF);
        assert_eq!(cold.hit, 0.5);
        assert_eq!(cold.pollution, 0.0);
        // Cardinality and totals are sane.
        assert!((t.contexts.estimate() - 10.0).abs() < 1.5);
        assert_eq!(t.issued.total(), 200);
        assert_eq!(t.hot.top().len(), 10);
    }

    #[test]
    fn compare_mode_tallies_and_prices_exact_state() {
        let mut t = recorded("compare:w256d4p10k16");
        assert_eq!(t.exact_srcs.len(), 10);
        assert_eq!(t.exact_counter_bytes(), Some(10 * 24));
        assert!(t.agreement().is_none(), "no decisions compared yet");
        t.tally_shadow(true, &[0.5, 0.0, 0.5], &[0.6, 0.0, 0.6]);
        t.tally_shadow(false, &[0.5, 0.0, 0.5], &[0.5, 0.0, 0.5]);
        assert_eq!(t.agreement(), Some(0.5));
        let mae = t.feature_mae().unwrap();
        assert!((mae - 0.2 / 6.0).abs() < 1e-9, "mae {mae}");
        // Sketch mode does not pay for the exact context set.
        let s = recorded("sketch");
        assert!(s.exact_srcs.is_empty());
        assert_eq!(s.exact_counter_bytes(), None);
    }

    #[test]
    fn merged_fleet_summary_equals_single_stream() {
        let cfg = TelemetryCfg::parse("sketch:w128d4p10k8").unwrap().unwrap();
        let mut whole = Telemetry::new(cfg);
        let mut shards: Vec<Telemetry> = (0..3).map(|_| Telemetry::new(cfg)).collect();
        for i in 0..900u64 {
            let src = crate::util::rng::mix64(i) % 40;
            whole.record_issue(src);
            whole.record_outcome(src, i % 3 == 0);
            let s = &mut shards[(i % 3) as usize];
            s.record_issue(src);
            s.record_outcome(src, i % 3 == 0);
        }
        let refs: Vec<&Telemetry> = shards.iter().collect();
        let merged = Telemetry::merged(&refs).unwrap();
        // Count-min / HLL merges are exact unions; the heavy-hitter
        // union is near the whole-stream table (same hot set).
        assert_eq!(merged.issued, whole.issued);
        assert_eq!(merged.useful, whole.useful);
        assert_eq!(merged.useless, whole.useless);
        assert_eq!(merged.contexts, whole.contexts);
        assert_eq!(merged.bytes(), whole.bytes());
        // Permutation invariance of the single-truncation union.
        let perm: Vec<&Telemetry> = vec![&shards[2], &shards[0], &shards[1]];
        let remerged = Telemetry::merged(&perm).unwrap();
        assert_eq!(remerged.hot, merged.hot);
        assert_eq!(remerged.summary_json().dump(), {
            let mut m = merged.clone();
            // Set iteration order is irrelevant to the summary.
            m.exact_srcs = remerged.exact_srcs.clone();
            m.summary_json().dump()
        });
        assert!(Telemetry::merged(&[]).is_none());
    }

    #[test]
    fn summary_json_is_stable_and_carries_the_documented_keys() {
        let t = recorded("compare:w64d2p8k4");
        let a = t.summary_json().dump();
        assert_eq!(a, t.summary_json().dump());
        for key in [
            "\"mode\"",
            "\"bytes\"",
            "\"cms_fill\"",
            "\"contexts_est\"",
            "\"topk\"",
            "\"agreement\"",
            "\"exact_bytes\"",
            "\"feature_mae\"",
        ] {
            assert!(a.contains(key), "summary missing {key}: {a}");
        }
        assert!(a.contains("compare:w64d2p8k4"));
    }
}
