//! Chrome trace-event (Perfetto-compatible) JSON builders. The event
//! objects use only simulated-µs timestamps, so a trace file is a pure
//! function of the run's event order. Mapping (DESIGN.md §11): one
//! *process* per (scenario, service) plus one controller process per
//! scenario, one *thread* per replica, sampled request slices as
//! `"ph":"X"` complete events, controller lever applications as
//! `"ph":"i"` instants.

use crate::util::json::Json;

/// `process_name` metadata event: names the Perfetto track group.
pub fn process_meta(pid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("process_name")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(0.0)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// `thread_name` metadata event: names one replica track.
pub fn thread_meta(pid: u64, tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("thread_name")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// `"ph":"X"` complete slice: `ts`/`dur` in simulated µs.
pub fn slice(
    pid: u64,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
    name: &str,
    args: Vec<(&str, Json)>,
) -> Json {
    Json::obj(vec![
        ("ph", Json::str("X")),
        ("cat", Json::str("request")),
        ("name", Json::str(name)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::num(ts_us)),
        ("dur", Json::num(dur_us)),
        ("args", Json::obj(args)),
    ])
}

/// `"ph":"i"` process-scoped instant (controller lever application).
pub fn instant(pid: u64, tid: u64, ts_us: f64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("i")),
        ("s", Json::str("p")),
        ("cat", Json::str("ctrl")),
        ("name", Json::str(name)),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::num(ts_us)),
    ])
}

/// Wrap the event list in the `{"traceEvents": [...]}` document
/// Perfetto and `chrome://tracing` both accept.
pub fn trace_doc(events: Vec<Json>) -> Json {
    Json::obj(vec![("traceEvents", Json::Arr(events))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_carry_the_trace_event_required_fields() {
        let doc = trace_doc(vec![
            process_meta(3, "scn/svc"),
            thread_meta(3, 1, "replica 0"),
            slice(3, 1, 10.0, 4.5, "req 12", vec![("queue_us", Json::num(2.0))]),
            instant(4, 0, 20.0, "scale +1"),
        ])
        .dump();
        assert!(doc.starts_with("{\"traceEvents\":["));
        assert!(doc.contains("\"ph\":\"M\"") && doc.contains("\"process_name\""));
        assert!(doc.contains("\"ph\":\"X\"") && doc.contains("\"dur\":4.5"));
        assert!(doc.contains("\"ph\":\"i\"") && doc.contains("\"s\":\"p\""));
        // ts values are simulated µs, emitted as plain numbers.
        assert!(doc.contains("\"ts\":10") && doc.contains("\"ts\":20"));
    }
}
