//! Request-span recording for the cluster engine: per-(request,
//! service) timing cells for a hash-sampled subset of requests, folded
//! into per-service critical-path digests at request completion. The
//! recorder is pure bookkeeping over timestamps the engine already
//! computes — it draws no randomness and schedules no events, so an
//! obs-enabled run replays the baseline event order exactly.

use super::ObsCfg;
use crate::util::percentile::Digest;

/// Sentinel for "slot carries no span".
const NONE: u32 = u32::MAX;

/// One sampled request's finished slice on one service (simulated µs).
#[derive(Clone, Debug)]
pub struct TraceSpan {
    /// Request id = global arrival index within the run.
    pub req: u64,
    pub tenant: u8,
    /// Service index (spec order).
    pub svc: u32,
    /// Replica that executed the slice (the Perfetto track).
    pub rep: u32,
    /// When the service became dispatchable (last upstream edge clear).
    pub enqueue_us: f64,
    pub start_us: f64,
    pub end_us: f64,
    /// Queue wait: `start - enqueue`.
    pub queue_us: f64,
    /// Fan-in stall: first upstream completion → dispatchable (0 for
    /// roots and single-parent services).
    pub fanin_us: f64,
    /// Service time added by tenant-interference dilation (0 on the
    /// single-tenant path).
    pub interference_us: f64,
}

/// Per-service percentile decomposition over the sampled spans.
#[derive(Clone, Debug)]
pub struct SpanStat {
    pub service: String,
    /// Sampled slices folded into the digests.
    pub samples: u64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub service_p50_us: f64,
    pub service_p99_us: f64,
    pub fanin_p50_us: f64,
    pub fanin_p99_us: f64,
    pub interference_p50_us: f64,
    pub interference_p99_us: f64,
}

/// One span's per-service timing cell. `NAN` = not yet recorded (and,
/// for `end`, "service not on this request's sub-DAG" at fold time).
#[derive(Clone, Copy, Debug)]
struct Cell {
    first_dep: f64,
    enqueue: f64,
    start: f64,
    end: f64,
    interference_us: f64,
    rep: u32,
}

impl Cell {
    const EMPTY: Cell = Cell {
        first_dep: f64::NAN,
        enqueue: f64::NAN,
        start: f64::NAN,
        end: f64::NAN,
        interference_us: 0.0,
        rep: 0,
    };
}

struct ActiveSpan {
    req: u64,
    tenant: u8,
    cells: Vec<Cell>,
}

/// Engine-facing span recorder. Active spans are recycled through a
/// free list (mirroring the request slab), so the sampled path settles
/// into zero per-request allocation too.
pub struct SpanRecorder {
    cfg: ObsCfg,
    nsvc: usize,
    /// Slab slot → active span index (`NONE` = unsampled).
    slot_span: Vec<u32>,
    spans: Vec<ActiveSpan>,
    free: Vec<u32>,
    /// Finished slices, request-completion order (deterministic).
    pub finished: Vec<TraceSpan>,
    /// Requests that carried a span.
    pub sampled: u64,
    queue_d: Vec<Digest>,
    service_d: Vec<Digest>,
    fanin_d: Vec<Digest>,
    interference_d: Vec<Digest>,
}

impl SpanRecorder {
    pub fn new(cfg: ObsCfg, nsvc: usize) -> SpanRecorder {
        SpanRecorder {
            cfg,
            nsvc,
            slot_span: Vec::new(),
            spans: Vec::new(),
            free: Vec::new(),
            finished: Vec::new(),
            sampled: 0,
            queue_d: (0..nsvc).map(|_| Digest::new()).collect(),
            service_d: (0..nsvc).map(|_| Digest::new()).collect(),
            fanin_d: (0..nsvc).map(|_| Digest::new()).collect(),
            interference_d: (0..nsvc).map(|_| Digest::new()).collect(),
        }
    }

    /// Decide sampling for the request landing in `slot` (`req` is its
    /// global arrival index) and bind a span when it hits.
    pub fn on_arrival(&mut self, slot: u32, req: u64, tenant: u8) {
        let s = slot as usize;
        if self.slot_span.len() <= s {
            self.slot_span.resize(s + 1, NONE);
        }
        if !self.cfg.sampled(req) {
            self.slot_span[s] = NONE;
            return;
        }
        self.sampled += 1;
        let idx = match self.free.pop() {
            Some(i) => {
                let span = &mut self.spans[i as usize];
                span.req = req;
                span.tenant = tenant;
                i
            }
            None => {
                self.spans.push(ActiveSpan {
                    req,
                    tenant,
                    cells: vec![Cell::EMPTY; self.nsvc],
                });
                (self.spans.len() - 1) as u32
            }
        };
        self.slot_span[s] = idx;
    }

    #[inline]
    fn cell(&mut self, slot: u32, svc: u32) -> Option<&mut Cell> {
        let idx = *self.slot_span.get(slot as usize)?;
        if idx == NONE {
            return None;
        }
        Some(&mut self.spans[idx as usize].cells[svc as usize])
    }

    /// An upstream edge into `svc` cleared at `t` (first one wins —
    /// the gap to the *last* one is the fan-in stall).
    #[inline]
    pub fn on_first_dep(&mut self, slot: u32, svc: u32, t: f64) {
        if let Some(c) = self.cell(slot, svc) {
            if c.first_dep.is_nan() {
                c.first_dep = t;
            }
        }
    }

    /// `svc` became dispatchable for the request at `t`.
    #[inline]
    pub fn on_enqueue(&mut self, slot: u32, svc: u32, t: f64) {
        if let Some(c) = self.cell(slot, svc) {
            c.enqueue = t;
        }
    }

    /// `svc` started executing on replica `rep` at `t`;
    /// `interference_us` is the dilation-added service time.
    #[inline]
    pub fn on_start(&mut self, slot: u32, svc: u32, rep: u32, t: f64, interference_us: f64) {
        if let Some(c) = self.cell(slot, svc) {
            c.start = t;
            c.rep = rep;
            c.interference_us = interference_us;
        }
    }

    /// `svc` completed for the request at `t`.
    #[inline]
    pub fn on_end(&mut self, slot: u32, svc: u32, t: f64) {
        if let Some(c) = self.cell(slot, svc) {
            c.end = t;
        }
    }

    /// The request completed: fold its cells into the per-service
    /// digests, emit finished slices, and recycle the span.
    pub fn on_finish(&mut self, slot: u32) {
        let s = slot as usize;
        let idx = match self.slot_span.get(s) {
            Some(&i) if i != NONE => i,
            _ => return,
        };
        self.slot_span[s] = NONE;
        let (req, tenant) = (self.spans[idx as usize].req, self.spans[idx as usize].tenant);
        let mut cells = std::mem::take(&mut self.spans[idx as usize].cells);
        for (svc, c) in cells.iter().enumerate() {
            if c.end.is_nan() {
                continue; // service not on this request's sub-DAG
            }
            let queue = (c.start - c.enqueue).max(0.0);
            let service = (c.end - c.start).max(0.0);
            let fanin =
                if c.first_dep.is_nan() { 0.0 } else { (c.enqueue - c.first_dep).max(0.0) };
            self.queue_d[svc].add(queue);
            self.service_d[svc].add(service);
            self.fanin_d[svc].add(fanin);
            self.interference_d[svc].add(c.interference_us);
            self.finished.push(TraceSpan {
                req,
                tenant,
                svc: svc as u32,
                rep: c.rep,
                enqueue_us: c.enqueue,
                start_us: c.start,
                end_us: c.end,
                queue_us: queue,
                fanin_us: fanin,
                interference_us: c.interference_us,
            });
        }
        cells.fill(Cell::EMPTY);
        self.spans[idx as usize].cells = cells;
        self.free.push(idx);
    }

    /// Per-service critical-path attribution (services with no sampled
    /// slices are skipped).
    pub fn stats(&mut self, services: &[String]) -> Vec<SpanStat> {
        (0..self.nsvc.min(services.len()))
            .filter(|&i| !self.queue_d[i].is_empty())
            .map(|i| SpanStat {
                service: services[i].clone(),
                samples: self.queue_d[i].len() as u64,
                queue_p50_us: self.queue_d[i].percentile(50.0),
                queue_p99_us: self.queue_d[i].percentile(99.0),
                service_p50_us: self.service_d[i].percentile(50.0),
                service_p99_us: self.service_d[i].percentile(99.0),
                fanin_p50_us: self.fanin_d[i].percentile(50.0),
                fanin_p99_us: self.fanin_d[i].percentile(99.0),
                interference_p50_us: self.interference_d[i].percentile(50.0),
                interference_p99_us: self.interference_d[i].percentile(99.0),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_a_pure_function_of_the_request_id() {
        let a = ObsCfg::on(4);
        let b = ObsCfg::on(4);
        let hits: Vec<u64> = (0..10_000).filter(|&r| a.sampled(r)).collect();
        assert_eq!(hits, (0..10_000).filter(|&r| b.sampled(r)).collect::<Vec<_>>());
        // ~1/16 rate, loose bounds.
        assert!(hits.len() > 400 && hits.len() < 900, "{} sampled", hits.len());
        // shift 0 samples everything.
        assert!((0..100).all(|r| ObsCfg::on(0).sampled(r)));
    }

    #[test]
    fn span_lifecycle_decomposes_components() {
        let mut rec = SpanRecorder::new(ObsCfg::on(0), 3);
        rec.on_arrival(0, 7, 1);
        // svc 0: root, runs 10→14 after a 2 µs queue wait.
        rec.on_enqueue(0, 0, 8.0);
        rec.on_start(0, 0, 2, 10.0, 0.5);
        rec.on_end(0, 0, 14.0);
        // svc 2: two parents, first clears at 14, last at 20.
        rec.on_first_dep(0, 2, 14.0);
        rec.on_first_dep(0, 2, 20.0); // later edge must not overwrite
        rec.on_enqueue(0, 2, 20.0);
        rec.on_start(0, 2, 0, 20.0, 0.0);
        rec.on_end(0, 2, 25.0);
        rec.on_finish(0);
        assert_eq!(rec.sampled, 1);
        assert_eq!(rec.finished.len(), 2, "svc 1 never ran — no slice");
        let s0 = &rec.finished[0];
        assert_eq!((s0.svc, s0.rep, s0.tenant, s0.req), (0, 2, 1, 7));
        assert_eq!((s0.queue_us, s0.fanin_us, s0.interference_us), (2.0, 0.0, 0.5));
        let s2 = &rec.finished[1];
        assert_eq!((s2.queue_us, s2.fanin_us), (0.0, 6.0));
        let names = vec!["a".to_string(), "b".into(), "c".into()];
        let stats = rec.stats(&names);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].service, "a");
        assert_eq!(stats[0].service_p50_us, 4.0);
        assert_eq!(stats[1].fanin_p99_us, 6.0);
        // Recycled span must start clean.
        rec.on_arrival(0, 9, 0);
        rec.on_enqueue(0, 1, 1.0);
        rec.on_start(0, 1, 0, 1.0, 0.0);
        rec.on_end(0, 1, 2.0);
        rec.on_finish(0);
        assert_eq!(rec.finished.len(), 3, "only the fresh slice is emitted");
    }

    #[test]
    fn unsampled_slots_record_nothing() {
        let mut rec = SpanRecorder::new(ObsCfg { enabled: true, sample_shift: 63 }, 2);
        for req in 0..64 {
            rec.on_arrival(req, req as u64, 0);
        }
        rec.on_enqueue(3, 0, 1.0);
        rec.on_start(3, 0, 0, 1.0, 0.0);
        rec.on_end(3, 0, 2.0);
        rec.on_finish(3);
        // Whatever was sampled, slot 3's activity only counts if slot 3
        // itself carries a span; a no-op recorder is also valid here.
        assert!(rec.finished.len() <= 1);
    }
}
