//! Leveled diagnostic sink: everything goes to **stderr**, never
//! stdout, so ad-hoc prints can never leak into the byte-compared
//! stdout that CI's determinism job diffs (DESIGN.md §8). The level is
//! a process-wide atomic set once from the CLI (`--quiet` = errors
//! only, `--verbose` = debug); the [`crate::obs_info!`]-family macros
//! check it before formatting, so a suppressed message costs one
//! relaxed load and no allocation.

use std::sync::atomic::{AtomicU8, Ordering};

/// Diagnostic severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Always printed (fatal/argument errors).
    Error = 0,
    /// Degraded-but-continuing conditions (e.g. a frozen controller).
    Warn = 1,
    /// Default chatter: timings, artifact paths.
    Info = 2,
    /// `--verbose` detail.
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-wide log threshold (messages above it are dropped).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether a message at `level` would be emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Write one pre-formatted message to stderr (used by the macros; call
/// sites should go through [`crate::obs_error!`] and friends so the
/// level check precedes formatting).
pub fn emit(args: std::fmt::Arguments<'_>) {
    eprintln!("{args}");
}

/// Log an error-level diagnostic to stderr (never suppressed).
#[macro_export]
macro_rules! obs_error {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit(format_args!($($t)*));
        }
    };
}

/// Log a warning to stderr (suppressed by `--quiet`).
#[macro_export]
macro_rules! obs_warn {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit(format_args!($($t)*));
        }
    };
}

/// Log an info-level diagnostic to stderr (the default level;
/// suppressed by `--quiet`).
#[macro_export]
macro_rules! obs_info {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit(format_args!($($t)*));
        }
    };
}

/// Log a debug-level diagnostic to stderr (printed only under
/// `--verbose`).
#[macro_export]
macro_rules! obs_debug {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit(format_args!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_gate() {
        // NOTE: the level is process-global; restore the default so
        // other tests' expectations hold regardless of ordering.
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug) && enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Warn) && !enabled(Level::Debug));
    }
}
