//! Metrics registry: named counters (monotone), gauges, and
//! fixed-bucket log2 histograms, snapshotted to sorted-key JSON at
//! SLO-window boundaries. Keys live in `BTreeMap`s and histograms have
//! a fixed bucket layout, so a snapshot's serialization is a pure
//! function of the recorded values — the JSONL timeseries built from
//! snapshots is byte-identical across `--threads` values and reruns.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Histogram bucket count: bucket 0 holds values < 1, bucket `i ≥ 1`
/// holds `[2^(i-1), 2^i)`, up to bucket 64 (the full u64 range).
pub const HIST_BUCKETS: usize = 65;

/// Fixed-layout log2 histogram over non-negative values.
#[derive(Clone, Debug, Default)]
pub struct Hist {
    pub count: u64,
    /// Bucket counts, allocated lazily on first observation.
    pub buckets: Vec<u64>,
}

impl Hist {
    /// Bucket index for `v`: 0 for values below 1 (or non-finite),
    /// else `1 + floor(log2(v))`.
    #[inline]
    pub fn bucket_of(v: f64) -> usize {
        if !v.is_finite() || v < 1.0 {
            return 0;
        }
        let u = v as u64;
        (64 - u.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }

    pub fn observe(&mut self, v: f64) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; HIST_BUCKETS];
        }
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
    }

    /// Bucket-wise accumulate another histogram into this one (the
    /// fleet-aggregation primitive — both sides share the fixed
    /// [`HIST_BUCKETS`] layout, so merge is associative and
    /// commutative). Handles the lazy bucket allocation on either side.
    pub fn merge(&mut self, other: &Hist) {
        if other.buckets.is_empty() {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; HIST_BUCKETS];
        }
        for (s, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *s += o;
        }
        self.count += other.count;
    }

    /// `{"count": n, "buckets": [...]}` with trailing zero buckets
    /// trimmed (the layout is fixed, so trimming is deterministic).
    fn to_json(&self) -> Json {
        let last = self.buckets.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            (
                "buckets",
                Json::Arr(self.buckets[..last].iter().map(|&c| Json::num(c as f64)).collect()),
            ),
        ])
    }
}

/// Named counters, gauges, and histograms (insertion is idempotent on
/// the key; values overwrite for counters/gauges, accumulate for
/// histograms).
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
}

impl Registry {
    /// Set a monotone counter to its current absolute value.
    pub fn counter(&mut self, name: &str, v: u64) {
        match self.counters.get_mut(name) {
            Some(slot) => *slot = v,
            None => {
                self.counters.insert(name.to_string(), v);
            }
        }
    }

    /// Set a gauge (point-in-time value; may go up or down).
    pub fn gauge(&mut self, name: &str, v: f64) {
        match self.gauges.get_mut(name) {
            Some(slot) => *slot = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    /// Add one observation to the named log2 histogram.
    pub fn observe(&mut self, name: &str, v: f64) {
        match self.hists.get_mut(name) {
            Some(h) => h.observe(v),
            None => {
                let mut h = Hist::default();
                h.observe(v);
                self.hists.insert(name.to_string(), h);
            }
        }
    }

    /// One snapshot object: `t_us` is simulated time, `window` the
    /// boundary index. Non-finite gauges serialize as `null` so the
    /// output stays valid JSON.
    pub fn snapshot(&self, t_us: f64, window: u64) -> Json {
        let counters =
            self.counters.iter().map(|(k, &v)| (k.clone(), Json::num(v as f64))).collect();
        let gauges = self
            .gauges
            .iter()
            .map(|(k, &v)| (k.clone(), if v.is_finite() { Json::num(v) } else { Json::Null }))
            .collect();
        let hists = self.hists.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        Json::obj(vec![
            ("t_us", Json::num(t_us)),
            ("window", Json::num(window as f64)),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("hists", Json::Obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_buckets_cover_the_ranges() {
        assert_eq!(Hist::bucket_of(0.0), 0);
        assert_eq!(Hist::bucket_of(0.9), 0);
        assert_eq!(Hist::bucket_of(f64::NAN), 0);
        assert_eq!(Hist::bucket_of(1.0), 1);
        assert_eq!(Hist::bucket_of(1.99), 1);
        assert_eq!(Hist::bucket_of(2.0), 2);
        assert_eq!(Hist::bucket_of(3.0), 2);
        assert_eq!(Hist::bucket_of(4.0), 3);
        assert_eq!(Hist::bucket_of(1024.0), 11);
        assert_eq!(Hist::bucket_of(f64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn bucket_edges_lock_powers_of_two_and_extremes() {
        // Exact powers of two open a new bucket; the value just below
        // stays in the previous one.
        for i in 1..=52u32 {
            let v = (1u64 << i) as f64;
            assert_eq!(Hist::bucket_of(v), i as usize + 1, "2^{i}");
            assert_eq!(Hist::bucket_of(v - 0.5), i as usize, "2^{i} - 0.5");
        }
        // Sub-1 and non-finite inputs all land in the underflow bucket.
        for v in [0.0, -1.0, 0.999_999, f64::NEG_INFINITY, f64::INFINITY, f64::NAN] {
            assert_eq!(Hist::bucket_of(v), 0, "{v}");
        }
        // u64::MAX-scale values saturate into the top bucket instead of
        // indexing out of range.
        assert_eq!(Hist::bucket_of(u64::MAX as f64), HIST_BUCKETS - 1);
        assert_eq!(Hist::bucket_of((1u64 << 63) as f64), HIST_BUCKETS - 1);
        assert_eq!(Hist::bucket_of((1u64 << 63) as f64 - 1_000_000.0), HIST_BUCKETS - 2);
    }

    #[test]
    fn merge_adds_bucket_wise_and_respects_lazy_allocation() {
        let mut a = Hist::default();
        let mut b = Hist::default();
        for v in [0.5, 3.0, 1024.0] {
            a.observe(v);
        }
        for v in [3.5, 2.0e18] {
            b.observe(v);
        }
        // Merging an empty histogram is a no-op (no allocation either).
        let empty = Hist::default();
        a.merge(&empty);
        assert_eq!(a.count, 3);
        // Empty absorbs a populated one.
        let mut c = Hist::default();
        c.merge(&a);
        c.merge(&b);
        assert_eq!(c.count, 5);
        assert_eq!(c.buckets[0], 1); // 0.5
        assert_eq!(c.buckets[2], 2); // 3.0, 3.5
        assert_eq!(c.buckets[11], 1); // 1024
        assert_eq!(c.buckets[Hist::bucket_of(2.0e18)], 1);
        // Merge equals observing the union stream.
        let mut whole = Hist::default();
        for v in [0.5, 3.0, 1024.0, 3.5, 2.0e18] {
            whole.observe(v);
        }
        assert_eq!(c.to_json().dump(), whole.to_json().dump());
    }

    #[test]
    fn snapshot_is_sorted_and_stable() {
        let mut r = Registry::default();
        r.counter("events", 10);
        r.gauge("zeta", 1.5);
        r.gauge("alpha", f64::NAN);
        r.observe("lat", 3.0);
        r.observe("lat", 300.0);
        let a = r.snapshot(123.0, 1).dump();
        let b = r.snapshot(123.0, 1).dump();
        assert_eq!(a, b);
        // Sorted keys, NaN as null, histogram carries both observations.
        assert!(a.find("alpha").unwrap() < a.find("zeta").unwrap());
        assert!(a.contains("\"alpha\":null"));
        assert!(a.contains("\"count\":2"));
        // Overwrites, not accumulation, for counters/gauges.
        r.counter("events", 20);
        assert!(r.snapshot(124.0, 2).dump().contains("\"events\":20"));
    }
}
