//! Hand-rolled CLI argument parsing (no clap offline): positional
//! subcommand + `--key value` / `--flag` options.

use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn u64_opt(&self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_opt(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    /// A comma-separated list option: `--policies a,b,c` → `["a", "b",
    /// "c"]` (`None` when absent; blank items are dropped, so trailing
    /// commas are harmless).
    pub fn list_opt(&self, key: &str) -> Option<Vec<String>> {
        self.opt(key).map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
    }

    /// The global `--threads N` knob (0 or absent = available
    /// parallelism), shared by `campaign` and the figure harness.
    pub fn threads(&self) -> Result<usize> {
        let n = self.u64_opt("threads", 0)? as usize;
        Ok(if n == 0 { crate::campaign::runner::default_threads() } else { n })
    }
}

/// Parse a prefetcher spec like `nl`, `eip256`, `ceip128`, `ceip256s`
/// (selective), `cheip2k`, `cheip4k`, `perfect`, `ceip256w12`.
pub fn parse_prefetcher(spec: &str) -> Result<crate::config::PrefetcherKind> {
    use crate::config::PrefetcherKind as P;
    let s = spec.to_lowercase();
    if s == "nl" {
        return Ok(P::NextLineOnly);
    }
    if s == "perfect" {
        return Ok(P::Perfect);
    }
    let (body, selective) = match s.strip_suffix('s') {
        Some(b) if b != "nl" => (b.to_string(), true),
        _ => (s.clone(), false),
    };
    // Selective mode only exists for the windowed compressed variants;
    // `eip256s` used to fall through and silently parse as plain EIP.
    if selective && body.starts_with("eip") {
        bail!("eip has no selective mode: '{spec}' (did you mean ceip{}s?)", &body[3..]);
    }
    let window_split = |b: &str| -> (String, u8) {
        if let Some((head, w)) = b.rsplit_once('w') {
            if let Ok(win) = w.parse::<u8>() {
                return (head.to_string(), win);
            }
        }
        (b.to_string(), 8)
    };
    if let Some(rest) = body.strip_prefix("eip") {
        let sets: u32 = rest.parse().map_err(|_| anyhow::anyhow!("bad eip spec '{spec}'"))?;
        return Ok(P::Eip { entries: sets * 16 });
    }
    if let Some(rest) = body.strip_prefix("ceip") {
        let (head, window) = window_split(rest);
        let sets: u32 = head.parse().map_err(|_| anyhow::anyhow!("bad ceip spec '{spec}'"))?;
        return Ok(P::Ceip {
            entries: sets * 16,
            window,
            whole_window: !selective,
        });
    }
    if let Some(rest) = body.strip_prefix("cheip") {
        let (head, window) = window_split(rest);
        let vt = match head.as_str() {
            "2k" => 2048,
            "4k" => 4096,
            other => other
                .parse()
                .map_err(|_| anyhow::anyhow!("bad cheip spec '{spec}'"))?,
        };
        return Ok(P::Cheip {
            vt_entries: vt,
            window,
            whole_window: !selective,
        });
    }
    bail!("unknown prefetcher spec '{spec}' (try nl|eip256|ceip256|cheip2k|perfect)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefetcherKind as P;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = args("figure 9 --records 1000 --seed=42 --quiet");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positional, vec!["9"]);
        assert_eq!(a.u64_opt("records", 0).unwrap(), 1000);
        assert_eq!(a.u64_opt("seed", 0).unwrap(), 42);
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.u64_opt("missing", 7).unwrap(), 7);
    }

    #[test]
    fn list_opt_splits_on_commas() {
        let a = args("cluster --policies reactive,hysteresis:4:0.7,cost-aware");
        assert_eq!(
            a.list_opt("policies").unwrap(),
            vec!["reactive", "hysteresis:4:0.7", "cost-aware"]
        );
        assert_eq!(a.list_opt("missing"), None);
        // Blank items (trailing/double commas) are dropped.
        let b = args("cluster --policies reactive,,hysteresis,");
        assert_eq!(b.list_opt("policies").unwrap(), vec!["reactive", "hysteresis"]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = args("simulate --records abc");
        assert!(a.u64_opt("records", 0).is_err());
    }

    #[test]
    fn threads_defaults_to_available_parallelism() {
        assert_eq!(args("campaign --threads 3").threads().unwrap(), 3);
        let auto = args("campaign").threads().unwrap();
        assert!(auto >= 1);
        assert_eq!(args("campaign --threads 0").threads().unwrap(), auto);
        assert!(args("campaign --threads x").threads().is_err());
    }

    #[test]
    fn prefetcher_specs() {
        assert_eq!(parse_prefetcher("nl").unwrap(), P::NextLineOnly);
        assert_eq!(parse_prefetcher("perfect").unwrap(), P::Perfect);
        assert_eq!(parse_prefetcher("eip256").unwrap(), P::Eip { entries: 4096 });
        assert_eq!(
            parse_prefetcher("ceip128").unwrap(),
            P::Ceip { entries: 2048, window: 8, whole_window: true }
        );
        assert_eq!(
            parse_prefetcher("ceip256s").unwrap(),
            P::Ceip { entries: 4096, window: 8, whole_window: false }
        );
        assert_eq!(
            parse_prefetcher("ceip256w12").unwrap(),
            P::Ceip { entries: 4096, window: 12, whole_window: true }
        );
        assert_eq!(
            parse_prefetcher("cheip2k").unwrap(),
            P::Cheip { vt_entries: 2048, window: 8, whole_window: true }
        );
        assert_eq!(
            parse_prefetcher("cheip4kw4").unwrap(),
            P::Cheip { vt_entries: 4096, window: 4, whole_window: true }
        );
        assert!(parse_prefetcher("bogus").is_err());
    }

    #[test]
    fn eip_selective_is_rejected_not_silently_accepted() {
        // `eip256s` used to fall through and parse as plain EIP-256.
        let err = parse_prefetcher("eip256s").unwrap_err().to_string();
        assert!(err.contains("no selective mode"), "unhelpful error: {err}");
        assert!(err.contains("ceip256s"), "no suggestion in: {err}");
        assert!(parse_prefetcher("eip128s").is_err());
    }

    #[test]
    fn empty_head_window_specs_are_errors() {
        // `ceipw8` has an empty set count before the window suffix.
        assert!(parse_prefetcher("ceipw8").is_err());
        assert!(parse_prefetcher("cheipw8").is_err());
        assert!(parse_prefetcher("ceip").is_err());
        assert!(parse_prefetcher("eip").is_err());
    }

    #[test]
    fn specs_are_case_insensitive() {
        assert_eq!(parse_prefetcher("NL").unwrap(), P::NextLineOnly);
        assert_eq!(parse_prefetcher("Perfect").unwrap(), P::Perfect);
        assert_eq!(
            parse_prefetcher("CEIP256S").unwrap(),
            parse_prefetcher("ceip256s").unwrap()
        );
        assert_eq!(
            parse_prefetcher("ChEiP2K").unwrap(),
            parse_prefetcher("cheip2k").unwrap()
        );
        assert!(parse_prefetcher("EIP256S").is_err(), "case must not bypass the eip-s check");
    }

    #[test]
    fn option_values_may_start_with_a_single_dash() {
        // `--churn-scale -1` must reach the domain validator (which
        // rejects negatives with its own message), not be eaten as a flag.
        let a = args("campaign --churn-scale -1 --records 10");
        assert_eq!(a.opt("churn-scale"), Some("-1"));
        assert_eq!(a.f64_opt("churn-scale", 1.0).unwrap(), -1.0);
        assert_eq!(a.u64_opt("records", 0).unwrap(), 10);
        // A `--`-prefixed token is never consumed as a value: the first
        // option becomes a flag and the second parses independently.
        let b = args("campaign --out --threads 3");
        assert!(b.flag("out"));
        assert_eq!(b.opt("out"), None);
        assert_eq!(b.threads().unwrap(), 3);
    }
}
