//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and executes them on the XLA PJRT CPU client.
//! This is the only place the Rust coordinator touches the JAX/Pallas
//! layers — Python is never on the request path.
//!
//! Interchange is HLO *text* (the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos with 64-bit instruction ids; the text parser
//! reassigns ids — see DESIGN.md and /opt/xla-example).

mod engine;

pub use engine::{artifacts_dir, PjrtEngine};
