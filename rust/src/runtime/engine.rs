//! The PJRT execution engine for the controller's AOT modules.

use crate::ml::features::DIM;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// AOT contract (must agree with `python/compile/aot.py`; verified against
/// the manifest at load time).
pub const AOT_BATCH: usize = 256;
pub const BANDIT_SLOTS: usize = 64;

/// Locate the artifacts directory: `$SLOFETCH_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (tests run from the crate root).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SLOFETCH_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

pub struct PjrtEngine {
    client: xla::PjRtClient,
    score_exe: xla::PjRtLoadedExecutable,
    train_exe: xla::PjRtLoadedExecutable,
    bandit_exe: xla::PjRtLoadedExecutable,
    /// Executions performed (diagnostics / §Perf accounting).
    pub executions: std::cell::Cell<u64>,
}

impl PjrtEngine {
    /// Load and compile all three modules from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let manifest = Json::parse(&manifest_text).context("parsing manifest.json")?;
        let batch = manifest.get("batch").and_then(Json::as_u64).context("manifest.batch")?;
        let feats = manifest
            .get("features")
            .and_then(Json::as_u64)
            .context("manifest.features")?;
        let slots = manifest
            .get("bandit_slots")
            .and_then(Json::as_u64)
            .context("manifest.bandit_slots")?;
        if batch as usize != AOT_BATCH || feats as usize != DIM || slots as usize != BANDIT_SLOTS {
            bail!(
                "AOT contract mismatch: manifest says batch={batch} features={feats} slots={slots}, \
                 runtime expects {AOT_BATCH}/{DIM}/{BANDIT_SLOTS} — re-run `make artifacts`"
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {name}"))
        };
        Ok(PjrtEngine {
            score_exe: load("score")?,
            train_exe: load("train")?,
            bandit_exe: load("bandit")?,
            client,
            executions: std::cell::Cell::new(0),
        })
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn bump(&self) {
        self.executions.set(self.executions.get() + 1);
    }

    /// Score a feature batch. `x` is row-major `[AOT_BATCH, DIM]`; shorter
    /// batches are zero-padded (padded scores are returned but meaningless
    /// — callers slice to their real length).
    pub fn score(&self, w: &[f32; DIM], b: f32, x: &[f32]) -> Result<Vec<f32>> {
        let rows = x.len() / DIM;
        if x.len() % DIM != 0 || rows > AOT_BATCH {
            bail!("score: bad batch shape ({} values)", x.len());
        }
        let mut padded = x.to_vec();
        padded.resize(AOT_BATCH * DIM, 0.0);
        let lw = xla::Literal::vec1(&w[..]);
        let lb = xla::Literal::scalar(b);
        let lx = xla::Literal::vec1(&padded).reshape(&[AOT_BATCH as i64, DIM as i64])?;
        self.bump();
        let result = self.score_exe.execute::<xla::Literal>(&[lw, lb, lx])?[0][0]
            .to_literal_sync()?;
        let p = result.to_tuple1()?;
        let mut v = p.to_vec::<f32>()?;
        v.truncate(rows);
        Ok(v)
    }

    /// One SGD step on a full AOT batch. Returns (w', b', loss).
    pub fn train_step(
        &self,
        w: &[f32; DIM],
        b: f32,
        x: &[f32],
        y: &[f32],
        lr: f32,
    ) -> Result<([f32; DIM], f32, f32)> {
        if x.len() != AOT_BATCH * DIM || y.len() != AOT_BATCH {
            bail!(
                "train_step requires a full batch ({} x {DIM}), got {}/{}",
                AOT_BATCH,
                x.len(),
                y.len()
            );
        }
        let lw = xla::Literal::vec1(&w[..]);
        let lb = xla::Literal::scalar(b);
        let lx = xla::Literal::vec1(x).reshape(&[AOT_BATCH as i64, DIM as i64])?;
        let ly = xla::Literal::vec1(y);
        let llr = xla::Literal::scalar(lr);
        self.bump();
        let result = self
            .train_exe
            .execute::<xla::Literal>(&[lw, lb, lx, ly, llr])?[0][0]
            .to_literal_sync()?;
        let (nw, nb, loss) = result.to_tuple3()?;
        let nw_v = nw.to_vec::<f32>()?;
        let mut w_out = [0.0f32; DIM];
        w_out.copy_from_slice(&nw_v);
        Ok((
            w_out,
            nb.to_vec::<f32>()?[0],
            loss.to_vec::<f32>()?[0],
        ))
    }

    /// Bandit value-table update: v' = v + lr * onehot * (r - v).
    pub fn bandit_update(
        &self,
        values: &[f32; BANDIT_SLOTS],
        slot: usize,
        reward: f32,
        lr: f32,
    ) -> Result<[f32; BANDIT_SLOTS]> {
        if slot >= BANDIT_SLOTS {
            bail!("bandit slot {slot} out of range");
        }
        let mut onehot = [0.0f32; BANDIT_SLOTS];
        onehot[slot] = 1.0;
        let lv = xla::Literal::vec1(&values[..]);
        let lo = xla::Literal::vec1(&onehot[..]);
        let lr_ = xla::Literal::scalar(lr);
        let lrw = xla::Literal::scalar(reward);
        self.bump();
        let result = self
            .bandit_exe
            .execute::<xla::Literal>(&[lv, lo, lrw, lr_])?[0][0]
            .to_literal_sync()?;
        let v = result.to_tuple1()?.to_vec::<f32>()?;
        let mut out = [0.0f32; BANDIT_SLOTS];
        out.copy_from_slice(&v);
        Ok(out)
    }
}

// Unit tests requiring artifacts live in rust/tests/integration_runtime.rs
// (they need `make artifacts` to have run). Here only the pure helpers.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("SLOFETCH_ARTIFACTS", "/tmp/custom_artifacts");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/custom_artifacts"));
        std::env::remove_var("SLOFETCH_ARTIFACTS");
    }

    #[test]
    fn load_missing_dir_fails_with_hint() {
        let err = match PjrtEngine::load(Path::new("/nonexistent/artifacts")) {
            Err(e) => e,
            Ok(_) => panic!("load must fail for a missing directory"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
