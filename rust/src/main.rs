//! `slofetch` — launcher for the SLOFetch reproduction.
//!
//! ```text
//! slofetch figure <1|2|...|13|table1|summary|rpc|ablation|all> [--records N] [--seed S] [--out DIR] [--threads N]
//! slofetch campaign --spec FILE [--threads N] [--out results.store] [--store-format jsonl|tiered]
//! slofetch campaign compact [--out results.store]
//! slofetch cluster --spec FILE [--threads N] [--policies reactive,hysteresis,...]
//!                  [--service-times analytic|empirical] [--trace FILE.slft]
//!                  [--tenants on|off] [--faults on|off] [--telemetry MODE]
//!                  [--scheduler heap|calendar] [--obs] [--obs-sample SHIFT]
//!                  [--trace-out FILE.json] [--metrics-out FILE.jsonl]
//! slofetch simulate --app websearch --prefetcher ceip256 [--records N] [--ml] [--budget N]
//!                   [--telemetry MODE]
//! slofetch gen-trace --app websearch --records N --out trace.slft
//! slofetch deploy --app admission --candidate cheip2k [--records N]
//! slofetch apps
//! slofetch runtime-check
//! ```

use anyhow::{bail, Context, Result};
use slofetch::campaign::{self, CampaignSpec, ResultStore, StoreFormat};
use slofetch::cli::{parse_prefetcher, Args};
use slofetch::config::{ControllerCfg, SimConfig};
use slofetch::coordinator::deploy::DeploymentManager;
use slofetch::figures::{self, FigureCtx};
use slofetch::ml::controller::{Backend, OnlineController};
use slofetch::obs::log::{set_level, Level};
use slofetch::obs::ObsCfg;
use slofetch::runtime::PjrtEngine;
use slofetch::sim::engine::Engine;
use slofetch::trace::gen::{self, apps};
use slofetch::trace::{codec, stats as trace_stats};
use slofetch::{obs_error, obs_info};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            obs_error!("argument error: {e}");
            std::process::exit(2);
        }
    };
    // Diagnostics are leveled and go to stderr only (DESIGN.md §11):
    // stdout stays the byte-compared determinism surface.
    if args.flag("quiet") {
        set_level(Level::Error);
    } else if args.flag("verbose") {
        set_level(Level::Debug);
    }
    if let Err(e) = dispatch(&args) {
        obs_error!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("figure") => cmd_figure(args),
        Some("campaign") => cmd_campaign(args),
        Some("cluster") => cmd_cluster(args),
        Some("simulate") => cmd_simulate(args),
        Some("gen-trace") => cmd_gen_trace(args),
        Some("deploy") => cmd_deploy(args),
        Some("apps") => cmd_apps(),
        Some("runtime-check") => cmd_runtime_check(),
        Some(other) => bail!("unknown subcommand '{other}'\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage:
  slofetch figure <1..13|table1|summary|rpc|ablation|all> [--records N] [--seed S] [--out DIR] [--threads N]
  slofetch campaign --spec FILE [--threads N] [--out results.store] [--store-format jsonl|tiered]
  slofetch campaign compact [--out results.store]
  slofetch cluster --spec FILE [--threads N] [--policies reactive,hysteresis,predictive,cost-aware]
                   [--service-times analytic|empirical] [--trace FILE.slft] [--tenants on|off]
                   [--faults on|off] [--telemetry MODE] [--scheduler heap|calendar]
                   [--obs] [--obs-sample SHIFT] [--trace-out FILE.json] [--metrics-out FILE.jsonl]
  slofetch simulate --app A --prefetcher P [--records N] [--ml] [--adapt-window] [--budget N] [--pjrt]
                    [--telemetry MODE]
  slofetch gen-trace --app A --records N --out FILE
  slofetch deploy --app A --candidate P [--records N]
  slofetch apps
  slofetch runtime-check

global options:
  --threads N   worker threads for matrix/campaign runs (default: available parallelism)
  --quiet       suppress stderr diagnostics below error level
  --verbose     enable debug-level stderr diagnostics

cluster observability (DESIGN.md §11):
  --obs               record request spans + windowed metrics (implied by --trace-out/--metrics-out)
  --obs-sample SHIFT  span-sample 1 in 2^SHIFT requests (default 6)
  --trace-out FILE    write a Perfetto-compatible trace (open at https://ui.perfetto.dev)
  --metrics-out FILE  write the SLO-window metrics timeseries as JSONL

campaign store (DESIGN.md §6):
  --store-format F    tiered (default) = a directory holding a write-ahead tail plus immutable
                      bloom-indexed segment files (fast resume probes, footer-only cold opens);
                      jsonl = the legacy single-file log. Opening a legacy .jsonl file in tiered
                      mode imports it in place; resumed cells and report bytes are unchanged.
  compact             merge a tiered store's segments into one, dropping superseded duplicates

sketch telemetry (DESIGN.md §12):
  --telemetry MODE    exact (default) | sketch[:GEOM] | compare[:GEOM] — bounded-memory streaming
                      summaries per simulation; GEOM = w<width>d<depth>p<hll_p>k<topk>, default
                      w256d4p10k16 (≈13.5 KB). 'sketch' feeds the ML controller from the sketches;
                      'compare' keeps exact decisions and measures sketch agreement";

fn figure_ctx(args: &Args) -> Result<FigureCtx> {
    let mut ctx = FigureCtx {
        records_per_app: args.u64_opt("records", 600_000)?,
        seed: args.u64_opt("seed", 7)?,
        parallelism: args.threads()?,
        ..Default::default()
    };
    if let Some(out) = args.opt("out") {
        ctx.out_dir = Some(out.into());
    }
    Ok(ctx)
}

fn cmd_figure(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let ctx = figure_ctx(args)?;
    if which == "all" {
        for t in figures::all(ctx)? {
            println!("{}", t.markdown());
        }
        return Ok(());
    }
    // Single figure: schematics and table1 don't need the matrix.
    let table = match which {
        "table1" => figures::table1(),
        "3" => figures::schematics::fig3(),
        "4" => figures::schematics::fig4(),
        "5" => figures::schematics::fig5(),
        "ablation" => figures::ablation(&ctx),
        _ => {
            let m = figures::Matrix::compute(ctx.clone());
            match which {
                "1" => figures::fig1(&m),
                "2" => figures::fig2(&m),
                "6" => figures::fig6(&m),
                "7" => figures::fig7(&m),
                "8" => figures::fig8(&m),
                "9" => figures::fig9(&m),
                "10" => figures::fig10(&m),
                "11" => figures::fig11(&m),
                "12" => figures::fig12(&m),
                "13" => figures::fig13(&m),
                "summary" => figures::summary(&m),
                "rpc" => figures::rpc_tails(&m),
                other => bail!("unknown figure '{other}'"),
            }
        }
    };
    println!("{}", table.markdown());
    if let Some(dir) = &ctx.out_dir {
        table.save(dir)?;
        println!("(saved to {}/{}.md)", dir.display(), table.id);
    }
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    let format = StoreFormat::parse(args.opt("store-format").unwrap_or("tiered"))?;
    let out = args.opt("out").unwrap_or(match format {
        StoreFormat::Tiered => "results.store",
        StoreFormat::Jsonl => "results.jsonl",
    });
    match args.positional.first().map(|s| s.as_str()) {
        Some("compact") => return cmd_campaign_compact(std::path::Path::new(out), format),
        Some(other) => bail!("unknown campaign action '{other}' (expected 'compact')\n{USAGE}"),
        None => {}
    }
    let spec_path = args.opt("spec").context("--spec FILE required")?;
    let spec = CampaignSpec::load(std::path::Path::new(spec_path))?;
    let threads = args.threads()?;
    let mut store = ResultStore::open_format(std::path::Path::new(out), format)?;
    let t0 = std::time::Instant::now();
    let outcome = campaign::run_to_store(&spec, threads, &mut store)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "campaign '{}': {} cells ({} computed, {} resumed) in {:.1}s \
         ({:.2} cells/s, {} threads) -> {out}",
        spec.name,
        outcome.total,
        outcome.computed,
        outcome.skipped,
        secs,
        outcome.computed as f64 / secs.max(1e-9),
        threads,
    );
    for t in campaign::report::reports(&store) {
        println!("{}", t.markdown());
    }
    // Campaigns never pay a surprise compaction mid-run; the WAL tail
    // is folded into a segment here, at the natural quiesce point.
    store.flush()?;
    Ok(())
}

/// `slofetch campaign compact`: explicit foreground segment merge
/// (DESIGN.md §6). Timing goes to stderr; the stats line is stdout.
fn cmd_campaign_compact(path: &std::path::Path, format: StoreFormat) -> Result<()> {
    if format == StoreFormat::Jsonl {
        bail!("compact requires a tiered store (--store-format tiered)");
    }
    let mut store = ResultStore::open_format(path, StoreFormat::Tiered)?;
    let t0 = std::time::Instant::now();
    let stats = store.compact()?;
    obs_info!("compacted {path:?} in {:.2}s", t0.elapsed().as_secs_f64());
    println!(
        "compacted {}: {} -> {} segments, {} records ({} superseded dropped)",
        path.display(),
        stats.segments_before,
        stats.segments_after,
        stats.records,
        stats.dropped,
    );
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let spec_path = args.opt("spec").context("--spec FILE required")?;
    let mut spec = slofetch::cluster::ClusterSpec::load(std::path::Path::new(spec_path))?;
    // `--policies a,b,c` overrides the spec's autoscaler scenarios
    // (replacing a legacy `adaptive` flag too); re-validated so a typo
    // fails before any simulation runs.
    if let Some(policies) = args.list_opt("policies") {
        spec.adaptive = false;
        spec.policies = policies;
    }
    // `--service-times empirical` switches every scenario to
    // trace-replayed per-request service times (DESIGN.md §8);
    // `--trace FILE.slft` additionally replays that file for *every*
    // service (and implies empirical mode).
    if let Some(model) = args.opt("service-times") {
        spec.service_times = model.to_string();
    }
    if let Some(trace) = args.opt("trace") {
        // Contradictory flags are an error, not a silent override: the
        // user who explicitly asked for the analytic model must not get
        // a trace-replayed run.
        if matches!(args.opt("service-times"), Some(m) if m != "empirical") {
            anyhow::bail!(
                "--trace replays service times from {trace} and requires \
                 --service-times empirical (got '{}')",
                args.opt("service-times").unwrap_or_default()
            );
        }
        spec.service_times = "empirical".into();
        for s in &mut spec.topology.services {
            s.trace = Some(trace.to_string());
        }
    }
    // `--tenants off` strips the tenant section — the single-tenant
    // baseline of the same spec file; `--tenants on` asserts the spec
    // actually declares tenants (catching a stale spec path).
    if let Some(mode) = args.opt("tenants") {
        match mode {
            "off" => spec.tenants.clear(),
            "on" => {
                if spec.tenants.is_empty() {
                    bail!("--tenants on: spec '{spec_path}' declares no tenants");
                }
            }
            other => bail!("--tenants expects on|off, got '{other}'"),
        }
    }
    // `--faults off` strips the fault section — the healthy baseline of
    // the same spec file, byte-identical to a spec without faults;
    // `--faults on` asserts the spec actually declares faults (catching
    // a stale spec path), mirroring `--tenants`.
    if let Some(mode) = args.opt("faults") {
        match mode {
            "off" => spec.faults = Default::default(),
            "on" => {
                if spec.faults.is_empty() {
                    bail!("--faults on: spec '{spec_path}' declares no faults");
                }
            }
            other => bail!("--faults expects on|off, got '{other}'"),
        }
    }
    // `--telemetry sketch[:GEOM]` / `compare[:GEOM]` turns on sketch
    // telemetry in the measurement cells (DESIGN.md §12) — the knob is
    // validated with the rest of the spec below.
    if let Some(knob) = args.opt("telemetry") {
        spec.telemetry = knob.to_string();
    }
    // `--scheduler heap|calendar` picks the event-queue backend
    // (DESIGN.md §13). Both produce byte-identical stdout; `heap` is
    // the cross-check oracle for the default calendar queue.
    if let Some(knob) = args.opt("scheduler") {
        spec.scheduler = knob.to_string();
    }
    spec.validate()?;
    let threads = args.threads()?;
    // Observability is opt-in: an explicit `--obs`, or implied by
    // asking for either artifact. Off is the byte-identical baseline.
    let trace_out = args.opt("trace-out");
    let metrics_out = args.opt("metrics-out");
    let obs = if args.flag("obs") || trace_out.is_some() || metrics_out.is_some() {
        ObsCfg::on(args.u64_opt("obs-sample", slofetch::obs::DEFAULT_SAMPLE_SHIFT as u64)? as u32)
    } else {
        ObsCfg::off()
    };
    let t0 = std::time::Instant::now();
    let out = slofetch::cluster::run_spec_obs(&spec, threads, &obs)?;
    // Timing goes to stderr: stdout is byte-identical across reruns and
    // thread counts (the determinism contract, DESIGN.md §8).
    obs_info!(
        "cluster '{}': {} scenarios in {:.1}s ({:.1}M events/s, {threads} threads)",
        spec.name,
        out.scenarios.len(),
        t0.elapsed().as_secs_f64(),
        out.total_events as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e6,
    );
    println!("{}", slofetch::cluster::report(&out).markdown());
    if let Some(t) = slofetch::cluster::model_report(&out) {
        println!("{}", t.markdown());
    }
    if let Some(t) = slofetch::cluster::tenant_report(&out) {
        println!("{}", t.markdown());
    }
    if let Some(t) = slofetch::cluster::action_report(&out) {
        println!("{}", t.markdown());
    }
    if let Some(t) = slofetch::cluster::fault_report(&out) {
        println!("{}", t.markdown());
    }
    if let Some(t) = slofetch::cluster::critical_path_report(&out) {
        println!("{}", t.markdown());
    }
    if let Some(t) = slofetch::cluster::fleet_report(&out) {
        println!("{}", t.markdown());
    }
    if let Some(t) = slofetch::cluster::fleet_topk_report(&out) {
        println!("{}", t.markdown());
    }
    if let Some(path) = trace_out {
        std::fs::write(path, slofetch::cluster::trace_json(&out).dump())
            .with_context(|| format!("writing trace to {path}"))?;
        obs_info!("wrote trace to {path} (open at https://ui.perfetto.dev)");
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, slofetch::cluster::metrics_jsonl(&out))
            .with_context(|| format!("writing metrics timeseries to {path}"))?;
        obs_info!("wrote metrics timeseries to {path}");
    }
    println!(
        "cluster '{}': {} scenarios, {} requests, {} events, {} IPC cells, SLO {:.2} µs",
        spec.name,
        out.scenarios.len(),
        out.total_requests,
        out.total_events,
        out.ipc_cells,
        out.slo_us,
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let app_name = args.opt("app").context("--app required")?;
    let spec = apps::app(app_name)
        .with_context(|| format!("unknown app '{app_name}' (see `slofetch apps`)"))?;
    let kind = parse_prefetcher(args.opt("prefetcher").unwrap_or("ceip256"))?;
    let records_n = args.u64_opt("records", 600_000)?;
    let seed = args.u64_opt("seed", 7)?;
    let mut cfg = SimConfig {
        prefetcher: kind,
        seed,
        ..Default::default()
    };
    if let Some(knob) = args.opt("telemetry") {
        slofetch::obs::telemetry::TelemetryCfg::parse(knob)
            .with_context(|| format!("--telemetry {knob}"))?;
        cfg.telemetry = knob.to_string();
    }
    if args.flag("ml") || args.opt("budget").is_some() || args.flag("adapt-window") {
        cfg.controller = Some(ControllerCfg {
            adapt_window: args.flag("adapt-window"),
            issue_budget_per_kcycle: args.u64_opt("budget", 0)? as u32,
            ..Default::default()
        });
    }
    let records = gen::generate_records(&spec, seed, records_n);
    let ts = trace_stats::analyze(&records);
    println!(
        "app={app_name} records={} unique-I-lines={} seq={:.2} fit20={:.2}",
        records.len(),
        ts.unique_ilines,
        ts.seq_frac,
        ts.fit20_frac
    );

    let mut engine = Engine::new(cfg.clone(), &records);
    // `--pjrt` routes controller training through the AOT artifacts.
    if args.flag("pjrt") {
        let ctrl_cfg = cfg.controller.clone().unwrap_or_default();
        let pjrt = PjrtEngine::load_default().context("loading AOT artifacts")?;
        println!("pjrt platform: {}", pjrt.platform());
        engine = engine.with_controller(OnlineController::with_backend(
            ctrl_cfg,
            seed,
            Backend::Pjrt(pjrt),
        ));
    }
    let r = engine.run();
    println!(
        "label={} ipc={:.4} mpki={:.2} accuracy={:.3} coverage={:.3} timeliness={:.3}",
        r.label,
        r.ipc(),
        r.stats.mpki(),
        r.stats.accuracy(),
        r.stats.coverage(),
        r.stats.timeliness()
    );
    println!(
        "issued={} timely={} late={} useless={} pollution={} skipped={} metadata={}",
        r.stats.pf_issued,
        r.stats.pf_timely,
        r.stats.pf_late,
        r.stats.pf_useless,
        r.stats.pollution_misses,
        r.stats.pf_skipped,
        figures::report::kb(r.metadata_bytes),
    );
    if let Some(t) = &r.telemetry {
        println!("telemetry: {}", t.summary_json().dump());
    }
    if let Some(cs) = r.controller {
        println!(
            "controller: decisions={} issued={} skipped={} trains={} last_loss={:.4} backend={}",
            cs.decisions,
            cs.issued,
            cs.skipped,
            cs.trains,
            cs.last_loss,
            if args.flag("pjrt") { "pjrt" } else { "native" },
        );
    }
    let f = r.stats.topdown.fractions();
    println!(
        "topdown: retiring={:.1}% frontend={:.1}% backend={:.1}% badspec={:.1}%",
        f[0] * 100.0,
        f[1] * 100.0,
        f[2] * 100.0,
        f[3] * 100.0
    );
    Ok(())
}

fn cmd_gen_trace(args: &Args) -> Result<()> {
    let app_name = args.opt("app").context("--app required")?;
    let spec = apps::app(app_name).with_context(|| format!("unknown app '{app_name}'"))?;
    let records_n = args.u64_opt("records", 1_000_000)?;
    let seed = args.u64_opt("seed", 7)?;
    let out = args.opt("out").context("--out required")?;
    let (meta, records, _) = gen::generate(&spec, seed, records_n);
    codec::write_trace_file(std::path::Path::new(out), &meta, &records)?;
    let bytes = std::fs::metadata(out)?.len();
    println!(
        "wrote {} records to {out} ({:.1} MB, {:.2} B/record)",
        records.len(),
        bytes as f64 / 1e6,
        bytes as f64 / records.len() as f64
    );
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let app_name = args.opt("app").unwrap_or("admission");
    let spec = apps::app(app_name).with_context(|| format!("unknown app '{app_name}'"))?;
    let candidate = parse_prefetcher(args.opt("candidate").unwrap_or("cheip2k"))?;
    let records_n = args.u64_opt("records", 500_000)?;
    let records = gen::generate_records(&spec, args.u64_opt("seed", 3)?, records_n);
    let control = SimConfig::default();
    let cand_cfg = SimConfig {
        prefetcher: candidate,
        controller: Some(ControllerCfg {
            train_interval_cycles: 200_000,
            ..Default::default()
        }),
        ..Default::default()
    };
    let dm = DeploymentManager::new(control, cand_cfg);
    let out = dm.run(&records);
    for r in &out.reports {
        println!("[{:?}] {}", r.stage, r.detail);
    }
    println!("final stage: {:?}", out.final_stage);
    Ok(())
}

fn cmd_apps() -> Result<()> {
    println!("{:<18} {:<6} {:>9} {:>8}", "app", "rt", "churn", "handlers");
    for a in apps::all_apps() {
        println!(
            "{:<18} {:<6} {:>9} {:>8}",
            a.name,
            format!("{:?}", a.runtime),
            a.churn_period,
            a.layout.handler_types
        );
    }
    Ok(())
}

fn cmd_runtime_check() -> Result<()> {
    let engine = PjrtEngine::load_default().context("loading AOT artifacts")?;
    println!("platform: {}", engine.platform());
    // Parity spot-check against the native mirror.
    let weights = slofetch::ml::logistic::Weights::default();
    let x: Vec<f32> = (0..16 * 4).map(|i| (i as f32 * 0.37).sin()).collect();
    let pjrt = engine.score(&weights.w, weights.b, &x)?;
    let native = weights.score_batch(&x);
    let max_err = pjrt
        .iter()
        .zip(&native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("score parity (pjrt vs native mirror): max |delta| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-5, "parity failure");
    println!("runtime OK");
    Ok(())
}
