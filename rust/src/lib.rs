//! # SLOFetch
//!
//! Full reproduction of *SLOFetch: Compressed-Hierarchical Instruction
//! Prefetching for Cloud Microservices* (2025): the CEIP compressed
//! 36-bit entangling entry, the CHEIP hierarchical metadata store, the
//! online ML issue controller (logistic scorer + contextual bandit), and
//! every substrate the evaluation depends on — a ZSim-like trace-driven
//! cache/timing simulator, a synthetic microservice trace generator, the
//! EIP/next-line/perfect baselines, an RPC tail-latency layer, a
//! discrete-event microservice-cluster simulator (request DAGs, traffic
//! shapes, SLO control loop), and the SLO-driven deployment coordinator.
//!
//! Architecture (see DESIGN.md): Layer 3 is this Rust crate; Layer 2/1 are
//! JAX/Pallas controller kernels AOT-lowered to HLO at build time and
//! executed from [`runtime`] via the PJRT CPU client. Python is never on
//! the request path.

pub mod campaign;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod ml;
pub mod obs;
pub mod prefetch;
pub mod rpc;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
