//! Microservice RPC tail-latency layer (paper §I, §VI, §XI): turns per-core
//! IPC from the cache simulator into end-to-end P50/P95/P99 request
//! latency through a queueing model of a service chain.
//!
//! This is the substitution for the paper's production-fleet measurements
//! (DESIGN.md): queueing amplification of service-time variance is exactly
//! the mechanism by which frontend stalls inflate tails, and that is what
//! we model — each node is a FCFS single-server queue whose service time
//! is `instructions-per-request / (IPC × frequency)` plus workload jitter.

pub mod graph;
pub mod queue;

pub use graph::{ServiceChain, ServiceNode};
pub use queue::{simulate_chain, ChainResult, QueueParams};
