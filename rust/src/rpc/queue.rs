//! FCFS queueing simulation over a service chain: Poisson arrivals, one
//! server per node, lognormal-ish service jitter. Exact recursive form for
//! tandem FCFS queues: `depart[i] = max(arrive[i], depart[i-1]) + service`.

use super::graph::ServiceChain;
use crate::util::percentile::Digest;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct QueueParams {
    /// Offered load as a fraction of the bottleneck rate (0, 1).
    pub utilization: f64,
    /// Requests to simulate.
    pub requests: usize,
    pub seed: u64,
}

impl Default for QueueParams {
    fn default() -> Self {
        QueueParams {
            utilization: 0.6,
            requests: 20_000,
            seed: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ChainResult {
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub stddev_us: f64,
    /// Zero-load latency of the chain (sum of mean service times, µs) —
    /// the floor the queueing tail is measured against. The SLO
    /// compliance fraction is *not* stored here; it is the second tuple
    /// element returned by [`simulate_chain_with_slo`].
    pub base_latency_us: f64,
    pub arrival_rate_per_us: f64,
}

/// Simulate `params.requests` requests through the chain. Returns the
/// latency distribution summary; `slo_us` (if finite) also yields the
/// compliance fraction as the second tuple element.
pub fn simulate_chain_with_slo(
    chain: &ServiceChain,
    params: &QueueParams,
    slo_us: f64,
) -> (ChainResult, f64) {
    let mut rng = Rng::new(params.seed);
    let lambda = chain.bottleneck_rate() * params.utilization;
    let mean_iat = 1.0 / lambda;
    let n = params.requests;

    // Per-node service-time generators (mean × jitter with the node's CV).
    let means: Vec<f64> = chain
        .nodes
        .iter()
        .map(|nd| nd.mean_service_us(chain.freq_ghz))
        .collect();

    let mut arrive = 0.0f64;
    let mut last_depart = vec![0.0f64; chain.nodes.len()];
    let mut digest = Digest::new();
    let mut met = 0usize;
    for _ in 0..n {
        arrive += rng.exp(mean_iat);
        let mut t = arrive;
        for (i, nd) in chain.nodes.iter().enumerate() {
            // Lognormal-flavored jitter: exp(cv * normal) normalized to
            // mean 1 (second-order), clamped for stability.
            let jitter = (nd.cv * rng.normal() - 0.5 * nd.cv * nd.cv).exp();
            let service = means[i] * jitter.clamp(0.05, 8.0);
            let start = t.max(last_depart[i]);
            let depart = start + service;
            last_depart[i] = depart;
            t = depart;
        }
        let latency = t - arrive;
        digest.add(latency);
        if latency <= slo_us {
            met += 1;
        }
    }
    (
        ChainResult {
            p50_us: digest.percentile(50.0),
            p95_us: digest.percentile(95.0),
            p99_us: digest.percentile(99.0),
            mean_us: digest.mean(),
            stddev_us: digest.stddev(),
            base_latency_us: chain.base_latency_us(),
            arrival_rate_per_us: lambda,
        },
        met as f64 / n as f64,
    )
}

/// Simulate without an SLO bound.
pub fn simulate_chain(chain: &ServiceChain, params: &QueueParams) -> ChainResult {
    simulate_chain_with_slo(chain, params, f64::INFINITY).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::graph::ServiceChain;

    fn chain(ipc: f64) -> ServiceChain {
        ServiceChain::control_plane(
            &[
                ("admission".into(), ipc),
                ("featurestore".into(), ipc * 0.9),
                ("mlserve".into(), ipc * 1.1),
            ],
            25_000.0,
            2.5,
        )
    }

    #[test]
    fn percentiles_are_ordered_and_above_base() {
        let r = simulate_chain(&chain(2.0), &QueueParams::default());
        assert!(r.p50_us <= r.p95_us && r.p95_us <= r.p99_us);
        assert!(r.p50_us >= r.base_latency_us * 0.5, "p50 below base/2?");
        assert!(r.p99_us > r.base_latency_us, "no queueing tail at 60% load?");
    }

    #[test]
    fn higher_ipc_tightens_tail() {
        // The paper's core operational claim (§XI): faster frontends (higher
        // IPC) narrow P95/P99 at fixed arrival rate.
        let p = QueueParams {
            utilization: 0.7,
            requests: 30_000,
            seed: 3,
        };
        let slow = simulate_chain(&chain(1.8), &p);
        // Same *absolute* arrival rate for the fast system: utilization
        // scales down with the speedup, so reuse utilization adjusted.
        let fast_chain = chain(1.8 * 1.05); // 5% speedup
        let fast_util = 0.7 / 1.05;
        let fast = simulate_chain(
            &fast_chain,
            &QueueParams {
                utilization: fast_util,
                ..p
            },
        );
        assert!(fast.p95_us < slow.p95_us);
        assert!(fast.p99_us < slow.p99_us);
        // Single-digit speedup compounds into a larger tail reduction.
        let p99_gain = slow.p99_us / fast.p99_us;
        assert!(p99_gain > 1.05, "p99 gain {p99_gain}");
    }

    #[test]
    fn utilization_increases_tails() {
        let lo = simulate_chain(
            &chain(2.0),
            &QueueParams {
                utilization: 0.3,
                ..Default::default()
            },
        );
        let hi = simulate_chain(
            &chain(2.0),
            &QueueParams {
                utilization: 0.85,
                ..Default::default()
            },
        );
        assert!(hi.p99_us > lo.p99_us * 1.3);
    }

    #[test]
    fn slo_compliance_counts() {
        let (r, frac) = simulate_chain_with_slo(
            &chain(2.0),
            &QueueParams::default(),
            1e9, // everything meets an absurd SLO
        );
        assert_eq!(frac, 1.0);
        let (_, tight) = simulate_chain_with_slo(&chain(2.0), &QueueParams::default(), r.p50_us);
        assert!((tight - 0.5).abs() < 0.05, "P50 SLO ≈ 50% compliance, got {tight}");
    }

    #[test]
    fn deterministic_for_seed() {
        let a = simulate_chain(&chain(2.0), &QueueParams::default());
        let b = simulate_chain(&chain(2.0), &QueueParams::default());
        assert_eq!(a.p99_us, b.p99_us);
    }
}
