//! Service-chain topology: a request traverses admission → feature lookup
//! → model dispatch → logging (the paper's §X-A service mix).

/// One microservice node.
#[derive(Clone, Debug)]
pub struct ServiceNode {
    pub name: String,
    /// Mean instructions executed per request at this node.
    pub instrs_per_req: f64,
    /// Measured IPC of this node's binary under the evaluated prefetcher
    /// (from `sim::engine`).
    pub ipc: f64,
    /// Coefficient of variation of per-request work (the trace generator's
    /// request-size dispersion).
    pub cv: f64,
}

impl ServiceNode {
    /// Mean service time in microseconds at `freq_ghz`.
    pub fn mean_service_us(&self, freq_ghz: f64) -> f64 {
        let cycles = self.instrs_per_req / self.ipc;
        cycles / (freq_ghz * 1000.0)
    }
}

/// A linear chain of services (control-plane RPC path).
#[derive(Clone, Debug)]
pub struct ServiceChain {
    pub nodes: Vec<ServiceNode>,
    pub freq_ghz: f64,
}

impl ServiceChain {
    /// The paper's canonical control-plane path, parameterized by per-node
    /// IPC measurements.
    pub fn control_plane(ipcs: &[(String, f64)], instrs_per_req: f64, freq_ghz: f64) -> Self {
        ServiceChain {
            nodes: ipcs
                .iter()
                .map(|(name, ipc)| ServiceNode {
                    name: name.clone(),
                    instrs_per_req,
                    ipc: *ipc,
                    cv: 0.35,
                })
                .collect(),
            freq_ghz,
        }
    }

    /// Sum of mean service times (zero-load latency), µs.
    pub fn base_latency_us(&self) -> f64 {
        self.nodes.iter().map(|n| n.mean_service_us(self.freq_ghz)).sum()
    }

    /// Max utilization-normalizing arrival rate: the bottleneck node's
    /// service rate (req/µs).
    pub fn bottleneck_rate(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| 1.0 / n.mean_service_us(self.freq_ghz))
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> ServiceChain {
        ServiceChain::control_plane(
            &[
                ("admission".into(), 2.0),
                ("featurestore".into(), 1.5),
                ("mlserve".into(), 2.5),
            ],
            25_000.0,
            2.5,
        )
    }

    #[test]
    fn service_time_math() {
        let n = ServiceNode {
            name: "x".into(),
            instrs_per_req: 25_000.0,
            ipc: 2.0,
            cv: 0.3,
        };
        // 12.5k cycles at 2.5 GHz = 5 µs.
        assert!((n.mean_service_us(2.5) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn base_latency_sums_nodes() {
        let c = chain();
        let expect = 25_000.0 / 2.0 / 2500.0 + 25_000.0 / 1.5 / 2500.0 + 25_000.0 / 2.5 / 2500.0;
        assert!((c.base_latency_us() - expect).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_is_slowest_node() {
        let c = chain();
        // Slowest node: ipc 1.5 → service 6.67 µs → rate 0.15 req/µs.
        assert!((c.bottleneck_rate() - 1.0 / (25_000.0 / 1.5 / 2500.0)).abs() < 1e-9);
    }

    #[test]
    fn higher_ipc_lowers_latency() {
        let slow = ServiceChain::control_plane(&[("a".into(), 1.0)], 10_000.0, 2.5);
        let fast = ServiceChain::control_plane(&[("a".into(), 1.2)], 10_000.0, 2.5);
        assert!(fast.base_latency_us() < slow.base_latency_us());
    }
}
